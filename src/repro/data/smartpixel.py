"""Simulated "smart pixel" dataset (paper §5, ref [24]).

The real dataset (Zenodo 10783560) is 500k fitted CMS pion tracks propagated
through a futuristic pixel sensor: a 21x13 pixel array with 50 x 12.5 um
pitch at r = 30 mm inside B = 3.8 T, each track recorded as eight deposited
charge arrays at 200 ps intervals. The classification target is whether the
track has p_T < 2 GeV (pileup -> reject at source).

The dataset is external, so we implement the physics generator here:

  * p_T spectrum: mixture of a steeply falling "pileup" component and a
    harder "hard-scatter" component (both falling power laws / exponentials,
    as in minimum-bias + hard QCD spectra).
  * Track incidence: in the transverse plane a track of transverse momentum
    p_T in field B has curvature radius R = p_T / (0.3 B) [m, GeV, T]. At
    layer radius r the local crossing angle relative to the sensor normal is
    alpha with sin(alpha) = r / (2R) = 0.3 B r / (2 p_T) — low-p_T tracks
    cross at steeper angles and leave LONGER clusters along the local y
    (r-phi) direction. This is exactly the paper's discriminating feature:
    "High-momentum particles are less curved ... traversing fewer pixels".
  * Charge deposition: the track segment through the sensor bulk (thickness
    t) is sampled in depth; each depth slice deposits Landau-fluctuated
    charge at a y position following the crossing angle, smeared by
    diffusion; charge arrives over 8 time slices of 200 ps following a
    drift-time profile tied to depth.
  * The x profile (along the field) is momentum-blind by construction, as
    stated in the paper.

Features used by the paper's BDT: the 13-entry y-profile (charge summed over
x and time) plus y0, the distance of the cluster seed from the interaction
point — 14 inputs total.

Everything is numpy + a fixed PRNG; generation is chunked so the full frames
(n, 8, 13, 21) never need to be materialized for large n.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

# --- sensor geometry (paper values) -----------------------------------------
N_X = 21           # pixels along x (50 um pitch), parallel to B
N_Y = 13           # pixels along y (12.5 um pitch), r-phi direction
N_T = 8            # 200 ps time slices
PITCH_X_UM = 50.0
PITCH_Y_UM = 12.5
THICKNESS_UM = 100.0   # sensor bulk thickness (smart-pixel sensor design)
LAYER_RADIUS_M = 0.030  # 30 mm
B_FIELD_T = 3.8
PT_CUT_GEV = 2.0        # label: p_T < 2 GeV -> pileup (positive class = signal = high pT? see below)

# Label convention (paper): the model "outputs a probability that the track
# has p_T < 2 GeV, indicating it is likely to be pileup". So the positive
# class (y=1) is PILEUP. "Signal efficiency" in Table 1 = efficiency for
# *retaining* high-p_T tracks; we keep both notions explicit in metrics.py.

N_FEATURES = 14  # 13 y-profile sums + y0


@dataclasses.dataclass(frozen=True)
class SmartPixelConfig:
    n_events: int = 500_000
    seed: int = 2024
    pileup_fraction: float = 0.85     # most tracks are soft pileup
    pileup_pt_scale: float = 0.55     # GeV, exponential-ish falling scale
    hard_pt_min: float = 0.5
    hard_pt_power: float = 2.6        # falling power law for the hard component
    pt_min: float = 0.1
    pt_max: float = 50.0
    charge_mpv: float = 22_000.0      # electrons, MPV of Landau per 100um Si
    charge_width: float = 3_500.0
    noise_electrons: float = 800.0    # per-pixel gaussian noise
    threshold_electrons: float = 800.0  # per-pixel zero suppression
    diffusion_um: float = 10.0
    lorentz_tan: float = 0.08         # small Lorentz drift along y
    depth_samples: int = 32
    # Effective geometric lever arm: the real smart-pixel sensor design
    # (tilted modules + large Lorentz angle + charge drift in 3.8 T) spreads
    # low-p_T clusters over SEVERAL 12.5 um pixels (paper Fig. 11), while
    # the bare thin-planar crossing angle alone is sub-pixel. This factor
    # scales tan(alpha) so the simulated y-profiles match that observable
    # regime (calibrated so a depth-5 tree lands in the paper's Table-1
    # operating band). Documented in DESIGN.md §8.
    geometry_gain: float = 4.0


def _sample_pt(rng: np.random.Generator, cfg: SmartPixelConfig, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Return (pt, is_pileup_component)."""
    is_pu = rng.random(n) < cfg.pileup_fraction
    # Pileup: exponential falling from pt_min.
    pt_pu = cfg.pt_min + rng.exponential(cfg.pileup_pt_scale, n)
    # Hard scatter: power-law tail pt ~ (x)^(-power) above hard_pt_min.
    u = rng.random(n)
    alpha = cfg.hard_pt_power - 1.0
    pt_hs = cfg.hard_pt_min * (1.0 - u) ** (-1.0 / alpha)
    pt = np.where(is_pu, pt_pu, pt_hs)
    return np.clip(pt, cfg.pt_min, cfg.pt_max), is_pu


def _crossing_angle(pt: np.ndarray, charge_sign: np.ndarray) -> np.ndarray:
    """Local crossing angle alpha in the transverse plane (radians).

    sin(alpha) = 0.3 * B * r / (2 * pt); sign from particle charge.
    """
    s = 0.3 * B_FIELD_T * LAYER_RADIUS_M / (2.0 * np.maximum(pt, 1e-3))
    s = np.clip(s, -0.999, 0.999)
    return charge_sign * np.arcsin(s)


def generate_batch(
    rng: np.random.Generator,
    cfg: SmartPixelConfig,
    n: int,
    return_frames: bool = False,
):
    """Generate one batch.

    Returns dict with:
      features : (n, 14) float32 — 13 y-profile charge sums (ke-) + y0 (um)
      label    : (n,) int8       — 1 if p_T < 2 GeV (pileup), else 0
      pt       : (n,) float32
      frames   : (n, 8, 13, 21) float32, only if return_frames
    """
    pt, _ = _sample_pt(rng, cfg, n)
    q_sign = np.where(rng.random(n) < 0.5, -1.0, 1.0)
    alpha = _crossing_angle(pt, q_sign)

    # Cluster seed position: impact point within the central pixels, plus the
    # "distance from interaction point" y0 feature (local offset of the
    # cluster within the module, correlated with track origin).
    y_impact_um = (rng.random(n) - 0.5) * 2.0 * PITCH_Y_UM  # within +-1 pixel of center
    y0_um = y_impact_um + rng.normal(0.0, 2.0, n)           # measured with small error

    x_impact_um = (rng.random(n) - 0.5) * 2.0 * PITCH_X_UM
    # Polar angle spread: gives x-direction cluster length, *independent* of pt.
    tan_theta_x = rng.normal(0.0, 0.35, n)

    depth = (np.arange(cfg.depth_samples) + 0.5) / cfg.depth_samples  # (d,)
    # y position of each depth sample relative to impact (track slope + Lorentz).
    tan_a = cfg.geometry_gain * np.tan(alpha)[:, None]  # (n, 1)
    y_um = (
        y_impact_um[:, None]
        + (depth[None, :] - 0.5) * THICKNESS_UM * (tan_a + cfg.lorentz_tan)
        + rng.normal(0.0, cfg.diffusion_um, (n, cfg.depth_samples))
    )  # (n, d)
    x_um = (
        x_impact_um[:, None]
        + (depth[None, :] - 0.5) * THICKNESS_UM * tan_theta_x[:, None]
        + rng.normal(0.0, cfg.diffusion_um, (n, cfg.depth_samples))
    )

    # Landau-ish charge per depth sample: moyal-distributed via inverse method
    # approximation (exponential of gaussian gives a heavy right tail).
    q_total = cfg.charge_mpv + cfg.charge_width * (
        rng.standard_normal(n) + 0.6 * rng.exponential(1.0, n)
    )
    q_total = np.maximum(q_total, 2_000.0)
    q_frac = rng.dirichlet(np.full(cfg.depth_samples, 3.0), size=n)
    q = q_total[:, None] * q_frac  # (n, d) electrons

    # Pixel indices (center the array).
    iy = np.floor(y_um / PITCH_Y_UM + N_Y / 2.0).astype(np.int64)
    ix = np.floor(x_um / PITCH_X_UM + N_X / 2.0).astype(np.int64)
    # Drift time -> time slice: charge from depth z arrives ~ linearly in z
    # with spread; slice of 200 ps, full drift ~ 1 ns across the bulk.
    t_ns = depth[None, :] * 1.0 + rng.normal(0.0, 0.12, (n, cfg.depth_samples))
    it = np.clip(np.floor(t_ns / 0.2).astype(np.int64), 0, N_T - 1)

    inside = (iy >= 0) & (iy < N_Y) & (ix >= 0) & (ix < N_X)
    q = np.where(inside, q, 0.0)
    iy_c = np.clip(iy, 0, N_Y - 1)
    ix_c = np.clip(ix, 0, N_X - 1)

    # Accumulate y-profile (sum over x and t): scatter-add per event.
    yprof = np.zeros((n, N_Y), dtype=np.float64)
    rows = np.repeat(np.arange(n), cfg.depth_samples)
    np.add.at(yprof, (rows, iy_c.ravel()), q.ravel())

    # Per-pixel noise on the profile (13 pixels x 21 columns x 8 slices of
    # noise fold into the sum; equivalent gaussian on the profile):
    yprof += rng.normal(0.0, cfg.noise_electrons * np.sqrt(N_X), (n, N_Y))
    yprof = np.maximum(yprof, 0.0)
    # Zero suppression at profile level (mirrors per-pixel threshold).
    yprof = np.where(yprof > cfg.threshold_electrons, yprof, 0.0)

    features = np.concatenate(
        [yprof / 1000.0, y0_um[:, None]], axis=1  # charge in ke-, y0 in um
    ).astype(np.float32)
    label = (pt < PT_CUT_GEV).astype(np.int8)

    out = {
        "features": features,
        "label": label,
        "pt": pt.astype(np.float32),
    }
    if return_frames:
        frames = np.zeros((n, N_T, N_Y, N_X), dtype=np.float32)
        flat = (
            rows * (N_T * N_Y * N_X)
            + it.ravel() * (N_Y * N_X)
            + iy_c.ravel() * N_X
            + ix_c.ravel()
        )
        np.add.at(frames.reshape(-1), flat, q.ravel().astype(np.float32))
        frames += rng.normal(0.0, cfg.noise_electrons, frames.shape).astype(np.float32)
        out["frames"] = frames
    return out


_BLOCK = 1_000  # PRNG consumption granularity: every block b is a pure
# function of (seed, b), so bulk generation and any streaming batch size
# produce identical events (and any host can regenerate any block).


def _block(cfg: SmartPixelConfig, b: int, n: int, return_frames: bool):
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, b]))
    return generate_batch(rng, cfg, n, return_frames=return_frames)


def generate(cfg: SmartPixelConfig = SmartPixelConfig(), return_frames: bool = False):
    """Generate the full dataset (block-deterministic)."""
    chunks = []
    done = 0
    b = 0
    while done < cfg.n_events:
        n = min(cfg.n_events - done, _BLOCK)
        chunks.append(_block(cfg, b, n, return_frames))
        done += n
        b += 1
    return {k: np.concatenate([c[k] for c in chunks]) for k in chunks[0]}


def iter_batches(
    cfg: SmartPixelConfig, batch_size: int, return_frames: bool = False
) -> Iterator[dict]:
    """Streaming interface (the 'PGPv4 data plane' analogue); any batch_size
    yields the same event stream as generate()."""
    buf: dict = {}
    done = 0
    b = 0
    pending: list = []
    n_pend = 0
    while done < cfg.n_events:
        while n_pend < batch_size and b * _BLOCK < cfg.n_events:
            n = min(cfg.n_events - b * _BLOCK, _BLOCK)
            pending.append(_block(cfg, b, n, return_frames))
            n_pend += n
            b += 1
        merged = {k: np.concatenate([c[k] for c in pending]) for k in pending[0]}
        take = min(batch_size, cfg.n_events - done)
        yield {k: v[:take] for k, v in merged.items()}
        pending = [{k: v[take:] for k, v in merged.items()}]
        n_pend -= take
        done += take


def train_test_split(data: dict, test_fraction: float = 0.3, seed: int = 7):
    n = len(data["label"])
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_test = int(n * test_fraction)
    test_idx, train_idx = perm[:n_test], perm[n_test:]
    tr = {k: v[train_idx] for k, v in data.items()}
    te = {k: v[test_idx] for k, v in data.items()}
    return tr, te
