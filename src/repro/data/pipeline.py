"""Deterministic, shard-recomputable data pipelines (LM tokens + frames).

Fault-tolerance property (DESIGN.md §5): every (step, shard) batch is a pure
function of (seed, step, shard_index) — no pipeline state to checkpoint, any
host can recompute any other host's shard after a failure, and elastic
rescaling (changing n_shards) is just re-indexing. This is the data-side
half of the straggler/failover story; the checkpoint side is
train/checkpoint.py.

Two synthetic corpora for the LM side:
  * "markov": a fixed random Markov chain over the vocab (low-entropy,
    learnable — examples/train_lm.py shows the loss dropping well below
    log V);
  * "uniform": i.i.d. tokens (for shape/throughput tests).

``FrameStream`` is the readout-side twin: RAW smart-pixel charge frames
per sensor — what the fused on-device frontend ingests (the server's
``submit_frames``), replacing the old host-featurized feature stream.
``batch_at(step, sensor)`` has the same (seed, step, shard)-pure contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np

from repro.data.smartpixel import SmartPixelConfig, generate_batch


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    kind: str = "markov"       # markov | uniform
    branching: int = 4         # out-degree of the markov chain


def _chain(vocab: int, branching: int, seed: int) -> np.ndarray:
    """Fixed successor table: (vocab, branching) int32."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, (vocab, branching), dtype=np.int32)


class TokenPipeline:
    def __init__(self, cfg: DataConfig, n_shards: int = 1, shard: int = 0):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.n_shards = n_shards
        self.shard = shard
        self._succ = (
            _chain(cfg.vocab, cfg.branching, cfg.seed) if cfg.kind == "markov" else None
        )

    def batch_at(self, step: int, shard: int | None = None) -> Dict[str, np.ndarray]:
        """The batch for (step, shard) — pure function, recomputable anywhere."""
        cfg = self.cfg
        shard = self.shard if shard is None else shard
        b_local = cfg.global_batch // self.n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard])
        )
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab, (b_local, cfg.seq_len + 1), dtype=np.int32)
        else:
            toks = np.empty((b_local, cfg.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, cfg.vocab, b_local)
            choices = rng.integers(0, cfg.branching, (b_local, cfg.seq_len))
            for t in range(cfg.seq_len):
                toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def entropy_bound_nats(self) -> float:
        """Lower bound on achievable loss (log branching for markov)."""
        if self.cfg.kind == "uniform":
            return float(np.log(self.cfg.vocab))
        return float(np.log(self.cfg.branching))


# --------------------------------------------------------------------------
# Raw-frame stream (the PGPv4 data-plane analogue, frames-first)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrameStreamConfig:
    n_sensors: int = 4
    batch: int = 256            # events per (step, sensor) block
    seed: int = 700
    sensor: SmartPixelConfig = SmartPixelConfig()  # physics knobs only


class FrameStream:
    """Deterministic raw-frame stream for N sensors.

    The readout server ingests RAW frames (B, T, Y, X) + y0 — the fused
    frontend featurizes on device — so the stream carries frames, not
    host-computed features. ``batch_at(step, sensor)`` is a pure function
    of (seed, step, sensor): any host can regenerate any sensor's block,
    the recompute-anywhere contract TokenPipeline makes for tokens.
    (``features``/``label``/``pt`` ride along for calibration and trigger
    -efficiency accounting; the server never sees them.)
    """

    def __init__(self, cfg: FrameStreamConfig = FrameStreamConfig()):
        self.cfg = cfg

    def batch_at(self, step: int, sensor: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert 0 <= sensor < cfg.n_sensors, sensor
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, sensor])
        )
        out = generate_batch(rng, cfg.sensor, cfg.batch, return_frames=True)
        out["y0"] = out["features"][:, -1]
        return out

    def __iter__(self) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
        """Round-robin over sensors: yields (sensor, block) forever."""
        step = 0
        while True:
            for s in range(self.cfg.n_sensors):
                yield s, self.batch_at(step, s)
            step += 1
