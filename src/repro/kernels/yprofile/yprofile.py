"""Pallas TPU kernel: smart-pixel frame -> feature reduction (front end).

Completes the on-device readout path: raw charge frames stream in over the
data plane, this kernel folds (T, Y, X) -> the 13-bin y-profile + y0, and
the result feeds bdt_infer / lut_eval without a host round-trip.

Shape strategy: the physical frame is tiny (8x13x21 = 2184 floats), far
below lane granularity — so the kernel works on the FLATTENED event layout
(B_TILE, T*Y*X padded to a 128 multiple) and reduces with a precomputed
one-hot fold matrix (T*Y*X_pad, Y_pad): charge cell (t, y, x) contributes
to profile bin y. The reduction is a single MXU matmul per tile — the same
"spatial structure -> dense contraction" adaptation as lut_eval
(DESIGN.md §3); zero suppression and the ke- scaling run on the VPU.

VMEM per tile: frames 256 x 2304 x 4B = 2.3 MiB + fold 2304 x 128 x 4B
= 1.2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(frames_ref, fold_ref, y0_ref, out_ref, *, threshold: float):
    flat = frames_ref[...]                      # (B, TYX_pad)
    fold = fold_ref[...]                        # (TYX_pad, Y_pad)
    prof = jax.lax.dot(flat, fold, preferred_element_type=jnp.float32)
    prof = jnp.maximum(prof, 0.0)
    prof = jnp.where(prof > threshold, prof, 0.0) / 1000.0
    # slot y0 (um) into the first padding column after the Y bins
    y0col = y0_ref[...]                         # (B, 128) with y0 in col 0
    out_ref[...] = prof + y0col


def yprofile_pallas(
    frames_flat: jnp.ndarray,   # (B, TYX_pad) f32
    fold: jnp.ndarray,          # (TYX_pad, Y_pad=128) f32 one-hot
    y0_cols: jnp.ndarray,       # (B, 128) f32 — y0 value in column n_y
    *,
    threshold: float,
    batch_tile: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, TYX = frames_flat.shape
    assert B % batch_tile == 0 and TYX % 128 == 0
    kernel = functools.partial(_kernel, threshold=threshold)
    return pl.pallas_call(
        kernel,
        grid=(B // batch_tile,),
        in_specs=[
            pl.BlockSpec((batch_tile, TYX), lambda b: (b, 0)),
            pl.BlockSpec((TYX, 128), lambda b: (0, 0)),
            pl.BlockSpec((batch_tile, 128), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile, 128), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 128), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
    )(frames_flat, fold, y0_cols)


def _kernel_stacked(frames_ref, fold_ref, y0_ref, out_ref, *, threshold: float):
    flat = frames_ref[0]                        # (B, TYX_pad)
    fold = fold_ref[...]                        # (TYX_pad, Y_pad)
    prof = jax.lax.dot(flat, fold, preferred_element_type=jnp.float32)
    prof = jnp.maximum(prof, 0.0)
    prof = jnp.where(prof > threshold, prof, 0.0) / 1000.0
    out_ref[0] = prof + y0_ref[0]


def yprofile_pallas_stacked(
    frames_flat: jnp.ndarray,   # (C, B, TYX_pad) f32 — chip-batched frames
    fold: jnp.ndarray,          # (TYX_pad, Y_pad=128) f32 one-hot, shared
    y0_cols: jnp.ndarray,       # (C, B, 128) f32 — y0 value in column n_y
    *,
    threshold: float,
    batch_tile: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chip-batched featurization: C sensors' frame streams reduced in ONE
    dispatch, the front half of the fused readout frontend
    (kernels/frontend.py). Grid (C, B//tile) with both axes parallel —
    same shape strategy as the chip axis of lut_eval_pallas_stacked, and
    the per-tile dot is identical to the single-chip kernel's, so the
    stacked path is bit-identical to C separate yprofile_pallas calls.
    """
    C, B, TYX = frames_flat.shape
    assert B % batch_tile == 0 and TYX % 128 == 0
    kernel = functools.partial(_kernel_stacked, threshold=threshold)
    return pl.pallas_call(
        kernel,
        grid=(C, B // batch_tile),
        in_specs=[
            pl.BlockSpec((1, batch_tile, TYX), lambda c, b: (c, b, 0)),
            pl.BlockSpec((TYX, 128), lambda c, b: (0, 0)),
            pl.BlockSpec((1, batch_tile, 128), lambda c, b: (c, b, 0)),
        ],
        out_specs=pl.BlockSpec((1, batch_tile, 128), lambda c, b: (c, b, 0)),
        out_shape=jax.ShapeDtypeStruct((C, B, 128), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(frames_flat, fold, y0_cols)
