"""Pure-jnp oracle for the yprofile kernel.

The smart-pixel front end reduces each event's raw charge frames
(N_T=8 time slices x N_Y=13 rows x N_X=21 columns) to the BDT's feature
vector: the 13-entry y-profile (charge summed over time and x, in ke-,
with per-pixel zero suppression applied at the profile level) plus y0.
"""
from __future__ import annotations

import jax.numpy as jnp


def yprofile_ref(frames: jnp.ndarray, y0: jnp.ndarray,
                 threshold_electrons: float = 800.0) -> jnp.ndarray:
    """frames: (B, T, Y, X) f32 electrons; y0: (B,) um -> (B, Y+1) f32."""
    prof = jnp.sum(frames, axis=(1, 3))                     # (B, Y)
    prof = jnp.maximum(prof, 0.0)
    prof = jnp.where(prof > threshold_electrons, prof, 0.0)
    return jnp.concatenate([prof / 1000.0, y0[:, None]], axis=1)
