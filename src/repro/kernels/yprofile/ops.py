"""jit'd wrapper + packing for the yprofile kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.smartpixel import N_T, N_X, N_Y
from repro.kernels.compat import default_interpret as _default_interpret
from repro.kernels.yprofile.yprofile import (
    yprofile_pallas,
    yprofile_pallas_stacked,
)

TYX = N_T * N_Y * N_X
TYX_PAD = (TYX + 127) // 128 * 128
N_FEATURES = N_Y + 1


@functools.lru_cache(maxsize=None)
def _fold_matrix() -> np.ndarray:
    """(TYX_pad, 128) one-hot: cell (t, y, x) -> profile bin y."""
    fold = np.zeros((TYX_PAD, 128), np.float32)
    idx = np.arange(TYX)
    fold[idx, (idx // N_X) % N_Y] = 1.0
    return fold


def fold_device() -> jnp.ndarray:
    """The fold matrix for the current trace/device, built lazily.

    Deliberately NOT a module-level jnp.asarray: importing this module
    must not allocate on a device before the caller has picked a backend
    (JAX_PLATFORMS, test conftest, dryrun flags all run at import time).
    Only the numpy matrix is cached — the jnp conversion happens per call
    because the first call typically runs inside a jit trace, where the
    result is a trace-local constant that must not leak across traces.
    """
    return jnp.asarray(_fold_matrix())


def yprofile_traced(frames, y0, *, threshold: float, batch_tile: int,
                    interpret: bool) -> jnp.ndarray:
    """Traceable chip-batched featurization: (C, B, T, Y, X) + (C, B) ->
    (C, B, 128) with the Y profile in columns [0, N_Y) and y0 in column
    N_Y. Safe to call inside an enclosing jit/shard_map — the back half of
    the fused frontend (kernels/frontend.py) chains it straight into the
    quantize + lut_eval stages with no host materialization. Requires
    B % batch_tile == 0 (the fused dispatch pads once for all stages).
    """
    C, B = frames.shape[0], frames.shape[1]
    flat = frames.reshape(C, B, TYX).astype(jnp.float32)
    flat = jnp.pad(flat, ((0, 0), (0, 0), (0, TYX_PAD - TYX)))
    y0_cols = jnp.zeros((C, B, 128), jnp.float32).at[:, :, N_Y].set(
        y0.astype(jnp.float32))
    return yprofile_pallas_stacked(
        flat, fold_device(), y0_cols, threshold=threshold,
        batch_tile=batch_tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("threshold", "batch_tile", "interpret"))
def _run(frames, y0, *, threshold, batch_tile, interpret):
    B = frames.shape[0]
    flat = frames.reshape(B, TYX).astype(jnp.float32)
    flat = jnp.pad(flat, ((0, 0), (0, TYX_PAD - TYX)))
    y0_cols = jnp.zeros((B, 128), jnp.float32).at[:, N_Y].set(
        y0.astype(jnp.float32))
    out = yprofile_pallas(flat, fold_device(), y0_cols, threshold=threshold,
                          batch_tile=batch_tile, interpret=interpret)
    return out[:, :N_FEATURES]


def yprofile(frames, y0, threshold_electrons: float = 800.0,
             batch_tile: int = 256, interpret: bool | None = None):
    """frames (B, 8, 13, 21) electrons + y0 (B,) um -> features (B, 14)."""
    if interpret is None:
        interpret = _default_interpret()
    frames = jnp.asarray(frames)
    y0 = jnp.asarray(y0)
    B = frames.shape[0]
    Bp = (max(B, 1) + batch_tile - 1) // batch_tile * batch_tile
    if Bp != B:
        frames = jnp.pad(frames, ((0, Bp - B), (0, 0), (0, 0), (0, 0)))
        y0 = jnp.pad(y0, ((0, Bp - B),))
    out = _run(frames, y0, threshold=float(threshold_electrons),
               batch_tile=batch_tile, interpret=interpret)
    return out[:B]
