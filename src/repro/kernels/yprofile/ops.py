"""jit'd wrapper + packing for the yprofile kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.smartpixel import N_T, N_X, N_Y
from repro.kernels.yprofile.yprofile import yprofile_pallas

TYX = N_T * N_Y * N_X
TYX_PAD = (TYX + 127) // 128 * 128
N_FEATURES = N_Y + 1


def _fold_matrix() -> np.ndarray:
    """(TYX_pad, 128) one-hot: cell (t, y, x) -> profile bin y."""
    fold = np.zeros((TYX_PAD, 128), np.float32)
    idx = 0
    for t in range(N_T):
        for y in range(N_Y):
            for x in range(N_X):
                fold[idx, y] = 1.0
                idx += 1
    return fold


_FOLD = jnp.asarray(_fold_matrix())


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("threshold", "batch_tile", "interpret"))
def _run(frames, y0, *, threshold, batch_tile, interpret):
    B = frames.shape[0]
    flat = frames.reshape(B, TYX).astype(jnp.float32)
    flat = jnp.pad(flat, ((0, 0), (0, TYX_PAD - TYX)))
    y0_cols = jnp.zeros((B, 128), jnp.float32).at[:, N_Y].set(
        y0.astype(jnp.float32))
    out = yprofile_pallas(flat, _FOLD, y0_cols, threshold=threshold,
                          batch_tile=batch_tile, interpret=interpret)
    return out[:, :N_FEATURES]


def yprofile(frames, y0, threshold_electrons: float = 800.0,
             batch_tile: int = 256, interpret: bool | None = None):
    """frames (B, 8, 13, 21) electrons + y0 (B,) um -> features (B, 14)."""
    if interpret is None:
        interpret = _default_interpret()
    frames = jnp.asarray(frames)
    y0 = jnp.asarray(y0)
    B = frames.shape[0]
    Bp = (max(B, 1) + batch_tile - 1) // batch_tile * batch_tile
    if Bp != B:
        frames = jnp.pad(frames, ((0, Bp - B), (0, 0), (0, 0), (0, 0)))
        y0 = jnp.pad(y0, ((0, Bp - B),))
    out = _run(frames, y0, threshold=float(threshold_electrons),
               batch_tile=batch_tile, interpret=interpret)
    return out[:B]
