"""jit'd wrappers + packing for the lut_eval kernel (single and multi-chip).

``pack_fabric`` turns a decoded bitstream (core.fabric.FabricConfig) into
the dense, 128-aligned arrays the kernel consumes; ``fabric_eval`` runs a
batch of events through one configured fabric. ``pack_fabrics`` stacks N
decoded bitstreams into ONE chip-batched structure sharing a padded
geometry, and ``fabric_eval_multi`` evaluates (chips, events) in a single
kernel dispatch — the device half of launch/readout_server.py.

Reconfiguring a fabric = repacking arrays; the compiled kernel is reused
across bitstreams with the same padded geometry (the paper's
reconfigurability property, DESIGN.md §3). For a stack this extends
per-slot: ``PackedFabricStack.swap_chip`` replaces one chip's arrays in
place, no recompile, as long as the new config fits the stack's envelope.

Routing is packed *banded* whenever it is cheaper: level l's selection
rows cover only [input segment | window of the K preceding levels], K the
config's fan-in reach (core.netlist.fanin_reach), cutting per-level matmul
cost from (in_seg + L*m_pad)*4M to (in_seg + K*m_pad)*4M. The dense layout
is the automatic fallback when K >= L (the window would span every level).
The band is part of the stack envelope: hot-swaps must fit it, which
StackGeometry.admits enforces via its fanin_reach budget. The band is a
*reach envelope*, not a kernel structure — the bit-sliced layout accepts
it too (its index gathers need no routing window, so the budget is pure
admission control, validated at pack and swap time).

Redundancy: ``pack_fabrics(..., redundancy="tmr")`` packs THREE
independently-encoded replicas of every chip (core.tmr.replicate_config —
distinct placements, so one configuration-memory address maps to
different logical LUTs per replica) as contiguous chip slots
``slot*3 .. slot*3+2``. All replica slots evaluate in the same
chip-batched dispatch; ``fabric_eval_bits_voted`` reduces them with the
2-of-3 majority vote before the output gather reaches the caller, and
reports which replicas disagreed with the vote (the SEU health monitor).
``swap_chip`` re-encodes all three replicas (hot-swap stays a pure array
swap); ``swap_replica`` replaces ONE replica's arrays — the
fault-injection port used by the SEU campaign (tests/test_seu.py).

Scrubbing: ``PackedFabricStack.readback_chip/readback_replica`` read the
LIVE device-side truth-table arrays back to the host in the padded
scrub-loop layout (core.fabric.packed_table_image — the same function
that packs them, so readback-vs-golden is a structural identity). The
readout server's background scrub task CRC-verifies these images against
its golden store (core.bitstream.GoldenImageStore) and heals a corrupted
replica through ``swap_replica`` — closing the mask -> detect -> repair
loop that TMR voting alone leaves open.

``fabric_eval_multi_scored`` is the serving entry for pre-packed input
bits: one jit'd dispatch that evaluates (and votes) the stack, decodes
two's-complement scores on device and applies the integer trigger cut —
with the chip axis shard_map'd over the "chips" readout mesh, so the
features ingestion path scales with devices exactly like the fused
frames frontend (kernels/frontend.py).

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles to Mosaic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fabric import (
    FabricConfig,
    StackGeometry,
    check_stackable,
    packed_table_image,
    stack_event_bits as fabric_stack_event_bits,
)
from repro.core.tmr import N_REPLICAS, majority_vote, replicate_config
from repro.kernels.compat import default_interpret as _default_interpret
from repro.kernels.compat import shard_map_compat as _shard_map_compat
from repro.kernels.lut_eval import bitsliced as _bitsliced
from repro.parallel.compression import sparse_trigger_pack_words
from repro.kernels.lut_eval.lut_eval import (
    lut_eval_pallas,
    lut_eval_pallas_banded,
    lut_eval_pallas_banded_stacked,
    lut_eval_pallas_stacked,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedFabric:
    """Device-array form of a decoded bitstream (pytree).

    ``band_k`` < ``n_levels`` means the selection tensor is *banded*:
    ``sel`` has ``in_seg + band_k*m_pad`` rows per level (input segment +
    a window of band_k preceding levels) and ``win_base[l]`` holds the
    window's read offset into the full net buffer. ``band_k == n_levels``
    is the dense layout (sel rows == n_nets_pad, win_base all in_seg).

    ``layout="bitsliced"`` (pack_fabric) replaces the one-hot ``sel``
    tensor with the compact ``src`` gather indices and ``sel`` is None:
    evaluation goes through the bit-parallel word path (bitsliced.py)
    instead of the Pallas matmul kernel. ``tables`` keeps the identical
    scrub-loop image in every layout.
    """

    sel: jnp.ndarray          # (L, n_rows, 4*M) bf16 0/1 — None if bitsliced
    tables: jnp.ndarray       # (L, M, 16) f32
    level_base: jnp.ndarray   # (L,) int32
    output_nets: jnp.ndarray  # (n_outputs,) int32 (padded layout)
    win_base: jnp.ndarray     # (L,) int32 — banded window read offsets
    n_inputs: int = dataclasses.field(metadata=dict(static=True))
    n_nets_pad: int = dataclasses.field(metadata=dict(static=True))
    m_pad: int = dataclasses.field(metadata=dict(static=True))
    n_levels: int = dataclasses.field(metadata=dict(static=True))
    in_seg: int = dataclasses.field(metadata=dict(static=True))
    band_k: int = dataclasses.field(metadata=dict(static=True))
    src: jnp.ndarray = None   # (L, M, 4) int32 — bitsliced layout only

    @property
    def banded(self) -> bool:
        return self.band_k < self.n_levels

    @property
    def bitsliced(self) -> bool:
        return self.src is not None


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedFabricStack:
    """N decoded bitstreams stacked into one chip-batched pytree.

    All chips share the padded geometry (L, N, M, in_seg); narrower chips
    are zero-padded. ``output_nets`` is padded with net 0 (const0), so
    padded output lanes evaluate to 0 — matching MultiFabricSim's zero
    padding. Per-chip true widths live in the static tuples.

    ``n_replicas`` > 1 is the TMR layout: the leading array axis holds
    ``n_replicas`` independently-encoded replica slots per LOGICAL chip,
    grouped contiguously (slot ``c`` occupies rows ``c*R .. c*R+R-1``).
    The static width tuples stay per logical chip — replicas share their
    chip's IO widths by construction.
    """

    sel: jnp.ndarray          # (R*C, L, n_rows, 4*M) bf16 0/1 — None if bitsliced
    tables: jnp.ndarray       # (R*C, L, M, 16) f32
    level_base: jnp.ndarray   # (L,) int32 — shared
    output_nets: jnp.ndarray  # (R*C, n_outputs_max) int32 (padded layout)
    win_base: jnp.ndarray     # (L,) int32 — shared banded window offsets
    n_inputs: int = dataclasses.field(metadata=dict(static=True))       # max
    n_outputs: int = dataclasses.field(metadata=dict(static=True))      # max
    n_inputs_each: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    n_outputs_each: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    n_nets_pad: int = dataclasses.field(metadata=dict(static=True))
    m_pad: int = dataclasses.field(metadata=dict(static=True))
    n_levels: int = dataclasses.field(metadata=dict(static=True))
    in_seg: int = dataclasses.field(metadata=dict(static=True))
    band_k: int = dataclasses.field(metadata=dict(static=True))  # shared band
    n_replicas: int = dataclasses.field(default=1, metadata=dict(static=True))
    src: jnp.ndarray = None   # (R*C, L, M, 4) int32 — bitsliced layout only

    @property
    def n_chips(self) -> int:
        """LOGICAL chip count (replica slots are n_replicas * n_chips)."""
        return len(self.n_inputs_each)

    @property
    def banded(self) -> bool:
        return self.band_k < self.n_levels

    @property
    def bitsliced(self) -> bool:
        return self.src is not None

    @property
    def layout(self) -> str:
        """'bitsliced', 'banded' or 'dense' — how this stack evaluates."""
        if self.bitsliced:
            return "bitsliced"
        return "banded" if self.banded else "dense"

    @property
    def redundant(self) -> bool:
        return self.n_replicas > 1

    def _envelope(self) -> StackGeometry:
        return StackGeometry(
            n_levels=self.n_levels,
            max_level_size=self.m_pad,
            n_inputs=self.n_inputs,
            n_outputs=self.n_outputs,
            fanin_reach=self.band_k if self.banded else None,
        )

    def _check_admits(self, config: FabricConfig) -> None:
        geo = self._envelope()
        if config.n_ffs or not geo.admits(config):
            raise ValueError(
                f"config does not fit stack envelope {geo} "
                f"(levels={len(config.level_sizes)}, "
                f"widest={max(config.level_sizes, default=1)}, "
                f"inputs={config.n_inputs}, outputs={len(config.output_nets)},"
                f" ffs={config.n_ffs}, fanin_reach={config.fanin_reach()})"
            )

    def swap_chip(self, slot: int, config: FabricConfig) -> "PackedFabricStack":
        """Hot-swap one chip's bitstream: pure array swap, no recompile.

        The new config must fit the stack's padded envelope (StackGeometry
        admits it — including the fan-in-reach budget when the stack is
        banded); true per-chip widths update so callers decode the right
        output lanes. On a redundant stack all ``n_replicas`` replica
        slots are re-encoded (core.tmr.replicate_config), so the swapped
        chip keeps the full TMR protection.
        """
        self._check_admits(config)
        R = self.n_replicas
        pack_one = (
            self._pack_slot_bitsliced if self.bitsliced else self._pack_slot
        )
        packed = [
            pack_one(replicate_config(config, r) if R > 1 else config)
            for r in range(R)
        ]
        # all R replica rows are contiguous: stack host-side and update in
        # ONE functional write per array (a .at[].set copies the whole
        # stack, so per-replica writes would triple the swap latency)
        lo = slot * R
        arrays = dict(
            tables=self.tables.at[lo : lo + R].set(
                jnp.asarray(np.stack([p[1] for p in packed]), jnp.float32)),
            output_nets=self.output_nets.at[lo : lo + R].set(
                jnp.asarray(np.stack([p[2] for p in packed]), jnp.int32)),
        )
        if self.bitsliced:
            arrays["src"] = self.src.at[lo : lo + R].set(
                jnp.asarray(np.stack([p[0] for p in packed]), jnp.int32))
        else:
            arrays["sel"] = self.sel.at[lo : lo + R].set(
                jnp.asarray(np.stack([p[0] for p in packed]), jnp.bfloat16))
        each_in = list(self.n_inputs_each)
        each_out = list(self.n_outputs_each)
        each_in[slot] = config.n_inputs
        each_out[slot] = len(config.output_nets)
        return dataclasses.replace(
            self,
            n_inputs_each=tuple(each_in),
            n_outputs_each=tuple(each_out),
            **arrays,
        )

    def _pack_slot(self, config: FabricConfig):
        """(sel, tables, out_nets) host arrays for one replica slot."""
        return _pack_arrays(
            config, self.n_levels, self.m_pad, self.in_seg, self.n_outputs,
            band_k=self.band_k if self.banded else None,
        )

    def _pack_slot_bitsliced(self, config: FabricConfig):
        """(src, tables, out_nets) host arrays for one replica slot."""
        return _pack_arrays_bitsliced(
            config, self.n_levels, self.m_pad, self.in_seg, self.n_outputs,
            band_k=self.band_k if self.banded else None,
        )

    def swap_replica(
        self, slot: int, replica: int, config: FabricConfig
    ) -> "PackedFabricStack":
        """Replace ONE replica's arrays — the fault-injection port.

        The SEU campaign perturbs a single replica's decoded bitstream
        (core.tmr.inject_seu on its replica-encoded config) and swaps it
        in here; the other replicas and the per-chip widths are
        untouched, so the voted output should mask the fault. Still an
        array swap: no recompile. The config must keep the slot's IO
        widths — a replica cannot disagree with its siblings about the
        chip's interface.
        """
        R = self.n_replicas
        if not 0 <= replica < R:
            raise ValueError(f"replica must be in [0, {R}), got {replica!r}")
        self._check_admits(config)
        if (config.n_inputs != self.n_inputs_each[slot]
                or len(config.output_nets) != self.n_outputs_each[slot]):
            raise ValueError(
                f"replica IO widths ({config.n_inputs} in, "
                f"{len(config.output_nets)} out) must match slot {slot}'s "
                f"({self.n_inputs_each[slot]} in, "
                f"{self.n_outputs_each[slot]} out)"
            )
        row = slot * R + replica
        if self.bitsliced:
            s, t, o = self._pack_slot_bitsliced(config)
            routing = dict(src=self.src.at[row].set(jnp.asarray(s, jnp.int32)))
        else:
            s, t, o = self._pack_slot(config)
            routing = dict(sel=self.sel.at[row].set(jnp.asarray(s, jnp.bfloat16)))
        return dataclasses.replace(
            self,
            tables=self.tables.at[row].set(jnp.asarray(t, jnp.float32)),
            output_nets=self.output_nets.at[row].set(jnp.asarray(o, jnp.int32)),
            **routing,
        )

    def readback_replica(self, slot: int, replica: int = 0) -> np.ndarray:
        """Read back ONE replica's LIVE configuration-memory truth tables
        from the device arrays: (n_levels, m_pad, 16) uint8 in the padded
        scrub-loop layout (core.fabric.packed_table_image).

        This is the detection half of the scrub loop (readback -> verify
        -> heal): it returns what the device is *actually* evaluating
        with — including any upset injected via ``swap_replica`` — so a
        CRC mismatch against the golden digest (core.bitstream.
        GoldenImageStore) proves corruption instead of inferring it from
        vote disagreements. The device tables are exact 0.0/1.0 float32,
        so the uint8 cast is lossless.
        """
        R = self.n_replicas
        if not 0 <= slot < self.n_chips:
            raise ValueError(
                f"slot must be in [0, {self.n_chips}), got {slot!r}")
        if not 0 <= replica < R:
            raise ValueError(f"replica must be in [0, {R}), got {replica!r}")
        return np.asarray(self.tables[slot * R + replica]).astype(np.uint8)

    def readback_chip(self, slot: int) -> np.ndarray:
        """Read back ALL replica slots of one logical chip:
        (n_replicas, n_levels, m_pad, 16) uint8."""
        return np.stack([
            self.readback_replica(slot, r) for r in range(self.n_replicas)
        ])


def _win_base(L: int, band_k: int, m_pad: int, in_seg: int) -> np.ndarray:
    """Per-level window read offsets: level l sees levels [max(0,l-K), l)."""
    return (
        in_seg + np.maximum(np.arange(L, dtype=np.int64) - band_k, 0) * m_pad
    ).astype(np.int32)


def _pack_arrays(
    c: FabricConfig,
    L: int,
    m_pad: int,
    in_seg: int,
    n_out_pad: int,
    band_k: int | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack one config into a forced (L, m_pad, in_seg) geometry.

    Fully vectorized (numpy scatter) — this is the hot-swap path, so pack
    latency must not scale with a Python loop over LUT count x 4.

    band_k=None packs the dense layout: sel rows are the full padded net
    space. With band_k=K, sel rows are [input segment | K-level window]
    and every comb source row is shifted by the packing-time window start
    max(0, l-K)*m_pad of its consumer's level l.

    Returns (sel (L, n_rows, 4*M) f32, tables (L, M, 16) f32, output_nets
    (n_out_pad,) int32 in the full padded layout, const0-padded).
    """
    if c.n_ffs:
        raise ValueError(
            "lut_eval kernel handles combinational modules (the readout "
            "classifier); sequential firmware uses core.fabric.FabricSim"
        )
    assert len(c.level_sizes) <= L
    assert max(c.level_sizes, default=1) <= m_pad
    assert 2 + c.n_inputs <= in_seg
    K = L if band_k is None else min(band_k, L)
    n_rows = in_seg + K * m_pad

    n_luts = c.n_luts
    remap, lut_level, pos = _net_layout(c, m_pad, in_seg)

    sel = np.zeros((L, n_rows, 4 * m_pad), np.float32)
    # the device tables ARE the scrub-loop image: readback_replica reads
    # them back verbatim, and the golden CRC digests are computed over
    # the same packed_table_image function (core/fabric.py)
    tables = packed_table_image(c, L, m_pad).astype(np.float32)
    if n_luts:
        src = remap[c.lut_inputs]                  # (n_luts, 4) dense rows
        # band shift: comb rows move into their consumer level's window
        shift = np.maximum(lut_level - K, 0) * m_pad
        rows = np.where(src >= in_seg, src - shift[:, None], src)
        if band_k is not None:
            bad = (src >= in_seg) & ((rows < in_seg) | (rows >= n_rows))
            if bad.any():
                raise ValueError(
                    f"fan-in reach exceeds band: K={K} but a LUT reads "
                    f"{int(bad.sum())} net(s) from outside its window"
                )
        cols = np.arange(4)[None, :] * m_pad + pos[:, None]
        sel[lut_level[:, None], rows, cols] = 1.0

    out_nets = np.zeros(n_out_pad, np.int64)  # pad with net 0 == const0
    out_nets[: len(c.output_nets)] = remap[c.output_nets]
    return sel, tables, out_nets.astype(np.int32)


def _net_layout(
    c: FabricConfig, m_pad: int, in_seg: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Kernel-order net ids -> the dense padded segmented layout shared
    by every device layout ([const0 | const1 | inputs | level slots]).

    Returns (remap (n_nets,), lut_level (n_luts,), pos (n_luts,)) — the
    one net-numbering convention, factored out so the matmul and
    bitsliced packers cannot drift apart.
    """
    level_sizes = np.asarray(c.level_sizes, np.int64)
    n_luts = c.n_luts
    base_comb = 2 + c.n_inputs  # no FFs
    remap = np.zeros(c.n_nets, np.int64)
    remap[1] = 1
    remap[2:base_comb] = np.arange(2, base_comb)
    if n_luts:
        lut_level = np.repeat(np.arange(len(level_sizes)), level_sizes)
        level_start = np.concatenate([[0], np.cumsum(level_sizes)])
        pos = np.arange(n_luts) - level_start[lut_level]
        remap[base_comb : base_comb + n_luts] = in_seg + lut_level * m_pad + pos
    else:
        lut_level = np.zeros(0, np.int64)
        pos = np.zeros(0, np.int64)
    return remap, lut_level, pos


def _pack_arrays_bitsliced(
    c: FabricConfig,
    L: int,
    m_pad: int,
    in_seg: int,
    n_out_pad: int,
    band_k: int | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack one config into the bit-sliced (L, m_pad) geometry.

    Instead of the one-hot selection tensor, the routing is the compact
    per-LUT gather indices ``src`` (L, m_pad, 4) int32 into the SAME
    dense padded net layout _pack_arrays uses. Padded LUT slots read net
    0 (const0) with an all-zero table, so they evaluate to 0 — identical
    to the matmul layout's zero padding.

    band_k=K enforces the fan-in-reach *envelope*: the gather indices do
    not change shape (index gathers have no routing window), but a LUT
    at level l may only read nets from levels [l-K, l) — the hardware
    reach budget a banded stack promises its hot-swap admission check.
    band_k=None admits any reach (the dense envelope).

    Returns (src (L, m_pad, 4) int32, tables (L, M, 16) f32 — the
    unchanged scrub-loop image, output_nets (n_out_pad,) int32).
    """
    if c.n_ffs:
        raise ValueError(
            "lut_eval kernel handles combinational modules (the readout "
            "classifier); sequential firmware uses core.fabric.FabricSim"
        )
    assert len(c.level_sizes) <= L
    assert max(c.level_sizes, default=1) <= m_pad
    assert 2 + c.n_inputs <= in_seg
    remap, lut_level, pos = _net_layout(c, m_pad, in_seg)
    tables = packed_table_image(c, L, m_pad).astype(np.float32)
    src = np.zeros((L, m_pad, 4), np.int64)
    if c.n_luts:
        rows = remap[c.lut_inputs]                 # (n_luts, 4) dense rows
        if band_k is not None:
            K = min(band_k, L)
            src_level = (rows - in_seg) // m_pad
            bad = (rows >= in_seg) & (lut_level[:, None] - src_level > K)
            if bad.any():
                raise ValueError(
                    f"fan-in reach exceeds band: K={K} but a LUT reads "
                    f"{int(bad.sum())} net(s) from outside its window"
                )
        src[lut_level, pos] = rows
    out_nets = np.zeros(n_out_pad, np.int64)  # pad with net 0 == const0
    out_nets[: len(c.output_nets)] = remap[c.output_nets]
    return src.astype(np.int32), tables, out_nets.astype(np.int32)


def _check_layout(layout: str, band: bool | None) -> None:
    """Validate the layout name. The band is layout-independent: it is a
    fan-in-reach *envelope* (a hardware routing constraint), not a kernel
    structure, so every layout accepts band=None/True/False."""
    del band  # accepted by every layout — kept for signature stability
    if layout not in ("matmul", "bitsliced"):
        raise ValueError(
            f"unknown layout {layout!r} (expected 'matmul' or 'bitsliced')")


def _band_choice(reach: int, L: int, band: bool | None) -> int:
    """Resolve the band width: auto-band iff strictly cheaper than dense.

    Returns band_k in [1, L]; band_k == L is the dense layout (the
    fallback when the window would cover every level anyway).
    """
    K = min(max(reach, 1), L)
    if band is None:
        band = K < L
    return K if (band and K < L) else L


def pack_fabric(
    config: FabricConfig,
    band: bool | None = None,
    layout: str = "matmul",
) -> PackedFabric:
    """Pack one decoded bitstream. band=None picks the banded *envelope*
    automatically when the config's fan-in reach fits a window narrower
    than the full depth (K < L); band=False forces the dense envelope.
    The band is layout-independent: for matmul it also selects the
    windowed selection tensor (the cheaper kernel), for bitsliced it is
    a pure reach budget validated at pack time.

    layout="bitsliced" packs the bit-parallel word layout instead
    (compact ``src`` gather indices, no selection tensor); evaluation
    then runs the 32-events-per-word path (bitsliced.py) rather than the
    Pallas matmul kernel.
    """
    _check_layout(layout, band)
    c = config
    if c.n_ffs:
        raise ValueError(
            "lut_eval kernel handles combinational modules (the readout "
            "classifier); sequential firmware uses core.fabric.FabricSim"
        )
    L = max(len(c.level_sizes), 1)
    m_pad = _round_up(max(c.level_sizes, default=1), 128)
    in_seg = _round_up(2 + c.n_inputs, 128)
    n_pad = in_seg + L * m_pad
    band_k = _band_choice(c.fanin_reach(), L, band)
    if layout == "bitsliced":
        src, tables, out_nets = _pack_arrays_bitsliced(
            c, L, m_pad, in_seg, len(c.output_nets),
            band_k=band_k if band_k < L else None,
        )
        sel = None
    else:
        sel_np, tables, out_nets = _pack_arrays(
            c, L, m_pad, in_seg, len(c.output_nets),
            band_k=band_k if band_k < L else None,
        )
        sel = jnp.asarray(sel_np, jnp.bfloat16)
        src = None
    return PackedFabric(
        sel=sel,
        tables=jnp.asarray(tables, jnp.float32),
        level_base=jnp.asarray(
            [in_seg + l * m_pad for l in range(L)], jnp.int32
        ),
        output_nets=jnp.asarray(out_nets, jnp.int32),
        win_base=jnp.asarray(_win_base(L, band_k, m_pad, in_seg)),
        n_inputs=c.n_inputs,
        n_nets_pad=n_pad,
        m_pad=m_pad,
        n_levels=L,
        in_seg=in_seg,
        band_k=band_k,
        src=None if src is None else jnp.asarray(src, jnp.int32),
    )


def pack_fabrics(
    configs: Sequence[FabricConfig],
    band: bool | None = None,
    redundancy: str = "none",
    layout: str = "matmul",
    geometry: StackGeometry | None = None,
) -> PackedFabricStack:
    """Stack N decoded bitstreams into one chip-batched structure.

    The shared geometry is the union envelope over all configs
    (core.fabric.StackGeometry); every chip is padded to it, so one
    compiled kernel serves heterogeneous designs. The band is shared too:
    K = max fan-in reach over the stack (auto-dense when not cheaper).

    ``geometry`` overrides the union envelope: every config must fit it
    (``StackGeometry.admits``, including its fan-in-reach budget), and
    the stack pads to the GIVEN envelope rather than the tightest one.
    This is the bucketed-pool primitive: stacks packed against the same
    quantized envelope (``bucket_envelope``) share one compiled kernel,
    so a config never seen before admits into a warm stack through
    ``swap_chip`` with zero retraces. When ``geometry.fanin_reach`` is
    set the stack is packed banded to exactly that reach budget (unless
    it already spans every level); when None it is packed dense.

    ``redundancy="tmr"`` packs three placement-distinct replica
    encodings of every chip (core.tmr.replicate_config) as contiguous
    slots. Replication is envelope-invariant — a within-level rotation
    changes neither level sizes, IO widths, nor fan-in reach — so the
    geometry (and the band) is computed from the base configs.

    ``layout="bitsliced"`` packs the bit-parallel word layout (compact
    ``src`` gather indices instead of the one-hot selection tensor);
    evaluation then runs 32 events per uint32 word with the chip axis as
    one batched XLA computation (bitsliced.py). The band applies here
    too, as a pure reach *envelope*: packing validates every LUT's
    fan-in reach against it and hot-swap admission enforces it, while
    the gather kernel itself is unchanged. The scrub-loop ``tables``
    image, hot-swap ports and readback are identical across layouts.
    """
    if redundancy not in ("none", "tmr"):
        raise ValueError(
            f"unknown redundancy {redundancy!r} (expected 'none' or 'tmr')")
    _check_layout(layout, band)
    n_replicas = N_REPLICAS if redundancy == "tmr" else 1
    geo = check_stackable(configs)
    if geometry is not None:
        for i, c in enumerate(configs):
            if not geometry.admits(c):
                raise ValueError(
                    f"config {i} does not fit the requested envelope "
                    f"{geometry} (levels={len(c.level_sizes)}, "
                    f"widest={max(c.level_sizes, default=1)}, "
                    f"inputs={c.n_inputs}, outputs={len(c.output_nets)}, "
                    f"fanin_reach={c.fanin_reach()})")
        geo = geometry
    L = geo.n_levels
    m_pad = _round_up(geo.max_level_size, 128)
    in_seg = _round_up(2 + geo.n_inputs, 128)
    n_pad = in_seg + L * m_pad
    bitsliced = layout == "bitsliced"
    # the band is shared across layouts: K = max fan-in reach over the
    # stack (auto-dense when the window would span every level anyway).
    # A pinned envelope pins the band too — its reach budget IS the
    # band (dense when unset), so every stack packed against the same
    # envelope resolves to the same static band_k and shares one jit.
    if geometry is not None:
        band_k = (min(geometry.fanin_reach, L)
                  if geometry.fanin_reach is not None else L)
    else:
        band_k = _band_choice(geo.fanin_reach or L, L, band)

    slot_configs = [
        replicate_config(c, r) for c in configs for r in range(n_replicas)
    ] if n_replicas > 1 else list(configs)
    sels, tbls, outs = [], [], []
    for c in slot_configs:
        if bitsliced:
            sel, tables, out_nets = _pack_arrays_bitsliced(
                c, L, m_pad, in_seg, geo.n_outputs,
                band_k=band_k if band_k < L else None,
            )
        else:
            sel, tables, out_nets = _pack_arrays(
                c, L, m_pad, in_seg, geo.n_outputs,
                band_k=band_k if band_k < L else None,
            )
        sels.append(sel)
        tbls.append(tables)
        outs.append(out_nets)

    return PackedFabricStack(
        sel=(None if bitsliced
             else jnp.asarray(np.stack(sels), jnp.bfloat16)),
        src=(jnp.asarray(np.stack(sels), jnp.int32) if bitsliced else None),
        tables=jnp.asarray(np.stack(tbls), jnp.float32),
        level_base=jnp.asarray(
            [in_seg + l * m_pad for l in range(L)], jnp.int32
        ),
        output_nets=jnp.asarray(np.stack(outs), jnp.int32),
        win_base=jnp.asarray(_win_base(L, band_k, m_pad, in_seg)),
        n_inputs=geo.n_inputs,
        n_outputs=geo.n_outputs,
        n_inputs_each=tuple(c.n_inputs for c in configs),
        n_outputs_each=tuple(len(c.output_nets) for c in configs),
        n_nets_pad=n_pad,
        m_pad=m_pad,
        n_levels=L,
        in_seg=in_seg,
        band_k=band_k,
        n_replicas=n_replicas,
    )


def _next_pow2(x: int) -> int:
    return 1 << max(0, x - 1).bit_length()


def bucket_envelope(
    config: FabricConfig,
    band: bool | None = None,
    width_quant: int = 128,
) -> StackGeometry:
    """Quantize one config's shape into a padded bucket envelope.

    The envelope axes are snapped to coarse grid points so that MANY
    distinct tenant configs collapse onto a SMALL set of envelopes —
    the bucket key of the geometry pool (``pack_fabric_pool``). Two
    configs with the same bucket envelope can live in (or hot-swap
    into) the same ``PackedFabricStack`` and therefore share one
    compiled kernel; admitting a never-seen config costs an array swap,
    never a retrace.

    Quantization per axis (all are ceilings, so the envelope always
    ``admits`` the config that produced it):

    * ``n_levels``        -> next power of two (depth drives both jit
      specialization and banded-window shape).
    * ``max_level_size``  -> next multiple of ``width_quant`` (the
      kernel pads level width to 128 lanes anyway, so width headroom
      inside the same multiple is free).
    * ``n_inputs``        -> fills the 128-aligned input segment
      (``in_seg - 2``): the pad bits exist either way.
    * ``n_outputs``       -> next power of two, capped at 31 (the
      score-decode limit ``decode_plan`` enforces).
    * ``fanin_reach``     -> next power of two, capped at the quantized
      depth; ``None`` (dense) when the window would span every level or
      when ``band=False`` forces the dense envelope. ``band=True``
      keeps the banded budget even when it equals the depth ceiling.

    The returned ``StackGeometry`` is hashable — use it directly as the
    bucket key.
    """
    c = config
    L = _next_pow2(max(len(c.level_sizes), 1))
    width = _round_up(max(c.level_sizes, default=1), width_quant)
    n_inputs = _round_up(2 + c.n_inputs, 128) - 2
    n_outputs = min(_next_pow2(max(len(c.output_nets), 1)), 31)
    reach: int | None = min(_next_pow2(max(c.fanin_reach(), 1)), L)
    if band is False or (band is None and reach >= L):
        reach = None
    return StackGeometry(
        n_levels=L,
        max_level_size=width,
        n_inputs=n_inputs,
        n_outputs=n_outputs,
        fanin_reach=reach,
    )


@dataclasses.dataclass(frozen=True)
class FabricBucket:
    """One geometry bucket of a fabric pool.

    ``stack`` is packed against the quantized ``envelope`` (not the
    member union), so any future config whose ``bucket_envelope``
    equals this envelope hot-swaps in with zero retraces. ``members``
    maps stack slots back to the caller's config indices:
    ``members[j]`` is the index (into the configs passed to
    ``pack_fabric_pool``) occupying stack slot ``j``.
    """

    envelope: StackGeometry
    stack: PackedFabricStack
    members: Tuple[int, ...]


def pack_fabric_pool(
    configs: Sequence[FabricConfig],
    band: bool | None = None,
    redundancy: str = "none",
    layout: str = "matmul",
    width_quant: int = 128,
) -> List[FabricBucket]:
    """Bin configs into bucketed geometry pools: one padded stack per
    quantized envelope, one jit per bucket.

    Where ``pack_fabrics`` pads every config to the tightest union
    envelope (one stack, one jit — but ANY new shape retraces),
    ``pack_fabric_pool`` groups configs by ``bucket_envelope`` and
    packs each group against its quantized envelope. The pool trades a
    bounded amount of padding (each axis rounds up to a grid point) for
    a hard no-retrace property: a tenant config that lands in an
    existing bucket admits via ``PackedFabricStack.swap_chip`` without
    compiling anything, because every static kernel dimension is a
    function of the envelope alone.

    Buckets are returned in first-seen order of their envelope;
    ``redundancy`` / ``layout`` apply uniformly (they are part of the
    pool identity, not the per-bucket key). The serving-layer analogue
    — per-bucket servers, tenant admission, LRU eviction — lives in
    ``launch/fleet.py``.
    """
    bins: dict = {}
    for i, c in enumerate(configs):
        bins.setdefault(bucket_envelope(c, band, width_quant), []).append(i)
    return [
        FabricBucket(
            envelope=env,
            stack=pack_fabrics(
                [configs[i] for i in idxs],
                band=band,
                redundancy=redundancy,
                layout=layout,
                geometry=env,
            ),
            members=tuple(idxs),
        )
        for env, idxs in bins.items()
    ]


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def _eval_packed(
    packed: PackedFabric,
    bits: jnp.ndarray,
    *,
    batch_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    B = bits.shape[0]
    if packed.bitsliced:
        return _bitsliced.eval_bits(
            packed.src[None], packed.tables[None], packed.output_nets[None],
            bits[None],
            n_inputs=packed.n_inputs, in_seg=packed.in_seg,
        )[0]
    bits_ext = jnp.zeros((B, packed.in_seg), jnp.float32)
    bits_ext = bits_ext.at[:, 1].set(1.0)
    bits_ext = bits_ext.at[:, 2 : 2 + packed.n_inputs].set(
        bits.astype(jnp.float32)
    )
    if packed.banded:
        vals = lut_eval_pallas_banded(
            bits_ext,
            packed.sel,
            packed.tables,
            packed.level_base,
            packed.win_base,
            n_nets_pad=packed.n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )
    else:
        vals = lut_eval_pallas(
            bits_ext,
            packed.sel,
            packed.tables,
            packed.level_base,
            n_nets_pad=packed.n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )
    return jnp.take(vals, packed.output_nets, axis=1).astype(jnp.uint8)


def fabric_eval_bits(
    sel: jnp.ndarray,
    tables: jnp.ndarray,
    level_base: jnp.ndarray,
    win_base: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,        # (C, B, n_inputs_max)
    *,
    n_inputs: int,
    n_nets_pad: int,
    in_seg: int,
    batch_tile: int,
    interpret: bool,
    src: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Traceable chip-batched evaluation of DEVICE-RESIDENT bit tensors.

    The un-jit'd core of ``fabric_eval_multi``: no numpy conversion, no
    padding, no host round-trip — ``bits`` may be the live output of an
    upstream device stage (the fused frontend's on-device quantize+pack,
    kernels/frontend.py) and this call composes inside the enclosing
    jit/shard_map. Requires B % batch_tile == 0.

    A non-None ``src`` selects the bit-sliced layout (``sel`` is None
    then): the word evaluator replaces the Pallas kernel. The branch is
    on the argument's pytree STRUCTURE, which jit caches on — a swap
    keeps the same structure, so hot-swaps still never retrace.
    """
    C, B = bits.shape[0], bits.shape[1]
    if src is not None:
        return _bitsliced.eval_bits(
            src, tables, output_nets, bits,
            n_inputs=n_inputs, in_seg=in_seg,
        )
    bits_ext = jnp.zeros((C, B, in_seg), jnp.float32)
    bits_ext = bits_ext.at[:, :, 1].set(1.0)
    bits_ext = bits_ext.at[:, :, 2 : 2 + n_inputs].set(
        bits.astype(jnp.float32)
    )
    # sel's row count is static under jit: fewer rows than the padded net
    # space means the banded layout (see PackedFabricStack).
    if sel.shape[2] < n_nets_pad:
        vals = lut_eval_pallas_banded_stacked(
            bits_ext,
            sel,
            tables,
            level_base,
            win_base,
            n_nets_pad=n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )                                               # (C, B, N)
    else:
        vals = lut_eval_pallas_stacked(
            bits_ext,
            sel,
            tables,
            level_base,
            n_nets_pad=n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )                                               # (C, B, N)
    idx = output_nets[:, None, :].astype(jnp.int32)     # (C, 1, O)
    return jnp.take_along_axis(vals.astype(jnp.int32), idx, axis=2).astype(
        jnp.uint8
    )


# NOTE: takes the stack's arrays and envelope scalars, NOT the
# PackedFabricStack pytree — its static per-chip width tuples change on
# swap_chip, and passing them through jit would retrace/recompile on every
# hot-swap, exactly the cost the stacked geometry exists to avoid.
_eval_stack_arrays = functools.partial(
    jax.jit,
    static_argnames=("n_inputs", "n_nets_pad", "in_seg", "batch_tile",
                     "interpret"),
)(fabric_eval_bits)


def fabric_eval_bits_voted(
    sel: jnp.ndarray,
    tables: jnp.ndarray,
    level_base: jnp.ndarray,
    win_base: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,        # (C, B, n_inputs_max) — per LOGICAL chip
    *,
    n_replicas: int,
    n_inputs: int,
    n_nets_pad: int,
    in_seg: int,
    batch_tile: int,
    interpret: bool,
    src: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Traceable redundant evaluation: replicas in ONE dispatch, then the
    2-of-3 majority vote before the caller sees outputs.

    ``bits`` is per logical chip; each event is broadcast to that chip's
    ``n_replicas`` contiguous replica slots, all R*C slots evaluate in the
    same chip-batched kernel dispatch, and the vote reduces them. Returns
    (voted output bits (C, B, O) uint8, disagree (C, R, B) bool — True
    where a replica's output bits differ from the voted word, the per-
    replica SEU health signal). n_replicas == 1 degrades to the plain
    evaluation with an all-False disagree tensor.

    A non-None ``src`` (bit-sliced layout) routes to the word evaluator,
    whose majority vote is folded into the same bitwise pass
    (core.tmr.majority_vote_words on sliced uint32 words) — the cheap-TMR
    serving mode.
    """
    C, B = bits.shape[0], bits.shape[1]
    if src is not None:
        return _bitsliced.eval_bits_voted(
            src, tables, output_nets, bits,
            n_replicas=n_replicas, n_inputs=n_inputs, in_seg=in_seg,
        )
    rep_bits = (
        jnp.repeat(bits, n_replicas, axis=0) if n_replicas > 1 else bits
    )
    outs = fabric_eval_bits(
        sel, tables, level_base, win_base, output_nets, rep_bits,
        n_inputs=n_inputs, n_nets_pad=n_nets_pad, in_seg=in_seg,
        batch_tile=batch_tile, interpret=interpret,
    )                                                   # (R*C, B, O) uint8
    if n_replicas == 1:
        return outs, jnp.zeros((C, 1, B), jnp.bool_)
    assert n_replicas == N_REPLICAS, n_replicas
    g = outs.reshape(C, n_replicas, B, outs.shape[-1])
    voted = majority_vote(g[:, 0], g[:, 1], g[:, 2])    # (C, B, O)
    disagree = jnp.any(g != voted[:, None], axis=-1)    # (C, R, B)
    return voted, disagree


_eval_stack_voted = functools.partial(
    jax.jit,
    static_argnames=("n_replicas", "n_inputs", "n_nets_pad", "in_seg",
                     "batch_tile", "interpret"),
)(fabric_eval_bits_voted)


def decode_scores_device(
    outs: jnp.ndarray,          # (C, B, O) voted output bits
    disagree: jnp.ndarray,      # (C, R, B) bool replica-vs-vote mismatches
    out_weight: jnp.ndarray,    # (C, O) int32 two's-complement weights
    threshold_raw: jnp.ndarray, # (C,) int32
    valid: jnp.ndarray,         # (C, B) bool
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared device tail of BOTH serving dispatches (the features path's
    _eval_stack_scored and the fused frontend's _score_frames): decode
    two's-complement scores, apply the integer trigger cut masked by
    ``valid``, and count valid-row disagreements per replica. One
    definition so the trigger semantics cannot fork between ingestion
    paths."""
    score = jnp.sum(outs.astype(jnp.int32) * out_weight[:, None, :], axis=-1)
    keep = (score <= threshold_raw[:, None]) & valid
    dis = jnp.sum((disagree & valid[:, None, :]).astype(jnp.int32), axis=-1)
    return score, keep, dis


def decode_keep_words_device(
    voted_w: jnp.ndarray,       # (C, W, O) uint32 voted output words
    dis_w: jnp.ndarray,         # (C, R, W) uint32 disagreement words
    out_weight: jnp.ndarray,    # (C, O) int32 two's-complement weights
    threshold_raw: jnp.ndarray, # (C,) int32
    valid: jnp.ndarray,         # (C, B) bool
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``decode_scores_device`` stopped in the WORD domain: the trigger
    cut, per-lane scores and SEU counters computed on sliced words,
    without the word->event transpose — so sparse egress can compact
    BEFORE any event-order tensor exists and only kept events are ever
    transposed/shipped.

    Returns (keep_w (C, W) uint32 keep-mask words masked by ``valid``,
    scores (C, W, 32) int32 per-lane scores — lane ``e`` of word ``w`` is
    event ``w*32+e``, and disagree counts (C, R) int32 — identical to the
    event-domain tail's third output). Cut semantics match
    ``decode_scores_device`` bit for bit: sign-extended two's-complement
    planes -> bit-serial biased unsigned compare ``score <= threshold``.
    """
    valid_w = _bitsliced.mask_words(valid)                  # (C, W)
    planes = _bitsliced.sign_extended_planes(voted_w, out_weight)
    keep_w = _bitsliced.keep_words(planes, threshold_raw, valid_w)
    scores = _bitsliced.lane_scores(planes)
    dis = _bitsliced.disagree_counts_words(dis_w, valid_w)
    return keep_w, scores, dis


def decode_plan(
    configs: Sequence[FabricConfig],
    n_outputs: int,
) -> np.ndarray:
    """Per-chip score-decode weights for the device scoring stage.

    Returns out_weight (C, n_outputs) int32 — two's-complement bit
    weights, zero on padded lanes. Same contract as the fused frontend's
    encode plan rows (kernels.frontend._plan_row), restated here so the
    features ingestion path can decode on device without a featurizer.
    Output width must be int32-representable (<= 31 bits). The integer
    trigger cuts are NOT derived here — the caller (the readout server)
    owns one threshold array and ships it to the dispatch directly, so
    there is exactly one copy to keep current.
    """
    C = len(configs)
    weight = np.zeros((C, n_outputs), np.int64)
    for i, c in enumerate(configs):
        n_out = len(c.output_nets)
        if n_out > 31:
            raise ValueError(
                f"device score decode is int32: chip {i} has {n_out} "
                "output bits > 31"
            )
        weight[i, :n_out] = 1 << np.arange(n_out)
        if n_out:
            weight[i, n_out - 1] = -(1 << (n_out - 1))
    return weight.astype(np.int32)


# Static args are the ENVELOPE + mesh only (never per-chip values): the
# same no-retrace rule as _eval_stack_arrays and the fused frontend's
# _score_frames — hot-swaps and threshold updates stay array swaps.
@functools.partial(
    jax.jit,
    static_argnames=("mesh", "n_replicas", "n_inputs", "n_nets_pad",
                     "in_seg", "batch_tile", "interpret", "sparse"),
)
def _eval_stack_scored(
    sel: jnp.ndarray,
    tables: jnp.ndarray,
    level_base: jnp.ndarray,
    win_base: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,          # (C, B, n_inputs_max)
    out_weight: jnp.ndarray,    # (C, n_outputs_max) int32
    threshold_raw: jnp.ndarray, # (C,) int32
    valid: jnp.ndarray,         # (C, B) bool — kills padded event rows
    src: jnp.ndarray | None = None,  # bit-sliced gather indices (or None)
    *,
    mesh: Mesh,
    n_replicas: int,
    n_inputs: int,
    n_nets_pad: int,
    in_seg: int,
    batch_tile: int,
    interpret: bool,
    sparse: bool = False,
):
    """Sharded serving dispatch for pre-packed input bits: evaluate (all
    replicas), vote, decode two's-complement scores and apply the integer
    trigger cut — chip axis shard_map'd over the "chips" readout mesh.

    Dense mode (``sparse=False``) returns (score (C, B) int32, keep
    (C, B) bool — already masked by ``valid``, disagree_counts (C, R)
    int32 — voted-against events per replica, counted over valid rows
    only).

    ``sparse=True`` (bit-sliced stacks only — requires ``src``) keeps the
    whole pipeline in the word domain: per shard the trigger cut and SEU
    counters come off sliced words (``decode_keep_words_device``), then
    — after the shard_map, where the chip axis is global again — the
    popcount prefix-sum compaction packs ONLY the kept events
    (``sparse_trigger_pack_words``). Returns (count () int32, idx
    (C*B*?,) int32 ascending flat indices -1 padded, vals int32 0
    padded, disagree_counts (C, R) int32) — the same wire format as
    ``parallel.compression.sparse_trigger_pack``, produced without ever
    materializing a dense event-order score tensor. The flag is static
    (one retrace per (shape, flag), bounded — it only toggles on the
    degrade ladder's sparse_egress rung or a config change).
    """

    shard = P("chips")

    if sparse:
        if src is None:
            raise ValueError(
                "sparse=True needs the word domain: pack the stack with "
                "layout='bitsliced' (matmul stacks have no word form)")

        def body_sparse(sel, tables, output_nets, bits, out_weight,
                        threshold_raw, valid, src):
            voted_w, dis_w = _bitsliced.eval_words_voted(
                src, tables, output_nets, bits,
                n_replicas=n_replicas, n_inputs=n_inputs, in_seg=in_seg,
            )
            return decode_keep_words_device(
                voted_w, dis_w, out_weight, threshold_raw, valid)

        keep_w, scores, dis = _shard_map_compat(
            body_sparse, mesh=mesh,
            in_specs=(shard,) * 8,
            out_specs=(shard, shard, shard),
            manual_axes={"chips"},
        )(sel, tables, output_nets, bits, out_weight, threshold_raw,
          valid, src)
        # Compaction is CROSS-chip (one ascending flat index space), so it
        # runs after the manual region but inside the same jit: nothing
        # event-ordered exists until only kept events remain.
        count, idx, vals = sparse_trigger_pack_words(keep_w, scores)
        return count, idx, vals, dis

    def body(sel, tables, output_nets, bits, out_weight, threshold_raw,
             valid, src):
        outs, disagree = fabric_eval_bits_voted(
            sel, tables, level_base, win_base, output_nets, bits,
            n_replicas=n_replicas, n_inputs=n_inputs,
            n_nets_pad=n_nets_pad, in_seg=in_seg, batch_tile=batch_tile,
            interpret=interpret, src=src,
        )
        return decode_scores_device(
            outs, disagree, out_weight, threshold_raw, valid)

    return _shard_map_compat(
        body, mesh=mesh,
        in_specs=(shard,) * 8,
        out_specs=(shard, shard, shard),
        manual_axes={"chips"},
    )(sel, tables, output_nets, bits, out_weight, threshold_raw, valid, src)


def fabric_eval_multi_scored(
    stack: PackedFabricStack,
    bits,
    out_weight,
    threshold_raw,
    valid=None,
    *,
    mesh: Mesh,
    batch_tile: int = 128,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Score (chips, events) input bits in one sharded, voted dispatch.

    The serving form of ``fabric_eval_multi``: replicas evaluated and
    majority-voted on device (redundant stacks), scores decoded on device
    (``decode_plan`` arrays) and the keep/drop cut applied there too —
    the host sees only (score, keep, per-replica disagreement counts),
    and with sparse readout (parallel.compression) only the kept events.
    Results are NOT materialized; np.asarray them (or let the readout
    server drain) to block.
    """
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    C, B = bits.shape[0], bits.shape[1]
    assert C == stack.n_chips, (C, stack.n_chips)
    Bp = _round_up(max(B, 1), batch_tile)
    if valid is None:
        valid = jnp.ones((C, B), jnp.bool_)
    else:
        valid = jnp.asarray(valid, jnp.bool_)
    if Bp != B:
        bits = jnp.pad(bits, ((0, 0), (0, Bp - B), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, Bp - B)))
    score, keep, dis = _eval_stack_scored(
        stack.sel, stack.tables, stack.level_base, stack.win_base,
        stack.output_nets, bits,
        jnp.asarray(out_weight, jnp.int32),
        jnp.asarray(threshold_raw, jnp.int32),
        valid,
        stack.src,
        mesh=mesh, n_replicas=stack.n_replicas, n_inputs=stack.n_inputs,
        n_nets_pad=stack.n_nets_pad, in_seg=stack.in_seg,
        batch_tile=batch_tile, interpret=interpret,
    )
    return score[:, :B], keep[:, :B], dis


def fabric_eval_multi_scored_sparse(
    stack: PackedFabricStack,
    bits,
    out_weight,
    threshold_raw,
    valid=None,
    *,
    mesh: Mesh,
    batch_tile: int = 128,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Word-domain sparse twin of ``fabric_eval_multi_scored``.

    Same inputs; instead of dense (score, keep) it returns the packed
    sparse wire tuple (count () int32, idx (C*B,) int32 ascending flat
    indices ``chip*B + event`` -1 padded, vals (C*B,) int32 kept scores 0
    padded, disagree_counts (C, R) int32). The keep cut, SEU counters and
    compaction all run on sliced words inside one jit — dropped events
    are never transposed back to event order and never leave the device.
    Bit-sliced stacks only (``stack.src`` must exist). Results are NOT
    materialized; slice ``idx[:count]`` on device and np.asarray to ship
    exactly the kept prefix (what the readout server's drain does).
    """
    if stack.src is None:
        raise ValueError(
            "fabric_eval_multi_scored_sparse needs layout='bitsliced' "
            "(word-domain egress has no matmul form)")
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    C, B = bits.shape[0], bits.shape[1]
    assert C == stack.n_chips, (C, stack.n_chips)
    Bp = _round_up(max(B, 1), batch_tile)
    if valid is None:
        valid = jnp.ones((C, B), jnp.bool_)
    else:
        valid = jnp.asarray(valid, jnp.bool_)
    if Bp != B:
        bits = jnp.pad(bits, ((0, 0), (0, Bp - B), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, Bp - B)))
    count, idx, vals, dis = _eval_stack_scored(
        stack.sel, stack.tables, stack.level_base, stack.win_base,
        stack.output_nets, bits,
        jnp.asarray(out_weight, jnp.int32),
        jnp.asarray(threshold_raw, jnp.int32),
        valid,
        stack.src,
        mesh=mesh, n_replicas=stack.n_replicas, n_inputs=stack.n_inputs,
        n_nets_pad=stack.n_nets_pad, in_seg=stack.in_seg,
        batch_tile=batch_tile, interpret=interpret, sparse=True,
    )
    if Bp != B:
        # Kept lanes always sit below B (``valid`` kills the pad tail), so
        # restriding the flat index from the tile-padded batch to the
        # caller's keeps ascending order and fits the packed vectors in
        # C*B slots.
        idx = jnp.where(idx >= 0, (idx // Bp) * B + (idx % Bp), -1)
        idx = idx[: C * B]
        vals = vals[: C * B]
    return count, idx, vals, dis


def fabric_eval(
    config_or_packed,
    bits,
    batch_tile: int = 128,
    interpret: bool | None = None,
    band: bool | None = None,
    layout: str = "matmul",
) -> jnp.ndarray:
    """Evaluate a batch of events on the configured fabric.

    bits: (B, n_inputs) 0/1. Returns (B, n_outputs) uint8. B is padded up to
    a batch_tile multiple internally. ``band``/``layout`` select the device
    layout when packing a raw config (ignored for an already-packed fabric).
    """
    packed = (
        config_or_packed
        if isinstance(config_or_packed, PackedFabric)
        else pack_fabric(config_or_packed, band=band, layout=layout)
    )
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    B = bits.shape[0]
    Bp = _round_up(max(B, 1), batch_tile)
    if Bp != B:
        bits = jnp.pad(bits, ((0, Bp - B), (0, 0)))
    out = _eval_packed(packed, bits, batch_tile=batch_tile, interpret=interpret)
    return out[:B]


def stack_input_bits(
    stack: PackedFabricStack, per_chip_bits: Sequence[np.ndarray]
) -> np.ndarray:
    """Zero-pad per-chip (B_i, n_inputs_i) bit arrays into the stacked
    (C, B_max, n_inputs_max) layout the multi kernel consumes."""
    assert len(per_chip_bits) == stack.n_chips, (
        len(per_chip_bits), stack.n_chips)
    for i, b in enumerate(per_chip_bits):
        if np.asarray(b).size:
            assert np.asarray(b).shape[1] == stack.n_inputs_each[i], (
                np.asarray(b).shape, stack.n_inputs_each[i])
    return fabric_stack_event_bits(per_chip_bits, stack.n_inputs)


def fabric_eval_multi(
    stack_or_configs: Union[PackedFabricStack, Sequence[FabricConfig]],
    bits,
    batch_tile: int = 128,
    interpret: bool | None = None,
    band: bool | None = None,
    layout: str = "matmul",
) -> jnp.ndarray:
    """Evaluate (chips, events) in ONE chip-batched kernel dispatch.

    bits: (C, B, n_inputs_max) 0/1 (see stack_input_bits), or a list of
    per-chip (B_i, n_inputs_i) arrays — always per LOGICAL chip. Returns
    (C, B, n_outputs_max) uint8 with padded lanes reading 0; slice lane i
    to n_outputs_each[i]. On a redundant stack all replicas evaluate in
    the same dispatch and the returned bits are the majority-voted word
    (use ``fabric_eval_multi_scored`` to also read the per-replica
    disagreement counters). ``band``/``layout`` select the device layout
    when packing raw configs.
    """
    stack = (
        stack_or_configs
        if isinstance(stack_or_configs, PackedFabricStack)
        else pack_fabrics(list(stack_or_configs), band=band, layout=layout)
    )
    if not isinstance(bits, (jnp.ndarray, np.ndarray)):
        bits = stack_input_bits(stack, bits)
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    C, B = bits.shape[0], bits.shape[1]
    assert C == stack.n_chips, (C, stack.n_chips)
    Bp = _round_up(max(B, 1), batch_tile)
    if Bp != B:
        bits = jnp.pad(bits, ((0, 0), (0, Bp - B), (0, 0)))
    if stack.redundant:
        out, _ = _eval_stack_voted(
            stack.sel, stack.tables, stack.level_base, stack.win_base,
            stack.output_nets, bits,
            n_replicas=stack.n_replicas, n_inputs=stack.n_inputs,
            n_nets_pad=stack.n_nets_pad, in_seg=stack.in_seg,
            batch_tile=batch_tile, interpret=interpret, src=stack.src,
        )
    else:
        out = _eval_stack_arrays(
            stack.sel, stack.tables, stack.level_base, stack.win_base,
            stack.output_nets, bits,
            n_inputs=stack.n_inputs, n_nets_pad=stack.n_nets_pad,
            in_seg=stack.in_seg, batch_tile=batch_tile, interpret=interpret,
            src=stack.src,
        )
    return out[:, :B]
