"""jit'd wrapper + packing for the lut_eval kernel.

``pack_fabric`` turns a decoded bitstream (core.fabric.FabricConfig) into
the dense, 128-aligned arrays the kernel consumes; ``fabric_eval`` runs a
batch of events through the configured fabric. Reconfiguring the fabric =
repacking arrays; the compiled kernel is reused across bitstreams with the
same padded geometry (the paper's reconfigurability property, DESIGN.md §3).

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles to Mosaic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric import FabricConfig
from repro.kernels.lut_eval.lut_eval import lut_eval_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedFabric:
    """Device-array form of a decoded bitstream (pytree)."""

    sel: jnp.ndarray          # (L, N, 4*M) bf16 0/1
    tables: jnp.ndarray       # (L, M, 16) f32
    level_base: jnp.ndarray   # (L,) int32
    output_nets: jnp.ndarray  # (n_outputs,) int32 (padded layout)
    n_inputs: int = dataclasses.field(metadata=dict(static=True))
    n_nets_pad: int = dataclasses.field(metadata=dict(static=True))
    m_pad: int = dataclasses.field(metadata=dict(static=True))
    n_levels: int = dataclasses.field(metadata=dict(static=True))
    in_seg: int = dataclasses.field(metadata=dict(static=True))


def pack_fabric(config: FabricConfig) -> PackedFabric:
    c = config
    if c.n_ffs:
        raise ValueError(
            "lut_eval kernel handles combinational modules (the readout "
            "classifier); sequential firmware uses core.fabric.FabricSim"
        )
    L = max(len(c.level_sizes), 1)
    m_pad = _round_up(max(c.level_sizes, default=1), 128)
    in_seg = _round_up(2 + c.n_inputs, 128)
    n_pad = in_seg + L * m_pad

    # Remap kernel-order nets -> padded segmented layout.
    remap = np.zeros(c.n_nets, np.int64)
    remap[0], remap[1] = 0, 1
    remap[2 : 2 + c.n_inputs] = np.arange(2, 2 + c.n_inputs)
    base_comb = 2 + c.n_inputs  # no FFs
    slot = 0
    for l, m in enumerate(c.level_sizes):
        for p in range(m):
            remap[base_comb + slot] = in_seg + l * m_pad + p
            slot += 1

    sel = np.zeros((L, n_pad, 4 * m_pad), np.float32)
    tables = np.zeros((L, m_pad, 16), np.float32)
    slot = 0
    for l, m in enumerate(c.level_sizes):
        for p in range(m):
            for k in range(4):
                src = remap[c.lut_inputs[slot, k]]
                sel[l, src, k * m_pad + p] = 1.0
            tables[l, p] = c.lut_tables[slot]
            slot += 1

    return PackedFabric(
        sel=jnp.asarray(sel, jnp.bfloat16),
        tables=jnp.asarray(tables, jnp.float32),
        level_base=jnp.asarray(
            [in_seg + l * m_pad for l in range(L)], jnp.int32
        ),
        output_nets=jnp.asarray(remap[c.output_nets], jnp.int32),
        n_inputs=c.n_inputs,
        n_nets_pad=n_pad,
        m_pad=m_pad,
        n_levels=L,
        in_seg=in_seg,
    )


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def _eval_packed(
    packed: PackedFabric,
    bits: jnp.ndarray,
    *,
    batch_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    B = bits.shape[0]
    bits_ext = jnp.zeros((B, packed.in_seg), jnp.float32)
    bits_ext = bits_ext.at[:, 1].set(1.0)
    bits_ext = bits_ext.at[:, 2 : 2 + packed.n_inputs].set(
        bits.astype(jnp.float32)
    )
    vals = lut_eval_pallas(
        bits_ext,
        packed.sel,
        packed.tables,
        packed.level_base,
        n_nets_pad=packed.n_nets_pad,
        batch_tile=batch_tile,
        interpret=interpret,
    )
    return jnp.take(vals, packed.output_nets, axis=1).astype(jnp.uint8)


def fabric_eval(
    config_or_packed,
    bits,
    batch_tile: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Evaluate a batch of events on the configured fabric.

    bits: (B, n_inputs) 0/1. Returns (B, n_outputs) uint8. B is padded up to
    a batch_tile multiple internally.
    """
    packed = (
        config_or_packed
        if isinstance(config_or_packed, PackedFabric)
        else pack_fabric(config_or_packed)
    )
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    B = bits.shape[0]
    Bp = _round_up(max(B, 1), batch_tile)
    if Bp != B:
        bits = jnp.pad(bits, ((0, Bp - B), (0, 0)))
    out = _eval_packed(packed, bits, batch_tile=batch_tile, interpret=interpret)
    return out[:B]
