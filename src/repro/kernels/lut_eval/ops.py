"""jit'd wrappers + packing for the lut_eval kernel (single and multi-chip).

``pack_fabric`` turns a decoded bitstream (core.fabric.FabricConfig) into
the dense, 128-aligned arrays the kernel consumes; ``fabric_eval`` runs a
batch of events through one configured fabric. ``pack_fabrics`` stacks N
decoded bitstreams into ONE chip-batched structure sharing a padded
geometry, and ``fabric_eval_multi`` evaluates (chips, events) in a single
kernel dispatch — the device half of launch/readout_server.py.

Reconfiguring a fabric = repacking arrays; the compiled kernel is reused
across bitstreams with the same padded geometry (the paper's
reconfigurability property, DESIGN.md §3). For a stack this extends
per-slot: ``PackedFabricStack.swap_chip`` replaces one chip's arrays in
place, no recompile, as long as the new config fits the stack's envelope.

Routing is packed *banded* whenever it is cheaper: level l's selection
rows cover only [input segment | window of the K preceding levels], K the
config's fan-in reach (core.netlist.fanin_reach), cutting per-level matmul
cost from (in_seg + L*m_pad)*4M to (in_seg + K*m_pad)*4M. The dense layout
is the automatic fallback when K >= L (the window would span every level).
The band is part of the stack envelope: hot-swaps must fit it, which
StackGeometry.admits enforces via its fanin_reach budget.

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles to Mosaic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric import (
    FabricConfig,
    StackGeometry,
    check_stackable,
    stack_event_bits as fabric_stack_event_bits,
)
from repro.kernels.compat import default_interpret as _default_interpret
from repro.kernels.lut_eval.lut_eval import (
    lut_eval_pallas,
    lut_eval_pallas_banded,
    lut_eval_pallas_banded_stacked,
    lut_eval_pallas_stacked,
)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedFabric:
    """Device-array form of a decoded bitstream (pytree).

    ``band_k`` < ``n_levels`` means the selection tensor is *banded*:
    ``sel`` has ``in_seg + band_k*m_pad`` rows per level (input segment +
    a window of band_k preceding levels) and ``win_base[l]`` holds the
    window's read offset into the full net buffer. ``band_k == n_levels``
    is the dense layout (sel rows == n_nets_pad, win_base all in_seg).
    """

    sel: jnp.ndarray          # (L, n_rows, 4*M) bf16 0/1
    tables: jnp.ndarray       # (L, M, 16) f32
    level_base: jnp.ndarray   # (L,) int32
    output_nets: jnp.ndarray  # (n_outputs,) int32 (padded layout)
    win_base: jnp.ndarray     # (L,) int32 — banded window read offsets
    n_inputs: int = dataclasses.field(metadata=dict(static=True))
    n_nets_pad: int = dataclasses.field(metadata=dict(static=True))
    m_pad: int = dataclasses.field(metadata=dict(static=True))
    n_levels: int = dataclasses.field(metadata=dict(static=True))
    in_seg: int = dataclasses.field(metadata=dict(static=True))
    band_k: int = dataclasses.field(metadata=dict(static=True))

    @property
    def banded(self) -> bool:
        return self.band_k < self.n_levels


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedFabricStack:
    """N decoded bitstreams stacked into one chip-batched pytree.

    All chips share the padded geometry (L, N, M, in_seg); narrower chips
    are zero-padded. ``output_nets`` is padded with net 0 (const0), so
    padded output lanes evaluate to 0 — matching MultiFabricSim's zero
    padding. Per-chip true widths live in the static tuples.
    """

    sel: jnp.ndarray          # (C, L, n_rows, 4*M) bf16 0/1
    tables: jnp.ndarray       # (C, L, M, 16) f32
    level_base: jnp.ndarray   # (L,) int32 — shared
    output_nets: jnp.ndarray  # (C, n_outputs_max) int32 (padded layout)
    win_base: jnp.ndarray     # (L,) int32 — shared banded window offsets
    n_inputs: int = dataclasses.field(metadata=dict(static=True))       # max
    n_outputs: int = dataclasses.field(metadata=dict(static=True))      # max
    n_inputs_each: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    n_outputs_each: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    n_nets_pad: int = dataclasses.field(metadata=dict(static=True))
    m_pad: int = dataclasses.field(metadata=dict(static=True))
    n_levels: int = dataclasses.field(metadata=dict(static=True))
    in_seg: int = dataclasses.field(metadata=dict(static=True))
    band_k: int = dataclasses.field(metadata=dict(static=True))  # shared band

    @property
    def n_chips(self) -> int:
        return len(self.n_inputs_each)

    @property
    def banded(self) -> bool:
        return self.band_k < self.n_levels

    def swap_chip(self, slot: int, config: FabricConfig) -> "PackedFabricStack":
        """Hot-swap one chip's bitstream: pure array swap, no recompile.

        The new config must fit the stack's padded envelope (StackGeometry
        admits it — including the fan-in-reach budget when the stack is
        banded); true per-chip widths update so callers decode the right
        output lanes.
        """
        geo = StackGeometry(
            n_levels=self.n_levels,
            max_level_size=self.m_pad,
            n_inputs=self.n_inputs,
            n_outputs=self.n_outputs,
            fanin_reach=self.band_k if self.banded else None,
        )
        if config.n_ffs or not geo.admits(config):
            raise ValueError(
                f"config does not fit stack envelope {geo} "
                f"(levels={len(config.level_sizes)}, "
                f"widest={max(config.level_sizes, default=1)}, "
                f"inputs={config.n_inputs}, outputs={len(config.output_nets)},"
                f" ffs={config.n_ffs}, fanin_reach={config.fanin_reach()})"
            )
        sel, tables, out_nets = _pack_arrays(
            config, self.n_levels, self.m_pad, self.in_seg, self.n_outputs,
            band_k=self.band_k if self.banded else None,
        )
        each_in = list(self.n_inputs_each)
        each_out = list(self.n_outputs_each)
        each_in[slot] = config.n_inputs
        each_out[slot] = len(config.output_nets)
        return dataclasses.replace(
            self,
            sel=self.sel.at[slot].set(jnp.asarray(sel, jnp.bfloat16)),
            tables=self.tables.at[slot].set(jnp.asarray(tables, jnp.float32)),
            output_nets=self.output_nets.at[slot].set(
                jnp.asarray(out_nets, jnp.int32)
            ),
            n_inputs_each=tuple(each_in),
            n_outputs_each=tuple(each_out),
        )


def _win_base(L: int, band_k: int, m_pad: int, in_seg: int) -> np.ndarray:
    """Per-level window read offsets: level l sees levels [max(0,l-K), l)."""
    return (
        in_seg + np.maximum(np.arange(L, dtype=np.int64) - band_k, 0) * m_pad
    ).astype(np.int32)


def _pack_arrays(
    c: FabricConfig,
    L: int,
    m_pad: int,
    in_seg: int,
    n_out_pad: int,
    band_k: int | None = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack one config into a forced (L, m_pad, in_seg) geometry.

    Fully vectorized (numpy scatter) — this is the hot-swap path, so pack
    latency must not scale with a Python loop over LUT count x 4.

    band_k=None packs the dense layout: sel rows are the full padded net
    space. With band_k=K, sel rows are [input segment | K-level window]
    and every comb source row is shifted by the packing-time window start
    max(0, l-K)*m_pad of its consumer's level l.

    Returns (sel (L, n_rows, 4*M) f32, tables (L, M, 16) f32, output_nets
    (n_out_pad,) int32 in the full padded layout, const0-padded).
    """
    if c.n_ffs:
        raise ValueError(
            "lut_eval kernel handles combinational modules (the readout "
            "classifier); sequential firmware uses core.fabric.FabricSim"
        )
    assert len(c.level_sizes) <= L
    assert max(c.level_sizes, default=1) <= m_pad
    assert 2 + c.n_inputs <= in_seg
    K = L if band_k is None else min(band_k, L)
    n_rows = in_seg + K * m_pad

    level_sizes = np.asarray(c.level_sizes, np.int64)
    n_luts = c.n_luts
    base_comb = 2 + c.n_inputs  # no FFs

    # Remap kernel-order nets -> (dense) padded segmented layout.
    remap = np.zeros(c.n_nets, np.int64)
    remap[1] = 1
    remap[2:base_comb] = np.arange(2, base_comb)

    sel = np.zeros((L, n_rows, 4 * m_pad), np.float32)
    tables = np.zeros((L, m_pad, 16), np.float32)
    if n_luts:
        lut_level = np.repeat(np.arange(len(level_sizes)), level_sizes)
        level_start = np.concatenate([[0], np.cumsum(level_sizes)])
        pos = np.arange(n_luts) - level_start[lut_level]
        remap[base_comb : base_comb + n_luts] = in_seg + lut_level * m_pad + pos

        src = remap[c.lut_inputs]                  # (n_luts, 4) dense rows
        # band shift: comb rows move into their consumer level's window
        shift = np.maximum(lut_level - K, 0) * m_pad
        rows = np.where(src >= in_seg, src - shift[:, None], src)
        if band_k is not None:
            bad = (src >= in_seg) & ((rows < in_seg) | (rows >= n_rows))
            if bad.any():
                raise ValueError(
                    f"fan-in reach exceeds band: K={K} but a LUT reads "
                    f"{int(bad.sum())} net(s) from outside its window"
                )
        cols = np.arange(4)[None, :] * m_pad + pos[:, None]
        sel[lut_level[:, None], rows, cols] = 1.0
        tables[lut_level, pos] = c.lut_tables

    out_nets = np.zeros(n_out_pad, np.int64)  # pad with net 0 == const0
    out_nets[: len(c.output_nets)] = remap[c.output_nets]
    return sel, tables, out_nets.astype(np.int32)


def _band_choice(reach: int, L: int, band: bool | None) -> int:
    """Resolve the band width: auto-band iff strictly cheaper than dense.

    Returns band_k in [1, L]; band_k == L is the dense layout (the
    fallback when the window would cover every level anyway).
    """
    K = min(max(reach, 1), L)
    if band is None:
        band = K < L
    return K if (band and K < L) else L


def pack_fabric(
    config: FabricConfig, band: bool | None = None
) -> PackedFabric:
    """Pack one decoded bitstream. band=None picks banded routing
    automatically when the config's fan-in reach makes it cheaper than
    dense (K < L); band=False forces the dense layout."""
    c = config
    if c.n_ffs:
        raise ValueError(
            "lut_eval kernel handles combinational modules (the readout "
            "classifier); sequential firmware uses core.fabric.FabricSim"
        )
    L = max(len(c.level_sizes), 1)
    m_pad = _round_up(max(c.level_sizes, default=1), 128)
    in_seg = _round_up(2 + c.n_inputs, 128)
    n_pad = in_seg + L * m_pad
    band_k = _band_choice(c.fanin_reach(), L, band)

    sel, tables, out_nets = _pack_arrays(
        c, L, m_pad, in_seg, len(c.output_nets),
        band_k=band_k if band_k < L else None,
    )
    return PackedFabric(
        sel=jnp.asarray(sel, jnp.bfloat16),
        tables=jnp.asarray(tables, jnp.float32),
        level_base=jnp.asarray(
            [in_seg + l * m_pad for l in range(L)], jnp.int32
        ),
        output_nets=jnp.asarray(out_nets, jnp.int32),
        win_base=jnp.asarray(_win_base(L, band_k, m_pad, in_seg)),
        n_inputs=c.n_inputs,
        n_nets_pad=n_pad,
        m_pad=m_pad,
        n_levels=L,
        in_seg=in_seg,
        band_k=band_k,
    )


def pack_fabrics(
    configs: Sequence[FabricConfig], band: bool | None = None
) -> PackedFabricStack:
    """Stack N decoded bitstreams into one chip-batched structure.

    The shared geometry is the union envelope over all configs
    (core.fabric.StackGeometry); every chip is padded to it, so one
    compiled kernel serves heterogeneous designs. The band is shared too:
    K = max fan-in reach over the stack (auto-dense when not cheaper).
    """
    geo = check_stackable(configs)
    L = geo.n_levels
    m_pad = _round_up(geo.max_level_size, 128)
    in_seg = _round_up(2 + geo.n_inputs, 128)
    n_pad = in_seg + L * m_pad
    band_k = _band_choice(geo.fanin_reach or L, L, band)

    sels, tbls, outs = [], [], []
    for c in configs:
        sel, tables, out_nets = _pack_arrays(
            c, L, m_pad, in_seg, geo.n_outputs,
            band_k=band_k if band_k < L else None,
        )
        sels.append(sel)
        tbls.append(tables)
        outs.append(out_nets)

    return PackedFabricStack(
        sel=jnp.asarray(np.stack(sels), jnp.bfloat16),
        tables=jnp.asarray(np.stack(tbls), jnp.float32),
        level_base=jnp.asarray(
            [in_seg + l * m_pad for l in range(L)], jnp.int32
        ),
        output_nets=jnp.asarray(np.stack(outs), jnp.int32),
        win_base=jnp.asarray(_win_base(L, band_k, m_pad, in_seg)),
        n_inputs=geo.n_inputs,
        n_outputs=geo.n_outputs,
        n_inputs_each=tuple(c.n_inputs for c in configs),
        n_outputs_each=tuple(len(c.output_nets) for c in configs),
        n_nets_pad=n_pad,
        m_pad=m_pad,
        n_levels=L,
        in_seg=in_seg,
        band_k=band_k,
    )


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def _eval_packed(
    packed: PackedFabric,
    bits: jnp.ndarray,
    *,
    batch_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    B = bits.shape[0]
    bits_ext = jnp.zeros((B, packed.in_seg), jnp.float32)
    bits_ext = bits_ext.at[:, 1].set(1.0)
    bits_ext = bits_ext.at[:, 2 : 2 + packed.n_inputs].set(
        bits.astype(jnp.float32)
    )
    if packed.banded:
        vals = lut_eval_pallas_banded(
            bits_ext,
            packed.sel,
            packed.tables,
            packed.level_base,
            packed.win_base,
            n_nets_pad=packed.n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )
    else:
        vals = lut_eval_pallas(
            bits_ext,
            packed.sel,
            packed.tables,
            packed.level_base,
            n_nets_pad=packed.n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )
    return jnp.take(vals, packed.output_nets, axis=1).astype(jnp.uint8)


def fabric_eval_bits(
    sel: jnp.ndarray,
    tables: jnp.ndarray,
    level_base: jnp.ndarray,
    win_base: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,        # (C, B, n_inputs_max)
    *,
    n_inputs: int,
    n_nets_pad: int,
    in_seg: int,
    batch_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    """Traceable chip-batched evaluation of DEVICE-RESIDENT bit tensors.

    The un-jit'd core of ``fabric_eval_multi``: no numpy conversion, no
    padding, no host round-trip — ``bits`` may be the live output of an
    upstream device stage (the fused frontend's on-device quantize+pack,
    kernels/frontend.py) and this call composes inside the enclosing
    jit/shard_map. Requires B % batch_tile == 0.
    """
    C, B = bits.shape[0], bits.shape[1]
    bits_ext = jnp.zeros((C, B, in_seg), jnp.float32)
    bits_ext = bits_ext.at[:, :, 1].set(1.0)
    bits_ext = bits_ext.at[:, :, 2 : 2 + n_inputs].set(
        bits.astype(jnp.float32)
    )
    # sel's row count is static under jit: fewer rows than the padded net
    # space means the banded layout (see PackedFabricStack).
    if sel.shape[2] < n_nets_pad:
        vals = lut_eval_pallas_banded_stacked(
            bits_ext,
            sel,
            tables,
            level_base,
            win_base,
            n_nets_pad=n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )                                               # (C, B, N)
    else:
        vals = lut_eval_pallas_stacked(
            bits_ext,
            sel,
            tables,
            level_base,
            n_nets_pad=n_nets_pad,
            batch_tile=batch_tile,
            interpret=interpret,
        )                                               # (C, B, N)
    idx = output_nets[:, None, :].astype(jnp.int32)     # (C, 1, O)
    return jnp.take_along_axis(vals.astype(jnp.int32), idx, axis=2).astype(
        jnp.uint8
    )


# NOTE: takes the stack's arrays and envelope scalars, NOT the
# PackedFabricStack pytree — its static per-chip width tuples change on
# swap_chip, and passing them through jit would retrace/recompile on every
# hot-swap, exactly the cost the stacked geometry exists to avoid.
_eval_stack_arrays = functools.partial(
    jax.jit,
    static_argnames=("n_inputs", "n_nets_pad", "in_seg", "batch_tile",
                     "interpret"),
)(fabric_eval_bits)


def fabric_eval(
    config_or_packed,
    bits,
    batch_tile: int = 128,
    interpret: bool | None = None,
    band: bool | None = None,
) -> jnp.ndarray:
    """Evaluate a batch of events on the configured fabric.

    bits: (B, n_inputs) 0/1. Returns (B, n_outputs) uint8. B is padded up to
    a batch_tile multiple internally. ``band`` selects banded/dense routing
    when packing a raw config (ignored for an already-packed fabric).
    """
    packed = (
        config_or_packed
        if isinstance(config_or_packed, PackedFabric)
        else pack_fabric(config_or_packed, band=band)
    )
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    B = bits.shape[0]
    Bp = _round_up(max(B, 1), batch_tile)
    if Bp != B:
        bits = jnp.pad(bits, ((0, Bp - B), (0, 0)))
    out = _eval_packed(packed, bits, batch_tile=batch_tile, interpret=interpret)
    return out[:B]


def stack_input_bits(
    stack: PackedFabricStack, per_chip_bits: Sequence[np.ndarray]
) -> np.ndarray:
    """Zero-pad per-chip (B_i, n_inputs_i) bit arrays into the stacked
    (C, B_max, n_inputs_max) layout the multi kernel consumes."""
    assert len(per_chip_bits) == stack.n_chips, (
        len(per_chip_bits), stack.n_chips)
    for i, b in enumerate(per_chip_bits):
        if np.asarray(b).size:
            assert np.asarray(b).shape[1] == stack.n_inputs_each[i], (
                np.asarray(b).shape, stack.n_inputs_each[i])
    return fabric_stack_event_bits(per_chip_bits, stack.n_inputs)


def fabric_eval_multi(
    stack_or_configs: Union[PackedFabricStack, Sequence[FabricConfig]],
    bits,
    batch_tile: int = 128,
    interpret: bool | None = None,
    band: bool | None = None,
) -> jnp.ndarray:
    """Evaluate (chips, events) in ONE chip-batched kernel dispatch.

    bits: (C, B, n_inputs_max) 0/1 (see stack_input_bits), or a list of
    per-chip (B_i, n_inputs_i) arrays. Returns (C, B, n_outputs_max) uint8
    with padded lanes reading 0; slice lane i to n_outputs_each[i].
    ``band`` selects banded/dense routing when packing raw configs.
    """
    stack = (
        stack_or_configs
        if isinstance(stack_or_configs, PackedFabricStack)
        else pack_fabrics(list(stack_or_configs), band=band)
    )
    if not isinstance(bits, (jnp.ndarray, np.ndarray)):
        bits = stack_input_bits(stack, bits)
    if interpret is None:
        interpret = _default_interpret()
    bits = jnp.asarray(bits)
    C, B = bits.shape[0], bits.shape[1]
    assert C == stack.n_chips, (C, stack.n_chips)
    Bp = _round_up(max(B, 1), batch_tile)
    if Bp != B:
        bits = jnp.pad(bits, ((0, 0), (0, Bp - B), (0, 0)))
    out = _eval_stack_arrays(
        stack.sel, stack.tables, stack.level_base, stack.win_base,
        stack.output_nets, bits,
        n_inputs=stack.n_inputs, n_nets_pad=stack.n_nets_pad,
        in_seg=stack.in_seg, batch_tile=batch_tile, interpret=interpret,
    )
    return out[:, :B]
