"""Pallas TPU kernel: batched eFPGA fabric evaluation.

The fabric's *spatial* parallelism (hundreds of LUT4s switching per clock)
maps to TPU as *batch* parallelism over events (DESIGN.md §3). A LUT4 read
is a 16-entry gather; random gathers are hostile to the TPU vector unit, so
both stages are reformulated as dense one-hot contractions that run on the
MXU:

  stage 1 (routing):  ins = V @ S_l      — selecting each LUT's 4 input nets
                      is a (B,N) x (N,4M) matmul with a 0/1 matrix;
  stage 2 (lookup):   out = Σ_k 1[idx=k] * T_l[:,k] — a 16-way one-hot
                      contraction against the truth tables.

Memory layout: net values live in a VMEM-resident (B_TILE, N) f32 buffer.
N is the *segmented* padded net count — [consts+inputs | level 0 | level 1
| ...] with every segment 128-lane aligned, so each level's write is a
statically-aligned dynamic slice (no sub-lane stores). The const0/const1
columns are part of the input segment (the ops wrapper prepends them), so
initialization is a single aligned block copy.

Grid: (chips, batch_tiles, n_levels); chip and batch axes are parallel,
the level axis is "arbitrary" (sequential) and revisits the same output
block, which Pallas keeps resident in VMEM across the level steps — the
standard accumulator pattern. The chip axis serves a *multi-chip readout
server* (launch/readout_server.py): N configured fabrics, padded to one
shared geometry, score their event streams in a single dispatch. Per-level
write offsets are scalar-prefetched (SMEM) so the dynamic slice start is
known to the DMA engine up front.

VMEM budget per step (BDT module, N=2048, M=128, B=128):
  V 128x2048x4B = 1.0 MiB, S block 2048x512x2B (bf16) = 2.0 MiB,
  tables 128x16x4B = 8 KiB  => ~3 MiB, comfortably under the ~16 MiB VMEM.

The selection matmul does ~B*N*4M flops per level — far more "arithmetic"
than the fabric's actual logic, but it is dense MXU work at 197 TFLOP/s
instead of serialized gathers; benchmarks/bench_fabric.py reports the
events/s this buys.

Banded variant (``lut_eval_pallas_banded_stacked``): levelized netlists
have bounded fan-in reach — a level-l LUT reads only primary inputs plus a
window of K preceding levels (core.netlist.fanin_reach). The dense kernel's
per-level matmul nevertheless pays for the *full* padded net buffer
(N = in_seg + L*m_pad), so total routing cost grows ~quadratically with
level count. The banded kernel's selection tensor has only
``in_seg + K*m_pad`` rows per level; the kernel concatenates the input
segment with a scalar-prefetched dynamic window of the net buffer
([win_base[l], win_base[l]+K*m_pad), always 128-aligned) and matmuls
against that — O(L*(in_seg+K*m_pad)*4M), near-linear in depth when K << L.
Levels earlier than the window's written prefix read zero-initialized
columns whose selection rows are all-zero, so the contraction is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(base_ref, bits_ref, sel_ref, tbl_ref, vals_ref, *, in_seg: int, m_pad: int):
    l = pl.program_id(2)

    # First level-visit of a (chip, batch-tile) cell: init the net buffer.
    @pl.when(l == 0)
    def _init():
        vals_ref[...] = jnp.zeros_like(vals_ref)
        vals_ref[0, :, : in_seg] = bits_ref[0]  # [const0, const1, inputs, pad]

    v = vals_ref[0]                                     # (B, N)
    sel = sel_ref[0, 0].astype(jnp.float32)             # (N, 4*M)
    ins = jax.lax.dot(v, sel, preferred_element_type=jnp.float32)
    ins = ins.reshape(v.shape[0], 4, m_pad)
    idx = (
        ins[:, 0] + 2.0 * ins[:, 1] + 4.0 * ins[:, 2] + 8.0 * ins[:, 3]
    ).astype(jnp.int32)                                 # (B, M)
    onehot = idx[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, 16), 2)
    out = jnp.sum(onehot.astype(jnp.float32) * tbl_ref[0, 0][None], axis=-1)

    vals_ref[0, :, pl.dslice(base_ref[l], m_pad)] = out


def lut_eval_pallas_stacked(
    bits_ext: jnp.ndarray,   # (C, B, in_seg) f32 — [const0, const1, inputs, 0-pad]
    sel: jnp.ndarray,        # (C, L, N, 4*M) 0/1 selection (bf16)
    tables: jnp.ndarray,     # (C, L, M, 16) f32
    level_base: jnp.ndarray, # (L,) int32 — 128-aligned write offset per level
    *,
    n_nets_pad: int,
    batch_tile: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chip-batched fabric evaluation: C configured chips x B events in ONE
    dispatch. Returns the padded net-value tensor (C, B, N) f32.

    The chip axis is an outer parallel grid dimension: each (chip, batch
    tile) cell walks the levels sequentially over its own VMEM-resident net
    buffer, streaming that chip's selection/table blocks. All chips share
    one padded geometry (L, N, M) — see ops.pack_fabrics — so swapping any
    chip's bitstream is an array swap with no recompile.
    """
    C, B, in_seg = bits_ext.shape
    Cs, L, N, M4 = sel.shape
    M = M4 // 4
    assert Cs == C, (Cs, C)
    assert N == n_nets_pad and in_seg % 128 == 0 and M % 128 == 0
    assert B % batch_tile == 0, (B, batch_tile)

    kernel = functools.partial(_kernel, in_seg=in_seg, m_pad=M)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, B // batch_tile, L),
        in_specs=[
            pl.BlockSpec((1, batch_tile, in_seg), lambda c, b, l, base: (c, b, 0)),
            pl.BlockSpec((1, 1, N, M4), lambda c, b, l, base: (c, l, 0, 0)),
            pl.BlockSpec((1, 1, M, 16), lambda c, b, l, base: (c, l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, batch_tile, N), lambda c, b, l, base: (c, b, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, B, N), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(level_base, bits_ext.astype(jnp.float32), sel, tables)


def _banded_kernel(
    base_ref, win_ref, bits_ref, sel_ref, tbl_ref, vals_ref,
    *, in_seg: int, m_pad: int, band_m: int,
):
    """Banded level step: route from [input segment | K-level window] only.

    The net-value buffer keeps the full dense layout (writes land at
    base_ref[l] exactly like the dense kernel), but the selection matmul's
    row space is the band — win_ref[l] = in_seg + max(0, l-K)*m_pad points
    the window at the K levels preceding l.
    """
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        vals_ref[...] = jnp.zeros_like(vals_ref)
        vals_ref[0, :, : in_seg] = bits_ref[0]  # [const0, const1, inputs, pad]

    v_in = vals_ref[0, :, :in_seg]                      # (B, in_seg)
    v_win = vals_ref[0, :, pl.dslice(win_ref[l], band_m)]  # (B, K*M)
    v = jnp.concatenate([v_in, v_win], axis=-1)         # (B, in_seg + K*M)
    sel = sel_ref[0, 0].astype(jnp.float32)             # (in_seg + K*M, 4*M)
    ins = jax.lax.dot(v, sel, preferred_element_type=jnp.float32)
    ins = ins.reshape(v.shape[0], 4, m_pad)
    idx = (
        ins[:, 0] + 2.0 * ins[:, 1] + 4.0 * ins[:, 2] + 8.0 * ins[:, 3]
    ).astype(jnp.int32)                                 # (B, M)
    onehot = idx[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, 16), 2)
    out = jnp.sum(onehot.astype(jnp.float32) * tbl_ref[0, 0][None], axis=-1)

    vals_ref[0, :, pl.dslice(base_ref[l], m_pad)] = out


def lut_eval_pallas_banded_stacked(
    bits_ext: jnp.ndarray,   # (C, B, in_seg) f32 — [const0, const1, inputs, 0-pad]
    sel: jnp.ndarray,        # (C, L, in_seg + K*M, 4*M) 0/1 banded selection (bf16)
    tables: jnp.ndarray,     # (C, L, M, 16) f32
    level_base: jnp.ndarray, # (L,) int32 — 128-aligned write offset per level
    win_base: jnp.ndarray,   # (L,) int32 — 128-aligned window read offset per level
    *,
    n_nets_pad: int,
    batch_tile: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chip-batched *banded* fabric evaluation.

    Identical contract to ``lut_eval_pallas_stacked`` (returns the full
    padded net-value tensor (C, B, N) f32) but each level's routing matmul
    touches only ``in_seg + K*m_pad`` net columns, K the shared fan-in
    reach of the stacked configs (ops.pack_fabrics computes it and falls
    back to the dense kernel when the band wouldn't be cheaper).
    """
    C, B, in_seg = bits_ext.shape
    Cs, L, n_rows, M4 = sel.shape
    M = M4 // 4
    band_m = n_rows - in_seg
    assert Cs == C, (Cs, C)
    assert in_seg % 128 == 0 and M % 128 == 0 and band_m % M == 0
    assert 0 < band_m <= n_nets_pad - in_seg, (band_m, n_nets_pad, in_seg)
    assert B % batch_tile == 0, (B, batch_tile)

    kernel = functools.partial(
        _banded_kernel, in_seg=in_seg, m_pad=M, band_m=band_m
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(C, B // batch_tile, L),
        in_specs=[
            pl.BlockSpec(
                (1, batch_tile, in_seg), lambda c, b, l, base, win: (c, b, 0)
            ),
            pl.BlockSpec(
                (1, 1, n_rows, M4), lambda c, b, l, base, win: (c, l, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, M, 16), lambda c, b, l, base, win: (c, l, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, batch_tile, n_nets_pad), lambda c, b, l, base, win: (c, b, 0)
        ),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, B, n_nets_pad), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(level_base, win_base, bits_ext.astype(jnp.float32), sel, tables)


def lut_eval_pallas(
    bits_ext: jnp.ndarray,   # (B, in_seg) f32 — [const0, const1, inputs, 0-pad]
    sel: jnp.ndarray,        # (L, N, 4*M) 0/1 selection (bf16)
    tables: jnp.ndarray,     # (L, M, 16) f32
    level_base: jnp.ndarray, # (L,) int32 — 128-aligned write offset per level
    *,
    n_nets_pad: int,
    batch_tile: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-chip evaluation: the C=1 slice of the stacked kernel.
    Returns the full padded net-value matrix (B, N) f32."""
    return lut_eval_pallas_stacked(
        bits_ext[None],
        sel[None],
        tables[None],
        level_base,
        n_nets_pad=n_nets_pad,
        batch_tile=batch_tile,
        interpret=interpret,
    )[0]


def lut_eval_pallas_banded(
    bits_ext: jnp.ndarray,   # (B, in_seg) f32
    sel: jnp.ndarray,        # (L, in_seg + K*M, 4*M) banded selection (bf16)
    tables: jnp.ndarray,     # (L, M, 16) f32
    level_base: jnp.ndarray, # (L,) int32
    win_base: jnp.ndarray,   # (L,) int32
    *,
    n_nets_pad: int,
    batch_tile: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-chip banded evaluation: the C=1 slice of the banded kernel."""
    return lut_eval_pallas_banded_stacked(
        bits_ext[None],
        sel[None],
        tables[None],
        level_base,
        win_base,
        n_nets_pad=n_nets_pad,
        batch_tile=batch_tile,
        interpret=interpret,
    )[0]
