"""Bit-sliced (bit-parallel) LUT evaluation: 32 events per uint32 word.

The classic gate-simulation trick applied to the eFPGA fabric: transpose
the event batch so ONE 32-bit word carries the same net for 32 events —
bit ``e`` of word ``w`` is event ``w*32 + e`` — and evaluate every 4-LUT
as pure bitwise mux logic over whole words:

    r_j = (s0 & t[2j+1]) | (~s0 & t[2j])        j = 0..7   (select on in0)
    q_j = (s1 & r[2j+1]) | (~s1 & r[2j])        j = 0..3   (select on in1)
    p_j = (s2 & q[2j+1]) | (~s2 & q[2j])        j = 0..1   (select on in2)
    out =  s3 ? p1 : p0                                    (select on in3)

where the 16 truth-table entries are broadcast to constant words (bit k
set for ALL lanes iff table bit k is 1) and each select word ``s_i``
muxes all 32 event lanes independently. 15 bitwise mux steps evaluate a
LUT for 32 events — the software analogue of the paper's fabric, where
every LUT is combinational logic settling each cycle.

TMR voting folds into the same bitwise pass: ``majority_vote_words``
(core.tmr) is the identity (a&b)|(a&c)|(b&c), which on sliced words
votes all 32 lanes of a net at once. That is what collapses the 8.3x
redundancy overhead of the matmul path — the vote costs 5 word ops per
output net instead of a third full evaluation's worth of bookkeeping.

Unlike the Pallas matmul path (lut_eval.py), this evaluator is plain
traceable jnp: XLA compiles it on every backend (no interpret-mode
penalty on CPU), it composes inside jit/shard_map, and the chip axis is
a leading batch dimension of one fused computation — so a multichip
stack is genuinely parallel instead of a sequential per-chip grid.

Array contract (the ``layout="bitsliced"`` packing, ops.py):
  src         (C, L, M, 4)  int32  — per-LUT source-net indices in the
                                     padded dense net layout; padded LUT
                                     slots read net 0 (const0) and carry
                                     all-zero tables, so they output 0.
  tables      (C, L, M, 16) f32    — THE scrub-loop config-memory image
                                     (core.fabric.packed_table_image),
                                     shared verbatim with the matmul
                                     layout so readback/golden-CRC and
                                     hot-swap code paths do not fork.
  output_nets (C, O)        int32  — gather indices, const0-padded.

The host-oracle twin is core.fabric.BitslicedSim (independently written
against the RAW config arrays, no padding), and the event transpose has
a numpy twin there too (pack_event_words/unpack_event_words); the
conformance suite (tests/test_bitsliced.py) holds the pair together.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.tmr import N_REPLICAS, majority_vote_words

WORD = 32
_ALL_ONES = 0xFFFFFFFF
_SIGN = 0x80000000


def pack_words(bits) -> jnp.ndarray:
    """Event-transpose: (..., B, n) 0/1 bits -> (..., W, n) uint32 words.

    W = ceil(B/32) (at least 1); bit ``e`` of word ``w`` is event
    ``w*32 + e``. Events past B land in zero tail lanes — callers mask
    or slice them back out (``unpack_words`` drops them).
    """
    bits = jnp.asarray(bits)
    B = bits.shape[-2]
    W = max(-(-B // WORD), 1)
    pad = W * WORD - B
    if pad:
        widths = [(0, 0)] * (bits.ndim - 2) + [(0, pad), (0, 0)]
        bits = jnp.pad(bits, widths)
    b = bits.reshape(bits.shape[:-2] + (W, WORD, bits.shape[-1]))
    b = b.astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)[:, None]    # (32, 1)
    # bits are 0/1 so the per-position shifted terms are disjoint powers
    # of two: sum == bitwise OR, and uint32 cannot overflow.
    return jnp.sum(b << shifts, axis=-2, dtype=jnp.uint32)


def unpack_words(words, n_events: int) -> jnp.ndarray:
    """Inverse event-transpose: (..., W, n) uint32 -> (..., B, n) uint8.

    Exact inverse of ``pack_words`` for n_events <= W*32; tail lanes
    (events >= n_events) are dropped, so whatever the evaluator computed
    for padding lanes never reaches a caller.
    """
    words = jnp.asarray(words)
    W = words.shape[-2]
    shifts = jnp.arange(WORD, dtype=jnp.uint32)[:, None]    # (32, 1)
    b = (words[..., None, :] >> shifts) & jnp.uint32(1)
    b = b.reshape(words.shape[:-2] + (W * WORD, words.shape[-1]))
    return b[..., :n_events, :].astype(jnp.uint8)


def input_words(bits, n_inputs: int, in_seg: int) -> jnp.ndarray:
    """(C, B, n_inputs) event bits -> (C, W, in_seg) input-segment words.

    Column 0 is const0 (all-zero word), column 1 const1 (all-ones word —
    including tail lanes, whose garbage outputs are sliced away on
    unpack), columns 2..2+n_inputs the transposed input bits.
    """
    C = bits.shape[0]
    words = pack_words(bits)                                # (C, W, n_in)
    W = words.shape[1]
    seg = jnp.zeros((C, W, in_seg), jnp.uint32)
    seg = seg.at[:, :, 1].set(jnp.uint32(_ALL_ONES))
    seg = seg.at[:, :, 2 : 2 + n_inputs].set(words)
    return seg


def eval_words(
    src: jnp.ndarray,          # (C, L, M, 4) int32
    tables: jnp.ndarray,       # (C, L, M, 16) f32 (0.0/1.0)
    output_nets: jnp.ndarray,  # (C, O) int32
    in_words: jnp.ndarray,     # (C, W, in_seg) uint32
) -> jnp.ndarray:
    """Levelized word evaluation: returns (C, W, O) uint32 output words.

    The net buffer mirrors the matmul layout ([const0 | const1 | inputs
    | level 0 slots | ...]); each level gathers its 4 source words per
    LUT by index and runs the 15-op mux tree. Everything is bitwise on
    uint32, so the same code is exact on every backend.
    """
    C, W, in_seg = in_words.shape
    L, M = src.shape[1], src.shape[2]
    vals = jnp.zeros((C, W, in_seg + L * M), jnp.uint32)
    vals = vals.at[:, :, :in_seg].set(in_words)
    tbl = jnp.where(
        tables > 0.5, jnp.uint32(_ALL_ONES), jnp.uint32(0)
    )                                                       # (C, L, M, 16)
    for l in range(L):
        idx = jnp.broadcast_to(
            src[:, l].reshape(C, 1, M * 4), (C, W, M * 4)
        )
        g = jnp.take_along_axis(vals, idx, axis=2).reshape(C, W, M, 4)
        t = tbl[:, l][:, None]                              # (C, 1, M, 16)
        for k in range(4):
            s = g[:, :, :, k : k + 1]                       # (C, W, M, 1)
            t = (s & t[..., 1::2]) | (~s & t[..., 0::2])
        base = in_seg + l * M
        vals = vals.at[:, :, base : base + M].set(t[..., 0])
    out_idx = output_nets[:, None, :].astype(jnp.int32)     # (C, 1, O)
    return jnp.take_along_axis(
        vals, jnp.broadcast_to(out_idx, (C, W, output_nets.shape[-1])),
        axis=2,
    )


def eval_bits(
    src: jnp.ndarray,
    tables: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,         # (C, B, n_inputs)
    *,
    n_inputs: int,
    in_seg: int,
) -> jnp.ndarray:
    """Same contract as ops.fabric_eval_bits: (C, B, O) uint8."""
    B = bits.shape[1]
    seg = input_words(bits, n_inputs, in_seg)
    return unpack_words(eval_words(src, tables, output_nets, seg), B)


def eval_bits_voted(
    src: jnp.ndarray,
    tables: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,         # (C, B, n_inputs) — per LOGICAL chip
    *,
    n_replicas: int,
    n_inputs: int,
    in_seg: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Redundant evaluation with the vote folded into the bitwise pass.

    Input words are packed ONCE per logical chip and broadcast to the
    chip's contiguous replica slots; the three replica output words are
    reduced by ``majority_vote_words`` while still sliced, and the
    per-replica disagreement signal is the OR over output nets of the
    replica-vs-vote XOR words. Same contract as
    ops.fabric_eval_bits_voted: (voted (C, B, O) uint8,
    disagree (C, R, B) bool).
    """
    B = bits.shape[1]
    voted_w, dis_w = eval_words_voted(
        src, tables, output_nets, bits,
        n_replicas=n_replicas, n_inputs=n_inputs, in_seg=in_seg,
    )
    voted = unpack_words(voted_w, B)                        # (C, B, O)
    dis = unpack_words(dis_w[..., None], B)[..., 0].astype(jnp.bool_)
    return voted, dis


def eval_words_voted(
    src: jnp.ndarray,
    tables: jnp.ndarray,
    output_nets: jnp.ndarray,
    bits: jnp.ndarray,         # (C, B, n_inputs) — per LOGICAL chip
    *,
    n_replicas: int,
    n_inputs: int,
    in_seg: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``eval_bits_voted`` stopped in the WORD domain: no event transpose.

    Returns (voted output words (C, W, O) uint32, per-replica
    disagreement words (C, R, W) uint32 — bit ``e`` set iff replica r's
    output differs from the vote for event ``w*32+e``). This is the
    serving core the word-domain sparse egress builds on: keep/drop and
    the SEU health signal can both be derived without ever unpacking
    dropped events back to event order.
    """
    C = bits.shape[0]
    seg = input_words(bits, n_inputs, in_seg)               # (C, W, in_seg)
    W = seg.shape[1]
    if n_replicas == 1:
        out_w = eval_words(src, tables, output_nets, seg)   # (C, W, O)
        return out_w, jnp.zeros((C, 1, W), jnp.uint32)
    assert n_replicas == N_REPLICAS, n_replicas
    rep = jnp.repeat(seg, n_replicas, axis=0)               # (R*C, W, seg)
    out_w = eval_words(src, tables, output_nets, rep)       # (R*C, W, O)
    O = out_w.shape[2]
    g = out_w.reshape(C, n_replicas, W, O)
    voted_w = majority_vote_words(g[:, 0], g[:, 1], g[:, 2])  # (C, W, O)
    diff = g ^ voted_w[:, None]                             # (C, R, W, O)
    dis_w = jnp.zeros((C, n_replicas, W), jnp.uint32)
    for j in range(O):
        dis_w = dis_w | diff[..., j]
    return voted_w, dis_w


# ------------------------------------------- word-domain sparse egress
# The trigger cut, the SEU disagreement counters and the egress
# compaction all computed on sliced words, so dropped events are never
# transposed back to event order (parallel.compression does the final
# popcount prefix-sum compaction over these masks).

def mask_words(mask: jnp.ndarray) -> jnp.ndarray:
    """(..., B) bool event mask -> (..., W) uint32 mask words (bit ``e``
    of word ``w`` = mask[w*32+e]; tail lanes past B are 0)."""
    return pack_words(jnp.asarray(mask, jnp.uint8)[..., None])[..., 0]


def sign_extended_planes(
    voted_w: jnp.ndarray,       # (C, W, O) uint32 output words
    out_weight: jnp.ndarray,    # (C, O) int32 two's-complement weights
) -> jnp.ndarray:
    """The 32 bit-planes of every lane's int32 score, still as words.

    Plane ``j`` (C, W) holds bit ``j`` of each event's two's-complement
    score. Chips narrower than 32 output bits sign-extend: the weight
    row encodes the sign position (the one negative weight), and every
    plane at or above it replicates that output word — exactly two's-
    complement sign extension, lane-parallel. A chip with no outputs
    (all-zero weights) reads plane 0 = const0 everywhere -> score 0.
    Returns (C, W, 32) uint32.
    """
    C, W, _ = voted_w.shape
    sign_pos = jnp.argmax(out_weight < 0, axis=-1)          # (C,) int
    j = jnp.arange(WORD)[None, None, :]                     # (1, 1, 32)
    idx = jnp.minimum(j, sign_pos[:, None, None])
    idx = jnp.broadcast_to(idx, (C, W, WORD)).astype(jnp.int32)
    return jnp.take_along_axis(voted_w, idx, axis=2)


def keep_words(
    planes: jnp.ndarray,        # (C, W, 32) sign-extended score planes
    threshold_raw: jnp.ndarray, # (C,) int32
    valid_w: jnp.ndarray,       # (C, W) uint32 valid-lane words
) -> jnp.ndarray:
    """The trigger cut computed entirely in the word domain.

    Bit-serial two's-complement compare, 32 lanes at a time: flipping
    the sign plane biases both sides to unsigned, then an MSB-down
    (lt, eq) sweep decides ``score <= threshold`` per lane in ~4 word
    ops per plane — no event transpose, no per-event integer ever
    materializes for the keep decision. Returns (C, W) uint32 keep
    words, masked by ``valid_w``.
    """
    C, W = valid_w.shape
    ones = jnp.uint32(_ALL_ONES)
    thr_u = threshold_raw.astype(jnp.uint32) ^ jnp.uint32(_SIGN)  # (C,)
    lt = jnp.zeros((C, W), jnp.uint32)
    eq = jnp.full((C, W), ones)
    for j in range(WORD - 1, -1, -1):
        a = planes[..., j]
        if j == WORD - 1:
            a = ~a                          # bias flip of the sign plane
        t_bit = (thr_u >> jnp.uint32(j)) & jnp.uint32(1)    # (C,)
        t = jnp.where(t_bit == 1, ones, jnp.uint32(0))[:, None]
        lt = lt | (eq & ~a & t)
        eq = eq & ~(a ^ t)
    return (lt | eq) & valid_w


def lane_scores(planes: jnp.ndarray) -> jnp.ndarray:
    """(C, W, 32) score planes -> (C, W, 32) int32 per-lane scores.

    A 32x32 bit transpose per word: lane ``e``'s score assembles bit
    ``e`` of every plane. Only the egress stage needs integer scores —
    and after compaction only the kept ones ship — so this is the one
    place words meet the integer domain.
    """
    lane = jnp.arange(WORD, dtype=jnp.uint32)
    b = (planes[..., None] >> lane) & jnp.uint32(1)     # (C, W, 32j, 32e)
    s = jnp.sum(b << lane[:, None], axis=-2, dtype=jnp.uint32)
    return s.astype(jnp.int32)          # uint32 wrap == two's complement


def disagree_counts_words(
    dis_w: jnp.ndarray,         # (C, R, W) uint32 disagreement words
    valid_w: jnp.ndarray,       # (C, W) uint32
) -> jnp.ndarray:
    """Per-replica voted-against event counts over valid lanes, straight
    from the word masks: popcount + sum, no unpack. Returns (C, R) int32."""
    masked = dis_w & valid_w[:, None]
    return jnp.sum(
        jax.lax.population_count(masked), axis=-1
    ).astype(jnp.int32)
