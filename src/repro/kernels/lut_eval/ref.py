"""Pure-jnp oracle for the lut_eval Pallas kernel.

Operates on the exact same packed arrays as the kernel (see ops.py for the
packing) so kernel-vs-ref comparisons are apples-to-apples; FabricSim
(numpy, core/fabric.py) provides a second, independently-written oracle.

Math (identical to the kernel):
  V    : (B, N) net values as f32 0/1, N = padded net count
  per level l:
    ins  = V_l @ S_l          S_l: (R, 4*M) one-hot selection  -> (B, 4*M)
    idx  = sum_k 2^k ins[:,k] (B, M)
    out  = one_hot(idx, 16) . T_l   T_l: (M, 16)               -> (B, M)
    V[:, base_l : base_l + M] = out

where V_l is the selection matmul's row view: the whole buffer for a dense
PackedFabric (R = N), or [input segment | K-level window at win_base[l]]
for a banded one (R = in_seg + K*m_pad).
"""
from __future__ import annotations

import jax.numpy as jnp


def fabric_eval_ref(packed, bits: jnp.ndarray) -> jnp.ndarray:
    """bits: (B, n_inputs) 0/1. Returns (B, n_outputs) uint8.

    ``packed`` is a kernels.lut_eval.ops.PackedFabric (dense or banded).
    """
    B = bits.shape[0]
    N = packed.n_nets_pad
    M = packed.m_pad
    band_m = packed.sel.shape[1] - packed.in_seg  # window rows (== N - in_seg when dense)

    v = jnp.zeros((B, N), jnp.float32)
    v = v.at[:, 1].set(1.0)  # const1
    v = v.at[:, 2 : 2 + packed.n_inputs].set(bits.astype(jnp.float32))

    for l in range(packed.n_levels):
        sel = packed.sel[l].astype(jnp.float32)        # (R, 4*M)
        if packed.banded:
            w = int(packed.win_base[l])
            v_l = jnp.concatenate(
                [v[:, : packed.in_seg], v[:, w : w + band_m]], axis=1
            )
        else:
            v_l = v
        ins = (v_l @ sel).reshape(B, 4, M)
        idx = (
            ins[:, 0] + 2.0 * ins[:, 1] + 4.0 * ins[:, 2] + 8.0 * ins[:, 3]
        ).astype(jnp.int32)                             # (B, M)
        onehot = (idx[..., None] == jnp.arange(16, dtype=jnp.int32)).astype(
            jnp.float32
        )                                               # (B, M, 16)
        out = jnp.sum(onehot * packed.tables[l][None], axis=-1)  # (B, M)
        base = int(packed.level_base[l])
        v = v.at[:, base : base + M].set(out)

    out_nets = packed.output_nets  # (n_outputs,) into padded layout
    return jnp.take(v, out_nets, axis=1).astype(jnp.uint8)
