"""Fused on-device readout frontend: frames -> features -> bits -> score.

The paper's point is data reduction *at the source*: the eFPGA sees raw
sensor charge, not pre-computed features — the whole frontend (featurize,
quantize, classify, keep/drop) lives in the readout path (PAPER.md §5).
This module is that path on TPU, as ONE jit'd dispatch with the chip axis
sharded across devices:

    frames (C, B, T, Y, X) + y0 (C, B)
      -> yprofile                 (kernels/yprofile, chip-batched Pallas)
      -> ap_fixed quantize        (core/quantize device path, int32)
      -> offset-binary bit pack   (per-chip gather plan, below)
      -> lut_eval                 (kernels/lut_eval, banded/dense Pallas)
      -> score decode + keep/drop (two's-complement weights, int32 cut)

No stage materializes on the host: the feature tensor, the bit tensor and
the net-value buffer live and die on the device. The host sees only the
(C, B) integer scores and keep mask.

Staying swap-friendly is the design constraint. Everything per-chip —
which features feed which input bit, the fixed-point spec, the output
decode weights, the trigger threshold — is carried as *dynamic* (C, ...)
arrays (the "encode plan"), never as static jit arguments. Hot-swapping a
chip is therefore an array-row update on top of
``PackedFabricStack.swap_chip``: no retrace, the same guarantee the
lut_eval stack already makes, now for the whole frontend. Input bit j of
chip c reads bit ``bit_idx[c, j]`` of feature ``feat_idx[c, j]``'s
offset-binary pattern (zeroed where j >= n_inputs_c), which turns the
host packer's reshape into a device gather that tolerates heterogeneous
specs and used-feature sets per chip.

Sharding: the chip axis is a `shard_map` over the "chips" mesh axis
(launch/mesh.py `make_readout_mesh`), so C chips spread over d | C
devices with every stage — including both Pallas kernels — running on the
local (C/d, B) slab. On a single-device host the axis has size 1: same
code path, bit-identical.

Bit-exactness vs the staged host path (yprofile materialized, numpy
quantize+pack, FabricSim) is asserted in tests/test_frontend.py; the
integer stages are exact by construction (core/quantize device-path
contract), and the featurize stage runs the identical per-tile Pallas dot
in both paths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fabric import FabricConfig, FrontendSpec
from repro.core.quantize import (
    FixedSpec,
    quantize_pattern_device,
    spec_device_params,
)
from repro.data.smartpixel import N_T, N_X, N_Y
from repro.kernels.compat import default_interpret, shard_map_compat
from repro.kernels.lut_eval import bitsliced as _bitsliced
from repro.kernels.lut_eval import ops as lut_ops
from repro.kernels.yprofile import ops as yp_ops
from repro.launch.mesh import make_readout_mesh
from repro.parallel.compression import sparse_trigger_pack_words


@dataclasses.dataclass(frozen=True)
class ChipFrontendSpec:
    """Per-chip encode/decode contract of the fused frontend.

    used_features: feature indices feeding the fabric, in input-bus order
        (SynthResult.used_features).
    spec: the chip's ap_fixed grid (int32-representable, W <= 31).
    threshold_raw: integer-domain trigger cut — keep iff score <= cut.
    """

    used_features: Tuple[int, ...]
    spec: FixedSpec
    threshold_raw: int


def default_frontend_spec(threshold_electrons: float = 800.0) -> FrontendSpec:
    """The smart-pixel featurizer contract (13 y-profile bins + y0)."""
    return FrontendSpec(
        n_features=yp_ops.N_FEATURES,
        frame_shape=(N_T, N_Y, N_X),
        threshold_electrons=threshold_electrons,
    )


def validate_chip_frontend(config: FabricConfig, cs: ChipFrontendSpec,
                           n_features: int) -> None:
    """Named, fail-fast check that a chip is encodable from the
    featurizer's output — the feature-stage half of what
    StackGeometry.admits checks for the fabric axes. Raised at pack/swap
    time (and by the server's ``reconfigure``) instead of surfacing as an
    index error inside a dispatch."""
    W = cs.spec.width
    if W > 31:
        raise ValueError(
            f"fused frontend quantizes in int32: spec width {W} > 31")
    if len(cs.used_features) * W != config.n_inputs:
        raise ValueError(
            f"encode plan mismatch: {len(cs.used_features)} used features x "
            f"W={W} bits != config n_inputs={config.n_inputs}")
    if cs.used_features and max(cs.used_features) >= n_features:
        raise ValueError(
            f"chip reads feature {max(cs.used_features)} but the featurizer "
            f"produces only {n_features}")
    if len(config.output_nets) > 31:
        raise ValueError(
            "fused frontend decodes scores in int32: "
            f"{len(config.output_nets)} output bits > 31")


def _plan_row(
    config: FabricConfig, cs: ChipFrontendSpec, J: int, O: int,
) -> Dict[str, np.ndarray]:
    """One chip's encode-plan row, zero-padded to the stack envelope."""
    W = cs.spec.width
    n_in = len(cs.used_features) * W
    assert n_in <= J and len(config.output_nets) <= O
    feat = np.zeros(J, np.int32)
    bit = np.zeros(J, np.int32)
    valid = np.zeros(J, np.int32)
    j = np.arange(n_in)
    if n_in:
        feat[:n_in] = np.asarray(cs.used_features, np.int64)[j // W]
        bit[:n_in] = j % W
        valid[:n_in] = 1
    weight = np.zeros(O, np.int64)
    n_out = len(config.output_nets)
    weight[:n_out] = 1 << np.arange(n_out)
    if n_out:
        weight[n_out - 1] = -(1 << (n_out - 1))  # two's-complement sign bit
    row = {"feat_idx": feat, "bit_idx": bit, "bit_valid": valid,
           "out_weight": weight.astype(np.int32),
           "threshold_raw": np.int32(cs.threshold_raw)}
    row.update(spec_device_params(cs.spec))
    return row


_PLAN_KEYS = ("feat_idx", "bit_idx", "bit_valid", "out_weight",
              "threshold_raw", "scale", "rnd_off", "wrap_mask", "sign_bit",
              "sat_lo", "sat_hi")


# Static args are the ENVELOPE only (never per-chip values), so hot-swaps
# and threshold updates are array swaps with no retrace — the same rule as
# lut_eval's _eval_stack_arrays.
def _score_frames_impl(
    frames: jnp.ndarray,        # (C, B, T, Y, X) f32
    y0: jnp.ndarray,            # (C, B) f32
    sel: jnp.ndarray,           # (R*C, L, rows, 4M)
    tables: jnp.ndarray,        # (R*C, L, M, 16)
    level_base: jnp.ndarray,    # (L,) shared
    win_base: jnp.ndarray,      # (L,) shared
    output_nets: jnp.ndarray,   # (R*C, O)
    plan: Dict[str, jnp.ndarray],
    valid: jnp.ndarray,         # (C, B) bool — kills padded event rows
    src: jnp.ndarray = None,    # (R*C, L, M, 4) — bit-sliced layout only
    *,
    mesh: Mesh,
    n_replicas: int,
    threshold_electrons: float,
    n_inputs: int,
    in_seg: int,
    n_nets_pad: int,
    batch_tile: int,
    interpret: bool,
    sparse: bool = False,
):
    def encode(frames, y0, plan):
        # 1. featurize: chip-batched yprofile -> (Cl, B, 128) feature cols
        feats = yp_ops.yprofile_traced(
            frames, y0, threshold=threshold_electrons,
            batch_tile=batch_tile, interpret=interpret)
        # 2. quantize every feature column to its chip's offset-binary
        #    pattern (per-chip spec params broadcast over (B, 128))
        c1 = lambda a: a[:, None, None]
        u = quantize_pattern_device(
            feats, scale=c1(plan["scale"]), rnd_off=c1(plan["rnd_off"]),
            wrap_mask=c1(plan["wrap_mask"]), sign_bit=c1(plan["sign_bit"]),
            sat_lo=c1(plan["sat_lo"]), sat_hi=c1(plan["sat_hi"]))
        # 3. pack input bits: bit j of chip c = bit bit_idx[c,j] of
        #    feature feat_idx[c,j]'s pattern (the host packer's reshape,
        #    as a gather that survives heterogeneous chips)
        taken = jnp.take_along_axis(u, plan["feat_idx"][:, None, :], axis=2)
        return jnp.bitwise_and(
            jnp.right_shift(taken, plan["bit_idx"][:, None, :]), jnp.int32(1)
        ) * plan["bit_valid"][:, None, :]

    shard = P("chips")

    if sparse:
        if src is None:
            raise ValueError(
                "sparse frame scoring needs the word domain: pack the "
                "frontend with layout='bitsliced'")

        def body_sparse(frames, y0, sel, tables, output_nets, plan, valid,
                        src):
            bits = encode(frames, y0, plan)
            # The event->word bit transpose (bitsliced.input_words) is
            # fused HERE, on device, against the just-encoded bit tensor —
            # packing never round-trips the host — and everything after it
            # stays in the word domain.
            voted_w, dis_w = _bitsliced.eval_words_voted(
                src, tables, output_nets, bits,
                n_replicas=n_replicas, n_inputs=n_inputs, in_seg=in_seg)
            return lut_ops.decode_keep_words_device(
                voted_w, dis_w, plan["out_weight"], plan["threshold_raw"],
                valid)

        keep_w, scores, dis = shard_map_compat(
            body_sparse, mesh=mesh,
            in_specs=(shard,) * 8,
            out_specs=(shard, shard, shard),
            manual_axes={"chips"},
        )(frames, y0, sel, tables, output_nets, plan, valid, src)
        # Cross-chip compaction: one ascending flat index space, so it runs
        # after the manual region but inside the same jit.
        count, idx, vals = sparse_trigger_pack_words(keep_w, scores)
        return count, idx, vals, dis

    def body(frames, y0, sel, tables, output_nets, plan, valid, src):
        bits = encode(frames, y0, plan)
        # 4. fabric evaluation on the device-resident bit tensor — on a
        #    redundant stack every replica slot evaluates here and the
        #    2-of-3 majority vote reduces them before decode; a
        #    bit-sliced stack (src not None) routes through the word
        #    evaluator with the vote folded into the bitwise pass
        outs, disagree = lut_ops.fabric_eval_bits_voted(
            sel, tables, level_base, win_base, output_nets, bits,
            n_replicas=n_replicas, n_inputs=n_inputs,
            n_nets_pad=n_nets_pad, in_seg=in_seg,
            batch_tile=batch_tile, interpret=interpret,
            src=src)                                     # (Cl, B, O) uint8
        # 5. score decode + trigger decision + SEU health counts — the
        #    SAME device tail as the features path's scoring dispatch
        return lut_ops.decode_scores_device(
            outs, disagree, plan["out_weight"], plan["threshold_raw"],
            valid)

    return shard_map_compat(
        body, mesh=mesh,
        in_specs=(shard,) * 8,
        out_specs=(shard, shard, shard),
        manual_axes={"chips"},
    )(frames, y0, sel, tables, output_nets, plan, valid, src)


_SCORE_STATICS = ("mesh", "n_replicas", "threshold_electrons", "n_inputs",
                  "in_seg", "n_nets_pad", "batch_tile", "interpret", "sparse")

_score_frames = functools.partial(
    jax.jit, static_argnames=_SCORE_STATICS,
)(_score_frames_impl)

# The zero-copy serving twin: frames and y0 — by far the largest inflight
# buffers, (C, B, T, Y, X) f32 — are DONATED, so XLA reuses their device
# memory for intermediates instead of holding both live across the
# dispatch. The caller must treat the exact arrays it passed as dead
# (the readout server stages fresh buffers per dispatch, so serving is
# always donation-safe). Donation is a no-op with a warning on backends
# that don't implement it (CPU), hence the separate twin — pack_frontend
# selects it per backend.
_score_frames_donated = functools.partial(
    jax.jit, static_argnames=_SCORE_STATICS, donate_argnums=(0, 1),
)(_score_frames_impl)


@dataclasses.dataclass(frozen=True)
class FusedFrontend:
    """N configured chips' whole frontends, one sharded device dispatch.

    Built by ``pack_frontend``; ``score_frames`` launches asynchronously
    (JAX dispatch) and returns device arrays — the readout server keeps
    batches in flight and materializes late (triple buffering).
    """

    stack: lut_ops.PackedFabricStack
    chip_specs: Tuple[ChipFrontendSpec, ...]
    plan: Dict[str, jnp.ndarray]        # (C, ...) dynamic encode plan
    mesh: Mesh
    batch_tile: int
    threshold_electrons: float
    interpret: bool
    # Donate (frames, y0) to the dispatch: zero-copy, but the arrays a
    # caller passed to score_frames* are DEAD afterwards — reuse is an
    # error. False on backends without donation support (CPU).
    donate: bool = False

    @property
    def n_chips(self) -> int:
        return self.stack.n_chips

    @property
    def n_replicas(self) -> int:
        """TMR replica slots per chip (1 = no redundancy)."""
        return self.stack.n_replicas

    @property
    def spec(self) -> FrontendSpec:
        """The feature-stage contract (StackGeometry.frontend metadata)."""
        return default_frontend_spec(self.threshold_electrons)

    def score_frames(
        self, frames, y0
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(C, B, T, Y, X) charge + (C, B) y0 -> ((C, B) int32 raw scores,
        (C, B) bool keep). One dispatch; results are NOT materialized —
        ``np.asarray`` them (or let the server drain) to block. On a
        redundant stack the scores are decoded from the majority-voted
        output word; ``score_frames_voted`` also exposes the per-replica
        disagreement counters."""
        score, keep, _ = self.score_frames_voted(frames, y0)
        return score, keep

    def score_frames_voted(
        self, frames, y0, valid=None
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Like ``score_frames`` but also returns the SEU health signal:
        disagree_counts (C, n_replicas) int32 — events (among ``valid``
        rows; None = all rows) where that replica's output word was voted
        against. All-zero on a healthy (or non-redundant) stack.

        With ``donate=True`` the (frames, y0) device buffers are consumed
        by the dispatch: do not reuse the exact arrays passed in."""
        score, keep, dis = self._dispatch(frames, y0, valid, sparse=False)
        B = np.shape(frames)[1]
        return score[:, :B], keep[:, :B], dis

    def score_frames_sparse(
        self, frames, y0, valid=None
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Word-domain sparse egress form of ``score_frames_voted``
        (bit-sliced stacks only): the trigger cut, SEU counters and the
        popcount prefix-sum compaction all run on sliced words inside the
        SAME fused dispatch — dropped events are never transposed back to
        event order, and only the kept prefix need cross the host link.

        Returns (count () int32, idx (C*B,) int32 ascending flat indices
        ``chip*B + event`` -1 padded, vals (C*B,) int32 kept scores 0
        padded, disagree_counts (C, R) int32) — the
        ``parallel.compression.sparse_trigger_pack`` wire format. Results
        are NOT materialized; slice ``idx[:count]`` on device before
        np.asarray to ship exactly the kept events (the server's drain
        does). Same donation invariant as ``score_frames_voted``."""
        C, B = np.shape(frames)[0], np.shape(frames)[1]
        count, idx, vals, dis = self._dispatch(frames, y0, valid,
                                               sparse=True)
        Bp = -(-max(B, 1) // self.batch_tile) * self.batch_tile
        if Bp != B:
            # Kept lanes sit below B (``valid`` kills the pad tail):
            # restride tile-padded flat indices to the caller's batch.
            idx = jnp.where(idx >= 0, (idx // Bp) * B + (idx % Bp), -1)
            idx = idx[: C * B]
            vals = vals[: C * B]
        return count, idx, vals, dis

    def _dispatch(self, frames, y0, valid, *, sparse: bool):
        frames = jnp.asarray(frames, jnp.float32)
        y0 = jnp.asarray(y0, jnp.float32)
        C, B = frames.shape[0], frames.shape[1]
        assert C == self.n_chips, (C, self.n_chips)
        if valid is None:
            valid = jnp.ones((C, B), jnp.bool_)
        else:
            valid = jnp.asarray(valid, jnp.bool_)
        Bp = (max(B, 1) + self.batch_tile - 1) // self.batch_tile
        Bp *= self.batch_tile
        if Bp != B:
            pad = ((0, 0), (0, Bp - B))
            frames = jnp.pad(frames, pad + ((0, 0),) * 3)
            y0 = jnp.pad(y0, pad)
            valid = jnp.pad(valid, pad)
        s = self.stack
        fn = _score_frames_donated if self.donate else _score_frames
        return fn(
            frames, y0, s.sel, s.tables, s.level_base, s.win_base,
            s.output_nets, self.plan, valid, s.src,
            mesh=self.mesh, n_replicas=s.n_replicas,
            threshold_electrons=self.threshold_electrons,
            n_inputs=s.n_inputs, in_seg=s.in_seg, n_nets_pad=s.n_nets_pad,
            batch_tile=self.batch_tile, interpret=self.interpret,
            sparse=sparse)

    def swap_chip(
        self, slot: int, config: FabricConfig, chip_spec: ChipFrontendSpec,
        stack: Optional[lut_ops.PackedFabricStack] = None,
    ) -> "FusedFrontend":
        """Hot-swap one chip's whole frontend: fabric arrays via
        PackedFabricStack.swap_chip plus this stack's encode-plan row —
        all dynamic, so the compiled dispatch is reused as-is. A caller
        that already swapped its own shared stack (the readout server)
        passes it via ``stack`` so the arrays are rebuilt once, not
        twice."""
        validate_chip_frontend(config, chip_spec, self.spec.n_features)
        if stack is None:
            stack = self.stack.swap_chip(slot, config)
        row = _plan_row(config, chip_spec, stack.n_inputs, stack.n_outputs)
        plan = {
            k: self.plan[k].at[slot].set(jnp.asarray(row[k]))
            for k in _PLAN_KEYS
        }
        specs = list(self.chip_specs)
        specs[slot] = chip_spec
        return dataclasses.replace(
            self, stack=stack, plan=plan, chip_specs=tuple(specs))

    def set_threshold(self, slot: int, threshold_raw: int) -> "FusedFrontend":
        """Retarget one chip's trigger cut (array-row update, no repack)."""
        specs = list(self.chip_specs)
        specs[slot] = dataclasses.replace(
            specs[slot], threshold_raw=int(threshold_raw))
        plan = dict(self.plan)
        plan["threshold_raw"] = self.plan["threshold_raw"].at[slot].set(
            jnp.int32(threshold_raw))
        return dataclasses.replace(self, plan=plan, chip_specs=tuple(specs))


def pack_frontend(
    configs: Sequence[FabricConfig],
    chip_specs: Sequence[ChipFrontendSpec],
    *,
    band: Optional[bool] = None,
    redundancy: str = "none",
    layout: str = "matmul",
    batch_tile: int = 128,
    threshold_electrons: float = 800.0,
    mesh: Optional[Mesh] = None,
    interpret: Optional[bool] = None,
    stack: Optional[lut_ops.PackedFabricStack] = None,
    donate: Optional[bool] = None,
) -> FusedFrontend:
    """Pack N (config, frontend-spec) pairs into one fused dispatch.

    ``band``/``layout``/``batch_tile`` feed the lut_eval stage exactly as
    in ``pack_fabrics`` (layout="bitsliced" routes the fabric stage
    through the 32-events-per-word evaluator with the TMR vote folded
    into the bitwise pass); ``batch_tile`` is also the featurizer tile, so the
    staged comparison path must featurize with the same tile to stay
    bit-identical (ScoringBackend.score_frames does). ``mesh`` defaults
    to launch.mesh.make_readout_mesh(len(configs)). A caller that already
    packed the configs (the readout server's lut_eval stack) shares the
    arrays via ``stack`` instead of packing them a second time.

    ``redundancy="tmr"`` serves every chip as three placement-distinct
    replica encodings voted on device (see lut_eval.ops.pack_fabrics);
    the encode plan stays per logical chip — featurize/quantize/pack run
    once per chip, only the fabric stage is triplicated.

    ``donate`` (None = auto: on wherever the backend implements buffer
    donation, i.e. everywhere but CPU) makes the dispatch CONSUME the
    (frames, y0) buffers — zero-copy inflight staging. Callers must not
    reuse the exact arrays they passed to ``score_frames*`` afterwards;
    the readout server stages fresh buffers per dispatch, so serving is
    always donation-safe.
    """
    if len(configs) != len(chip_specs):
        raise ValueError(f"{len(configs)} configs vs {len(chip_specs)} specs")
    n_features = default_frontend_spec(threshold_electrons).n_features
    for config, cs in zip(configs, chip_specs):
        validate_chip_frontend(config, cs, n_features)
    if stack is None:
        stack = lut_ops.pack_fabrics(
            list(configs), band=band, redundancy=redundancy, layout=layout)
    elif redundancy != "none" and stack.n_replicas == 1:
        raise ValueError(
            f"redundancy={redundancy!r} but the shared stack is not "
            "redundant — pack it with pack_fabrics(redundancy=...)")
    assert stack.n_chips == len(configs), (stack.n_chips, len(configs))
    rows = [
        _plan_row(c, cs, stack.n_inputs, stack.n_outputs)
        for c, cs in zip(configs, chip_specs)
    ]
    plan = {
        k: jnp.asarray(np.stack([r[k] for r in rows])) for k in _PLAN_KEYS
    }
    return FusedFrontend(
        stack=stack,
        chip_specs=tuple(chip_specs),
        plan=plan,
        mesh=mesh if mesh is not None else make_readout_mesh(len(configs)),
        batch_tile=batch_tile,
        threshold_electrons=float(threshold_electrons),
        interpret=default_interpret() if interpret is None else interpret,
        donate=(jax.default_backend() != "cpu") if donate is None
        else bool(donate),
    )
