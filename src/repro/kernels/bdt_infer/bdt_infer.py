"""Pallas TPU kernel: quantized BDT ensemble inference.

This is the *performance* path for at-source classification (the fabric
kernel lut_eval is the *fidelity* path — bit-identical to the silicon). The
tree ensemble is evaluated node-parallel with one-hot matmuls instead of
pointer-chasing gathers, the TPU-native reformulation of tree traversal
(DESIGN.md §3):

  * all trees traverse simultaneously: the padded node axis P concatenates
    every tree's nodes (block-diagonal child matrices), the initial one-hot
    marks every root;
  * per depth step: route the one-hot mass left/right with two (B,P)x(P,P)
    MXU matmuls; leaves self-loop so depth-D traversal is exact for any
    tree shape;
  * feature lookup: 14 static broadcast-multiply-accumulate steps in int32
    on the VPU (raw fixed-point values up to 2^27 exceed f32's exact-int
    range, so the compare side stays integer);
  * leaf readout: value matmuls split into 14-bit halves so f32 stays
    integer-exact; scores come back as exact int32 raw fixed-point.

Block shapes: B_TILE x P with P = 128-padded node count (a depth-5 tree has
<= 63 nodes, so one lane group handles 2 trees' worth; the paper's single
tree uses P=128). Whole node table + child matrices live in VMEM:
P=128: 2 * 128x128x4B = 128 KiB. Batch is the only blocked axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(
    x_ref, featsel_ref, thr_ref, root_ref, left_ref, right_ref,
    vhi_ref, vlo_ref, out_ref, *, depth: int, n_features: int,
):
    x = x_ref[...]                       # (B, F) int32
    featsel = featsel_ref[...]           # (F, P) int32 0/1
    B = x.shape[0]
    P = featsel.shape[1]

    # fval[b, p] = x[b, feature(p)] — static MAC loop, exact int32.
    fval = jnp.zeros((B, P), jnp.int32)
    for f in range(n_features):
        fval = fval + x[:, f : f + 1] * featsel[f : f + 1, :]

    cond = (fval <= thr_ref[...]).astype(jnp.float32)      # (B, P)
    h = jnp.broadcast_to(root_ref[...], (B, P)).astype(jnp.float32)

    left = left_ref[...].astype(jnp.float32)
    right = right_ref[...].astype(jnp.float32)
    for _ in range(depth):
        go_l = h * cond
        go_r = h - go_l  # h * (1 - cond), one fewer multiply
        h = jax.lax.dot(go_l, left, preferred_element_type=jnp.float32)
        h = h + jax.lax.dot(go_r, right, preferred_element_type=jnp.float32)

    hi = jax.lax.dot(h, vhi_ref[...], preferred_element_type=jnp.float32)
    lo = jax.lax.dot(h, vlo_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = (hi.astype(jnp.int32) << 14) + lo.astype(jnp.int32)


def bdt_infer_pallas(
    x_raw: jnp.ndarray,      # (B, F) int32
    featsel: jnp.ndarray,    # (F, P) int32
    thr: jnp.ndarray,        # (1, P) int32  (+inf-like for leaves/pad)
    root_onehot: jnp.ndarray,  # (1, P) f32
    left: jnp.ndarray,       # (P, P) f32 0/1 (leaves self-loop)
    right: jnp.ndarray,      # (P, P) f32 0/1
    value_hi: jnp.ndarray,   # (P, 128) f32 — leaf value >> 14, col 0
    value_lo: jnp.ndarray,   # (P, 128) f32 — leaf value & 0x3FFF, col 0
    *,
    depth: int,
    batch_tile: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, 128) int32; column 0 holds Σ_trees leaf_value (no f0)."""
    B, F = x_raw.shape
    P = featsel.shape[1]
    assert B % batch_tile == 0

    kernel = functools.partial(_kernel, depth=depth, n_features=F)
    return pl.pallas_call(
        kernel,
        grid=(B // batch_tile,),
        in_specs=[
            pl.BlockSpec((batch_tile, F), lambda b: (b, 0)),
            pl.BlockSpec((F, P), lambda b: (0, 0)),
            pl.BlockSpec((1, P), lambda b: (0, 0)),
            pl.BlockSpec((1, P), lambda b: (0, 0)),
            pl.BlockSpec((P, P), lambda b: (0, 0)),
            pl.BlockSpec((P, P), lambda b: (0, 0)),
            pl.BlockSpec((P, 128), lambda b: (0, 0)),
            pl.BlockSpec((P, 128), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile, 128), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 128), jnp.int32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)
        ),
    )(x_raw, featsel, thr, root_onehot, left, right, value_hi, value_lo)
