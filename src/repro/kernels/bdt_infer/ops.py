"""Packing + jit'd wrapper for the bdt_infer kernel.

``pack_ensemble`` lays every tree of a QuantizedEnsemble into one padded
node axis (block-diagonal traversal — see bdt_infer.py); ``bdt_infer`` runs
raw fixed-point features through the ensemble and returns exact int32 raw
scores, bit-identical to QuantizedEnsemble.decision_function_raw.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bdt import LEAF, QuantizedEnsemble
from repro.kernels.bdt_infer.bdt_infer import bdt_infer_pallas
from repro.kernels.compat import default_interpret as _default_interpret


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PackedEnsemble:
    featsel: jnp.ndarray      # (F, P) int32
    thr: jnp.ndarray          # (1, P) int32
    root_onehot: jnp.ndarray  # (1, P) f32
    left: jnp.ndarray         # (P, P) f32
    right: jnp.ndarray        # (P, P) f32
    value_hi: jnp.ndarray     # (P, 128) f32
    value_lo: jnp.ndarray     # (P, 128) f32
    f0_raw: int = dataclasses.field(metadata=dict(static=True))
    depth: int = dataclasses.field(metadata=dict(static=True))
    n_features: int = dataclasses.field(metadata=dict(static=True))
    width: int = dataclasses.field(metadata=dict(static=True))


def pack_ensemble(ens: QuantizedEnsemble, n_features: int) -> PackedEnsemble:
    if ens.spec.width > 31:
        raise ValueError("kernel path needs raw values in int32 (W <= 31)")
    sizes = [t.n_nodes for t in ens.trees]
    P = _round_up(sum(sizes), 128)
    depth = max(t.depth() for t in ens.trees)

    featsel = np.zeros((n_features, P), np.int32)
    thr = np.full((1, P), np.iinfo(np.int32).max, np.int32)
    root = np.zeros((1, P), np.float32)
    left = np.zeros((P, P), np.float32)
    right = np.zeros((P, P), np.float32)
    value = np.zeros(P, np.int64)

    off = 0
    for t in ens.trees:
        root[0, off] = 1.0
        for i in range(t.n_nodes):
            p = off + i
            f = int(t.feature[i])
            if f == LEAF:
                left[p, p] = 1.0   # self-loop
                right[p, p] = 1.0
                value[p] = int(t.value_raw[i])
            else:
                featsel[f, p] = 1
                thr[0, p] = int(t.threshold_raw[i])
                left[p, off + int(t.children_left[i])] = 1.0
                right[p, off + int(t.children_right[i])] = 1.0
        off += t.n_nodes
    for p in range(off, P):  # padding slots absorb
        left[p, p] = 1.0
        right[p, p] = 1.0

    vhi = np.zeros((P, 128), np.float32)
    vlo = np.zeros((P, 128), np.float32)
    vhi[:, 0] = (value >> 14).astype(np.float32)
    vlo[:, 0] = (value & 0x3FFF).astype(np.float32)

    return PackedEnsemble(
        featsel=jnp.asarray(featsel),
        thr=jnp.asarray(thr),
        root_onehot=jnp.asarray(root),
        left=jnp.asarray(left),
        right=jnp.asarray(right),
        value_hi=jnp.asarray(vhi),
        value_lo=jnp.asarray(vlo),
        f0_raw=int(ens.f0_raw),
        depth=int(depth),
        n_features=int(n_features),
        width=int(ens.spec.width),
    )


@functools.partial(jax.jit, static_argnames=("batch_tile", "interpret"))
def _infer_packed(packed, x_raw, *, batch_tile, interpret):
    out = bdt_infer_pallas(
        x_raw,
        packed.featsel, packed.thr, packed.root_onehot,
        packed.left, packed.right, packed.value_hi, packed.value_lo,
        depth=packed.depth,
        batch_tile=batch_tile,
        interpret=interpret,
    )
    return out[:, 0] + jnp.int32(packed.f0_raw)


def bdt_infer(
    packed_or_ens,
    x_raw,
    n_features: int | None = None,
    batch_tile: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(B, F) int32 raw features -> (B,) exact int32 raw scores."""
    packed = (
        packed_or_ens
        if isinstance(packed_or_ens, PackedEnsemble)
        else pack_ensemble(packed_or_ens, n_features)
    )
    if interpret is None:
        interpret = _default_interpret()
    x_raw = jnp.asarray(x_raw, jnp.int32)
    B = x_raw.shape[0]
    Bp = _round_up(max(B, 1), batch_tile)
    if Bp != B:
        x_raw = jnp.pad(x_raw, ((0, Bp - B), (0, 0)))
    out = _infer_packed(packed, x_raw, batch_tile=batch_tile, interpret=interpret)
    return out[:B]
