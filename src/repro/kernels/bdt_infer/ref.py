"""Pure-jnp oracle for the bdt_infer Pallas kernel.

Same packed arrays, same math (one-hot node-parallel traversal), written
with plain jnp ops. core.bdt.QuantizedEnsemble.decision_function_raw is the
second, independently-written (numpy, gather-based) oracle.

Traversal, all trees at once (block-diagonal in the padded node axis P):
  h_0[p]  = 1 iff p is a root
  fval    = Σ_f X[:, f] * featsel[f, :]          (B, P) int32, exact
  cond    = fval <= thr                           (B, P)
  h_{d+1} = (h_d * cond) @ L  +  (h_d * !cond) @ R
  score   = Σ_p h_D[p] * value[p]  (split into hi/lo 14-bit halves so the
            f32 matmuls stay integer-exact; |value_raw| < 2^27)
"""
from __future__ import annotations

import jax.numpy as jnp


def bdt_infer_ref(packed, x_raw: jnp.ndarray) -> jnp.ndarray:
    """x_raw: (B, n_features) int32 raw fixed-point. -> (B,) int32 scores."""
    B = x_raw.shape[0]
    P = packed.featsel.shape[1]

    fval = (x_raw.astype(jnp.int32) @ packed.featsel.astype(jnp.int32))  # (B, P)
    cond = (fval <= packed.thr).astype(jnp.float32)
    h = jnp.broadcast_to(packed.root_onehot, (B, P)).astype(jnp.float32)

    for _ in range(packed.depth):
        go_l = h * cond
        go_r = h * (1.0 - cond)
        h = go_l @ packed.left.astype(jnp.float32) + go_r @ packed.right.astype(
            jnp.float32
        )

    hi = (h @ packed.value_hi.astype(jnp.float32)).astype(jnp.int32)[:, 0]
    lo = (h @ packed.value_lo.astype(jnp.float32)).astype(jnp.int32)[:, 0]
    return packed.f0_raw + (hi << 14) + lo
