"""JAX-version compatibility for the Pallas kernels.

This container family spans JAX releases; the TPU compiler-params class
was renamed (TPUCompilerParams -> CompilerParams). One shim, imported by
every kernel, instead of a per-file getattr.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
