"""JAX-version compatibility + shared defaults for the Pallas kernels.

This container family spans JAX releases; everything release-dependent the
kernels need lives here, once, instead of a per-file getattr:

  * ``CompilerParams``: the TPU compiler-params class was renamed
    (TPUCompilerParams -> CompilerParams).
  * ``default_interpret()``: the shared interpret-mode default — every
    kernel wrapper runs interpret everywhere except a real TPU backend.
    One helper (not three per-kernel copies) so a future backend gains
    compiled support in exactly one place.
  * ``shard_map_compat()``: newer JAX exposes ``jax.shard_map`` with
    partial-manual ``axis_names``; on older releases only
    ``jax.experimental.shard_map.shard_map`` exists, and its
    partial-manual form (``auto=...``) trips an XLA partitioner check, so
    we fall back to a fully-manual region there (axes not named in
    ``manual_axes`` are simply replicated through the body). Used by the
    fused readout frontend (kernels/frontend.py) to shard the chip axis
    and by the compressed gradient all-reduce (parallel/compression.py).
"""
from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_HAS_PARTIAL_MANUAL = hasattr(jax, "shard_map")


def default_interpret() -> bool:
    """Pallas kernels interpret everywhere but TPU (Mosaic)."""
    return jax.default_backend() != "tpu"


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes):
    """``jax.shard_map`` across JAX versions (see module docstring)."""
    if _HAS_PARTIAL_MANUAL:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=frozenset(manual_axes), check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
