"""Roofline-term extraction from compiled SPMD artifacts.

``cost_analysis()`` gives per-device HLO FLOPs and HBM bytes, but XLA does
not report collective traffic — we parse the compiled HLO text and convert
each collective op into per-device *wire bytes* under the standard ring
algorithm:

    all-gather         (g-1)/g * result_bytes
    all-reduce         2 (g-1)/g * result_bytes     (reduce-scatter + all-gather)
    reduce-scatter     (g-1) * result_bytes          (operand = g * result)
    all-to-all         (g-1)/g * result_bytes
    collective-permute result_bytes

where g is the replica-group size parsed from ``replica_groups=[n,g]<=[...]``
(iota form) or explicit group lists. ``-start`` async forms are counted,
``-done`` forms are not (same transfer). Wire bytes / ICI link bandwidth =
the collective roofline term (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(?P<shapes>\(?[\w\[\],{}\s/*]+?\)?)\s+"
    r"(?P<op>all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\(",
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0            # per-device ring wire bytes
    result_bytes: float = 0.0
    count: int = 0
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    ops: List[Tuple[str, int, int]] = dataclasses.field(default_factory=list)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m is None:
            continue
        op = m.group("op").replace("-start", "")
        rb = _shape_bytes(m.group("shapes"))
        g = _group_size(line, n_devices)
        if op == "all-reduce":
            wire = 2.0 * rb * (g - 1) / max(g, 1)
        elif op == "all-gather":
            wire = rb * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = rb * (g - 1)
        elif op == "all-to-all":
            wire = rb * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = float(rb)
        stats.wire_bytes += wire
        stats.result_bytes += rb
        stats.count += 1
        stats.by_op[op] = stats.by_op.get(op, 0.0) + wire
        stats.ops.append((op, rb, g))
    return stats


def summarize_compiled(compiled, n_devices: int) -> Dict:
    """cost_analysis + memory_analysis + collective parse -> plain dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some versions return [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        mem["peak_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"]
        )
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    coll = parse_collectives(compiled.as_text(), n_devices)
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_wire_bytes_per_device": coll.wire_bytes,
        "collective_result_bytes": coll.result_bytes,
        "collective_count": coll.count,
        "collective_by_op": coll.by_op,
        "memory": mem,
    }
