"""Sharding rules: param/optimizer/cache/batch PartitionSpecs per arch.

Profiles (chosen per arch by size — DESIGN.md §5):

  * "tp"       (7B–16B dense/MoE): tensor parallel over "model"; params
                replicated over "data"; batch/activations over ("pod","data").
  * "tp_fsdp"  (>=70B): TP over "model" + ZeRO-3/FSDP over "data" — every
                matrix sharded on two axes; optimizer state inherits the
                same specs (sharded optimizer = ZeRO).
  * "dp"       (<3B: mamba2, zamba2, whisper): params replicated; pure data
                parallel. The roofline table shows what this leaves on the
                table — TP-izing these is a §Perf hillclimb lever.

MoE experts always shard over "model" (expert parallelism); the "pod" axis
is pure DP (gradient all-reduce crosses the DCN — that is where the paper's
at-source compression idea lands, parallel/compression.py).

KV caches shard batch→("pod","data") and heads→"model" when divisible,
falling back to head_dim→"model" (GQA with few KV heads), else replicated.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec

PyTree = Any


def profile_of(cfg: ArchConfig) -> str:
    if cfg.pure_fsdp:
        return "fsdp_pure"
    n = cfg.param_count()
    if n < 3e9:
        return "dp"
    return "tp_fsdp" if cfg.fsdp else "tp"


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _model_dim(mesh: Mesh) -> int:
    return mesh.shape["model"]


def spec_for_param(cfg: ArchConfig, mesh: Mesh, path: str, shape) -> P:
    """PartitionSpec for one parameter leaf (path is '/'-joined)."""
    ndim = len(shape)
    prof = profile_of(cfg)
    if prof == "dp":
        return P()
    if prof == "fsdp_pure":
        # ZeRO-3: shard the largest divisible dim over every mesh axis;
        # weights all-gather per layer at use time, no tensor parallelism.
        axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        n = _prod(mesh, axes)
        for d in range(ndim - 1, -1, -1):
            if shape[d] % n == 0 and shape[d] >= n:
                parts = [None] * ndim
                parts[d] = axes
                return P(*parts)
        return P()
    fsdp = "data" if prof == "tp_fsdp" else None

    def last_two(a, b):
        # stacked leaves carry a leading layer axis -> None-pad on the left
        return P(*([None] * (ndim - 2) + [a, b]))

    if "embed/tok" in path:
        return P("model", fsdp)
    if "lm_head" in path:
        return P(fsdp, "model")
    # MoE experts: (L, E, D, F) / (L, E, F, D). Expert-parallel over "model"
    # when E divides; few-big-expert models (grok: E=8 < 16) fall back to
    # intra-expert TP on the FFN dim.
    if "moe/w_up" in path or "moe/w_gate" in path:
        if shape[1] % _model_dim(mesh) == 0:
            return P(None, "model", fsdp, None)
        return P(None, None, fsdp, "model")
    if "moe/w_down" in path:
        if shape[1] % _model_dim(mesh) == 0:
            return P(None, "model", None, fsdp)
        return P(None, None, "model", fsdp)
    if "moe/router" in path:
        return P(None, fsdp, None)
    if "moe/shared" in path:
        if "w_down" in path:
            return last_two("model", fsdp)
        return last_two(fsdp, "model")
    # attention / dense MLP
    if any(k in path for k in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj")):
        return last_two(fsdp, "model")
    if any(k in path for k in ("wo", "w_down", "out_proj")):
        return last_two("model", fsdp)
    # SSM small tensors, norms, biases, scalars
    return P(*([None] * ndim))


def param_specs(cfg: ArchConfig, mesh: Mesh, params_shape: PyTree) -> PyTree:
    def f(path, leaf):
        return spec_for_param(cfg, mesh, _path_str(path), leaf.shape)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def _zero1_spec(mesh: Mesh, spec: P, shape) -> P:
    """ZeRO-1: shard a moment leaf over every mesh axis the param spec
    leaves unused, picking divisible dims (moments are pure elementwise
    state — any sharding is valid, so use ALL the silicon)."""
    used = set()
    for s in spec:
        if isinstance(s, (tuple, list)):
            used.update(s)
        elif s is not None:
            used.add(s)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for axis in ("data", "model", "pod"):
        if axis not in mesh.axis_names or axis in used:
            continue
        n = mesh.shape[axis]
        for d in range(len(shape) - 1, -1, -1):
            if parts[d] is None and shape[d] % n == 0 and shape[d] >= n:
                parts[d] = axis
                used.add(axis)
                break
    return P(*parts)


def grad_specs(cfg: ArchConfig, mesh: Mesh, params_shape: PyTree) -> PyTree:
    """ZeRO-2 gradient sharding: gradients (and the microbatch accumulator)
    shard over every mesh axis the parameter leaves idle. For a dp-profile
    arch this turns N replicated f32 gradient copies into N/256 shards; for
    TP archs it reduce-scatters the data axis. Pure win: the all-reduce the
    baseline would do becomes reduce-scatter (+ all-gather folded into the
    optimizer's param update)."""

    def f(path, leaf):
        base = spec_for_param(cfg, mesh, _path_str(path), leaf.shape)
        return _zero1_spec(mesh, base, leaf.shape)

    return jax.tree_util.tree_map_with_path(f, params_shape)


def opt_state_specs(cfg: ArchConfig, mesh: Mesh, opt_shape: PyTree,
                    pspecs: PyTree) -> PyTree:
    """Optimizer-state specs derived from parameter specs.

    adamw:     {"m": ZeRO-1(params), "v": ZeRO-1(params), "step": P()}
    adafactor: {"v": {leafwise {"vr": spec[:-1], "vc": spec[:-2]+[-1]}}, ...}

    Moments get ZeRO-1 treatment: sharded over the mesh axes the parameter
    itself doesn't use (for a pure-TP 14B model this turns 2x 56 GB of
    replicated f32 moments into 2x 3.5 GB per device).
    """
    if "m" in opt_shape:  # adamw
        mspecs = jax.tree_util.tree_map_with_path(
            lambda path, leaf: _zero1_spec(
                mesh,
                spec_for_param(cfg, mesh, _path_str(path), leaf.shape),
                leaf.shape,
            ),
            opt_shape["m"],
        )
        return {"m": mspecs, "v": mspecs, "step": P()}

    flat_p, tdef = jax.tree.flatten(pspecs)

    def fac(spec_and_leaf):
        spec, leaf = spec_and_leaf
        parts = list(spec)
        if isinstance(leaf, dict) and "vr" in leaf:
            nd_r = len(leaf["vr"].shape)
            nd_c = len(leaf["vc"].shape)
            parts_full = parts + [None] * (nd_r + 1 - len(parts))
            return {
                "vr": P(*parts_full[:nd_r]),
                "vc": P(*(parts_full[: nd_c - 1] + parts_full[nd_r:nd_r + 1])),
            }
        return {"v": P(*parts) if parts else P()}

    # walk the opt "v" tree in parallel with param specs
    v_leaves = tdef.flatten_up_to(opt_shape["v"])
    out_v = [fac((s, l)) for s, l in zip(flat_p, v_leaves)]
    return {"v": jax.tree.unflatten(tdef, out_v), "step": P()}


def _prod(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def batch_dim(cfg: ArchConfig, mesh: Mesh, global_batch: int):
    """Mesh axes carrying the batch dim.

    dp-profile archs (params replicated) data-parallel over EVERY axis when
    divisible — leaving "model" idle for a 130M model wastes 16/17 of the
    pod. TP profiles keep "model" for weights and use ("pod","data")."""
    cands = []
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    if profile_of(cfg) in ("dp", "fsdp_pure"):
        cands.append(all_axes)
    cands.append(dp_axes(mesh))
    cands.append(("data",))
    for c in cands:
        if global_batch % max(_prod(mesh, c), 1) == 0:
            return c if len(c) > 1 else c[0]
    return None


def batch_specs(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec) -> PyTree:
    """Input batch specs."""
    bdim = batch_dim(cfg, mesh, shape.global_batch)
    if cfg.family == "vlm" or cfg.embeds_in:
        return {"embeds": P(bdim, None, None), "labels": P(bdim, None)}
    if cfg.family == "encdec":
        return {
            "enc_embeds": P(bdim, None, None),
            "tokens": P(bdim, None),
            "labels": P(bdim, None),
        }
    return {"tokens": P(bdim, None), "labels": P(bdim, None)}


def _uses_model(bdim) -> bool:
    if bdim is None:
        return False
    if isinstance(bdim, str):
        return bdim == "model"
    return "model" in bdim


def _kv_spec(cfg: ArchConfig, mesh: Mesh, bdim) -> P:
    """(n_stack, B, T, KV, hd) cache spec."""
    m = _model_dim(mesh)
    if _uses_model(bdim):  # all-axis DP already consumes "model"
        return P(None, bdim, None, None, None)
    if cfg.n_kv_heads % m == 0:
        return P(None, bdim, None, "model", None)
    if cfg.resolved_head_dim() % m == 0:
        return P(None, bdim, None, None, "model")
    return P(None, bdim, None, None, None)


def cache_specs(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec,
                cache_shape: PyTree) -> PyTree:
    bdim = batch_dim(cfg, mesh, shape.global_batch)
    kv = _kv_spec(cfg, mesh, bdim)
    m = _model_dim(mesh)

    def f(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if p in ("k", "v", "cross_k", "cross_v"):
            return kv
        if p in ("k_scale", "v_scale"):  # (L, B, T)
            return P(None, bdim, None)
        if p == "ssm":  # (L, B, H, P, N)
            d_in_heads = leaf.shape[2]
            if not _uses_model(bdim) and d_in_heads % m == 0:
                return P(None, bdim, "model", None, None)
            return P(None, bdim, None, None, None)
        if p == "conv":  # (L, B, K-1, C)
            return P(None, bdim, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def decode_tokens_spec(cfg: ArchConfig, mesh: Mesh, shape: ShapeSpec) -> P:
    bdim = batch_dim(cfg, mesh, shape.global_batch)
    if cfg.family == "vlm" or cfg.embeds_in:
        return P(bdim, None, None)
    return P(bdim, None)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
