"""At-source compression before the expensive link (the paper's core insight
carried into the distributed runtime — DESIGN.md §3).

The paper reduces detector data *on the sensor ASIC* because transmission is
the scarce resource. In a multi-pod trainer the analogous scarce resource is
the cross-pod (DCN) link crossed by the gradient all-reduce. We compress at
the source: per-pod partial gradients are int8-quantized (per-leaf absmax
scale) before crossing the pod axis, cutting pod-link bytes 2x vs bf16 / 4x
vs f32, then dequantized and averaged.

Mechanics: jax.shard_map with ``axis_names={"pod"}`` — the pod axis becomes
manual (we own the collective), while "data"/"model" stay auto (GSPMD keeps
sharding them as usual). The quantized reduction is an int8 all_gather +
local dequant-sum: int8 summation would overflow, and this keeps the wire
format 8-bit, which is what the HLO collective-bytes parse (and the real
DCN) sees.

Error bound: absmax int8 quantization has per-element error <= scale/2
= max|g| / 254; tests/test_compression.py checks the end-to-end bound and
that training still converges on the quickstart model.

Serve-side: ``quantize_kv`` / ``dequantize_kv`` give int8 KV caches (the
decode-memory hillclimb lever in EXPERIMENTS.md §Perf).

Trigger-side: ``sparse_trigger_pack`` / ``sparse_trigger_unpack`` are the
paper's at-source reduction applied to the readout server's host link.
The keep/drop cut already ran on device (behind the TMR vote when
redundancy is on); instead of shipping the dense (chips, events) score +
keep tensors across the host link, only keep-flagged events cross it as
a packed (flat indices, scores) pair — bytes on the wire scale with the
trigger rate, not the bunch-crossing rate. The pack is shape-static
(padded with -1) so it lives inside jit; the server slices the true
``count`` prefix when materializing, which is what actually crosses the
link. Round-trip identity (including all-keep / all-drop masks) is
property-tested in tests/test_compression.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


# The JAX-version shard_map shim is shared with the fused readout frontend
# (kernels/frontend.py); see kernels/compat.py for the fallback semantics.
from repro.kernels.compat import shard_map_compat as _shard_map_compat  # noqa: E402


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """absmax-scaled symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantized_psum_leaf(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Sum ``x`` over the manual axis with an int8 wire format."""
    q, s = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)        # int8 across the link
    ss = jax.lax.all_gather(s, axis_name)        # one f32 scale per shard
    return jnp.sum(qs.astype(jnp.float32) * ss.reshape(
        (-1,) + (1,) * x.ndim), axis=0).astype(x.dtype)


def quantized_psum(tree: PyTree, axis_name: str) -> PyTree:
    return jax.tree.map(lambda x: quantized_psum_leaf(x, axis_name), tree)


def make_compressed_value_and_grad(
    loss_fn: Callable,
    mesh: Mesh,
    batch_spec_tree: PyTree,
    grad_specs: PyTree = None,
):
    """value_and_grad with int8-compressed gradient reduction over "pod".

    loss_fn(params, batch) -> scalar. The batch must have its leading batch
    dim divisible by the pod axis; params are replicated across pods.
    Inside, "data"/"model" remain auto-sharded by GSPMD.

    grad_specs (PartitionSpec tree over the intra-pod axes) is ESSENTIAL:
    without it the per-pod partial grads are unconstrained inside the manual
    body, XLA replicates them over data/model, and every device exchanges
    the FULL gradient across the pod link instead of its 1/256 shard — the
    first measured iteration of EXPERIMENTS.md §Perf C (refuted, 6.7x worse)
    was exactly this bug.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("compressed grad reduction needs a 'pod' mesh axis")
    n_pod = mesh.shape["pod"]

    def strip_pod(spec: P) -> P:
        parts = []
        for s in spec:
            if isinstance(s, (tuple, list)):
                kept = tuple(a for a in s if a != "pod")
                parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                parts.append(None if s == "pod" else s)
        return P(*parts)

    inner_grad_specs = (
        jax.tree.map(strip_pod, grad_specs, is_leaf=lambda x: isinstance(x, P))
        if grad_specs is not None else None
    )

    def pod_dim_only(spec: P) -> P:
        # keep only the "pod" component of the batch spec for the manual axis
        parts = []
        for s in spec:
            if s == "pod":
                parts.append("pod")
            elif isinstance(s, (tuple, list)) and "pod" in s:
                parts.append("pod")
            else:
                parts.append(None)
        return P(*parts)

    in_batch_specs = jax.tree.map(
        pod_dim_only, batch_spec_tree, is_leaf=lambda x: isinstance(x, P)
    )

    def body(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # intra-pod sharding constraints need the partial-manual form
        # (data/model still auto); in the fully-manual fallback they would
        # reference axes the region owns — skip them there (perf-only).
        if inner_grad_specs is not None and _HAS_PARTIAL_MANUAL:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, inner_grad_specs)
        grads = quantized_psum(grads, "pod")               # int8 on the wire
        grads = jax.tree.map(lambda g: g / n_pod, grads)   # mean over pods
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads

    return _shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(), in_batch_specs),
        out_specs=(P(), P()),
        manual_axes={"pod"},
    )


# ------------------------------------------------- sparse trigger readout
# Wire cost model for the report's accounting: a sparse event ships a
# flat int32 index + int32 score; the dense alternative ships an int32
# score + a keep byte for EVERY scored event, kept or not.
SPARSE_BYTES_PER_EVENT = 8
DENSE_BYTES_PER_EVENT = 5
SPARSE_HEADER_BYTES = 4  # the count word
# Little-endian struct formats of the sparse wire units — net/protocol.py
# frames exactly these on the socket, so the in-process host link and the
# network egress share one byte layout (changing either breaks both test
# suites, by design).
SPARSE_RECORD_STRUCT = "<ii"   # (flat index i32, score i32) per kept event
SPARSE_COUNT_STRUCT = "<I"     # the SPARSE_HEADER_BYTES count prefix


class WireFormatError(ValueError):
    """A wire-format unit failed validation (count prefix out of range,
    index out of the dense shape, mismatched index/score buffers).

    Base of the named-error family shared with the network protocol
    (net/protocol.py's ProtocolError subclasses this): every malformed
    buffer raises from this family — never a raw numpy IndexError, never
    a silent partial decode."""


def sparse_trigger_pack(
    score: jnp.ndarray, keep: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compact keep-flagged events: (count, flat indices, scores).

    score/keep are any matching shape (the server uses (chips, events)).
    Returns (count () int32 — number of kept events; idx (n,) int32 —
    ascending flat indices of kept events, -1 padded to the static size;
    vals (n,) int32 — the kept scores, 0 on padding). Shape-static so it
    composes inside jit; jit'd module-level as ``sparse_trigger_pack_jit``
    so the server's drain launches it without retracing.
    """
    flat_keep = keep.ravel()
    flat_score = score.ravel().astype(jnp.int32)
    idx = jnp.nonzero(flat_keep, size=flat_keep.size, fill_value=-1)[0]
    idx = idx.astype(jnp.int32)
    safe = jnp.clip(idx, 0, flat_keep.size - 1)
    vals = jnp.where(idx >= 0, flat_score[safe], 0)
    count = jnp.sum(flat_keep.astype(jnp.int32))
    return count, idx, vals


sparse_trigger_pack_jit = jax.jit(sparse_trigger_pack)


def sparse_trigger_pack_words(
    keep_w: jnp.ndarray,        # (C, W) uint32 keep words (bit e = event w*32+e)
    scores: jnp.ndarray,        # (C, W, 32) int32 per-lane scores
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``sparse_trigger_pack`` computed FROM the word domain: popcount
    prefix-sum compaction over keep words, so the (chips, events) bool
    mask never materializes and dropped events are never transposed back
    to event order.

    Each word's kept-lane count comes from one ``population_count``; an
    exclusive cumsum over words gives every word its output base; a
    lane's within-word rank is the popcount of the keep bits below it.
    Kept lanes scatter to ``base + rank`` (dropped lanes aim one past
    the end and fall off via ``mode="drop"``), which reproduces the
    ascending-index wire format of ``sparse_trigger_pack`` bit for bit:
    (count () int32, idx (C*W*32,) int32 ascending flat indices -1
    padded, vals int32 0 padded). Property-tested against the event-
    domain oracle in tests/test_compression.py.
    """
    C, W = keep_w.shape
    n = C * W * 32
    flat_kw = keep_w.reshape(C * W)
    counts = jax.lax.population_count(flat_kw).astype(jnp.int32)
    word_base = jnp.cumsum(counts) - counts              # exclusive cumsum
    count = jnp.sum(counts)

    lane = jnp.arange(32, dtype=jnp.uint32)
    below = (jnp.uint32(1) << lane) - jnp.uint32(1)      # bits strictly below
    keep_bit = (flat_kw[:, None] >> lane) & jnp.uint32(1)       # (CW, 32)
    rank = jax.lax.population_count(
        flat_kw[:, None] & below[None, :]).astype(jnp.int32)
    dest = jnp.where(keep_bit == 1, word_base[:, None] + rank, n)
    flat_idx = (
        jnp.arange(C * W, dtype=jnp.int32)[:, None] * 32
        + lane.astype(jnp.int32)
    )
    idx = jnp.full((n,), -1, jnp.int32).at[dest.reshape(-1)].set(
        flat_idx.reshape(-1), mode="drop")
    vals = jnp.zeros((n,), jnp.int32).at[dest.reshape(-1)].set(
        scores.reshape(-1).astype(jnp.int32), mode="drop")
    return count, idx, vals


def sparse_trigger_unpack(
    idx, vals, shape, count: int | None = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side inverse of ``sparse_trigger_pack``.

    Accepts the packed pair (padded or already count-sliced) and the
    dense shape; returns (score (shape) int32 — 0 where dropped, keep
    (shape) bool). ``unpack(pack(s, k)) == (s * k, k)`` for every keep
    mask, including all-keep and all-drop.

    ``count``, when given, is the wire's count prefix: the first
    ``count`` records of idx/vals are the payload, the rest padding.
    The buffers are VALIDATED before any scatter — a count prefix
    larger than the buffer, mismatched idx/vals lengths, or an index
    outside the dense shape raises :class:`WireFormatError` (the same
    named family as the network decoder) instead of silently slicing
    short or crashing with a raw numpy IndexError.
    """
    idx = np.asarray(idx, np.int64).ravel()
    vals = np.asarray(vals, np.int64).ravel()
    if idx.shape != vals.shape:
        raise WireFormatError(
            f"sparse trigger buffers disagree: {idx.size} indices vs "
            f"{vals.size} scores")
    if count is not None:
        if not (0 <= count <= idx.size):
            raise WireFormatError(
                f"sparse trigger count prefix {count} outside the "
                f"record buffer (0..{idx.size})")
        idx = idx[:count]
        vals = vals[:count]
    n = int(np.prod(shape))
    kept = idx >= 0
    kidx = idx[kept]
    if kidx.size and (int(kidx.max()) >= n or int(idx.min()) < -1):
        raise WireFormatError(
            f"sparse trigger index outside dense shape {tuple(shape)}: "
            f"indices span [{int(idx.min())}, {int(kidx.max())}], "
            f"valid flat range is [-1 (padding), {n - 1}]")
    score = np.zeros(n, np.int32)
    keep = np.zeros(n, bool)
    score[kidx] = vals[kept]
    keep[kidx] = True
    return score.reshape(shape), keep.reshape(shape)


# ------------------------------------------------------------- KV caches
def quantize_kv(kv: jnp.ndarray, axis: int = -1):
    """Per-vector absmax int8 along head_dim (decode-memory compression)."""
    xf = kv.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)
