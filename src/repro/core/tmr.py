"""Triple modular redundancy (paper §5 future work).

"Additionally, any readout ASIC in a collider inner system will need to be
insensitive to radiation-induced issues such as single-event effects. The
implementation of triple modular redundancy (TMR) in FABulous could open up
the broad usage of eFPGAs in collider readout scenarios."

``triplicate`` transforms any netlist into its TMR form: three independent
replicas of all logic + per-output majority voters (vote = ab|ac|bc, one
LUT3 per output bit). FFs are triplicated too, so a single-event upset
(SEU) in ONE replica's configuration or state cannot corrupt any output.

Cost: 3x logic + one voter LUT per output — which is exactly why the paper
calls for a larger next-generation fabric: the 294-LUT BDT needs ~900 LUTs
under TMR, far beyond the 448-cell 28nm chip. ``FABRIC_28NM_XL`` models
that next-generation part (4x the logic columns of the fabricated 28nm
chip, same tile library) so the TMR readout chip is buildable end-to-end.

SEU injection (``inject_seu``) flips one configuration bit (a LUT truth
table entry) in a decoded bitstream — the standard fault model for
configuration-memory upsets.

Two TMR granularities live here:

  * ``triplicate`` — netlist-level TMR (3x logic + voter LUTs inside ONE
    fabric), the paper's on-chip form. Costs 3x the cells of a single
    fabric, hence ``FABRIC_28NM_XL``.
  * ``replicate_config`` — serving-level TMR: three independently-encoded
    decoded bitstreams of the SAME design, each with a distinct placement
    (LUT order rotated within every level), evaluated as three chip slots
    of a ``PackedFabricStack`` and reduced by a device majority vote
    (kernels/lut_eval/ops.py, ``redundancy="tmr"``). Distinct placements
    mean one configuration-memory address maps to different logical LUTs
    in each replica, so a common-mode flip at a shared address cannot
    produce three identically-wrong replicas. Levels narrower than 3
    cells cannot give all replicas distinct slots (pigeonhole); single
    faults are still voted out regardless.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.fabric import (
    FabricConfig, FabricSpec, _col, _make_grid, packed_table_image,
)
from repro.core.netlist import (
    CONST0, CONST1, FF, LUT, Netlist, table_from_fn,
)

TBL_VOTE = table_from_fn(lambda a, b, c: (a & b) | (a & c) | (b & c), 3)

# Serving-level TMR replica count (the only redundancy the majority vote
# supports; 2-of-3 voting needs exactly three replicas).
N_REPLICAS = 3


def majority_vote(a, b, c):
    """Elementwise 2-of-3 majority on 0/1 bit tensors.

    Pure bitwise expression — the SAME function is the host oracle (numpy
    arrays) and the device voter (jax arrays inside the scoring dispatch),
    so the vote has a single source of truth.
    """
    return (a & b) | (a & c) | (b & c)


def majority_vote_words(a, b, c):
    """Word-parallel 2-of-3 majority for bit-sliced 32-event words.

    The same bitwise identity as ``majority_vote`` — (a&b)|(a&c)|(b&c)
    is per-bit, so applied to uint32 words of the bit-sliced layout
    (kernels.lut_eval.bitsliced: bit ``e`` of a word = event ``e``'s net
    value) it votes all 32 event lanes of a net at once. One definition
    shared by the device evaluator and the host oracle
    (core.fabric.BitslicedSim), so the folded-in TMR vote cannot fork
    from the per-bit vote the rest of the stack uses.
    """
    return majority_vote(a, b, c)


def replicate_config(config: FabricConfig, replica: int) -> FabricConfig:
    """Re-encode a decoded bitstream as TMR replica ``replica`` (0..2).

    Replica 0 is the original encoding. Replicas 1 and 2 rotate the LUT
    order within every level by ``replica`` slots — a different placement
    (and therefore a different configuration-memory image) computing the
    identical function: net ids, truth-table rows and physical cells all
    move together. Functional identity holds because levelized evaluation
    is order-independent within a level; fan-in *levels* are untouched, so
    the banded-routing reach is replica-invariant and all replicas share
    one stack envelope.
    """
    if not 0 <= replica < N_REPLICAS:
        raise ValueError(f"replica must be in [0, {N_REPLICAS}), got {replica!r}")
    if replica == 0:
        return config
    c = config
    n_luts = c.n_luts
    sizes = np.asarray(c.level_sizes, np.int64)
    starts = np.concatenate([[0], np.cumsum(sizes)])
    # order[new_slot] = old_slot: rotate within each level
    order = np.arange(n_luts, dtype=np.int64)
    for l, size in enumerate(sizes):
        if size > 1:
            lo = int(starts[l])
            order[lo : lo + size] = lo + (np.arange(size) + replica) % size
    inv = np.empty_like(order)
    inv[order] = np.arange(n_luts)

    base = 2 + c.n_inputs + c.n_ffs
    remap = np.arange(c.n_nets, dtype=np.int64)
    remap[base : base + n_luts] = base + inv
    return dataclasses.replace(
        c,
        lut_inputs=remap[c.lut_inputs[order]].astype(np.int32),
        lut_tables=c.lut_tables[order].copy(),
        output_nets=remap[c.output_nets].astype(np.int32),
        ff_d_nets=(
            remap[c.ff_d_nets].astype(np.int32) if c.n_ffs else c.ff_d_nets.copy()
        ),
        cell_of_lut=c.cell_of_lut[order].copy(),
    )


def triplicate(nl: Netlist) -> Netlist:
    """Return the TMR form of a netlist (shared inputs, voted outputs)."""
    n_copies = 3

    def remap_for(copy: int):
        # nets: consts + inputs shared; everything else per-copy
        shared = {CONST0: CONST0, CONST1: CONST1}
        for net in nl.inputs:
            shared[net] = net
        return shared

    next_net = nl.n_nets
    per_copy_map = []
    for c in range(n_copies):
        m = remap_for(c)
        for net in range(nl.n_nets):
            if net in m:
                continue
            if c == 0:
                m[net] = net  # copy 0 keeps original ids
            else:
                m[net] = next_net
                next_net += 1
        per_copy_map.append(m)

    luts = []
    ffs = []
    for c in range(n_copies):
        m = per_copy_map[c]
        for l in nl.luts:
            luts.append(LUT(
                inputs=tuple(m[i] for i in l.inputs),
                table=l.table,
                out=m[l.out],
            ))
        for f in nl.ffs:
            ffs.append(FF(d=m[f.d], q=m[f.q], init=f.init))

    # majority voters on each output
    outputs = []
    names = dict(nl.names)
    for out in nl.outputs:
        voted = next_net
        next_net += 1
        luts.append(LUT(
            inputs=(per_copy_map[0][out], per_copy_map[1][out],
                    per_copy_map[2][out], CONST0),
            table=TBL_VOTE,
            out=voted,
        ))
        names[voted] = f"vote({nl.names.get(out, out)})"
        outputs.append(voted)

    return Netlist(
        n_nets=next_net,
        inputs=list(nl.inputs),
        outputs=outputs,
        luts=luts,
        ffs=ffs,
        names=names,
    )


# Next-generation 28nm fabric (paper §5: "A next-generation eFPGA with a
# larger logical capacity"): same tile library, 4x the LUT4AB columns.
FABRIC_28NM_XL = FabricSpec(
    name="efpga_28nm_xl",
    node="28nm",
    grid=_make_grid(
        [_col("WEST_IO", 8)]
        + [_col("LUT4AB", 8) for _ in range(14)]
        + [["DSP_top", "DSP_bot"] * 4]
        + [_col("LUT4AB", 8) for _ in range(14)]
        + [_col("EAST_IO", 8)]
    ),
    config_bus_in=128,
    config_bus_out=128,
    stream_bits=64,
)


def replica_lut_index(config: FabricConfig, replica: int,
                      lut_index: int) -> int:
    """Slot of base-encoding LUT ``lut_index`` in ``replica``'s encoding.

    The coordinate translation for injecting the SAME logical fault into
    several replicas (the double-fault campaign): replica r's within-level
    rotation moves base slot j to ``lo + ((j - lo - r) % size)``.
    """
    if not 0 <= lut_index < config.n_luts:
        raise ValueError(
            f"lut_index must be in [0, {config.n_luts}), got {lut_index!r}")
    if not 0 <= replica < N_REPLICAS:
        raise ValueError(f"replica must be in [0, {N_REPLICAS}), got {replica!r}")
    if replica == 0:
        return int(lut_index)
    lo = 0
    for size in config.level_sizes:
        if lut_index < lo + size:
            if size <= 1:
                return int(lut_index)
            return int(lo + ((lut_index - lo - replica) % size))
        lo += size
    raise AssertionError("unreachable: lut_index inside n_luts")


def replica_table_images(
    config: FabricConfig, n_levels: int, m_pad: int,
    n_replicas: int = N_REPLICAS,
) -> List[np.ndarray]:
    """Golden configuration-memory truth-table images, one per served
    replica encoding, in the padded scrub-loop layout.

    Each replica's image is ``packed_table_image`` of its placement-
    rotated encoding — the exact bytes a clean readback of that replica
    slot returns (device stack or host-oracle twin), so the scrubbing
    subsystem's golden CRC digests (core.bitstream.GoldenImageStore) are
    computed here once at (re)configuration time. ``n_replicas=1`` is the
    non-redundant, CRC-only-detection case (the base encoding alone).
    """
    return [
        packed_table_image(replicate_config(config, r), n_levels, m_pad)
        for r in range(n_replicas)
    ]


def inject_seu(config: FabricConfig, lut_index: int, bit: int) -> FabricConfig:
    """Flip one truth-table configuration bit (SEU in config memory).

    ``lut_index``/``bit`` are bounds-checked with a named error: numpy's
    fancy indexing would otherwise silently wrap negative indices to the
    other end of the config memory, making a fault-injection campaign
    sweep the wrong addresses without noticing.
    """
    n = config.n_luts
    if not isinstance(lut_index, (int, np.integer)) or not 0 <= lut_index < n:
        raise ValueError(
            f"lut_index must be an int in [0, {n}) for this config, "
            f"got {lut_index!r}"
        )
    if not isinstance(bit, (int, np.integer)) or not 0 <= bit < 16:
        raise ValueError(
            f"bit must be an int in [0, 16) (LUT4 truth table), got {bit!r}"
        )
    tables = config.lut_tables.copy()
    tables[lut_index, bit] ^= 1
    return dataclasses.replace(config, lut_tables=tables)


# register so bitstreams/configs resolve the name
from repro.core.fabric import FABRICS  # noqa: E402

FABRICS["efpga_28nm_xl"] = FABRIC_28NM_XL
