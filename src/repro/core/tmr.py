"""Triple modular redundancy (paper §5 future work).

"Additionally, any readout ASIC in a collider inner system will need to be
insensitive to radiation-induced issues such as single-event effects. The
implementation of triple modular redundancy (TMR) in FABulous could open up
the broad usage of eFPGAs in collider readout scenarios."

``triplicate`` transforms any netlist into its TMR form: three independent
replicas of all logic + per-output majority voters (vote = ab|ac|bc, one
LUT3 per output bit). FFs are triplicated too, so a single-event upset
(SEU) in ONE replica's configuration or state cannot corrupt any output.

Cost: 3x logic + one voter LUT per output — which is exactly why the paper
calls for a larger next-generation fabric: the 294-LUT BDT needs ~900 LUTs
under TMR, far beyond the 448-cell 28nm chip. ``FABRIC_28NM_XL`` models
that next-generation part (4x the logic columns of the fabricated 28nm
chip, same tile library) so the TMR readout chip is buildable end-to-end.

SEU injection (``inject_seu``) flips one configuration bit (a LUT truth
table entry) in a decoded bitstream — the standard fault model for
configuration-memory upsets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.fabric import FabricConfig, FabricSpec, _col, _make_grid
from repro.core.netlist import (
    CONST0, CONST1, FF, LUT, Netlist, table_from_fn,
)

TBL_VOTE = table_from_fn(lambda a, b, c: (a & b) | (a & c) | (b & c), 3)


def triplicate(nl: Netlist) -> Netlist:
    """Return the TMR form of a netlist (shared inputs, voted outputs)."""
    n_copies = 3

    def remap_for(copy: int):
        # nets: consts + inputs shared; everything else per-copy
        shared = {CONST0: CONST0, CONST1: CONST1}
        for net in nl.inputs:
            shared[net] = net
        return shared

    next_net = nl.n_nets
    per_copy_map = []
    for c in range(n_copies):
        m = remap_for(c)
        for net in range(nl.n_nets):
            if net in m:
                continue
            if c == 0:
                m[net] = net  # copy 0 keeps original ids
            else:
                m[net] = next_net
                next_net += 1
        per_copy_map.append(m)

    luts = []
    ffs = []
    for c in range(n_copies):
        m = per_copy_map[c]
        for l in nl.luts:
            luts.append(LUT(
                inputs=tuple(m[i] for i in l.inputs),
                table=l.table,
                out=m[l.out],
            ))
        for f in nl.ffs:
            ffs.append(FF(d=m[f.d], q=m[f.q], init=f.init))

    # majority voters on each output
    outputs = []
    names = dict(nl.names)
    for out in nl.outputs:
        voted = next_net
        next_net += 1
        luts.append(LUT(
            inputs=(per_copy_map[0][out], per_copy_map[1][out],
                    per_copy_map[2][out], CONST0),
            table=TBL_VOTE,
            out=voted,
        ))
        names[voted] = f"vote({nl.names.get(out, out)})"
        outputs.append(voted)

    return Netlist(
        n_nets=next_net,
        inputs=list(nl.inputs),
        outputs=outputs,
        luts=luts,
        ffs=ffs,
        names=names,
    )


# Next-generation 28nm fabric (paper §5: "A next-generation eFPGA with a
# larger logical capacity"): same tile library, 4x the LUT4AB columns.
FABRIC_28NM_XL = FabricSpec(
    name="efpga_28nm_xl",
    node="28nm",
    grid=_make_grid(
        [_col("WEST_IO", 8)]
        + [_col("LUT4AB", 8) for _ in range(14)]
        + [["DSP_top", "DSP_bot"] * 4]
        + [_col("LUT4AB", 8) for _ in range(14)]
        + [_col("EAST_IO", 8)]
    ),
    config_bus_in=128,
    config_bus_out=128,
    stream_bits=64,
)


def inject_seu(config: FabricConfig, lut_index: int, bit: int) -> FabricConfig:
    """Flip one truth-table configuration bit (SEU in config memory)."""
    tables = config.lut_tables.copy()
    tables[lut_index, bit] ^= 1
    return dataclasses.replace(config, lut_tables=tables)


# register so bitstreams/configs resolve the name
from repro.core.fabric import FABRICS  # noqa: E402

FABRICS["efpga_28nm_xl"] = FABRIC_28NM_XL
