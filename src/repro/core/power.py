"""Analytical power/area model calibrated to the paper's measurements.

No silicon in this container, so Fig. 5 (130nm) and Fig. 10 (28nm) are
reproduced by a classic digital power model

    P_rail(f) = P_static + k_dyn * f          (k_dyn ∝ C_eff * V^2)

with coefficients calibrated to the paper's stated relations:

  * §3: "a factor of 2.8 reduction in core power consumption at 100 MHz";
  * §4.4.2: "the 28nm ASIC's core voltage rail power consumption at a
    125 MHz clock is approximately one third that of the 130nm ASIC";
  * rails: 130nm core +1.2V, IO +1.2V; 28nm core +0.9V, IO +1.8V;
  * valid ranges: 130nm measured 10–125 MHz (SUGOI readback degraded above
    74 MHz — the slow output driver, slew 38/32 ns); 28nm 10–250 MHz
    (stopped by FPGA-side PGPv4 CRC timing, not the ASIC).

With the chosen coefficients: ratio(100 MHz) = 2.85 ≈ 2.8 and
ratio(125 MHz) = 2.86 ≈ "approximately one third". Area efficiency uses the
fabric macro areas (die sizes are 5x5 mm vs 1x1 mm, Figs. 3/8) calibrated so
the §3 "factor of 21 improvement in area efficiency" is reproduced.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class RailModel:
    static_mw: float
    dyn_mw_per_mhz: float
    voltage: float

    def power_mw(self, f_mhz: float) -> float:
        return self.static_mw + self.dyn_mw_per_mhz * f_mhz


@dataclasses.dataclass(frozen=True)
class NodeModel:
    name: str
    core: RailModel
    io: RailModel
    f_min_mhz: float
    f_max_mhz: float
    readback_limit_mhz: float  # SUGOI readback ceiling (130nm driver bug)
    die_mm2: float
    fabric_macro_mm2: float
    equiv_logic: float  # logic cells + weighted DSP/RegFile


# Equivalent-logic weights: LUT4AB cell = 1, DSP slice = 20, RegFile tile = 16.
_EQ_130 = 384 + 4 * 20 + 4 * 16   # = 528
_EQ_28 = 448 + 4 * 20             # = 528

NODE_130NM = NodeModel(
    name="130nm",
    core=RailModel(static_mw=2.0, dyn_mw_per_mhz=0.75, voltage=1.2),
    io=RailModel(static_mw=1.5, dyn_mw_per_mhz=0.30, voltage=1.2),
    f_min_mhz=10.0,
    f_max_mhz=125.0,           # P&R timing constraint (§2.4.2)
    readback_limit_mhz=74.0,   # output-driver slew bug (§2.4.2)
    die_mm2=25.0,              # 5 mm x 5 mm (Fig. 3)
    fabric_macro_mm2=13.23,
    equiv_logic=_EQ_130,
)

NODE_28NM = NodeModel(
    name="28nm",
    core=RailModel(static_mw=1.0, dyn_mw_per_mhz=0.26, voltage=0.9),
    io=RailModel(static_mw=1.0, dyn_mw_per_mhz=0.12, voltage=1.8),
    f_min_mhz=10.0,
    f_max_mhz=250.0,           # FPGA-side PGPv4 CRC timing, not the ASIC (§4.4.2)
    readback_limit_mhz=250.0,
    die_mm2=1.0,               # 1 mm x 1 mm (Fig. 8)
    fabric_macro_mm2=0.63,
    equiv_logic=_EQ_28,
)

NODES: Dict[str, NodeModel] = {"130nm": NODE_130NM, "28nm": NODE_28NM}


def power_mw(node: str, f_mhz: float, rail: str = "core") -> float:
    m = NODES[node]
    r = m.core if rail == "core" else m.io
    return r.power_mw(f_mhz)


def total_power_mw(node: str, f_mhz: float) -> float:
    return power_mw(node, f_mhz, "core") + power_mw(node, f_mhz, "io")


def sweep(node: str, freqs_mhz: List[float] | None = None) -> List[Dict[str, float]]:
    """Reproduce Fig. 5 / Fig. 10: power vs clock frequency per rail."""
    m = NODES[node]
    if freqs_mhz is None:
        freqs_mhz = [10, 25, 50, 74, 100, 125] if node == "130nm" else [
            10, 25, 50, 100, 125, 150, 200, 250]
    rows = []
    for f in freqs_mhz:
        rows.append({
            "f_mhz": float(f),
            "core_mw": power_mw(node, f, "core"),
            "io_mw": power_mw(node, f, "io"),
            "total_mw": total_power_mw(node, f),
            "sugoi_readback_ok": float(f <= m.readback_limit_mhz),
        })
    return rows


def core_power_ratio(f_mhz: float) -> float:
    """130nm / 28nm core power at a given clock (paper: 2.8x at 100 MHz)."""
    return power_mw("130nm", f_mhz, "core") / power_mw("28nm", f_mhz, "core")


def area_efficiency_ratio() -> float:
    """Equivalent logic per mm^2, 28nm over 130nm (paper §3: factor ~21)."""
    e130 = NODE_130NM.equiv_logic / NODE_130NM.fabric_macro_mm2
    e28 = NODE_28NM.equiv_logic / NODE_28NM.fabric_macro_mm2
    return e28 / e130


def energy_per_inference_nj(node: str, f_mhz: float, cycles: int = 1) -> float:
    """Core energy per fabric evaluation at clock f (nJ) — used by the
    readout benchmarks to compare against off-detector transmission cost."""
    p_w = power_mw(node, f_mhz, "core") * 1e-3
    t_s = cycles / (f_mhz * 1e6)
    return p_w * t_s * 1e9
