"""Bitstream encode/decode for the eFPGA fabric (paper §2.2/§4.2).

On the real ASIC the bitstream is shifted in through the eFPGA
configuration/status module over AXI-Lite (SUGOI control plane). Here the
bitstream is a byte string with a framed format:

    magic "FABU" | version u16 | fabric-name (u8 len + bytes)
    | header: n_nets n_inputs n_ffs n_outputs n_luts n_levels (u32 each)
    | level_sizes u32[n_levels]
    | lut_inputs  i32[n_luts*4]
    | lut_tables  packed u16[n_luts]      (16-bit truth tables)
    | output_nets i32[n_outputs]
    | ff_d_nets   i32[n_ffs] | ff_init u8[n_ffs]
    | cell_of_lut i32[n_luts] | cell_of_ff i32[n_ffs]
    | crc32 u32 over everything above

Round-tripping through bytes (including the CRC check) is the software
analogue of the paper's "successful loading of the bitstream" bring-up test;
corrupting any byte must be detected (tests/test_bitstream.py).

The scrubbing subsystem (launch/readout_server.py) extends this integrity
story from load time to *run* time: ``GoldenImageStore`` keeps each served
chip's golden bitstream plus per-replica CRC digests of its packed
configuration-memory truth-table image (core.fabric.packed_table_image),
so a background readback->verify loop can *detect* an accumulated SEU —
not just outvote it — and heal by re-encoding from the golden bitstream.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.fabric import FabricConfig

MAGIC = b"FABU"
VERSION = 2


class BitstreamError(RuntimeError):
    pass


class GoldenSlotError(BitstreamError, KeyError):
    """Lookup of a slot/tenant with no registered golden image.

    Raised by ``GoldenImageStore`` when ``digest``/``n_replicas``/
    ``verify``/``golden_config`` name a slot that was never registered or
    was discarded (e.g. a tenant evicted from the fleet whose golden image
    was dropped). Named — like the ``WireFormatError``/``ProtocolError``
    family — so callers can distinguish "unknown tenant" from a genuine
    bug, and subclasses ``KeyError`` so pre-existing ``except KeyError``
    handlers keep working.
    """

    def __init__(self, slot):
        self.slot = slot
        super().__init__(
            f"no golden image registered for slot {slot!r} "
            f"(never registered, or evicted/discarded)")

    def __str__(self) -> str:  # KeyError.__str__ would repr() the args
        return self.args[0]


def _pack_tables(tables: np.ndarray) -> np.ndarray:
    """(n, 16) 0/1 -> (n,) uint16."""
    weights = (1 << np.arange(16)).astype(np.uint32)
    return (tables.astype(np.uint32) * weights).sum(-1).astype(np.uint16)


def _unpack_tables(packed: np.ndarray) -> np.ndarray:
    return ((packed[:, None].astype(np.uint32) >> np.arange(16)) & 1).astype(np.uint8)


def encode(config: FabricConfig) -> bytes:
    c = config
    name = c.fabric_name.encode()
    parts = [
        MAGIC,
        struct.pack("<HB", VERSION, len(name)),
        name,
        struct.pack(
            "<6I",
            c.n_nets, c.n_inputs, c.n_ffs,
            len(c.output_nets), c.n_luts, len(c.level_sizes),
        ),
        np.asarray(c.level_sizes, "<u4").tobytes(),
        np.asarray(c.lut_inputs, "<i4").tobytes(),
        _pack_tables(c.lut_tables).astype("<u2").tobytes(),
        np.asarray(c.output_nets, "<i4").tobytes(),
        np.asarray(c.ff_d_nets, "<i4").tobytes(),
        np.asarray(c.ff_init, "u1").tobytes(),
        np.asarray(c.cell_of_lut, "<i4").tobytes(),
        np.asarray(c.cell_of_ff, "<i4").tobytes(),
    ]
    payload = b"".join(parts)
    return payload + struct.pack("<I", zlib.crc32(payload))


def decode(data: bytes) -> FabricConfig:
    if len(data) < 12 or data[:4] != MAGIC:
        raise BitstreamError("bad magic")
    payload, (crc,) = data[:-4], struct.unpack("<I", data[-4:])
    if zlib.crc32(payload) != crc:
        raise BitstreamError("CRC mismatch — corrupted bitstream")
    off = 4
    version, name_len = struct.unpack_from("<HB", data, off)
    off += 3
    if version != VERSION:
        raise BitstreamError(f"unsupported bitstream version {version}")
    fabric_name = data[off : off + name_len].decode()
    off += name_len
    n_nets, n_inputs, n_ffs, n_outputs, n_luts, n_levels = struct.unpack_from(
        "<6I", data, off
    )
    off += 24

    def take(dtype, count):
        nonlocal off
        a = np.frombuffer(data, dtype=dtype, count=count, offset=off)
        off += a.nbytes
        return a

    level_sizes = take("<u4", n_levels).astype(np.int64).tolist()
    lut_inputs = take("<i4", n_luts * 4).reshape(n_luts, 4).astype(np.int32)
    lut_tables = _unpack_tables(take("<u2", n_luts).astype(np.uint16))
    output_nets = take("<i4", n_outputs).astype(np.int32)
    ff_d_nets = take("<i4", n_ffs).astype(np.int32)
    ff_init = take("u1", n_ffs).astype(np.uint8)
    cell_of_lut = take("<i4", n_luts).astype(np.int32)
    cell_of_ff = take("<i4", n_ffs).astype(np.int32)
    return FabricConfig(
        fabric_name=fabric_name,
        n_nets=int(n_nets),
        n_inputs=int(n_inputs),
        n_ffs=int(n_ffs),
        level_sizes=level_sizes,
        lut_inputs=lut_inputs.copy(),
        lut_tables=lut_tables.reshape(n_luts, 16).copy(),
        output_nets=output_nets.copy(),
        ff_d_nets=ff_d_nets.copy(),
        ff_init=ff_init.copy(),
        cell_of_lut=cell_of_lut.copy(),
        cell_of_ff=cell_of_ff.copy(),
    )


# --------------------------------------------------------------------------
# Golden-image store (the reference side of the scrub loop)
# --------------------------------------------------------------------------


def table_digest(tables: np.ndarray) -> int:
    """CRC32 digest of a truth-table configuration-memory image.

    Canonicalized to contiguous uint8 bytes first, so the digest is
    identical whether the image was read back from the device stack
    (float32 0.0/1.0 arrays), from the host-oracle twin (uint8), or
    computed fresh from a decoded bitstream.
    """
    a = np.ascontiguousarray(np.asarray(tables).astype(np.uint8))
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class GoldenImage:
    """One served slot's golden reference: the encoded (CRC-framed)
    bitstream to heal from, plus per-replica digests to verify against."""

    bitstream: bytes
    digests: Tuple[int, ...]


class GoldenImageStore:
    """Per-chip golden bitstreams + per-replica CRC digests.

    The scrub scheduler's reference memory: ``register`` snapshots a
    slot's golden truth at (re)configuration time, ``verify`` CRC-checks a
    live readback image against it, and ``golden_config`` decodes the
    stored bitstream (itself CRC-framed, so the reference cannot rot
    silently either) for the heal re-encode. Digests are per *replica*
    because TMR replicas are placement-rotated — each one is a distinct
    configuration-memory image of the same function (core.tmr).
    """

    def __init__(self):
        self._slots: Dict[int, GoldenImage] = {}

    def __contains__(self, slot: int) -> bool:
        return slot in self._slots

    def __len__(self) -> int:
        return len(self._slots)

    def _get(self, slot: int) -> GoldenImage:
        try:
            return self._slots[slot]
        except KeyError:
            raise GoldenSlotError(slot) from None

    def register(
        self, slot: int, config: FabricConfig,
        replica_images: Sequence[np.ndarray],
    ) -> None:
        """(Re)register a slot's golden truth: the config's bitstream and
        one packed table image per served replica encoding."""
        if not replica_images:
            raise ValueError("need at least one replica image")
        self._slots[slot] = GoldenImage(
            bitstream=encode(config),
            digests=tuple(table_digest(im) for im in replica_images),
        )

    def discard(self, slot: int) -> None:
        """Drop a slot's golden image (no-op if absent) — the terminal
        state of a tenant retired from the fleet. A later lookup raises
        ``GoldenSlotError``; an LRU-*evicted* tenant, by contrast, keeps
        its golden image so it can re-admit from it."""
        self._slots.pop(slot, None)

    def n_replicas(self, slot: int) -> int:
        return len(self._get(slot).digests)

    def digest(self, slot: int, replica: int) -> int:
        d = self._get(slot).digests
        if not 0 <= replica < len(d):
            raise ValueError(
                f"replica must be in [0, {len(d)}), got {replica!r}")
        return d[replica]

    def verify(self, slot: int, replica: int, tables: np.ndarray) -> bool:
        """True iff the live image's CRC matches the golden digest.

        Raises ``GoldenSlotError`` if the slot has no registered image —
        an unverifiable readback must not silently pass OR fail.
        """
        return table_digest(tables) == self.digest(slot, replica)

    def golden_config(self, slot: int) -> FabricConfig:
        """Decode the stored golden bitstream (CRC-checked) for healing
        or fleet re-admission. Raises ``GoldenSlotError`` on an
        unknown/discarded slot."""
        return decode(self._get(slot).bitstream)
