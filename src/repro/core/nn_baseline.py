"""The paper's NN baseline (§5): a small fully-connected net that DOESN'T fit.

"An initial attempt was to design a simple Neural Network with two or three
fully connected layers. Despite utilizing a few nodes per layer, this
shallow NN required over 6,000 LUTs, significantly exceeding the capacity of
the 28nm eFPGA ASIC."

We reproduce both halves of that finding:

  * a trainable JAX MLP (the accuracy side — it *is* a competent classifier;
    the problem is resources, not learning);
  * an hls4ml-style LUT cost estimator for a fully-unrolled fixed-point
    implementation (the resource side — lands >6,000 LUTs for 2–3 layers of
    "a few nodes", >> 448 available).

Cost model (fully parallel, II=1, no DSPs — matching the paper's statement
that the BDT needs no DSP/BRAM while the NN would):
  - W_w x W_x multiplier ≈ W_w*W_x/2 LUT4s (Booth/array synthesis estimate)
  - adder tree per neuron: (fan_in-1) adds x acc_width/2 LUT4s
  - ReLU: acc_width/2 LUT4s (sign mux); bias add: acc_width/2
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import FixedSpec


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    layer_sizes: Tuple[int, ...] = (14, 8, 4, 1)  # "a few nodes per layer"
    weight_bits: int = 8
    act_bits: int = 8
    acc_bits: int = 16


def lut_cost(spec: MLPSpec) -> Dict[str, int]:
    """hls4ml-style fully-unrolled LUT estimate."""
    mults = 0
    adders = 0
    relus = 0
    for fan_in, n_out in zip(spec.layer_sizes[:-1], spec.layer_sizes[1:]):
        mults += fan_in * n_out
        adders += max(fan_in - 1, 0) * n_out + n_out  # tree + bias
        relus += n_out
    lut_mult = mults * (spec.weight_bits * spec.act_bits) // 2
    lut_add = adders * spec.acc_bits // 2
    lut_relu = relus * spec.acc_bits // 2
    total = lut_mult + lut_add + lut_relu
    return {
        "multipliers": mults,
        "lut_mult": lut_mult,
        "lut_add": lut_add,
        "lut_relu": lut_relu,
        "lut_total": total,
    }


def dsp_schedule(spec: MLPSpec, n_dsp: int = 4, clock_mhz: float = 200.0) -> Dict[str, float]:
    """Time-multiplexed DSP mapping (the alternative to LUT multipliers).

    The 28nm fabric has 4 DSP slices (8x8 MAC). Scheduling the NN's MACs
    over them: cycles = ceil(total_MACs / n_dsp); at the 200 MHz P&R clock
    the latency blows through the 25 ns bunch-crossing budget by >10x —
    the quantitative second half of the paper's "NN does not fit" finding
    (resources AND latency).
    """
    macs = 0
    for fan_in, n_out in zip(spec.layer_sizes[:-1], spec.layer_sizes[1:]):
        macs += fan_in * n_out
    cycles = -(-macs // n_dsp)
    ns = cycles / clock_mhz * 1e3
    return {"macs": macs, "cycles": float(cycles), "latency_ns": ns,
            "meets_25ns": ns < 25.0}


def init_mlp(rng: jax.Array, spec: MLPSpec):
    params = []
    keys = jax.random.split(rng, len(spec.layer_sizes) - 1)
    for k, (n_in, n_out) in zip(keys, zip(spec.layer_sizes[:-1], spec.layer_sizes[1:])):
        w = jax.random.normal(k, (n_in, n_out), jnp.float32) * (2.0 / n_in) ** 0.5
        b = jnp.zeros((n_out,), jnp.float32)
        params.append({"w": w, "b": b})
    return params


def mlp_logits(params, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h[..., 0]


def train_mlp(
    X: np.ndarray,
    y: np.ndarray,
    spec: MLPSpec = MLPSpec(),
    steps: int = 300,
    batch: int = 4096,
    lr: float = 3e-3,
    seed: int = 0,
):
    """Plain Adam training loop (self-contained; the big-model path uses
    train/optimizer.py)."""
    mu = X.mean(0, keepdims=True)
    sd = X.std(0, keepdims=True) + 1e-6
    Xn = ((X - mu) / sd).astype(np.float32)
    params = init_mlp(jax.random.PRNGKey(seed), spec)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step_fn(params, m, v, xb, yb, t):
        def loss_fn(p):
            z = mlp_logits(p, xb)
            return jnp.mean(
                jnp.maximum(z, 0) - z * yb + jnp.log1p(jnp.exp(-jnp.abs(z)))
            )

        loss, g = jax.value_and_grad(loss_fn)(params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        mhat = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
        params = jax.tree.map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mhat, vhat
        )
        return params, m, v, loss

    rng = np.random.default_rng(seed)
    loss = None
    for t in range(1, steps + 1):
        idx = rng.integers(0, len(Xn), batch)
        params, m, v, loss = step_fn(
            params, m, v, Xn[idx], y[idx].astype(np.float32), jnp.float32(t)
        )
    norm = {"mu": mu, "sd": sd}
    return params, norm, float(loss)


def mlp_proba(params, norm, X: np.ndarray) -> np.ndarray:
    Xn = (X - norm["mu"]) / norm["sd"]
    return np.asarray(jax.nn.sigmoid(mlp_logits(params, jnp.asarray(Xn, jnp.float32))))
