"""FABulous-style eFPGA fabric model: tile grids, capacity, place, configure.

Reproduces the two fabricated fabrics of the paper:

  * 130nm (§2): 384 logic cells (48 LUT4AB tiles x 8 cells), 128 LUTRAM
    registers (4 RegFile tiles x 32x4b), 4 DSP slices (DSP_top/DSP_bot
    pairs), W_IO GPIO column (2b/tile), CPU_IO column (8b in / 12b out per
    tile), N/S termination tiles.
  * 28nm (§4): 448 logic cells (56 LUT4AB tiles), 4 DSP slices, RegFile
    removed (replaced by LUT4AB), WEST_IO / EAST_IO user tiles that expose
    the 32-bit bus + AXI-Stream data plane of the ASIC.

What we model bit-exactly: LUT truth tables, FF state, the levelized
evaluation a configured fabric performs, resource capacities, and the
bitstream contents (core/bitstream.py). What we abstract: the switch-matrix
routing graph — routing is modeled as a full crossbar (any cell input can
see any net) with *capacity* checks on cells and IO. This preserves
functional and resource fidelity; routability of the physical fabric was
proven by the paper's own tapeouts.

A configured fabric (``FabricConfig``) is exactly the levelized-array form
the Pallas kernel consumes — "loading a bitstream" on TPU is swapping these
arrays, with no recompilation (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.netlist import LevelizedNetlist, Netlist
from repro.core.netlist import fanin_reach as _fanin_reach


# --------------------------------------------------------------------------
# Tile library (paper §2.1 / §4.1)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileType:
    name: str
    logic_cells: int = 0      # LUT4+FF pairs
    lutram_bits: int = 0      # RegFile storage
    dsp_half: int = 0         # DSP_top+DSP_bot pair = one 8x8 MAC slice
    gpio_bits: int = 0        # W_IO-style general IO
    bus_in_bits: int = 0      # CPU_IO / EAST_IO style in
    bus_out_bits: int = 0


TILE_LIBRARY: Dict[str, TileType] = {
    "NULL": TileType("NULL"),
    "N_term_single2": TileType("N_term_single2"),
    "S_term_single2": TileType("S_term_single2"),
    "W_IO": TileType("W_IO", gpio_bits=2),
    "RegFile": TileType("RegFile", lutram_bits=32 * 4),
    "DSP_top": TileType("DSP_top", dsp_half=1),
    "DSP_bot": TileType("DSP_bot", dsp_half=1),
    "LUT4AB": TileType("LUT4AB", logic_cells=8),
    "CPU_IO": TileType("CPU_IO", bus_in_bits=8, bus_out_bits=12),
    "WEST_IO": TileType("WEST_IO", gpio_bits=2, bus_in_bits=16, bus_out_bits=16),
    "EAST_IO": TileType("EAST_IO", bus_in_bits=16, bus_out_bits=16),
}


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    name: str
    node: str                     # "130nm" | "28nm"
    grid: Tuple[Tuple[str, ...], ...]  # rows of tile names (the .csv of Fig 1/6)
    # The ASIC-side bus interface (32-bit buses into/out of the eFPGA):
    config_bus_in: int = 96       # bits loadable from AXI-Lite regs (3x32 @130nm)
    config_bus_out: int = 96
    stream_bits: int = 0          # AXI-Stream data plane width (28nm only)

    def tile_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for row in self.grid:
            for t in row:
                out[t] = out.get(t, 0) + 1
        return out

    def totals(self) -> Dict[str, int]:
        c = {"logic_cells": 0, "lutram_bits": 0, "dsp_slices": 0,
             "gpio_bits": 0, "bus_in_bits": 0, "bus_out_bits": 0}
        for row in self.grid:
            for t in row:
                tt = TILE_LIBRARY[t]
                c["logic_cells"] += tt.logic_cells
                c["lutram_bits"] += tt.lutram_bits
                c["dsp_slices"] += tt.dsp_half
                c["gpio_bits"] += tt.gpio_bits
                c["bus_in_bits"] += tt.bus_in_bits
                c["bus_out_bits"] += tt.bus_out_bits
        c["dsp_slices"] //= 2  # top+bot pair = one slice
        return c

    @property
    def n_logic_cells(self) -> int:
        return self.totals()["logic_cells"]

    @property
    def input_capacity(self) -> int:
        """Bits presentable to the fabric per evaluation: config-plane bus
        registers + streaming plane + GPIO inputs."""
        t = self.totals()
        return self.config_bus_in + self.stream_bits + t["gpio_bits"] + t["bus_in_bits"]

    @property
    def output_capacity(self) -> int:
        t = self.totals()
        return self.config_bus_out + self.stream_bits + t["gpio_bits"] + t["bus_out_bits"]


def _col(tile: str, n: int) -> List[str]:
    return [tile] * n


def _make_grid(cols: List[List[str]]) -> Tuple[Tuple[str, ...], ...]:
    n_rows = max(len(c) for c in cols)
    rows = []
    # N/S termination rows as in the paper's tile CSVs.
    rows.append(tuple("N_term_single2" for _ in cols))
    for r in range(n_rows):
        rows.append(tuple(c[r] if r < len(c) else "NULL" for c in cols))
    rows.append(tuple("S_term_single2" for _ in cols))
    return tuple(rows)


# 130nm (§2.1): 48 LUT4AB (384 cells), 4 RegFile (128 regs), 4 DSP slices.
FABRIC_130NM = FabricSpec(
    name="efpga_130nm",
    node="130nm",
    grid=_make_grid([
        _col("W_IO", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        ["DSP_top", "DSP_bot"] * 4,
        _col("RegFile", 4) + _col("LUT4AB", 4),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 4) + _col("NULL", 4),
        _col("CPU_IO", 8),
    ]),
    config_bus_in=96,    # three 32-bit buses (§2.2)
    config_bus_out=96,
    stream_bits=0,
)

# 28nm (§4.1): 56 LUT4AB (448 cells), 4 DSP slices, WEST_IO/EAST_IO.
FABRIC_28NM = FabricSpec(
    name="efpga_28nm",
    node="28nm",
    grid=_make_grid([
        _col("WEST_IO", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        ["DSP_top", "DSP_bot"] * 4,
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        _col("LUT4AB", 8),
        _col("EAST_IO", 8),
    ]),
    config_bus_in=128,   # four 32-bit buses (§4.2)
    config_bus_out=128,
    stream_bits=64,      # AXI-Stream to/from PGPv4 (§4.2)
)

FABRICS: Dict[str, FabricSpec] = {
    "efpga_130nm": FABRIC_130NM,
    "efpga_28nm": FABRIC_28NM,
    "130nm": FABRIC_130NM,
    "28nm": FABRIC_28NM,
}


class CapacityError(RuntimeError):
    pass


# --------------------------------------------------------------------------
# Configured fabric (== decoded bitstream)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class FabricConfig:
    """Everything the bitstream encodes, in levelized-array form.

    ``cell_of_lut[i]`` maps kernel LUT slot i to a physical logic cell index
    (tile-major) — the placement. The arrays mirror LevelizedNetlist so the
    Pallas kernel and the host simulator consume a decoded bitstream
    directly.
    """

    fabric_name: str
    n_nets: int
    n_inputs: int
    n_ffs: int
    level_sizes: List[int]
    lut_inputs: np.ndarray    # (n_luts, 4) int32
    lut_tables: np.ndarray    # (n_luts, 16) uint8
    output_nets: np.ndarray   # (n_outputs,) int32
    ff_d_nets: np.ndarray     # (n_ffs,) int32
    ff_init: np.ndarray       # (n_ffs,) uint8
    cell_of_lut: np.ndarray   # (n_luts,) int32
    cell_of_ff: np.ndarray    # (n_ffs,) int32

    @property
    def n_luts(self) -> int:
        return len(self.lut_inputs)

    @property
    def spec(self) -> FabricSpec:
        return FABRICS[self.fabric_name]

    def fanin_reach(self) -> int:
        """Max levels any LUT-to-LUT edge spans (>= 1).

        This is the K of the banded lut_eval routing: level l only reads
        primary inputs plus LUT outputs from levels [l-K, l). Derived from
        the decoded bitstream arrays, so it survives encode/decode.
        """
        return _fanin_reach(
            self.level_sizes, self.lut_inputs, 2 + self.n_inputs + self.n_ffs
        )

    def utilization(self) -> Dict[str, float]:
        spec = self.spec
        cells_used = len(
            np.unique(np.concatenate([self.cell_of_lut, self.cell_of_ff]))
        ) if (self.n_luts or self.n_ffs) else 0
        return {
            "luts": self.n_luts,
            "ffs": self.n_ffs,
            "logic_cells_used": cells_used,
            "logic_cells_total": spec.n_logic_cells,
            "lut_utilization": self.n_luts / spec.n_logic_cells,
            "depth": len(self.level_sizes),
        }


def place_and_route(netlist: Netlist, fabric: FabricSpec) -> FabricConfig:
    """Map a netlist into the fabric (first-fit packing + capacity checks).

    Packing rule (mirrors LUT4AB cells): a FF whose D input is the output of
    a LUT shares that LUT's cell; other FFs take a cell of their own.
    """
    lv = netlist.to_levelized()
    spec = fabric

    n_cells = spec.n_logic_cells
    lut_out_net = {}  # kernel-order net of each lut slot
    base = lv.base_comb
    for i in range(lv.n_luts):
        lut_out_net[base + i] = i

    cell_of_lut = np.arange(lv.n_luts, dtype=np.int32)
    cell_of_ff = np.full(lv.n_ffs, -1, dtype=np.int32)
    next_free = lv.n_luts
    for s in range(lv.n_ffs):
        d = int(lv.ff_d_nets[s])
        if d in lut_out_net:  # pack with driving LUT's cell
            cell_of_ff[s] = cell_of_lut[lut_out_net[d]]
        else:
            cell_of_ff[s] = next_free
            next_free += 1

    cells_used = max(int(next_free), lv.n_luts)
    if cells_used > n_cells:
        raise CapacityError(
            f"{netlist.n_luts} LUTs + {netlist.n_ffs} FFs need {cells_used} "
            f"logic cells; fabric {spec.name} has {n_cells}"
        )
    if lv.n_inputs > spec.input_capacity:
        raise CapacityError(
            f"netlist needs {lv.n_inputs} input bits; fabric {spec.name} "
            f"exposes {spec.input_capacity}"
        )
    if len(lv.output_nets) > spec.output_capacity:
        raise CapacityError(
            f"netlist needs {len(lv.output_nets)} output bits; fabric "
            f"{spec.name} exposes {spec.output_capacity}"
        )

    return FabricConfig(
        fabric_name=spec.name,
        n_nets=lv.n_nets,
        n_inputs=lv.n_inputs,
        n_ffs=lv.n_ffs,
        level_sizes=list(lv.level_sizes),
        lut_inputs=lv.lut_inputs.copy(),
        lut_tables=lv.lut_tables.copy(),
        output_nets=lv.output_nets.copy(),
        ff_d_nets=lv.ff_d_nets.copy(),
        ff_init=lv.ff_init.copy(),
        cell_of_lut=cell_of_lut,
        cell_of_ff=cell_of_ff,
    )


# --------------------------------------------------------------------------
# Multi-config stacking (many configured chips, one batched evaluation)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrontendSpec:
    """Feature-stage metadata of a frames-ingesting (fused) stack.

    A stack that scores RAW sensor frames carries the featurizer contract
    alongside the fabric envelope: the frame tensor shape, the feature
    vector width the frames->features stage produces, and the
    zero-suppression threshold baked into that stage. A chip hot-swapping
    into such a stack must be *encodable* from those features (every used
    feature index < n_features, int32-representable spec) — the server
    enforces this on reconfigure, the same way the fabric axes are
    enforced via ``admits``.
    """

    n_features: int
    frame_shape: Tuple[int, int, int]   # (n_t, n_y, n_x)
    threshold_electrons: float


@dataclasses.dataclass(frozen=True)
class StackGeometry:
    """Shared padded geometry a set of decoded bitstreams can stack into.

    Two configs are stack-compatible when both fit the same (levels, widest
    level, inputs, outputs) envelope; a config narrower on any axis is
    zero-padded up to it. This is what lets N heterogeneous chips share one
    chip-batched kernel dispatch — and what lets a *new* bitstream hot-swap
    into a running stack without recompiling, as long as it fits the
    envelope (the paper's reconfigurability property, now per-slot).
    """

    n_levels: int
    max_level_size: int
    n_inputs: int
    n_outputs: int
    # Fan-in-reach budget of the envelope: a banded stack only routes a
    # window of this many preceding levels into each level's matmul, so a
    # config with larger reach cannot hot-swap in. None = unconstrained
    # (dense stacks admit any reach <= n_levels).
    fanin_reach: Optional[int] = None
    # Feature-stage metadata when the stack ingests raw frames (the fused
    # frontend, kernels/frontend.py). None = the stack is fed pre-packed
    # input bits / host-computed features and has no featurizer contract.
    frontend: Optional[FrontendSpec] = None

    @classmethod
    def union(cls, configs: Sequence["FabricConfig"]) -> "StackGeometry":
        if not configs:
            raise ValueError("cannot stack zero configs")
        return cls(
            n_levels=max(max(len(c.level_sizes), 1) for c in configs),
            max_level_size=max(
                max(c.level_sizes, default=1) for c in configs
            ),
            n_inputs=max(c.n_inputs for c in configs),
            n_outputs=max(len(c.output_nets) for c in configs),
            fanin_reach=max(c.fanin_reach() for c in configs),
        )

    def admits(self, config: "FabricConfig") -> bool:
        """True if `config` fits this envelope (can swap into the stack)."""
        return (
            len(config.level_sizes) <= self.n_levels
            and max(config.level_sizes, default=1) <= self.max_level_size
            and config.n_inputs <= self.n_inputs
            and len(config.output_nets) <= self.n_outputs
            and (
                self.fanin_reach is None
                or config.fanin_reach() <= self.fanin_reach
            )
        )


def check_stackable(configs: Sequence[FabricConfig]) -> StackGeometry:
    """Validate a set of configs for chip-batched evaluation.

    All must be combinational (the batched kernel path, like lut_eval) and
    each must individually respect its own fabric's capacity — stacking
    never relaxes per-chip capacity.
    """
    geo = StackGeometry.union(configs)
    for i, c in enumerate(configs):
        if c.n_ffs:
            raise CapacityError(
                f"config {i} ({c.fabric_name}) is sequential ({c.n_ffs} FFs);"
                " chip-batched evaluation is combinational-only"
            )
    return geo


def stack_event_bits(
    per_chip_bits: Sequence[np.ndarray], n_inputs: int
) -> np.ndarray:
    """Zero-pad per-chip (B_i, n_inputs_i) bit arrays into the stacked
    (C, B_max, n_inputs) layout. THE padding convention: both the Pallas
    kernel packing (kernels/lut_eval/ops.py) and the host oracle consume
    this one layout, so the bit-identical guarantee has a single source."""
    C = len(per_chip_bits)
    B = max((len(b) for b in per_chip_bits), default=0)
    out = np.zeros((C, B, n_inputs), np.uint8)
    for i, b in enumerate(per_chip_bits):
        b = np.asarray(b, np.uint8)
        if b.size:
            assert b.shape[1] <= n_inputs, (b.shape, n_inputs)
            out[i, : len(b), : b.shape[1]] = b
    return out


def packed_table_image(
    config: FabricConfig, n_levels: int, m_pad: int
) -> np.ndarray:
    """The configuration-memory image of a config's truth tables in the
    padded (level, slot-in-level) layout: (n_levels, m_pad, 16) uint8,
    zero on unoccupied slots.

    This is THE scrub-loop representation: the kernel stack packs its
    device ``tables`` arrays through this function (kernels/lut_eval),
    readback returns it, and the golden CRC digests (core.bitstream) are
    computed over it — so "readback equals golden" is a structural
    identity, not two parallel packings that merely happen to agree.
    """
    c = config
    assert len(c.level_sizes) <= n_levels, (len(c.level_sizes), n_levels)
    assert max(c.level_sizes, default=1) <= m_pad, (c.level_sizes, m_pad)
    img = np.zeros((n_levels, m_pad, 16), np.uint8)
    if c.n_luts:
        sizes = np.asarray(c.level_sizes, np.int64)
        lut_level = np.repeat(np.arange(len(sizes)), sizes)
        starts = np.concatenate([[0], np.cumsum(sizes)])
        pos = np.arange(c.n_luts) - starts[lut_level]
        img[lut_level, pos] = c.lut_tables
    return img


class MultiFabricSim:
    """Per-chip numpy oracle for a stacked batch of combinational chips.

    Input is the stacked layout the kernel consumes: bits (C, B, n_inputs)
    zero-padded to the geometry's input width. Output is (C, B, n_outputs)
    zero-padded — padded output lanes read constant 0, matching the
    kernel's const0-net padding.

    ``geometry`` pins an explicit (usually wider) envelope — e.g. a
    readout server's fixed stack envelope — so the oracle's dims stay
    stable when a chip is hot-swapped for a narrower one. Every config
    must fit it.
    """

    def __init__(self, configs: Sequence[FabricConfig],
                 geometry: Optional[StackGeometry] = None):
        base = check_stackable(configs)
        if geometry is None:
            geometry = base
        else:
            for i, c in enumerate(configs):
                if not geometry.admits(c):
                    raise CapacityError(
                        f"config {i} does not fit pinned envelope {geometry}"
                    )
        self.geometry = geometry
        self.configs = list(configs)
        self._sims = [FabricSim(c) for c in configs]

    def swap_config(self, index: int, config: "FabricConfig") -> None:
        """Replace ONE slot's config in place, rebuilding only that
        slot's simulator — the host-backend hot-swap/SEU-injection path
        (a full-fleet rebuild per flipped bit would make a fault-
        injection sweep O(chips x replicas) per flip). The config must
        fit the pinned envelope, like construction."""
        if config.n_ffs:
            raise CapacityError(
                f"config is sequential ({config.n_ffs} FFs); chip-batched "
                "evaluation is combinational-only")
        if not self.geometry.admits(config):
            raise CapacityError(
                f"config does not fit pinned envelope {self.geometry}")
        self.configs[index] = config
        self._sims[index] = FabricSim(config)

    def readback_tables(
        self, index: int, n_levels: int, m_pad: int
    ) -> np.ndarray:
        """Host-oracle scrub twin of ``PackedFabricStack.readback_replica``:
        the LIVE truth-table image of one simulated slot, in the same
        padded (n_levels, m_pad, 16) uint8 layout the device readback
        uses — so one golden CRC digest verifies both backends. Reads the
        simulator's own config (the image ``swap_config`` perturbs), not
        any cached golden copy."""
        if not 0 <= index < len(self.configs):
            raise ValueError(
                f"index must be in [0, {len(self.configs)}), got {index!r}")
        return packed_table_image(self.configs[index], n_levels, m_pad)

    def run(self, bits: np.ndarray) -> np.ndarray:
        bits = np.asarray(bits, np.uint8)
        C, B = bits.shape[0], bits.shape[1]
        assert C == len(self.configs), (C, len(self.configs))
        assert bits.shape[2] == self.geometry.n_inputs
        out = np.zeros((C, B, self.geometry.n_outputs), np.uint8)
        for i, sim in enumerate(self._sims):
            c = self.configs[i]
            o, _ = sim.run(bits[i, :, : c.n_inputs])
            out[i, :, : o.shape[1]] = o
        return out


# --------------------------------------------------------------------------
# Host-side functional simulator (bit-exact oracle for the Pallas kernel)
# --------------------------------------------------------------------------


class FabricSim:
    """Cycle simulator for a configured fabric (numpy, bit-exact)."""

    def __init__(self, config: FabricConfig):
        self.cfg = config
        c = config
        self._level_start = np.concatenate(
            [[0], np.cumsum(c.level_sizes)]
        ).astype(np.int64)

    def run(
        self,
        input_bits: np.ndarray,
        n_cycles: int = 1,
        state: Optional[np.ndarray] = None,
        trace_outputs: bool = False,
    ):
        """Same contract as Netlist.evaluate, but driven by the decoded
        bitstream arrays (closing the netlist->bitstream->fabric loop)."""
        c = self.cfg
        input_bits = np.asarray(input_bits, np.uint8)
        if input_bits.ndim == 2:
            input_bits = np.repeat(input_bits[:, None, :], n_cycles, axis=1)
        batch = input_bits.shape[0]
        assert input_bits.shape[2] == c.n_inputs

        values = np.zeros((batch, c.n_nets), np.uint8)
        values[:, 1] = 1
        if state is None:
            state = np.tile(c.ff_init, (batch, 1)) if c.n_ffs else np.zeros(
                (batch, 0), np.uint8)

        base = 2 + c.n_inputs + c.n_ffs
        traces = []
        for t in range(n_cycles):
            values[:, 2 : 2 + c.n_inputs] = input_bits[:, t, :]
            if c.n_ffs:
                values[:, 2 + c.n_inputs : base] = state
            for lvi in range(len(c.level_sizes)):
                lo, hi = self._level_start[lvi], self._level_start[lvi + 1]
                ins = c.lut_inputs[lo:hi]          # (m, 4)
                vals = values[:, ins]               # (batch, m, 4)
                idx = (
                    vals[..., 0] + 2 * vals[..., 1] + 4 * vals[..., 2] + 8 * vals[..., 3]
                )
                tbl = c.lut_tables[lo:hi]            # (m, 16)
                values[:, base + lo : base + hi] = np.take_along_axis(
                    tbl[None].repeat(batch, 0), idx[..., None].astype(np.int64), 2
                )[..., 0]
            if c.n_ffs:
                state = values[:, c.ff_d_nets].copy()
            if trace_outputs:
                traces.append(values[:, c.output_nets].copy())
        outs = np.stack(traces, 1) if trace_outputs else values[:, c.output_nets].copy()
        return outs, state


# --------------------------------------------------------------------------
# Bit-sliced host oracle (numpy twin of kernels/lut_eval/bitsliced.py)
# --------------------------------------------------------------------------

_WORD = 32
_ALL_ONES32 = np.uint32(0xFFFFFFFF)


def pack_event_words(bits: np.ndarray) -> np.ndarray:
    """Event-transpose for the bit-sliced layout: (..., B, n) 0/1 bits ->
    (..., W, n) uint32 words, W = ceil(B/32) (at least 1).

    THE word convention: bit ``e`` of word ``w`` is event ``w*32 + e``.
    The device packer (kernels.lut_eval.bitsliced.pack_words) is the jnp
    twin of this function; the property tests in tests/test_bitsliced.py
    hold the pair bit-identical (round-trip, arbitrary tails). Events
    past B land in zero tail lanes.
    """
    bits = np.asarray(bits, np.uint8)
    B = bits.shape[-2]
    W = max(-(-B // _WORD), 1)
    pad = W * _WORD - B
    if pad:
        widths = [(0, 0)] * (bits.ndim - 2) + [(0, pad), (0, 0)]
        bits = np.pad(bits, widths)
    b = bits.reshape(bits.shape[:-2] + (W, _WORD, bits.shape[-1]))
    b = b.astype(np.uint32)
    shifts = np.arange(_WORD, dtype=np.uint32)[:, None]     # (32, 1)
    return np.bitwise_or.reduce(b << shifts, axis=-2).astype(np.uint32)


def unpack_event_words(words: np.ndarray, n_events: int) -> np.ndarray:
    """Inverse event-transpose: (..., W, n) uint32 -> (..., B, n) uint8.

    Exact inverse of ``pack_event_words`` for n_events <= W*32; tail
    lanes (events >= n_events) are dropped — padding lanes can never
    leak past this function.
    """
    words = np.asarray(words, np.uint32)
    W = words.shape[-2]
    shifts = np.arange(_WORD, dtype=np.uint32)[:, None]     # (32, 1)
    b = (words[..., None, :] >> shifts) & np.uint32(1)
    b = b.reshape(words.shape[:-2] + (W * _WORD, words.shape[-1]))
    return b[..., :n_events, :].astype(np.uint8)


class BitslicedSim:
    """Host oracle for the bit-sliced evaluator: 32 events per word.

    Independently written against the RAW decoded-bitstream arrays (net
    ids, no kernel padding) — like FabricSim is for the matmul kernel —
    so agreement with the device path (kernels/lut_eval/bitsliced.py,
    which evaluates the PACKED layout) is a real cross-check, not the
    same packing read back twice. Each 4-LUT is the 15-op bitwise mux
    tree over uint32 words; combinational configs only.

    ``band_k`` makes this the BANDED oracle: the band is a fan-in-reach
    envelope (a routing constraint), not an evaluation structure, so a
    banded fabric must *reject* configs whose reach exceeds K at
    admission — with a named error, the host twin of the device
    packer's check — and then evaluate admitted configs identically to
    the unbanded case. That identity (validation changes, outputs don't)
    is exactly what the conformance suite pins.
    """

    def __init__(self, config: FabricConfig, band_k: int | None = None):
        if config.n_ffs:
            raise CapacityError(
                f"config is sequential ({config.n_ffs} FFs); bit-sliced "
                "evaluation is combinational-only"
            )
        if band_k is not None:
            reach = config.fanin_reach()
            if reach > band_k:
                raise ValueError(
                    f"fan-in reach exceeds band: K={band_k} but the "
                    f"config's reach is {reach}"
                )
        self.band_k = band_k
        self.cfg = config
        self._level_start = np.concatenate(
            [[0], np.cumsum(config.level_sizes)]
        ).astype(np.int64)

    def run_words(self, in_words: np.ndarray) -> np.ndarray:
        """(W, n_inputs) uint32 input words -> (W, n_outputs) uint32."""
        c = self.cfg
        in_words = np.asarray(in_words, np.uint32)
        W = in_words.shape[0]
        assert in_words.shape[1] == c.n_inputs, (
            in_words.shape, c.n_inputs)
        vals = np.zeros((W, c.n_nets), np.uint32)
        vals[:, 1] = _ALL_ONES32                       # const1: all lanes
        vals[:, 2 : 2 + c.n_inputs] = in_words
        base = 2 + c.n_inputs
        for lvi in range(len(c.level_sizes)):
            lo, hi = self._level_start[lvi], self._level_start[lvi + 1]
            g = vals[:, c.lut_inputs[lo:hi]]           # (W, m, 4)
            t = np.where(
                c.lut_tables[lo:hi][None] != 0, _ALL_ONES32, np.uint32(0)
            )                                          # (1, m, 16)
            for k in range(4):
                s = g[:, :, k : k + 1]                 # (W, m, 1)
                t = (s & t[..., 1::2]) | (~s & t[..., 0::2])
            vals[:, base + lo : base + hi] = t[..., 0]
        return vals[:, c.output_nets].copy()

    def run(self, bits: np.ndarray) -> np.ndarray:
        """Same contract as FabricSim.run for one combinational pass:
        (B, n_inputs) 0/1 -> (B, n_outputs) uint8, via the word
        transpose (pack -> run_words -> unpack)."""
        bits = np.asarray(bits, np.uint8)
        B = bits.shape[0]
        return unpack_event_words(self.run_words(pack_event_words(bits)), B)
