"""Gradient-boosted decision trees, from scratch (no sklearn in this container).

The paper trains "a single tree with a depth of 5 ... using gradient boosting
with the scikit-learn package" for the pileup classification task, then
synthesizes it with Conifer onto the 28nm eFPGA.

We reproduce the same algorithm family:

  * binary log-loss gradient boosting (sklearn ``GradientBoostingClassifier``
    semantics): F0 = prior log-odds; each stage fits a regression tree to the
    residuals ``r_i = y_i - sigmoid(F(x_i))`` with Friedman's MSE criterion,
    and leaf values take a Newton step ``sum(r) / sum(p (1-p))``;
  * histogram-based exact-greedy split search (256 quantile bins) so training
    on 500k x 14 is fast in pure numpy;
  * flat-array tree representation (feature / threshold / children / value)
    that downstream synthesis (``core/synth.py``) and the Pallas inference
    kernel (``kernels/bdt_infer``) consume directly;
  * a *quantized* evaluation path in which thresholds live on the
    ap_fixed<W,I> grid and comparisons are exact integer compares — this is
    the "golden model" the fabric must match 100%.

The ensemble generalizes beyond the paper's single tree (their limit was the
448-LUT fabric, not the algorithm); ``n_estimators`` is free.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.quantize import FixedSpec, AP_FIXED_28_19, quantize_raw

LEAF = -1  # sentinel in the `feature` array


@dataclasses.dataclass
class Tree:
    """Flat binary tree. Node 0 is the root.

    feature[i] == LEAF marks a leaf; value[i] is the leaf value (logit
    contribution). Internal nodes route LEFT iff x[feature] <= threshold
    (sklearn / Conifer convention).
    """

    feature: np.ndarray       # (n_nodes,) int32
    threshold: np.ndarray     # (n_nodes,) float64
    children_left: np.ndarray   # (n_nodes,) int32
    children_right: np.ndarray  # (n_nodes,) int32
    value: np.ndarray         # (n_nodes,) float64

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    @property
    def n_leaves(self) -> int:
        return int((self.feature == LEAF).sum())

    @property
    def n_internal(self) -> int:
        return self.n_nodes - self.n_leaves

    def depth(self) -> int:
        d = np.zeros(self.n_nodes, dtype=np.int32)
        for i in range(self.n_nodes):
            if self.feature[i] != LEAF:
                d[self.children_left[i]] = d[i] + 1
                d[self.children_right[i]] = d[i] + 1
        return int(d.max()) if self.n_nodes else 0

    def used_features(self) -> np.ndarray:
        return np.unique(self.feature[self.feature != LEAF])

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Vectorized float-domain traversal."""
        n = len(X)
        node = np.zeros(n, dtype=np.int32)
        for _ in range(self.depth() + 1):
            f = self.feature[node]
            is_leaf = f == LEAF
            go_left = X[np.arange(n), np.maximum(f, 0)] <= self.threshold[node]
            nxt = np.where(go_left, self.children_left[node], self.children_right[node])
            node = np.where(is_leaf, node, nxt).astype(np.int32)
        return self.value[node]

    def quantized(self, spec: FixedSpec) -> "QuantizedTree":
        return QuantizedTree.from_tree(self, spec)


@dataclasses.dataclass
class QuantizedTree:
    """Tree with thresholds and leaf values on the ap_fixed grid (raw ints).

    This is the "golden model" of the paper's §5: once thresholds are raw
    integers, traversal is exact, and the fabric-executed netlist must agree
    on every event.
    """

    feature: np.ndarray
    threshold_raw: np.ndarray  # (n_nodes,) int64 on the fixed grid
    children_left: np.ndarray
    children_right: np.ndarray
    value_raw: np.ndarray      # (n_nodes,) int64 leaf logits on the fixed grid
    spec: FixedSpec

    @classmethod
    def from_tree(cls, tree: Tree, spec: FixedSpec) -> "QuantizedTree":
        return cls(
            feature=tree.feature.copy(),
            threshold_raw=quantize_raw(tree.threshold, spec),
            children_left=tree.children_left.copy(),
            children_right=tree.children_right.copy(),
            value_raw=quantize_raw(tree.value, spec),
            spec=spec,
        )

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    def depth(self) -> int:
        d = np.zeros(self.n_nodes, dtype=np.int32)
        for i in range(self.n_nodes):
            if self.feature[i] != LEAF:
                d[self.children_left[i]] = d[i] + 1
                d[self.children_right[i]] = d[i] + 1
        return int(d.max()) if self.n_nodes else 0

    def predict_raw(self, X_raw: np.ndarray) -> np.ndarray:
        """Exact integer-domain traversal: X_raw is (n, n_features) int64."""
        n = len(X_raw)
        node = np.zeros(n, dtype=np.int32)
        for _ in range(self.depth() + 1):
            f = self.feature[node]
            is_leaf = f == LEAF
            go_left = X_raw[np.arange(n), np.maximum(f, 0)] <= self.threshold_raw[node]
            nxt = np.where(go_left, self.children_left[node], self.children_right[node])
            node = np.where(is_leaf, node, nxt).astype(np.int32)
        return self.value_raw[node]


# --------------------------------------------------------------------------
# Histogram-based regression tree fitting (Friedman MSE + Newton leaves)
# --------------------------------------------------------------------------


def _quantile_bin_edges(X: np.ndarray, n_bins: int) -> List[np.ndarray]:
    edges = []
    for j in range(X.shape[1]):
        qs = np.quantile(X[:, j], np.linspace(0, 1, n_bins + 1)[1:-1])
        edges.append(np.unique(qs))
    return edges


def _bin_features(X: np.ndarray, edges: List[np.ndarray]) -> np.ndarray:
    binned = np.empty(X.shape, dtype=np.int16)
    for j, e in enumerate(edges):
        binned[:, j] = np.searchsorted(e, X[:, j], side="right")
    return binned


@dataclasses.dataclass
class _NodeBuild:
    node_id: int
    sample_idx: np.ndarray
    depth: int


def _fit_regression_tree(
    Xb: np.ndarray,
    edges: List[np.ndarray],
    X: np.ndarray,
    grad: np.ndarray,
    hess: np.ndarray,
    max_depth: int,
    min_samples_leaf: int,
    max_leaf_nodes: Optional[int] = None,
) -> Tree:
    """Grow one regression tree on (grad, hess) with histogram splits.

    Split criterion: Friedman variance reduction on the residuals
    (maximize S_L^2/n_L + S_R^2/n_R); leaf value: Newton step
    sum(grad)/sum(hess). Matches sklearn's GradientBoosting tree stage.
    """
    n_features = Xb.shape[1]
    feature, threshold, left, right, value = [], [], [], [], []

    def new_node() -> int:
        feature.append(LEAF)
        threshold.append(0.0)
        left.append(-1)
        right.append(-1)
        value.append(0.0)
        return len(feature) - 1

    root = new_node()
    stack = [_NodeBuild(root, np.arange(len(Xb)), 0)]
    n_leaves = 1

    while stack:
        nb = stack.pop()
        idx = nb.sample_idx
        g = grad[idx]
        h = hess[idx]
        G, H, n = g.sum(), h.sum(), len(idx)
        # Newton leaf value (set now; overwritten only by recursion bookkeeping).
        value[nb.node_id] = float(G / max(H, 1e-12))

        if nb.depth >= max_depth or n < 2 * min_samples_leaf:
            continue
        if max_leaf_nodes is not None and n_leaves >= max_leaf_nodes:
            continue

        parent_score = G * G / max(n, 1)
        best = (0.0, -1, -1)  # (gain, feature, bin)
        xb = Xb[idx]
        for j in range(n_features):
            nb_bins = len(edges[j]) + 1
            if nb_bins < 2:
                continue
            sums = np.bincount(xb[:, j], weights=g, minlength=nb_bins)
            cnts = np.bincount(xb[:, j], minlength=nb_bins)
            cs = np.cumsum(sums)[:-1]
            cc = np.cumsum(cnts)[:-1]
            nl = cc
            nr = n - cc
            ok = (nl >= min_samples_leaf) & (nr >= min_samples_leaf)
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = cs * cs / np.maximum(nl, 1) + (G - cs) ** 2 / np.maximum(nr, 1)
            gain = np.where(ok, gain - parent_score, -np.inf)
            b = int(np.argmax(gain))
            if gain[b] > best[0]:
                best = (float(gain[b]), j, b)

        gain, j, b = best
        if j < 0 or gain <= 1e-12:
            continue

        thr = float(edges[j][b])  # split: x <= thr goes left
        go_left = X[idx, j] <= thr
        li, ri = idx[go_left], idx[~go_left]
        if len(li) < min_samples_leaf or len(ri) < min_samples_leaf:
            continue

        lid, rid = new_node(), new_node()
        feature[nb.node_id] = j
        threshold[nb.node_id] = thr
        left[nb.node_id] = lid
        right[nb.node_id] = rid
        n_leaves += 1
        stack.append(_NodeBuild(lid, li, nb.depth + 1))
        stack.append(_NodeBuild(rid, ri, nb.depth + 1))

    return Tree(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float64),
        children_left=np.asarray(left, np.int32),
        children_right=np.asarray(right, np.int32),
        value=np.asarray(value, np.float64),
    )


# --------------------------------------------------------------------------
# Gradient boosting
# --------------------------------------------------------------------------


def _sigmoid(z: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-np.clip(z, -60, 60)))


@dataclasses.dataclass
class GradientBoostedClassifier:
    """Binary GBM with log loss. Paper config: n_estimators=1, max_depth=5."""

    n_estimators: int = 1
    max_depth: int = 5
    learning_rate: float = 0.1
    min_samples_leaf: int = 64
    n_bins: int = 256
    max_leaf_nodes: Optional[int] = None

    trees: List[Tree] = dataclasses.field(default_factory=list)
    f0: float = 0.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GradientBoostedClassifier":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        p = float(np.clip(y.mean(), 1e-6, 1 - 1e-6))
        self.f0 = float(np.log(p / (1 - p)))
        F = np.full(len(y), self.f0)
        edges = _quantile_bin_edges(X, self.n_bins)
        Xb = _bin_features(X, edges)
        self.trees = []
        for _ in range(self.n_estimators):
            prob = _sigmoid(F)
            grad = y - prob          # negative gradient of log loss
            hess = prob * (1 - prob)
            tree = _fit_regression_tree(
                Xb, edges, X, grad, hess,
                self.max_depth, self.min_samples_leaf, self.max_leaf_nodes,
            )
            self.trees.append(tree)
            F = F + self.learning_rate * tree.predict(X)
        return self

    # --- float ("pre-quantization") path ---
    def decision_function(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        F = np.full(len(X), self.f0)
        for t in self.trees:
            F = F + self.learning_rate * t.predict(X)
        return F

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return _sigmoid(self.decision_function(X))

    # --- quantized ("golden") path ---
    def quantized(self, spec: FixedSpec = AP_FIXED_28_19) -> "QuantizedEnsemble":
        return QuantizedEnsemble(
            trees=[t.quantized(spec) for t in self.trees],
            # fold learning rate + f0 into the quantized leaf values:
            lr=self.learning_rate,
            f0=self.f0,
            spec=spec,
        )


@dataclasses.dataclass
class QuantizedEnsemble:
    """Golden quantized model: integer thresholds, integer leaf logits.

    The learning-rate-scaled leaf values and f0 are folded into the fixed
    grid at construction so the whole decision function is integer-exact.
    """

    trees: List[QuantizedTree]
    lr: float
    f0: float
    spec: FixedSpec

    def __post_init__(self):
        # Fold lr into leaf values (re-quantize the scaled leaves).
        folded = []
        for qt in self.trees:
            scaled = qt.value_raw / qt.spec.scale * self.lr
            folded.append(
                QuantizedTree(
                    feature=qt.feature,
                    threshold_raw=qt.threshold_raw,
                    children_left=qt.children_left,
                    children_right=qt.children_right,
                    value_raw=quantize_raw(scaled, qt.spec),
                    spec=qt.spec,
                )
            )
        self.trees = folded
        self.f0_raw = int(quantize_raw(np.asarray(self.f0), self.spec))

    def quantize_features(self, X: np.ndarray) -> np.ndarray:
        return quantize_raw(np.asarray(X, np.float64), self.spec)

    def decision_function_raw(self, X_raw: np.ndarray) -> np.ndarray:
        acc = np.full(len(X_raw), self.f0_raw, dtype=np.int64)
        for qt in self.trees:
            acc = acc + qt.predict_raw(X_raw)
        return acc

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        return self.decision_function_raw(self.quantize_features(X)) / self.spec.scale

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return _sigmoid(self.decision_function(X))


# --------------------------------------------------------------------------
# Metrics (paper Table 1 vocabulary)
# --------------------------------------------------------------------------


def signal_eff_background_rej(
    score: np.ndarray, is_pileup: np.ndarray, thresholds: np.ndarray
) -> List[Tuple[float, float, float]]:
    """Paper convention: score = P(pileup). A track is REJECTED if score > thr.

    signal efficiency    = fraction of non-pileup (high-pT) tracks retained
    background rejection = fraction of pileup tracks rejected
    Returns [(thr, sig_eff, bkg_rej)].
    """
    is_pu = is_pileup.astype(bool)
    out = []
    for thr in np.atleast_1d(thresholds):
        keep = score <= thr
        sig_eff = float(keep[~is_pu].mean()) if (~is_pu).any() else float("nan")
        bkg_rej = float((~keep)[is_pu].mean()) if is_pu.any() else float("nan")
        out.append((float(thr), sig_eff, bkg_rej))
    return out


def operating_point_at_signal_eff(
    score: np.ndarray, is_pileup: np.ndarray, target_sig_eff: float
) -> Tuple[float, float, float]:
    """Find the threshold whose signal efficiency is closest to the target.

    A depth-5 tree emits only ~10 distinct scores (one per leaf), so the
    achievable operating points are discrete — we enumerate the unique
    score values as candidate thresholds (this is also what the paper's
    Table 1 reflects: three discrete achievable points)."""
    cands = np.unique(score)
    rows = signal_eff_background_rej(score, is_pileup, cands)
    best = min(rows, key=lambda r: (abs(r[1] - target_sig_eff), -r[2]))
    return best
