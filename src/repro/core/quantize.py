"""ap_fixed<W,I> fixed-point arithmetic, bit-exact with HLS semantics.

The paper synthesizes the BDT with ``ap_fixed<28,19>`` (Vivado/Vitis HLS):
  - W  = total width in bits (including sign)
  - I  = integer bits (including sign); F = W - I fractional bits
  - default quantization mode AP_TRN (truncate toward -inf)
  - default overflow mode     AP_WRAP (two's-complement wraparound)

We back the representation with exact int64 raw values (value = raw / 2**F)
so that threshold comparisons inside the synthesized netlist are *exact*
integer comparisons — this is what makes the paper's "100% agreement with the
golden model" experiment reproducible bit-for-bit.

This module is deliberately numpy-based: quantization happens host-side (data
preparation and synthesis). JAX runs with 32-bit defaults in this framework,
so the device-side kernels consume int32 raw values (W <= 31 is asserted at
the kernel boundary); the full-precision multiply path needs int64 and stays
on host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class FixedSpec:
    """Static description of an ap_fixed<width, int_bits> type."""

    width: int = 28
    int_bits: int = 19
    rounding: str = "trn"  # "trn" (AP_TRN, floor) | "rnd" (AP_RND, round-half-up)
    overflow: str = "wrap"  # "wrap" (AP_WRAP) | "sat" (AP_SAT)

    def __post_init__(self):
        if not (1 <= self.width <= 62):
            raise ValueError(f"width must be in [1, 62], got {self.width}")
        if not (0 <= self.int_bits <= self.width):
            raise ValueError(f"int_bits must be in [0, width], got {self.int_bits}")
        if self.rounding not in ("trn", "rnd"):
            raise ValueError(f"unknown rounding mode {self.rounding!r}")
        if self.overflow not in ("wrap", "sat"):
            raise ValueError(f"unknown overflow mode {self.overflow!r}")

    @property
    def frac_bits(self) -> int:
        return self.width - self.int_bits

    @property
    def scale(self) -> float:
        return float(2.0 ** self.frac_bits)

    @property
    def raw_min(self) -> int:
        return -(1 << (self.width - 1))

    @property
    def raw_max(self) -> int:
        return (1 << (self.width - 1)) - 1

    @property
    def min_value(self) -> float:
        return self.raw_min / self.scale

    @property
    def max_value(self) -> float:
        return self.raw_max / self.scale

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale


# The paper's synthesis precision.
AP_FIXED_28_19 = FixedSpec(width=28, int_bits=19)


def _wrap(raw: np.ndarray, spec: FixedSpec) -> np.ndarray:
    """Two's complement wraparound into [raw_min, raw_max]."""
    span = np.int64(1) << np.int64(spec.width)
    half = np.int64(1) << np.int64(spec.width - 1)
    # ((raw + half) mod span) - half, with python-style (floored) modulo.
    return ((raw + half) % span) - half


def _saturate(raw: np.ndarray, spec: FixedSpec) -> np.ndarray:
    return np.clip(raw, spec.raw_min, spec.raw_max)


def _overflow(raw: np.ndarray, spec: FixedSpec) -> np.ndarray:
    if spec.overflow == "sat":
        return _saturate(raw, spec)
    return _wrap(raw, spec)


def quantize_raw(x, spec: FixedSpec) -> np.ndarray:
    """float -> raw int64 per the spec's rounding + overflow modes."""
    x = np.asarray(x, dtype=np.float64)
    scaled = x * spec.scale
    if spec.rounding == "trn":
        raw = np.floor(scaled)
    else:  # AP_RND: round-half-up (add 0.5 ulp then truncate)
        raw = np.floor(scaled + 0.5)
    raw = raw.astype(np.int64)
    return _overflow(raw, spec)


def dequantize_raw(raw, spec: FixedSpec) -> np.ndarray:
    return np.asarray(raw, dtype=np.float64) / spec.scale


def quantize(x, spec: FixedSpec = AP_FIXED_28_19) -> np.ndarray:
    """Round-trip a float array through the fixed-point grid."""
    return dequantize_raw(quantize_raw(x, spec), spec)


# --- raw-domain arithmetic (the synthesized netlist's integer semantics) ----


def fx_add(a_raw, b_raw, spec: FixedSpec) -> np.ndarray:
    return _overflow(np.asarray(a_raw, np.int64) + np.asarray(b_raw, np.int64), spec)


def fx_sub(a_raw, b_raw, spec: FixedSpec) -> np.ndarray:
    return _overflow(np.asarray(a_raw, np.int64) - np.asarray(b_raw, np.int64), spec)


def fx_mul(a_raw, b_raw, spec: FixedSpec) -> np.ndarray:
    """Full-precision product then truncate back to spec (AP_TRN).

    The product of two W-bit values carries 2F fractional bits; the arithmetic
    right shift by F is AP_TRN (floor) for two's complement.
    """
    if 2 * spec.width > 62:
        raise ValueError("product would overflow int64; reduce width")
    prod = np.asarray(a_raw, np.int64) * np.asarray(b_raw, np.int64)
    shifted = prod >> np.int64(spec.frac_bits)
    return _overflow(shifted, spec)


def fx_lt(a_raw, b_raw) -> np.ndarray:
    """Exact fixed-point comparison (what the LUT comparators compute)."""
    return np.asarray(a_raw, np.int64) < np.asarray(b_raw, np.int64)


def fx_le(a_raw, b_raw) -> np.ndarray:
    return np.asarray(a_raw, np.int64) <= np.asarray(b_raw, np.int64)


def to_unsigned_bits(raw, spec: FixedSpec) -> np.ndarray:
    """Map signed raw to an order-preserving unsigned bit pattern.

    For building *unsigned* LUT comparators we flip the sign bit: the mapping
    u = twos_complement_pattern(raw) XOR (1 << (W-1)) is monotone from signed
    order to unsigned order, so ``a < b  <=>  u(a) < u(b)`` with plain
    unsigned comparison. This is the standard trick used by HLS comparator
    synthesis.
    """
    sign = np.int64(1) << np.int64(spec.width - 1)
    span = np.int64(1) << np.int64(spec.width)
    raw = np.asarray(raw, np.int64)
    pattern = np.where(raw < 0, raw + span, raw)  # two's-complement bit pattern
    return pattern ^ sign  # flip sign bit -> offset binary (order-preserving)


def unsigned_bit(u, bit: int) -> np.ndarray:
    return (np.asarray(u, np.int64) >> np.int64(bit)) & np.int64(1)


# --- device-side (JAX) quantize + offset-binary bit packing ------------------
#
# The fused on-device frontend (kernels/frontend.py) quantizes features and
# packs fabric input bits *inside* the scoring dispatch, so the host packer
# above needs a bit-exact int32 twin that is traceable under jit. JAX runs
# 32-bit here, hence the int32 raw domain and the W <= 31 requirement (the
# same boundary the kernels already assert).
#
# Bit-exactness vs the numpy path holds under two documented preconditions:
#   * |x * scale| < 2**31 (the int32 conversion must not clip) — any
#     physical feature is orders of magnitude inside this;
#   * for rounding="rnd", |x * scale| < 2**23 (the +0.5 ulp must survive
#     float32 addition; the host path adds it in float64). The paper's
#     spec is AP_TRN, which is exact for the full int32 range: x is
#     float32 data, scale a power of two, so x*scale and floor() are both
#     exact float32 operations.
#
# The ``*_device`` helpers take the spec as *arrays* (broadcastable against
# x) instead of a static FixedSpec: the fused multi-chip frontend carries a
# per-chip (C,)-shaped encode plan, so a hot-swapped chip with a different
# spec is an array-row update, never a retrace.


def spec_device_params(spec: FixedSpec) -> Dict[str, np.ndarray]:
    """The per-spec scalars ``quantize_pattern_device`` consumes, as numpy
    values ready to be stacked into a per-chip plan."""
    if spec.width > 31:
        raise ValueError(
            f"device quantize path is int32 (W <= 31), got W={spec.width}"
        )
    no_clip = np.int32(2**31 - 1)
    return {
        "scale": np.float32(spec.scale),
        "rnd_off": np.float32(0.5 if spec.rounding == "rnd" else 0.0),
        "wrap_mask": np.int32((1 << spec.width) - 1),
        "sign_bit": np.int32(1 << (spec.width - 1)),
        "sat_lo": np.int32(spec.raw_min) if spec.overflow == "sat" else -no_clip,
        "sat_hi": np.int32(spec.raw_max) if spec.overflow == "sat" else no_clip,
    }


def quantize_pattern_device(x, *, scale, rnd_off, wrap_mask, sign_bit,
                            sat_lo, sat_hi):
    """float -> offset-binary bit pattern, int32, traceable.

    Mirrors quantize_raw + to_unsigned_bits: scale, round (trn/rnd via
    rnd_off), overflow (sat via the clip bounds, wrap via the mask — the
    masked low W bits of an int32 ARE the two's-complement pattern), then
    the order-preserving sign-bit flip. All spec parameters broadcast
    against x, so one call serves heterogeneous per-chip specs.
    """
    import jax.numpy as jnp

    scaled = x.astype(jnp.float32) * scale + rnd_off
    raw = jnp.floor(scaled).astype(jnp.int32)
    raw = jnp.clip(raw, sat_lo, sat_hi)
    pattern = jnp.bitwise_and(raw, wrap_mask)
    return jnp.bitwise_xor(pattern, sign_bit)


def quantize_raw_jax(x, spec: FixedSpec):
    """float -> raw int32, the device twin of ``quantize_raw``."""
    import jax.numpy as jnp

    p = spec_device_params(spec)
    u = quantize_pattern_device(
        jnp.asarray(x), scale=p["scale"], rnd_off=p["rnd_off"],
        wrap_mask=p["wrap_mask"], sign_bit=p["sign_bit"],
        sat_lo=p["sat_lo"], sat_hi=p["sat_hi"],
    )
    pattern = jnp.bitwise_xor(u, p["sign_bit"])
    span = np.int32(1) << np.int32(spec.width)
    return jnp.where(pattern >= p["sign_bit"], pattern - span, pattern)


def to_unsigned_bits_jax(raw, spec: FixedSpec):
    """raw int32 -> offset-binary pattern, the device twin of
    ``to_unsigned_bits``."""
    import jax.numpy as jnp

    p = spec_device_params(spec)
    pattern = jnp.bitwise_and(jnp.asarray(raw, jnp.int32), p["wrap_mask"])
    return jnp.bitwise_xor(pattern, p["sign_bit"])


def encode_offset_binary_jax(x, spec: FixedSpec):
    """float (..., n) -> 0/1 int32 bits (..., n, W) LSB-first: the device
    twin of the host packer (quantize_raw -> to_unsigned_bits -> unpack)."""
    import jax.numpy as jnp

    p = spec_device_params(spec)
    u = quantize_pattern_device(
        jnp.asarray(x), scale=p["scale"], rnd_off=p["rnd_off"],
        wrap_mask=p["wrap_mask"], sign_bit=p["sign_bit"],
        sat_lo=p["sat_lo"], sat_hi=p["sat_hi"],
    )
    shifts = jnp.arange(spec.width, dtype=jnp.int32)
    return jnp.bitwise_and(
        jnp.right_shift(u[..., None], shifts), jnp.int32(1)
    )
