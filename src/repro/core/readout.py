"""End-to-end at-source readout pipeline (paper §5).

Chains the whole front-end data path:

    sensor frames / features  ->  quantize (ap_fixed)  ->  offset-binary bits
    ->  configured eFPGA fabric (bitstream)  ->  score  ->  keep/drop

and accounts for the data-rate reduction that is the paper's point: at the
LHC every bunch crossing (40 MHz) produces hits; rejecting pileup tracks at
the source shrinks the off-detector link budget.

Two execution backends, behind the ScoringBackend interface (swappable per
call, by name or by instance):
  * "host":  numpy FabricSim (bit-exact oracle)
  * "kernel": the Pallas lut_eval kernel via kernels/lut_eval/ops.py
    (interpret mode on CPU, compiled on TPU)
"""
from __future__ import annotations

import abc
import collections
import dataclasses
from typing import Dict, Optional, Union

import numpy as np

from repro.core.bdt import GradientBoostedClassifier, QuantizedEnsemble
from repro.core.bitstream import decode, encode
from repro.core.fabric import FABRICS, FabricConfig, FabricSim, place_and_route
from repro.core.quantize import AP_FIXED_28_19, FixedSpec
from repro.core.synth import SynthResult, synth_ensemble


# --------------------------------------------------------------------------
# Scoring backends
# --------------------------------------------------------------------------


class ScoringBackend(abc.ABC):
    """Evaluates input bits on a configured fabric.

    The interface point where host-oracle and device execution are
    interchangeable: ReadoutChip and launch/readout_server.py accept either
    a backend name ("host" / "kernel") or an instance, per call. Backends
    cache derived per-config structures (simulators, packed device arrays)
    keyed by config identity, so repeated calls don't re-pack.

    Two entry points, one per ingestion stage:
      * ``score_bits``   — pre-packed fabric input bits (the classic path);
      * ``score_frames`` — RAW charge frames. The base implementation is
        the STAGED pipeline (featurize -> quantize+pack -> score_bits),
        every stage materialized on the host between steps — the oracle
        the fused path is compared against. KernelBackend overrides it
        with the fused single-dispatch frontend (kernels/frontend.py).
    """

    name: str = "?"

    @abc.abstractmethod
    def score_bits(self, config: FabricConfig, bits: np.ndarray) -> np.ndarray:
        """(B, n_inputs) 0/1 -> (B, n_outputs) uint8 output bits."""

    def score_frames(
        self,
        chip: "ReadoutChip",
        frames: np.ndarray,
        y0: np.ndarray,
        feature_tile: int = 128,
        threshold_electrons: float = 800.0,
    ) -> np.ndarray:
        """(B, T, Y, X) charge + (B,) y0 -> (B,) raw integer scores.

        Staged path: the featurizer runs as its own dispatch (it is the
        one float stage, so the SAME per-tile Pallas dot must be used on
        both paths — float matmuls have no order-independent host
        oracle), then numpy quantize + offset-binary packing + the
        backend's own bit scorer. ``feature_tile`` must match the fused
        path's batch_tile for the comparison to be bit-identical.
        """
        from repro.kernels.yprofile import ops as yp_ops

        feats = np.asarray(yp_ops.yprofile(
            frames, y0, threshold_electrons=threshold_electrons,
            batch_tile=feature_tile))
        bits = chip.encode_features(feats)
        outs = self.score_bits(chip.config, bits)
        return chip.synth.decode_outputs(outs)


class _ConfigCache:
    """Small LRU of per-config derived structures.

    Keyed by id() but each entry pins the config object, so entries can't
    go stale through id reuse; bounded so a long-running service that
    keeps reconfiguring doesn't pin every packed fabric it ever saw.
    """

    def __init__(self, build, max_entries: int = 8):
        self._build = build
        self._max = max_entries
        self._entries: "collections.OrderedDict[int, tuple]" = (
            collections.OrderedDict()
        )

    def get(self, config: FabricConfig, build=None):
        """``build`` overrides the default builder for this miss — used
        when the derived structure needs more context than the config
        (e.g. a chip's encode plan for the fused frontend)."""
        entry = self._entries.get(id(config))
        if entry is not None and entry[0] is config:
            self._entries.move_to_end(id(config))
            return entry[1]
        derived = (build or self._build)(config)
        self._entries[id(config)] = (config, derived)
        self._entries.move_to_end(id(config))
        while len(self._entries) > self._max:
            self._entries.popitem(last=False)
        return derived


class HostBackend(ScoringBackend):
    """numpy FabricSim — the bit-exact oracle."""

    name = "host"

    def __init__(self):
        self._sims = _ConfigCache(FabricSim)

    def score_bits(self, config: FabricConfig, bits: np.ndarray) -> np.ndarray:
        outs, _ = self._sims.get(config).run(bits)
        return np.asarray(outs)


class KernelBackend(ScoringBackend):
    """Pallas lut_eval — interpret mode on CPU, Mosaic on TPU.

    ``band`` controls the routing layout used when packing configs:
    None (default) auto-selects banded routing whenever the config's
    fan-in reach makes it cheaper than dense; True/False force it.
    ``layout="bitsliced"`` packs the bit-parallel word layout instead
    (32 events per uint32 word, kernels/lut_eval/bitsliced.py); ``band``
    must stay None then — the gathers have no routing window.
    """

    name = "kernel"

    def __init__(self, batch_tile: int = 128, band: Optional[bool] = None,
                 layout: str = "matmul"):
        self.batch_tile = batch_tile
        self.band = band
        self.layout = layout

        def build(config):
            from repro.kernels.lut_eval import ops as lut_ops

            return lut_ops.pack_fabric(config, band=self.band,
                                       layout=self.layout)

        self._packed = _ConfigCache(build)
        self._frontends = _ConfigCache(None)

    def score_bits(self, config: FabricConfig, bits: np.ndarray) -> np.ndarray:
        from repro.kernels.lut_eval import ops as lut_ops

        return np.asarray(
            lut_ops.fabric_eval(
                self._packed.get(config), bits, batch_tile=self.batch_tile
            )
        )

    def score_frames(
        self,
        chip: "ReadoutChip",
        frames: np.ndarray,
        y0: np.ndarray,
        feature_tile: Optional[int] = None,
        threshold_electrons: float = 800.0,
    ) -> np.ndarray:
        """FUSED path: frames -> features -> bits -> score in one jit'd
        dispatch (kernels/frontend.py), no host materialization between
        stages. ``feature_tile`` is ignored — the fused dispatch tiles
        every stage with this backend's batch_tile."""
        from repro.kernels import frontend as fe

        # cached per (config identity, featurizer threshold): the packed
        # frontend bakes the zero-suppression threshold into its dispatch,
        # so a different threshold must NOT reuse a stale frontend.
        by_thr = self._frontends.get(chip.config, build=lambda _cfg: {})
        front = by_thr.get(float(threshold_electrons))
        if front is None:
            front = fe.pack_frontend(
                [chip.config], [chip.frontend_spec()], band=self.band,
                layout=self.layout, batch_tile=self.batch_tile,
                threshold_electrons=threshold_electrons)
            by_thr[float(threshold_electrons)] = front
        score, _keep = front.score_frames(
            np.asarray(frames)[None], np.asarray(y0)[None])
        return np.asarray(score)[0].astype(np.int64)


_BACKENDS: Dict[str, ScoringBackend] = {}


def get_backend(backend: Union[str, ScoringBackend]) -> ScoringBackend:
    """Resolve "host"/"kernel" to a shared cached instance; pass instances
    through unchanged."""
    if isinstance(backend, ScoringBackend):
        return backend
    if backend not in ("host", "kernel"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend not in _BACKENDS:
        _BACKENDS[backend] = (
            HostBackend() if backend == "host" else KernelBackend()
        )
    return _BACKENDS[backend]


@dataclasses.dataclass
class ReadoutChip:
    """A configured eFPGA acting as the front-end classifier ASIC."""

    synth: SynthResult
    golden: QuantizedEnsemble
    config: FabricConfig
    bitstream: bytes
    score_threshold_raw: int  # reject if score_raw > threshold_raw

    @classmethod
    def build(
        cls,
        clf: GradientBoostedClassifier,
        fabric: str = "efpga_28nm",
        spec: FixedSpec = AP_FIXED_28_19,
        score_threshold: float = 0.5,
        adder: str = "tree",
    ) -> "ReadoutChip":
        """``adder`` is the ensemble summation structure: "tree" (default,
        shallow carry-select reduction — faster to evaluate, ~2.5x the
        adder LUTs) or "ripple" (minimal area, for near-capacity designs).
        Single trees have no adders, so the paper's chip is unaffected."""
        golden = clf.quantized(spec)
        synth = synth_ensemble(golden, adder=adder)
        config = place_and_route(synth.netlist, FABRICS[fabric])
        bs = encode(config)
        # thresholding happens in logit space on the integer grid
        logit = float(np.log(score_threshold / (1 - score_threshold)))
        thr_raw = int(np.floor(logit * spec.scale))
        # reload through the bitstream (the "program the chip" step)
        return cls(
            synth=synth,
            golden=golden,
            config=decode(bs),
            bitstream=bs,
            score_threshold_raw=thr_raw,
        )

    # ---------------------------------------------------------------- run
    def encode_features(self, X: np.ndarray) -> np.ndarray:
        """features (n, 14) float -> fabric input bits (host featurization)."""
        return self.synth.encode_inputs(self.golden.quantize_features(X))

    def infer_raw(
        self, X: np.ndarray, backend: Union[str, ScoringBackend] = "host"
    ) -> np.ndarray:
        """features (n, 14) float -> raw integer scores, via the fabric."""
        bits = self.encode_features(X)
        outs = get_backend(backend).score_bits(self.config, bits)
        return self.synth.decode_outputs(outs)

    def frontend_spec(self):
        """This chip's fused-frontend encode/decode contract
        (kernels.frontend.ChipFrontendSpec): which features feed the
        fabric, on which ap_fixed grid, with which trigger cut."""
        from repro.kernels.frontend import ChipFrontendSpec

        return ChipFrontendSpec(
            used_features=tuple(self.synth.used_features),
            spec=self.golden.spec,
            threshold_raw=int(self.score_threshold_raw),
        )

    def infer_from_frames(self, frames: np.ndarray, y0: np.ndarray,
                          backend: Union[str, ScoringBackend] = "kernel") -> np.ndarray:
        """Full front end: raw charge frames -> raw integer scores.

        Routed through the backend's ``score_frames`` pipeline: the
        kernel backend runs the FUSED single-dispatch frontend
        (frames -> features -> bits -> score with no host round-trip);
        the host backend runs the same pipeline staged, each stage
        materialized — the bit-exact comparison oracle.
        """
        return get_backend(backend).score_frames(self, frames, y0)

    def infer_proba(self, X: np.ndarray,
                    backend: Union[str, ScoringBackend] = "host") -> np.ndarray:
        raw = self.infer_raw(X, backend)
        return 1.0 / (1.0 + np.exp(-raw / self.golden.spec.scale))

    def keep_mask(self, X: np.ndarray,
                  backend: Union[str, ScoringBackend] = "host") -> np.ndarray:
        """True = retain (not classified as pileup)."""
        return self.infer_raw(X, backend) <= self.score_threshold_raw

    # ----------------------------------------------------------- accounting
    def data_reduction_report(
        self,
        X: np.ndarray,
        is_pileup: np.ndarray,
        bits_per_hit: int = 256,
        hit_rate_hz: float = 40e6,
        backend: Union[str, ScoringBackend] = "host",
    ) -> Dict[str, float]:
        keep = self.keep_mask(X, backend)
        is_pu = is_pileup.astype(bool)
        frac_kept = float(keep.mean())
        return {
            "n": float(len(X)),
            "fraction_kept": frac_kept,
            "signal_efficiency": float(keep[~is_pu].mean()) if (~is_pu).any() else 1.0,
            "background_rejection": float((~keep)[is_pu].mean()) if is_pu.any() else 0.0,
            "link_rate_in_gbps": hit_rate_hz * bits_per_hit / 1e9,
            "link_rate_out_gbps": hit_rate_hz * bits_per_hit * frac_kept / 1e9,
            "data_reduction_factor": 1.0 / max(frac_kept, 1e-9),
        }

    def calibrate(self, X_val: np.ndarray, is_pileup_val: np.ndarray,
                  target_sig_eff: float = 0.975) -> Dict[str, float]:
        """Pick the reject threshold achieving ~target signal efficiency on
        a validation set (integer-domain, so the deployed cut is exact)."""
        from repro.core.bdt import operating_point_at_signal_eff

        raw = self.golden.decision_function_raw(
            self.golden.quantize_features(X_val))
        thr, se, br = operating_point_at_signal_eff(
            raw.astype(np.float64), is_pileup_val, target_sig_eff)
        self.score_threshold_raw = int(thr)
        return {"threshold_raw": int(thr), "signal_efficiency": se,
                "background_rejection": br}

    def verify_vs_golden(self, X: np.ndarray,
                         backend: Union[str, ScoringBackend] = "host") -> Dict[str, float]:
        """The 100%-accuracy check of §5, through bitstream + fabric."""
        X_raw = self.golden.quantize_features(X)
        got = self.infer_raw(X, backend)
        want = self.golden.decision_function_raw(X_raw)
        return {
            "n": float(len(X)),
            "n_match": float((got == want).sum()),
            "accuracy": float((got == want).mean()),
        }
