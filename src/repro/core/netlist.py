"""LUT4-level netlist IR — the logic representation the eFPGA fabric executes.

A netlist is a DAG of 4-input LUTs plus optional flip-flops, with primary
inputs and outputs. This mirrors what FABulous' flow (yosys + nextpnr) hands
to the fabric: every combinational function decomposed into LUT4s, every
state element a FF in a LUT4AB logic cell.

Net ordering convention (important — the Pallas kernel relies on it):

    [const0, const1, inputs..., ff_q..., level-0 LUT outs, level-1 LUT outs, ...]

so each level's outputs form a contiguous range and a levelized evaluation
is a sequence of dense "select inputs -> 16-way table lookup -> write slice"
steps. On TPU the select step is a one-hot matmul (MXU) and the lookup is a
16-way one-hot contraction — the fabric's *spatial* parallelism becomes
*batch* parallelism (see DESIGN.md §3).

The numpy evaluator in this file is the bit-exact host oracle; the pure-jnp
oracle lives in kernels/lut_eval/ref.py and the TPU kernel in
kernels/lut_eval/lut_eval.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

CONST0 = 0
CONST1 = 1


def fanin_reach(
    level_sizes: Sequence[int], lut_inputs: np.ndarray, base_comb: int
) -> int:
    """Max levels a LUT input edge spans in a levelized netlist.

    A level-``l`` LUT reads consts/inputs/FF outputs (reach 0, they live in
    the kernel's input segment) or nets produced by LUTs at levels
    ``l - reach``. The returned K bounds the window of preceding levels any
    level needs to see — the banded lut_eval kernel touches only
    ``in_seg + K * m_pad`` net columns per level instead of all of them.
    Returns at least 1 so a band is never degenerate.
    """
    level_sizes = np.asarray(level_sizes, np.int64)
    lut_inputs = np.asarray(lut_inputs, np.int64).reshape(-1, 4)
    n_luts = len(lut_inputs)
    if n_luts == 0:
        return 1
    assert int(level_sizes.sum()) == n_luts, (level_sizes, n_luts)
    # level of each LUT slot (kernel order = level-major)
    lut_level = np.repeat(np.arange(len(level_sizes)), level_sizes)
    is_comb = lut_inputs >= base_comb
    src_slot = np.where(is_comb, lut_inputs - base_comb, 0)
    src_level = lut_level[src_slot]
    reach = np.where(is_comb, lut_level[:, None] - src_level, 0)
    return max(int(reach.max(initial=0)), 1)


def table_from_fn(fn: Callable[..., int], n_inputs: int) -> int:
    """Build a 16-bit LUT4 truth table from a boolean function of n_inputs.

    Input bit k of the table index is LUT input k; unused high inputs are
    don't-care (tied to const0 by the builder, so entries with those bits set
    are unreachable but still filled consistently).
    """
    table = 0
    for idx in range(16):
        bits = [(idx >> k) & 1 for k in range(4)]
        if fn(*bits[:n_inputs]):
            table |= 1 << idx
    return table


TBL_NOT = table_from_fn(lambda a: 1 - a, 1)
TBL_BUF = table_from_fn(lambda a: a, 1)
TBL_AND2 = table_from_fn(lambda a, b: a & b, 2)
TBL_OR2 = table_from_fn(lambda a, b: a | b, 2)
TBL_XOR2 = table_from_fn(lambda a, b: a ^ b, 2)
TBL_MUX2 = table_from_fn(lambda s, a, b: b if s else a, 3)  # s=0 -> a
TBL_AND3 = table_from_fn(lambda a, b, c: a & b & c, 3)
TBL_OR3 = table_from_fn(lambda a, b, c: a | b | c, 3)
TBL_AND4 = table_from_fn(lambda a, b, c, d: a & b & c & d, 4)
TBL_OR4 = table_from_fn(lambda a, b, c, d: a | b | c | d, 4)


@dataclasses.dataclass(frozen=True)
class LUT:
    inputs: Tuple[int, int, int, int]  # net ids (pad with CONST0)
    table: int                          # 16-bit truth table
    out: int                            # output net id


@dataclasses.dataclass(frozen=True)
class FF:
    d: int      # combinational net sampled at the clock edge
    q: int      # state net driven by this FF
    init: int = 0


@dataclasses.dataclass
class Netlist:
    n_nets: int
    inputs: List[int]
    outputs: List[int]
    luts: List[LUT]
    ffs: List[FF]
    names: Dict[int, str]

    @property
    def n_luts(self) -> int:
        return len(self.luts)

    @property
    def n_ffs(self) -> int:
        return len(self.ffs)

    def resource_report(self) -> Dict[str, int]:
        lv = self.levelize()
        return {
            "luts": self.n_luts,
            "ffs": self.n_ffs,
            "inputs": len(self.inputs),
            "outputs": len(self.outputs),
            "nets": self.n_nets,
            "depth": len(lv),
        }

    def levelize(self) -> List[List[int]]:
        """Group LUT indices into combinational levels.

        Level of a LUT = 1 + max(level of driver LUTs); inputs/consts/FF
        outputs are level 0. Raises on combinational cycles.
        """
        driver: Dict[int, int] = {l.out: i for i, l in enumerate(self.luts)}
        level = [-1] * len(self.luts)

        def lut_level(i: int, visiting: set) -> int:
            if level[i] >= 0:
                return level[i]
            if i in visiting:
                raise ValueError("combinational cycle through LUT %d" % i)
            visiting.add(i)
            lv = 0
            for net in self.luts[i].inputs:
                j = driver.get(net)
                if j is not None:
                    lv = max(lv, lut_level(j, visiting) + 1)
            visiting.discard(i)
            level[i] = lv
            return lv

        for i in range(len(self.luts)):
            lut_level(i, set())
        n_levels = (max(level) + 1) if level else 0
        out: List[List[int]] = [[] for _ in range(n_levels)]
        for i, lv in enumerate(level):
            out[lv].append(i)
        return out

    # ---------------------------------------------------------------- eval
    def evaluate(
        self,
        input_bits: np.ndarray,
        n_cycles: int = 1,
        state: Optional[np.ndarray] = None,
        trace_outputs: bool = False,
    ):
        """Bit-exact batched evaluation (host oracle).

        input_bits: (batch, n_inputs) or (batch, n_cycles, n_inputs) 0/1.
        Returns (outputs, state): outputs (batch, n_outputs) for the final
        cycle, or (batch, n_cycles, n_outputs) if trace_outputs.
        """
        input_bits = np.asarray(input_bits, dtype=np.uint8)
        if input_bits.ndim == 2:
            input_bits = np.repeat(input_bits[:, None, :], n_cycles, axis=1)
        batch = input_bits.shape[0]
        assert input_bits.shape[1] == n_cycles
        assert input_bits.shape[2] == len(self.inputs), (
            input_bits.shape, len(self.inputs))

        levels = self.levelize()
        values = np.zeros((batch, self.n_nets), dtype=np.uint8)
        values[:, CONST1] = 1
        if state is None:
            state = np.tile(
                np.asarray([f.init for f in self.ffs], np.uint8), (batch, 1)
            ) if self.ffs else np.zeros((batch, 0), np.uint8)
        tables = np.array(
            [[(l.table >> k) & 1 for k in range(16)] for l in self.luts], np.uint8
        ) if self.luts else np.zeros((0, 16), np.uint8)

        traces = []
        for c in range(n_cycles):
            values[:, self.inputs] = input_bits[:, c, :]
            for f, s in zip(self.ffs, range(len(self.ffs))):
                values[:, f.q] = state[:, s]
            for lv in levels:
                for i in lv:
                    l = self.luts[i]
                    idx = (
                        values[:, l.inputs[0]]
                        + 2 * values[:, l.inputs[1]]
                        + 4 * values[:, l.inputs[2]]
                        + 8 * values[:, l.inputs[3]]
                    )
                    values[:, l.out] = tables[i][idx]
            if self.ffs:
                state = values[:, [f.d for f in self.ffs]].copy()
            if trace_outputs:
                traces.append(values[:, self.outputs].copy())
        outs = (
            np.stack(traces, axis=1) if trace_outputs else values[:, self.outputs].copy()
        )
        return outs, state

    def to_levelized(self) -> "LevelizedNetlist":
        return LevelizedNetlist.from_netlist(self)


@dataclasses.dataclass
class LevelizedNetlist:
    """Dense-array form consumed by the fabric simulator and Pallas kernel.

    Nets are RENUMBERED into kernel order:
      [const0, const1, inputs, ff_q, lvl0 outs, lvl1 outs, ...]
    """

    n_nets: int
    n_inputs: int
    n_ffs: int
    level_sizes: List[int]           # LUTs per level
    lut_inputs: np.ndarray           # (n_luts, 4) int32, kernel-order net ids
    lut_tables: np.ndarray           # (n_luts, 16) uint8
    output_nets: np.ndarray          # (n_outputs,) int32 kernel-order
    ff_d_nets: np.ndarray            # (n_ffs,) int32 kernel-order
    ff_init: np.ndarray              # (n_ffs,) uint8
    lut_order: np.ndarray            # (n_luts,) original LUT index per kernel slot

    @property
    def n_luts(self) -> int:
        return len(self.lut_inputs)

    @property
    def base_comb(self) -> int:
        """First net id of level-0 LUT outputs."""
        return 2 + self.n_inputs + self.n_ffs

    def fanin_reach(self) -> int:
        """Max levels any LUT-to-LUT edge spans (see module fanin_reach)."""
        return fanin_reach(self.level_sizes, self.lut_inputs, self.base_comb)

    @classmethod
    def from_netlist(cls, nl: Netlist) -> "LevelizedNetlist":
        levels = nl.levelize()
        remap = {CONST0: 0, CONST1: 1}
        nxt = 2
        for net in nl.inputs:
            remap[net] = nxt
            nxt += 1
        for f in nl.ffs:
            remap[f.q] = nxt
            nxt += 1
        order: List[int] = []
        for lv in levels:
            for i in lv:
                remap[nl.luts[i].out] = nxt
                nxt += 1
                order.append(i)
        lut_inputs = np.array(
            [[remap[n] for n in nl.luts[i].inputs] for i in order], np.int32
        ).reshape(-1, 4)
        lut_tables = np.array(
            [[(nl.luts[i].table >> k) & 1 for k in range(16)] for i in order],
            np.uint8,
        ).reshape(-1, 16)
        return cls(
            n_nets=nxt,
            n_inputs=len(nl.inputs),
            n_ffs=len(nl.ffs),
            level_sizes=[len(lv) for lv in levels],
            lut_inputs=lut_inputs,
            lut_tables=lut_tables,
            output_nets=np.array([remap[n] for n in nl.outputs], np.int32),
            ff_d_nets=np.array([remap[f.d] for f in nl.ffs], np.int32),
            ff_init=np.array([f.init for f in nl.ffs], np.uint8),
            lut_order=np.array(order, np.int32),
        )


# --------------------------------------------------------------------------
# Builder
# --------------------------------------------------------------------------


class NetlistBuilder:
    def __init__(self):
        self._n = 2  # const0, const1
        self._inputs: List[int] = []
        self._outputs: List[int] = []
        self._luts: List[LUT] = []
        self._ffs: List[FF] = []
        self._names: Dict[int, str] = {0: "const0", 1: "const1"}

    def _new_net(self, name: str = "") -> int:
        net = self._n
        self._n += 1
        if name:
            self._names[net] = name
        return net

    def input(self, name: str = "") -> int:
        net = self._new_net(name or f"in{len(self._inputs)}")
        self._inputs.append(net)
        return net

    def input_bus(self, width: int, name: str = "in") -> List[int]:
        return [self.input(f"{name}[{i}]") for i in range(width)]

    def mark_output(self, net: int, name: str = "") -> int:
        self._outputs.append(net)
        if name:
            self._names[net] = name
        return net

    def lut(self, table: int, ins: Sequence[int], name: str = "") -> int:
        ins = list(ins) + [CONST0] * (4 - len(ins))
        out = self._new_net(name)
        self._luts.append(LUT(inputs=tuple(ins[:4]), table=table & 0xFFFF, out=out))
        return out

    def ff(self, d: int, init: int = 0, name: str = "") -> int:
        q = self._new_net(name or f"ff{len(self._ffs)}")
        self._ffs.append(FF(d=d, q=q, init=init))
        return q

    # convenience gates --------------------------------------------------
    def not_(self, a: int) -> int:
        return self.lut(TBL_NOT, [a])

    def buf(self, a: int) -> int:
        return self.lut(TBL_BUF, [a])

    def and_(self, *nets: int) -> int:
        nets = list(nets)
        while len(nets) > 1:
            grp, rest = nets[:4], nets[4:]
            tbl = {2: TBL_AND2, 3: TBL_AND3, 4: TBL_AND4}[max(len(grp), 2)]
            nets = [self.lut(tbl, grp)] + rest
        return nets[0]

    def or_(self, *nets: int) -> int:
        nets = list(nets)
        while len(nets) > 1:
            grp, rest = nets[:4], nets[4:]
            tbl = {2: TBL_OR2, 3: TBL_OR3, 4: TBL_OR4}[max(len(grp), 2)]
            nets = [self.lut(tbl, grp)] + rest
        return nets[0]

    def xor_(self, a: int, b: int) -> int:
        return self.lut(TBL_XOR2, [a, b])

    def mux2(self, sel: int, a: int, b: int) -> int:
        """sel == 0 -> a, sel == 1 -> b."""
        return self.lut(TBL_MUX2, [sel, a, b])

    def fn(self, f: Callable[..., int], *nets: int) -> int:
        """LUT computing an arbitrary boolean fn of up to 4 nets."""
        assert 1 <= len(nets) <= 4
        return self.lut(table_from_fn(f, len(nets)), list(nets))

    # wide comparators (HLS-style, against a CONSTANT) --------------------
    def le_const(self, bits: Sequence[int], const: int) -> int:
        """Return net computing  unsigned(bits) <= const.

        bits are LSB-first. Synthesized like HLS does for constant
        comparison: 4-bit slices each produce (lt, eq) vs the constant
        nibble (1 LUT each), then a combine chain folds MSB->LSB:
            le = lt_hi | (eq_hi & le_lo)
        Cost: 2*ceil(W/4) + (ceil(W/4)-1) LUTs for W-bit compare.
        """
        W = len(bits)
        n_slices = (W + 3) // 4
        lts, eqs = [], []
        for s in range(n_slices):
            lo = s * 4
            grp = list(bits[lo : lo + 4])
            k = (const >> lo) & ((1 << len(grp)) - 1)
            nb = len(grp)

            def lt_fn(*xs, _k=k, _nb=nb):
                v = sum(x << i for i, x in enumerate(xs[:_nb]))
                return 1 if v < _k else 0

            def eq_fn(*xs, _k=k, _nb=nb):
                v = sum(x << i for i, x in enumerate(xs[:_nb]))
                return 1 if v == _k else 0

            lts.append(self.lut(table_from_fn(lt_fn, nb), grp))
            eqs.append(self.lut(table_from_fn(eq_fn, nb), grp))
        # Combine from LSB slice up: le_so_far starts as (lt_0 | eq_0).
        le = self.fn(lambda l, e: l | e, lts[0], eqs[0])
        for s in range(1, n_slices):
            # le_new = lt_s | (eq_s & le_prev)   (one LUT3)
            le = self.fn(lambda l, e, p: l | (e & p), lts[s], eqs[s], le)
        return le

    # arithmetic -----------------------------------------------------------
    def increment(self, bits: Sequence[int]) -> List[int]:
        """Return bits of unsigned(bits) + 1 (same width, wraps)."""
        out = []
        carry = CONST1
        for b in bits:
            out.append(self.xor_(b, carry))
            carry = self.and_(b, carry)
        return out

    def build(self) -> Netlist:
        return Netlist(
            n_nets=self._n,
            inputs=list(self._inputs),
            outputs=list(self._outputs),
            luts=list(self._luts),
            ffs=list(self._ffs),
            names=dict(self._names),
        )


# --------------------------------------------------------------------------
# Reference firmware (the paper's bring-up tests)
# --------------------------------------------------------------------------


def counter_netlist(width: int = 16) -> Netlist:
    """The paper's §2.4.1/§4.4.1 bring-up firmware: a free-running counter."""
    b = NetlistBuilder()
    qs = [b.ff(CONST0, name=f"q[{i}]") for i in range(width)]  # d patched below
    inc = b.increment(qs)
    # Rewire each FF's D input to the incremented bit.
    nl = b.build()
    ffs = [FF(d=inc[i], q=nl.ffs[i].q, init=0) for i in range(width)]
    nl = Netlist(
        n_nets=nl.n_nets, inputs=nl.inputs, outputs=nl.outputs,
        luts=nl.luts, ffs=ffs, names=nl.names,
    )
    for q in qs:
        nl.outputs.append(q)
    return nl


def loopback_netlist(width: int = 8) -> Netlist:
    """§4.4.3 AXI-Stream loopback: one register stage with valid/ready.

    Inputs:  data[width], in_valid, out_ready
    Outputs: out_data[width], out_valid, in_ready
    Single skid-free register stage: accepts when empty or when downstream
    consumes this cycle.
    """
    b = NetlistBuilder()
    data = b.input_bus(width, "in_data")
    in_valid = b.input("in_valid")
    out_ready = b.input("out_ready")

    full_q = b.ff(CONST0, name="full")  # d patched below
    # in_ready = !full | out_ready
    in_ready = b.fn(lambda f, r: (1 - f) | r, full_q, out_ready)
    accept = b.and_(in_valid, in_ready)
    # next_full = accept | (full & !out_ready)
    next_full = b.fn(lambda a, f, r: a | (f & (1 - r)), accept, full_q, out_ready)

    data_q = []
    for i, d_in in enumerate(data):
        dq = b.ff(CONST0, name=f"data_q[{i}]")
        data_q.append(dq)
    nl0 = b.build()

    # Patch FF D-inputs: full <- next_full; data_q <- accept ? in : hold.
    b2_luts = list(nl0.luts)
    ffs = []
    for f in nl0.ffs:
        ffs.append(f)
    # Build the hold muxes with a second pass builder-free (append LUTs).
    nets = nl0.n_nets

    def add_lut(table, ins):
        nonlocal nets
        out = nets
        nets += 1
        ins = list(ins) + [CONST0] * (4 - len(ins))
        b2_luts.append(LUT(inputs=tuple(ins[:4]), table=table & 0xFFFF, out=out))
        return out

    new_ffs = [FF(d=next_full, q=ffs[0].q, init=0)]
    for i, dq in enumerate(data_q):
        d_next = add_lut(TBL_MUX2, [accept, dq, data[i]])  # accept=1 -> take input
        new_ffs.append(FF(d=d_next, q=dq, init=0))

    outputs = list(data_q) + [ffs[0].q, in_ready]  # out_data, out_valid(=full), in_ready
    return Netlist(
        n_nets=nets, inputs=nl0.inputs, outputs=outputs,
        luts=b2_luts, ffs=new_ffs, names=nl0.names,
    )
