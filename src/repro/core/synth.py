"""Conifer-style synthesis: quantized BDT -> LUT4 netlist (paper §5).

The paper's flow: scikit-learn BDT -> Conifer -> HLS (C -> Verilog) ->
yosys/nextpnr -> 28nm eFPGA bitstream. The synthesized module had
"only 9 threshold parameters and 7 inputs" and "utilized 294 LUTs",
evaluating in a single combinational pass (< 25 ns).

We reproduce the same structure directly at the LUT level:

  1. thresholds/leaves quantized onto the ap_fixed<W,I> grid (quantize.py);
  2. per internal node, an HLS-style *constant comparator*:
     the feature's offset-binary bits are compared against the constant in
     4-bit slices (one LUT4 per (lt, eq) pair per slice) folded by a
     combine chain — 2*ceil(W/4) + ceil(W/4) - 1 LUTs per node;
  3. per leaf, a polarity-aware AND of the path conditions (one-hot);
  4. per output bit, an OR over the leaves whose (f0-folded) value has that
     bit set — constant bits across all leaves cost zero LUTs.

The result is a pure combinational netlist: one fabric pass per event, the
exact analogue of the paper's single decision-function module. Multi-tree
ensembles synthesize each tree and sum with ripple-carry adders (beyond the
paper's single tree, bounded by fabric capacity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bdt import LEAF, QuantizedEnsemble, QuantizedTree
from repro.core.netlist import (
    CONST0,
    CONST1,
    Netlist,
    NetlistBuilder,
    table_from_fn,
)
from repro.core.quantize import FixedSpec, to_unsigned_bits


@dataclasses.dataclass
class SynthResult:
    netlist: Netlist
    spec: FixedSpec
    used_features: List[int]            # feature indices that must be fed
    # input net order: for f in used_features: W bits LSB-first (offset-binary)
    n_thresholds: int
    report: Dict[str, int]

    def encode_inputs(self, X_raw: np.ndarray) -> np.ndarray:
        """(n, n_features) raw int64 -> (n, n_used * W) input bits."""
        u = to_unsigned_bits(X_raw[:, self.used_features], self.spec)
        W = self.spec.width
        bits = ((u[..., None] >> np.arange(W)) & 1).astype(np.uint8)
        return bits.reshape(len(X_raw), -1)

    def decode_outputs(self, out_bits: np.ndarray) -> np.ndarray:
        """(n, W) two's-complement bits LSB-first -> signed raw int64."""
        W = self.spec.width
        u = (out_bits.astype(np.int64) * (np.int64(1) << np.arange(W))).sum(-1)
        sign = np.int64(1) << (W - 1)
        return np.where(u >= sign, u - (sign << 1), u)


def _and_polarity(b: NetlistBuilder, terms: List[Tuple[int, bool]]) -> int:
    """AND of terms with polarities (net, keep_if_true) — negations folded
    into the LUT tables, 4 terms per LUT."""
    if not terms:
        return CONST1
    nets = list(terms)
    while len(nets) > 1 or (len(nets) == 1 and not nets[0][1]):
        grp, rest = nets[:4], nets[4:]
        pols = [p for _, p in grp]

        def fn(*xs, _p=pols):
            v = 1
            for x, p in zip(xs, _p):
                v &= x if p else (1 - x)
            return v

        out = b.lut(table_from_fn(fn, len(grp)), [n for n, _ in grp])
        nets = [(out, True)] + rest
    return nets[0][0]


def _ripple_add(b: NetlistBuilder, a: List[int], c: List[int]) -> List[int]:
    """W-bit two's-complement ripple-carry adder (wraps), 2 LUTs/bit."""
    W = len(a)
    out, carry = [], CONST0
    for i in range(W):
        s = b.fn(lambda x, y, ci: x ^ y ^ ci, a[i], c[i], carry)
        carry = b.fn(lambda x, y, ci: (x & y) | (ci & (x | y)), a[i], c[i], carry)
        out.append(s)
    return out


def _const_bus(value_pattern: int, W: int) -> List[int]:
    return [CONST1 if (value_pattern >> k) & 1 else CONST0 for k in range(W)]


def _tc_pattern(v: int, W: int) -> int:
    """Two's complement bit pattern of signed v in W bits."""
    return v & ((1 << W) - 1)


def synth_tree(
    b: NetlistBuilder,
    qt: QuantizedTree,
    feat_bits: Dict[int, List[int]],
    fold_const: int = 0,
) -> Tuple[List[int], int]:
    """Emit one tree; returns (output bit bus, n_thresholds).

    fold_const is added into every leaf value at synth time (used to fold
    the ensemble's f0 into the first tree for free).
    """
    W = qt.spec.width
    # 1. comparators, deduplicated on (feature, threshold)
    cmp_net: Dict[Tuple[int, int], int] = {}
    for i in range(qt.n_nodes):
        f = int(qt.feature[i])
        if f == LEAF:
            continue
        t_raw = int(qt.threshold_raw[i])
        key = (f, t_raw)
        if key in cmp_net:
            continue
        t_u = int(to_unsigned_bits(np.asarray(t_raw), qt.spec))
        cmp_net[key] = b.le_const(feat_bits[f], t_u)

    # 2. leaf one-hots: AND of path conditions with polarity
    leaves: List[Tuple[int, int]] = []  # (onehot net, leaf value pattern)

    def walk(node: int, path: List[Tuple[int, bool]]):
        f = int(qt.feature[node])
        if f == LEAF:
            v = int(qt.value_raw[node]) + fold_const
            onehot = _and_polarity(b, path)
            leaves.append((onehot, _tc_pattern(v, W)))
            return
        c = cmp_net[(f, int(qt.threshold_raw[node]))]
        walk(int(qt.children_left[node]), path + [(c, True)])
        walk(int(qt.children_right[node]), path + [(c, False)])

    walk(0, [])

    # 3. output bits: OR of one-hots whose leaf value has the bit set.
    out_bits: List[int] = []
    for k in range(W):
        ones = [net for net, pat in leaves if (pat >> k) & 1]
        if not ones:
            out_bits.append(CONST0)
        elif len(ones) == len(leaves):
            out_bits.append(CONST1)
        else:
            out_bits.append(b.or_(*ones))
    return out_bits, len(cmp_net)


def synth_ensemble(ens: QuantizedEnsemble) -> SynthResult:
    """Synthesize a quantized ensemble into a combinational LUT4 netlist."""
    spec = ens.spec
    W = spec.width
    used = sorted(
        {int(f) for qt in ens.trees for f in qt.feature[qt.feature != LEAF]}
    )
    b = NetlistBuilder()
    feat_bits: Dict[int, List[int]] = {}
    for f in used:
        feat_bits[f] = b.input_bus(W, name=f"x{f}")

    total_thresholds = 0
    acc: Optional[List[int]] = None
    for ti, qt in enumerate(ens.trees):
        fold = ens.f0_raw if ti == 0 else 0
        bits, n_thr = synth_tree(b, qt, feat_bits, fold_const=fold)
        total_thresholds += n_thr
        acc = bits if acc is None else _ripple_add(b, acc, bits)

    assert acc is not None
    for k, net in enumerate(acc):
        b.mark_output(net, name=f"score[{k}]")
    nl = b.build()
    rep = nl.resource_report()
    rep["thresholds"] = total_thresholds
    rep["used_features"] = len(used)
    return SynthResult(
        netlist=nl,
        spec=spec,
        used_features=used,
        n_thresholds=total_thresholds,
        report=rep,
    )


def verify_against_golden(
    result: SynthResult,
    ens: QuantizedEnsemble,
    X_raw: np.ndarray,
    batch: int = 8192,
) -> Dict[str, float]:
    """The paper's §5 experiment: netlist output vs golden quantized model.

    Returns dict with n, n_match, accuracy. The paper reports 100%.
    """
    n = len(X_raw)
    n_match = 0
    for lo in range(0, n, batch):
        xs = X_raw[lo : lo + batch]
        bits = result.encode_inputs(xs)
        outs, _ = result.netlist.evaluate(bits)
        got = result.decode_outputs(outs)
        want = ens.decision_function_raw(xs)
        n_match += int((got == want).sum())
    return {"n": n, "n_match": n_match, "accuracy": n_match / max(n, 1)}
