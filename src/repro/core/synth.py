"""Conifer-style synthesis: quantized BDT -> LUT4 netlist (paper §5).

The paper's flow: scikit-learn BDT -> Conifer -> HLS (C -> Verilog) ->
yosys/nextpnr -> 28nm eFPGA bitstream. The synthesized module had
"only 9 threshold parameters and 7 inputs" and "utilized 294 LUTs",
evaluating in a single combinational pass (< 25 ns).

We reproduce the same structure directly at the LUT level:

  1. thresholds/leaves quantized onto the ap_fixed<W,I> grid (quantize.py);
  2. per internal node, an HLS-style *constant comparator*:
     the feature's offset-binary bits are compared against the constant in
     4-bit slices (one LUT4 per (lt, eq) pair per slice) folded by a
     combine chain — 2*ceil(W/4) + ceil(W/4) - 1 LUTs per node;
  3. per leaf, a polarity-aware AND of the path conditions (one-hot);
  4. per output bit, an OR over the leaves whose (f0-folded) value has that
     bit set — constant bits across all leaves cost zero LUTs.

The result is a pure combinational netlist: one fabric pass per event, the
exact analogue of the paper's single decision-function module. Multi-tree
ensembles synthesize each tree and sum them (beyond the paper's single
tree, bounded by fabric capacity).

Two ensemble summation strategies (``synth_ensemble(..., adder=...)``):

  * ``"ripple"`` — the minimal-area chain: fold trees left-to-right with
    W-bit ripple-carry adders (2 LUTs/bit). The carry chain makes the
    levelized netlist ~W levels deeper per chain, and — worse for the
    banded lut_eval kernel — a deep carry LUT still reads the *flat* tree
    output bits many levels below it, so fan-in reach grows with depth.
  * ``"tree"`` (default) — balanced tree reduction with carry-select
    adders: each W-bit add splits into 4-bit blocks that ripple both
    carry-in polarities in parallel, then a short block-carry mux chain
    selects. Depth per add drops from ~W to ~(block + W/block) and every
    LUT reads at most ~(block + W/block) levels back, so both the level
    count L *and* the band K of the banded routing kernel stay small.
    Costs ~2.5x the adder LUTs of ripple — the classic speed/area trade.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bdt import LEAF, QuantizedEnsemble, QuantizedTree
from repro.core.netlist import (
    CONST0,
    CONST1,
    Netlist,
    NetlistBuilder,
    table_from_fn,
)
from repro.core.quantize import FixedSpec, to_unsigned_bits


@dataclasses.dataclass
class SynthResult:
    netlist: Netlist
    spec: FixedSpec
    used_features: List[int]            # feature indices that must be fed
    # input net order: for f in used_features: W bits LSB-first (offset-binary)
    n_thresholds: int
    report: Dict[str, int]
    adder: str = "tree"  # ensemble summation structure ("tree" | "ripple")

    def encode_inputs(self, X_raw: np.ndarray) -> np.ndarray:
        """(n, n_features) raw int64 -> (n, n_used * W) input bits."""
        u = to_unsigned_bits(X_raw[:, self.used_features], self.spec)
        W = self.spec.width
        bits = ((u[..., None] >> np.arange(W)) & 1).astype(np.uint8)
        return bits.reshape(len(X_raw), -1)

    def decode_outputs(self, out_bits: np.ndarray) -> np.ndarray:
        """(n, W) two's-complement bits LSB-first -> signed raw int64."""
        W = self.spec.width
        u = (out_bits.astype(np.int64) * (np.int64(1) << np.arange(W))).sum(-1)
        sign = np.int64(1) << (W - 1)
        return np.where(u >= sign, u - (sign << 1), u)


def _and_polarity(b: NetlistBuilder, terms: List[Tuple[int, bool]]) -> int:
    """AND of terms with polarities (net, keep_if_true) — negations folded
    into the LUT tables, 4 terms per LUT."""
    if not terms:
        return CONST1
    nets = list(terms)
    while len(nets) > 1 or (len(nets) == 1 and not nets[0][1]):
        grp, rest = nets[:4], nets[4:]
        pols = [p for _, p in grp]

        def fn(*xs, _p=pols):
            v = 1
            for x, p in zip(xs, _p):
                v &= x if p else (1 - x)
            return v

        out = b.lut(table_from_fn(fn, len(grp)), [n for n, _ in grp])
        nets = [(out, True)] + rest
    return nets[0][0]


def _ripple_add(b: NetlistBuilder, a: List[int], c: List[int]) -> List[int]:
    """W-bit two's-complement ripple-carry adder (wraps), 2 LUTs/bit."""
    W = len(a)
    out, carry = [], CONST0
    for i in range(W):
        s = b.fn(lambda x, y, ci: x ^ y ^ ci, a[i], c[i], carry)
        carry = b.fn(lambda x, y, ci: (x & y) | (ci & (x | y)), a[i], c[i], carry)
        out.append(s)
    return out


def _ripple_block(
    b: NetlistBuilder, a: List[int], c: List[int], carry: int
) -> Tuple[List[int], int]:
    """Ripple add of one block with an explicit carry-in net; returns
    (sum bits, carry-out net)."""
    out = []
    for x, y in zip(a, c):
        out.append(b.fn(lambda p, q, ci: p ^ q ^ ci, x, y, carry))
        carry = b.fn(lambda p, q, ci: (p & q) | (ci & (p | q)), x, y, carry)
    return out, carry


def _carry_select_add(
    b: NetlistBuilder, a: List[int], c: List[int], block: int = 4
) -> List[int]:
    """W-bit two's-complement carry-select adder (wraps).

    Blocks of ``block`` bits ripple both carry-in polarities in parallel;
    a mux chain on the block carries selects the real sums. Depth is
    ~(block + W/block + 1) levels instead of the ripple chain's ~W, and no
    LUT reads further than ~(block + W/block) levels back — the bounded
    fan-in reach the banded lut_eval kernel exploits. Cost: ~5 LUTs/bit
    vs ripple's 2.
    """
    W = len(a)
    assert len(c) == W and block >= 1
    # Low block needs no speculation: carry-in is 0.
    out, carry = _ripple_block(b, a[:block], c[:block], CONST0)
    for lo in range(block, W, block):
        hi = min(lo + block, W)
        s0, c0 = _ripple_block(b, a[lo:hi], c[lo:hi], CONST0)
        s1, c1 = _ripple_block(b, a[lo:hi], c[lo:hi], CONST1)
        out.extend(b.mux2(carry, z, o) for z, o in zip(s0, s1))
        carry = b.mux2(carry, c0, c1)
    return out


def _reduce_tree(
    b: NetlistBuilder, buses: List[List[int]], block: int = 4
) -> List[int]:
    """Balanced tree reduction of W-bit buses with carry-select adders:
    O(log2 n) adder layers instead of the ripple chain's O(n). Two's-
    complement wraparound is associative, so any reduction order is
    bit-exact vs the sequential sum."""
    while len(buses) > 1:
        nxt = [
            _carry_select_add(b, buses[i], buses[i + 1], block=block)
            for i in range(0, len(buses) - 1, 2)
        ]
        if len(buses) % 2:
            nxt.append(buses[-1])
        buses = nxt
    return buses[0]


def _const_bus(value_pattern: int, W: int) -> List[int]:
    return [CONST1 if (value_pattern >> k) & 1 else CONST0 for k in range(W)]


def _tc_pattern(v: int, W: int) -> int:
    """Two's complement bit pattern of signed v in W bits."""
    return v & ((1 << W) - 1)


def synth_tree(
    b: NetlistBuilder,
    qt: QuantizedTree,
    feat_bits: Dict[int, List[int]],
    fold_const: int = 0,
) -> Tuple[List[int], int]:
    """Emit one tree; returns (output bit bus, n_thresholds).

    fold_const is added into every leaf value at synth time (used to fold
    the ensemble's f0 into the first tree for free).
    """
    W = qt.spec.width
    # 1. comparators, deduplicated on (feature, threshold)
    cmp_net: Dict[Tuple[int, int], int] = {}
    for i in range(qt.n_nodes):
        f = int(qt.feature[i])
        if f == LEAF:
            continue
        t_raw = int(qt.threshold_raw[i])
        key = (f, t_raw)
        if key in cmp_net:
            continue
        t_u = int(to_unsigned_bits(np.asarray(t_raw), qt.spec))
        cmp_net[key] = b.le_const(feat_bits[f], t_u)

    # 2. leaf one-hots: AND of path conditions with polarity
    leaves: List[Tuple[int, int]] = []  # (onehot net, leaf value pattern)

    def walk(node: int, path: List[Tuple[int, bool]]):
        f = int(qt.feature[node])
        if f == LEAF:
            v = int(qt.value_raw[node]) + fold_const
            onehot = _and_polarity(b, path)
            leaves.append((onehot, _tc_pattern(v, W)))
            return
        c = cmp_net[(f, int(qt.threshold_raw[node]))]
        walk(int(qt.children_left[node]), path + [(c, True)])
        walk(int(qt.children_right[node]), path + [(c, False)])

    walk(0, [])

    # 3. output bits: OR of one-hots whose leaf value has the bit set.
    out_bits: List[int] = []
    for k in range(W):
        ones = [net for net, pat in leaves if (pat >> k) & 1]
        if not ones:
            out_bits.append(CONST0)
        elif len(ones) == len(leaves):
            out_bits.append(CONST1)
        else:
            out_bits.append(b.or_(*ones))
    return out_bits, len(cmp_net)


def synth_ensemble(
    ens: QuantizedEnsemble,
    adder: str = "tree",
    adder_block: int = 4,
) -> SynthResult:
    """Synthesize a quantized ensemble into a combinational LUT4 netlist.

    ``adder`` picks the ensemble summation structure (single trees have no
    adders, so the choice is a no-op there): "tree" = balanced carry-select
    tree reduction (shallow, reach-bounded — the default, what the banded
    lut_eval kernel wants); "ripple" = sequential ripple-carry chain
    (minimal LUTs, deep, reach ~ depth).
    """
    if adder not in ("tree", "ripple"):
        raise ValueError(f"unknown adder strategy {adder!r}")
    spec = ens.spec
    W = spec.width
    used = sorted(
        {int(f) for qt in ens.trees for f in qt.feature[qt.feature != LEAF]}
    )
    b = NetlistBuilder()
    feat_bits: Dict[int, List[int]] = {}
    for f in used:
        feat_bits[f] = b.input_bus(W, name=f"x{f}")

    total_thresholds = 0
    buses: List[List[int]] = []
    for ti, qt in enumerate(ens.trees):
        fold = ens.f0_raw if ti == 0 else 0
        bits, n_thr = synth_tree(b, qt, feat_bits, fold_const=fold)
        total_thresholds += n_thr
        buses.append(bits)

    if adder == "ripple":
        acc = buses[0]
        for bus in buses[1:]:
            acc = _ripple_add(b, acc, bus)
    else:
        acc = _reduce_tree(b, buses, block=adder_block)

    for k, net in enumerate(acc):
        b.mark_output(net, name=f"score[{k}]")
    nl = b.build()
    rep = nl.resource_report()
    rep["thresholds"] = total_thresholds
    rep["used_features"] = len(used)
    return SynthResult(
        netlist=nl,
        spec=spec,
        used_features=used,
        n_thresholds=total_thresholds,
        report=rep,
        adder=adder,
    )


def verify_against_golden(
    result: SynthResult,
    ens: QuantizedEnsemble,
    X_raw: np.ndarray,
    batch: int = 8192,
) -> Dict[str, float]:
    """The paper's §5 experiment: netlist output vs golden quantized model.

    Returns dict with n, n_match, accuracy. The paper reports 100%.
    """
    n = len(X_raw)
    n_match = 0
    for lo in range(0, n, batch):
        xs = X_raw[lo : lo + batch]
        bits = result.encode_inputs(xs)
        outs, _ = result.netlist.evaluate(bits)
        got = result.decode_outputs(outs)
        want = ens.decision_function_raw(xs)
        n_match += int((got == want).sum())
    return {"n": n, "n_match": n_match, "accuracy": n_match / max(n, 1)}
