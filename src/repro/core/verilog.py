"""Structural Verilog export of a synthesized netlist.

The paper's flow ends in "synthesis from C to Verilog firmware"; this
module closes that loop for ours: any Netlist exports to a structural
Verilog module of LUT4/FF primitives, suitable for the FABulous/yosys
toolchain (each LUT4 instance carries its 16-bit INIT parameter, exactly
the configuration frame the bitstream encodes).

The export is also a useful audit artifact: reviewers can diff the emitted
module against the resource report (tests assert instance counts match).
"""
from __future__ import annotations

from typing import List

from repro.core.netlist import CONST0, CONST1, Netlist


def _net(n: int) -> str:
    if n == CONST0:
        return "1'b0"
    if n == CONST1:
        return "1'b1"
    return f"n{n}"


def to_verilog(nl: Netlist, module_name: str = "readout_module") -> str:
    lines: List[str] = []
    in_ports = [f"input wire in_{i}" for i in range(len(nl.inputs))]
    out_ports = [f"output wire out_{i}" for i in range(len(nl.outputs))]
    clk = ["input wire clk"] if nl.ffs else []
    lines.append(f"module {module_name} (")
    lines.append("  " + ",\n  ".join(clk + in_ports + out_ports))
    lines.append(");")

    nets = sorted({l.out for l in nl.luts} | {f.q for f in nl.ffs})
    if nets:
        lines.append("  wire " + ", ".join(_net(n) for n in nets) + ";")
    for i, net in enumerate(nl.inputs):
        lines.append(f"  // primary input {i}")
    for i, net in enumerate(nl.inputs):
        lines.append(f"  wire n{net}; assign n{net} = in_{i};")

    for k, l in enumerate(nl.luts):
        ins = ", ".join(f".I{j}({_net(l.inputs[j])})" for j in range(4))
        lines.append(
            f"  LUT4 #(.INIT(16'h{l.table:04X})) lut_{k} "
            f"({ins}, .O({_net(l.out)}));"
        )
    for k, f in enumerate(nl.ffs):
        lines.append(
            f"  FDRE #(.INIT(1'b{f.init})) ff_{k} "
            f"(.C(clk), .D({_net(f.d)}), .Q({_net(f.q)}));"
        )
    for i, net in enumerate(nl.outputs):
        lines.append(f"  assign out_{i} = {_net(net)};")
    lines.append("endmodule")
    return "\n".join(lines) + "\n"
