"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]. Sub-quadratic family: long_500k runs."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="[arXiv:2411.15242; hf]",
    n_layers=38,          # Mamba2 layers
    d_model=2_048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8_192,           # shared block MLP
    vocab=32_000,
    head_dim=64,
    mlp="gelu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
    shared_attn_every=6,  # one weight-shared attn+MLP block every 6 layers
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=1,
    attn_chunk=128,
    prefill_microbatches=2,
    skip_shapes=(),
)
