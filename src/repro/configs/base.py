"""Architecture / run configuration schema.

One ``ArchConfig`` per assigned architecture lives in configs/<id>.py; the
registry in configs/__init__.py resolves ``--arch <id>`` strings. Shape
presets (train_4k / prefill_32k / decode_32k / long_500k) are defined here
because they are shared across the LM family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned input-shape set (same for all 10 LM-family archs).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Smoke-test shape (reduced, CPU-runnable).
SMOKE_SHAPE = ShapeSpec("smoke", 64, 2, "train")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str            # dense | moe | ssm | hybrid | encdec | vlm
    source: str            # provenance note "[arXiv:...; tier]"

    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    mlp: str = "swiglu"                     # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0                    # per-expert hidden size
    capacity_factor: float = 1.25
    moe_group_size: int = 1_024             # dispatch group length (tokens)

    # SSM (Mamba2 / SSD)
    expert_slices: int = 1                  # split each expert into s F-slices
    # (exact for elementwise MLPs: y = sum_s act(x@W1_s)@W2_s). Lets a
    # few-big-expert model (grok: E=8) present E*s virtual experts that
    # divide the 16-way model axis -> clean expert-parallel sharding.
    moe_token_axes: Tuple[str, ...] = ()    # shard MoE token-groups over
    # these mesh axes (few-expert models where E < model-axis: groups use
    # ALL devices while expert weights FSDP-gather per layer)

    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style shared attention blocks)
    shared_attn_every: int = 0              # apply shared block every N layers

    # enc-dec (whisper-style); frontend is a stub per the assignment
    n_enc_layers: int = 0
    enc_len: int = 1_500

    # vlm: inputs are precomputed patch/text embeddings (stub frontend)
    embeds_in: bool = False

    # numerics / memory policy
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"                # adamw | adafactor
    remat: str = "full"                     # full | dots | none
    num_microbatches: int = 1               # grad-accumulation steps
    fsdp: bool = False                      # shard params over the data axis too
    pure_fsdp: bool = False                 # ZeRO-3/FSDP over ALL axes, no TP
    # (beyond-paper §Perf lever: for <=16B models at large token batches,
    # FSDP param-gathers move ~3x params/step vs Megatron-SP's ~8x
    # activations/step — see EXPERIMENTS.md §Perf starcoder2 hillclimb)
    # activation sharding of the residual stream between blocks:
    #   "none" — replicated over "model" (baseline for small/mid archs)
    #   "seq"  — sequence dim sharded over "model" (Megatron-style sequence
    #            parallelism; required for the >=70B archs to fit HBM)
    act_shard: str = "none"
    act_dp_axes: Tuple[str, ...] = ("data",)  # batch-dim mesh axes for acts
    loss_chunk: int = 1_024                 # chunked-xent sequence chunk
    attn_chunk: int = 512                   # flash-style query-chunked attention
    grad_accum_dtype: str = "float32"       # microbatch grad accumulator dtype
    prefill_microbatches: int = 1           # sequential prefill waves (serving)
    decode_unroll: bool = False             # unroll decode layer loop (aliasing)
    # KV-cache storage dtype for decode. "int8" stores absmax-quantized
    # entries + per-(layer,batch,pos) bf16 scales — the paper's at-source
    # quantization idea applied to decode memory (2x vs bf16; needed where
    # XLA's while-loop double-buffering would not fit 32k caches in HBM).
    kv_cache_dtype: str = "bfloat16"

    # which assigned shapes apply (long_500k only for sub-quadratic archs)
    skip_shapes: Tuple[str, ...] = ()

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def shapes(self):
        out = []
        for s in SHAPES.values():
            if s.name in self.skip_shapes:
                continue
            out.append(s)
        return out

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.resolved_head_dim()
        attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) + (
            self.n_heads * hd) * D
        mlp_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        if self.family == "moe":
            mlp = self.n_experts * mlp_mult * D * self.expert_d_ff
            mlp += self.n_shared_experts * mlp_mult * D * self.expert_d_ff
            mlp += D * self.n_experts  # router
        elif self.family in ("ssm",):
            mlp = 0
        else:
            mlp = mlp_mult * D * F
        if self.family == "ssm":
            d_in = self.ssm_expand * D
            per_layer = D * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim)
            per_layer += d_in * D  # out proj
            layers = L * per_layer
        elif self.family == "hybrid":
            d_in = self.ssm_expand * D
            ssm_per = D * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * D
            n_shared_apps = 1  # weights shared
            layers = L * ssm_per + n_shared_apps * (attn + 3 * D * F)
        elif self.family == "encdec":
            # enc self-attn+mlp, dec self+cross+mlp
            layers = self.n_enc_layers * (attn + mlp) + L * (2 * attn + mlp)
        else:
            layers = L * (attn + mlp)
        emb = V * D * (1 if self.tie_embeddings else 2)
        return layers + emb

    def active_param_count(self) -> int:
        """Activated params per token (MoE uses top_k + shared)."""
        if self.family != "moe":
            return self.param_count()
        D, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim()
        attn = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) + (
            self.n_heads * hd) * D
        mlp_mult = 3
        act_mlp = (self.top_k + self.n_shared_experts) * mlp_mult * D * self.expert_d_ff
        emb = self.vocab * D * 2
        return L * (attn + act_mlp + D * self.n_experts) + emb
