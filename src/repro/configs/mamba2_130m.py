"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060; unverified].

Attention-free: 24 Mamba2 layers, d_model=768, d_state=128. Runs long_500k
(constant-size recurrent state — the sub-quadratic family).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    source="[arXiv:2405.21060; unverified]",
    n_layers=24,
    d_model=768,
    n_heads=1,       # attention-free; kept for schema completeness
    n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    norm="rmsnorm",
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=1,
    skip_shapes=(),
)
