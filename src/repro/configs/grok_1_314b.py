"""grok-1-314b — 8 experts top-2 MoE [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    source="[hf:xai-org/grok-1; unverified]",
    n_layers=64,
    d_model=6_144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab=131_072,
    head_dim=128,
    mlp="gelu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    expert_d_ff=32_768,
    capacity_factor=1.25,
    moe_group_size=512,
    expert_slices=2,   # 8 experts x 2 F-slices = 16 virtual experts (EP=16)
    param_dtype="bfloat16",
    optimizer="adafactor",
    fsdp=True,
    num_microbatches=8,
    act_shard="seq",
    attn_chunk=256,
    prefill_microbatches=8,
    kv_cache_dtype="int8",
    skip_shapes=("long_500k",),
)
