"""internvl2-76b — InternViT + InternLM2 VLM backbone [arXiv:2404.16821; unverified].

Per the assignment, [vlm] entries specify the transformer BACKBONE only; the
modality frontend (InternViT patch embedder) is a STUB — input_specs()
provides precomputed patch/text embeddings of shape (batch, seq, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    source="[arXiv:2404.16821; unverified]",
    n_layers=80,
    d_model=8_192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=128_256,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    embeds_in=True,
    param_dtype="bfloat16",
    optimizer="adafactor",
    fsdp=True,
    num_microbatches=8,
    act_shard="seq",
    skip_shapes=("long_500k",),  # full attention — sub-quadratic required
)
