"""starcoder2-7b — GQA + RoPE code model [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    source="[arXiv:2402.19173; hf]",
    n_layers=32,
    d_model=4_608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18_432,
    vocab=49_152,
    head_dim=128,
    mlp="gelu",          # starcoder2 uses a plain GELU MLP
    norm="layernorm",
    rope_theta=100_000.0,
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=8,
    act_shard="seq",
    skip_shapes=("long_500k",),
)
