"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    source="[arXiv:2404.14219; unverified]",
    n_layers=40,
    d_model=5_120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17_920,
    vocab=100_352,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=8,
    act_shard="seq",
    skip_shapes=("long_500k",),
)
