"""nemotron-4-340b — GQA, squared-ReLU MLP [arXiv:2402.16819; unverified].

The largest assigned dense model: full 2D sharding (TP over "model" +
FSDP/ZeRO-3 over "data") and a factored optimizer are required to fit
16 GB/chip — see DESIGN.md §5 and the dry-run memory analysis.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    source="[arXiv:2402.16819; unverified]",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab=256_000,
    head_dim=192,
    mlp="relu2",         # squared ReLU
    norm="layernorm",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    optimizer="adafactor",
    fsdp=True,
    num_microbatches=8,
    act_shard="seq",
    attn_chunk=256,
    grad_accum_dtype="bfloat16",
    prefill_microbatches=8,
    kv_cache_dtype="int8",
    skip_shapes=("long_500k",),
)
