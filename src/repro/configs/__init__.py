"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, SMOKE_SHAPE

from repro.configs.internvl2_76b import CONFIG as _internvl2_76b
from repro.configs.mamba2_130m import CONFIG as _mamba2_130m
from repro.configs.starcoder2_7b import CONFIG as _starcoder2_7b
from repro.configs.gemma_7b import CONFIG as _gemma_7b
from repro.configs.phi3_medium_14b import CONFIG as _phi3_medium_14b
from repro.configs.nemotron_4_340b import CONFIG as _nemotron_4_340b
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek_moe_16b
from repro.configs.grok_1_314b import CONFIG as _grok_1_314b
from repro.configs.whisper_tiny import CONFIG as _whisper_tiny
from repro.configs.zamba2_1_2b import CONFIG as _zamba2_1_2b

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _internvl2_76b,
        _mamba2_130m,
        _starcoder2_7b,
        _gemma_7b,
        _phi3_medium_14b,
        _nemotron_4_340b,
        _deepseek_moe_16b,
        _grok_1_314b,
        _whisper_tiny,
        _zamba2_1_2b,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: small widths/layers/experts/vocab, so one
    forward/train step runs on CPU in the smoke tests. The FULL configs are
    exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
    c = get_arch(name)
    return dataclasses.replace(
        c,
        n_layers=2,
        n_enc_layers=min(c.n_enc_layers, 2),
        enc_len=16 if c.family == "encdec" else c.enc_len,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if c.n_kv_heads < c.n_heads else 4,
        head_dim=16,
        d_ff=128 if c.d_ff else 0,
        vocab=512,
        n_experts=8 if c.n_experts else 0,
        n_shared_experts=min(c.n_shared_experts, 1),
        top_k=min(c.top_k, 2),
        expert_d_ff=64 if c.expert_d_ff else 0,
        moe_group_size=32,
        ssm_state=16 if c.ssm_state else 0,
        ssm_head_dim=16 if c.ssm_state else c.ssm_head_dim,
        ssm_chunk=16 if c.ssm_state else c.ssm_chunk,
        shared_attn_every=2 if c.shared_attn_every else 0,
        param_dtype="float32",
        num_microbatches=1,
        fsdp=False,
        act_shard="none",  # no mesh context in smoke tests
        loss_chunk=32,
        kv_cache_dtype="float32",
        moe_token_axes=(),
    )


__all__ = [
    "ARCHS", "ArchConfig", "ShapeSpec", "SHAPES", "SMOKE_SHAPE",
    "get_arch", "smoke_config",
]
