"""gemma-7b — GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    source="[arXiv:2403.08295; hf]",
    n_layers=28,
    d_model=3_072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24_576,
    vocab=256_000,
    head_dim=256,        # 16 heads x 256 != d_model — explicit head_dim
    mlp="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=4,
    act_shard="seq",
    kv_cache_dtype="int8",
    skip_shapes=("long_500k",),
)
