"""whisper-tiny — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

Per the assignment, the audio frontend is a stub: input_specs() provides
precomputed log-mel frame embeddings (batch, enc_len, d_model). Enc-dec has
a decoder, so decode shapes run; long_500k is skipped (full attention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    source="[arXiv:2212.04356; unverified]",
    n_layers=4,           # decoder layers
    n_enc_layers=4,
    enc_len=1_500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1_536,
    vocab=51_865,
    head_dim=64,
    mlp="gelu",
    norm="layernorm",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=1,
    skip_shapes=("long_500k",),
)
