"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="[arXiv:2401.06066; hf]",
    n_layers=28,
    d_model=2_048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1_408,           # per-expert hidden (fine-grained experts)
    vocab=102_400,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1_408,
    capacity_factor=1.25,
    moe_group_size=1_024,
    param_dtype="bfloat16",
    optimizer="adamw",
    num_microbatches=8,
    act_shard="seq",
    kv_cache_dtype="int8",
    skip_shapes=("long_500k",),
)
