"""Hybrid SSM + shared-attention model (zamba2-1.2b).

Zamba2's signature structure: a Mamba2 backbone with ONE weight-shared
transformer block (attention + MLP) invoked every ``shared_attn_every``
layers. The shared block's weights are reused at every application, but
each application keeps its own KV cache during decode.

Sub-quadratic family: long_500k runs; decode memory = constant SSM state +
(n_applications) KV caches.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S


def n_shared_applications(cfg: ArchConfig) -> int:
    every = max(cfg.shared_attn_every, 1)
    return (cfg.n_layers + every - 1) // every


def _segment_sizes(cfg: ArchConfig):
    every = max(cfg.shared_attn_every, 1)
    sizes = []
    rest = cfg.n_layers
    while rest > 0:
        sizes.append(min(every, rest))
        rest -= every
    return sizes


def init(cfg: ArchConfig, key: jax.Array) -> Dict:
    ke, kb, ks1, ks2 = jax.random.split(key, 4)
    block_keys = jax.random.split(kb, cfg.n_layers)
    return {
        "embed": L.init_embed(cfg, ke),
        "blocks": jax.vmap(lambda k: S.init_ssm_block(cfg, k))(block_keys),
        "shared": {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": L.init_attention(cfg, ks1),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(cfg, ks2),
        },
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def _shared_apply(cfg, sp, x, positions, cache=None):
    h, new_cache = L.attention(
        cfg, sp["attn"], L.apply_norm(cfg, sp["ln1"], x), positions, cache=cache
    )
    x = x + h
    x = x + L.mlp(cfg, sp["mlp"], L.apply_norm(cfg, sp["ln2"], x))
    return x, new_cache


def hidden_states(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
                  positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = L.embed_tokens(params["embed"], tokens)
    B, Ssz = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(Ssz, dtype=jnp.int32), (B, Ssz))
    x = L.act_constraint(cfg, x)

    body = lambda lp, c: S.ssm_block_apply(cfg, lp, c)[0]
    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        body = jax.checkpoint(body, policy=policy)

    # shared block is rematted too: its 32k attention probs would otherwise
    # be saved for backward at every one of the ~7 applications.
    shared_fn = lambda sp, c: _shared_apply(cfg, sp, c, positions)[0]
    if cfg.remat != "none":
        shared_fn = jax.checkpoint(shared_fn)

    off = 0
    for seg in _segment_sizes(cfg):
        x = L.act_constraint(cfg, shared_fn(params["shared"], x))
        seg_blocks = jax.tree.map(lambda a: a[off : off + seg], params["blocks"])
        x, _ = jax.lax.scan(lambda c, lp: (body(lp, c), None), x, seg_blocks)
        off += seg
    return L.apply_norm(cfg, params["final_norm"], x)


def forward(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    return L.lm_logits(
        cfg, params["embed"], hidden_states(cfg, params, tokens, positions)
    )


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    x = hidden_states(cfg, params, batch["tokens"])
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"])


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    d_in, H, N, conv_ch = S._dims(cfg)
    P = cfg.ssm_head_dim
    hd = cfg.resolved_head_dim()
    n_app = n_shared_applications(cfg)
    dt = L.dtype_of(cfg)
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), dt),
        "k": jnp.zeros((n_app, batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((n_app, batch, max_len, cfg.n_kv_heads, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens: jnp.ndarray):
    x = L.embed_tokens(params["embed"], tokens)
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)

    nk, nv = [], []
    ns_list, ncv_list = [], []
    off = 0
    for app, seg in enumerate(_segment_sizes(cfg)):
        x, c = _shared_apply(
            cfg, params["shared"], x, positions,
            cache={"k": cache["k"][app], "v": cache["v"][app], "pos": pos},
        )
        nk.append(c["k"])
        nv.append(c["v"])

        def scan_fn(carry, inputs):
            x = carry
            lp, s_ssm, s_conv = inputs
            out, st = S.ssm_block_apply(cfg, lp, x, state={"ssm": s_ssm, "conv": s_conv})
            return out, (st["ssm"], st["conv"])

        seg_blocks = jax.tree.map(lambda a: a[off : off + seg], params["blocks"])
        x, (s_new, c_new) = jax.lax.scan(
            scan_fn, x,
            (seg_blocks, cache["ssm"][off : off + seg], cache["conv"][off : off + seg]),
        )
        ns_list.append(s_new)
        ncv_list.append(c_new)
        off += seg

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    new_cache = {
        "ssm": jnp.concatenate(ns_list, axis=0),
        "conv": jnp.concatenate(ncv_list, axis=0),
        "k": jnp.stack(nk, axis=0),
        "v": jnp.stack(nv, axis=0),
        "pos": pos + 1,
    }
    return logits, new_cache
