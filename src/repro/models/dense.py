"""Decoder-only dense transformer (GQA + RoPE + configurable MLP/norm).

Covers the assigned archs: starcoder2-7b, gemma-7b, phi3-medium-14b,
nemotron-4-340b, and the internvl2-76b VLM backbone (embeds_in=True: the
patch/text embeddings arrive precomputed per the assignment's stub rule).

Layers are stacked on a leading axis and driven by lax.scan; remat policy
is applied to the scanned block (cfg.remat: "full" | "dots" | "none").
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def init_block(cfg: ArchConfig, key: jax.Array) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, k2),
    }


def init(cfg: ArchConfig, key: jax.Array) -> Dict:
    ke, kb = jax.random.split(key)
    block_keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(cfg, k))(block_keys)
    params = {
        "blocks": blocks,
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    params["embed"] = L.init_embed(cfg, ke)
    return params


def _block_apply(cfg: ArchConfig, lp: Dict, x: jnp.ndarray, positions: jnp.ndarray):
    h, _ = L.attention(
        cfg, lp["attn"], L.act_entry(cfg, L.apply_norm(cfg, lp["ln1"], x)),
        positions)
    x = L.act_constraint(cfg, x + h)
    x = x + L.mlp(cfg, lp["mlp"], L.act_entry(cfg, L.apply_norm(cfg, lp["ln2"], x)))
    return L.act_constraint(cfg, x)


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.nothing_saveable
        if cfg.remat == "full"
        else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def hidden_states(
    cfg: ArchConfig,
    params: Dict,
    tokens_or_embeds: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full-sequence forward -> final hidden states (B, S, D)."""
    if cfg.embeds_in:
        x = tokens_or_embeds.astype(L.dtype_of(cfg))
    else:
        x = L.embed_tokens(params["embed"], tokens_or_embeds)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = L.act_constraint(cfg, x)

    body = _remat(cfg, functools.partial(_block_apply, cfg))

    def scan_fn(carry, lp):
        return body(lp, carry, positions), None

    x, _ = jax.lax.scan(scan_fn, x, params["blocks"])
    return L.apply_norm(cfg, params["final_norm"], x)


def forward(cfg: ArchConfig, params: Dict, tokens_or_embeds: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full logits (small models / tests; the loss path uses chunked xent)."""
    return L.lm_logits(
        cfg, params["embed"], hidden_states(cfg, params, tokens_or_embeds, positions)
    )


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    inp = batch["embeds"] if cfg.embeds_in else batch["tokens"]
    x = hidden_states(cfg, params, inp)
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"])


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    hd = cfg.resolved_head_dim()
    kv_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros(kv_shape, jnp.int8),
            "v": jnp.zeros(kv_shape, jnp.int8),
            "k_scale": jnp.zeros((cfg.n_layers, batch, max_len), jnp.bfloat16),
            "v_scale": jnp.zeros((cfg.n_layers, batch, max_len), jnp.bfloat16),
            "pos": jnp.zeros((), jnp.int32),
        }
    dt = L.dtype_of(cfg)
    return {
        "k": jnp.zeros(kv_shape, dt),
        "v": jnp.zeros(kv_shape, dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(
    cfg: ArchConfig,
    params: Dict,
    cache: Dict,
    tokens_or_embeds: jnp.ndarray,  # (B, 1) int32  or (B, 1, D) embeds
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against a static-shape KV cache."""
    if cfg.embeds_in:
        x = tokens_or_embeds.astype(L.dtype_of(cfg))
    else:
        x = L.embed_tokens(params["embed"], tokens_or_embeds)
    pos = cache["pos"]
    quant = cfg.kv_cache_dtype == "int8"

    def body(l, carry):
        if quant:
            x, ck, cv, ks, vs = carry
        else:
            x, ck, cv = carry
        lp = L.index_layer(params["blocks"], l)
        res = L.attention_decode_inplace(
            cfg, lp["attn"], L.apply_norm(cfg, lp["ln1"], x), pos, ck, cv, l,
            scales=(ks, vs) if quant else None)
        if quant:
            h, ck, cv, ks, vs = res
        else:
            h, ck, cv = res
        x = x + h
        x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], x))
        return (x, ck, cv, ks, vs) if quant else (x, ck, cv)

    carry0 = (
        (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
        if quant else (x, cache["k"], cache["v"])
    )
    if cfg.decode_unroll:
        # flat graph: XLA aliases the dynamic-update-slice chain in place,
        # where a while-loop carry would be double-buffered (2x cache).
        carry = carry0
        for l in range(cfg.n_layers):
            carry = body(l, carry)
    else:
        carry = jax.lax.fori_loop(0, cfg.n_layers, body, carry0)
    x = carry[0]
    new_cache = {"k": carry[1], "v": carry[2], "pos": pos + 1}
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = carry[3], carry[4]
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, new_cache
