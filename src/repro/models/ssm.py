"""Mamba2 / SSD (state-space duality) model — mamba2-130m, and the backbone
blocks of zamba2 (hybrid.py).

Implements the chunked SSD algorithm of arXiv:2405.21060 (single B/C group):

  per layer:  x -> in_proj -> [z | xBC | dt];  xBC -> causal conv (K taps,
  silu) -> [x_ssm | B | C];  dt -> softplus(dt + bias);  a_t = exp(dt_t A_h)

  chunked scan (chunk length Q):
    diag block:   Y[t] = Σ_{s<=t, same chunk} (C_t·B_s) exp(Σ_{u=s+1..t} a_u) x̄_s
    chunk state:  S_c  = Σ_q exp(A_last - A_q) B_q x̄_qᵀ
    recurrence:   S_c  = exp(A_sum_c) S_{c-1} + S_c   (lax.scan over chunks)
    off-diag:     Y[t] += C_t · S_{c-1} exp(A_cum_t)

  gate + RMSNorm + out_proj, residual. Decode carries (S, conv buffer) —
  constant-size state, which is why this family runs the long_500k shape.

Training FLOPs scale as O(S·Q) intra + O(S/Q) scan — sub-quadratic.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = d_in + 2 * N
    return d_in, H, N, conv_ch


def init_ssm_block(cfg: ArchConfig, key: jax.Array) -> Dict:
    D = cfg.d_model
    d_in, H, N, conv_ch = _dims(cfg)
    dt = L.dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    sc = 1.0 / jnp.sqrt(jnp.float32(D))
    return {
        "norm": L.init_norm(cfg, D),
        "in_proj": (
            jax.random.normal(k1, (D, 2 * d_in + 2 * N + H)) * sc
        ).astype(dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch)) * 0.3).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H).astype(jnp.float32)
        ),  # A = -exp(A_log)
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((d_in,), dt)},
        "out_proj": (
            jax.random.normal(k3, (d_in, D)) * (1.0 / jnp.sqrt(jnp.float32(d_in)))
        ).astype(dt),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """u: (B, S, C), w: (K, C) depthwise causal conv."""
    K = w.shape[0]
    out = jnp.zeros_like(u)
    for k in range(K):
        shift = K - 1 - k
        pad = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1], :]
        out = out + pad * w[k]
    return out + b


def _ssd_scan(
    x: jnp.ndarray,     # (B, S, H, P) — already dt-scaled ("x̄")
    a: jnp.ndarray,     # (B, S, H)    — log decay (negative)
    Bv: jnp.ndarray,    # (B, S, N)
    Cv: jnp.ndarray,    # (B, S, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, H, P = x.shape
    N = Bv.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    ac = a.reshape(B, nc, chunk, H)
    Bc = Bv.reshape(B, nc, chunk, N).astype(jnp.float32)
    Cc = Cv.reshape(B, nc, chunk, N).astype(jnp.float32)

    A_cum = jnp.cumsum(ac, axis=2)                        # inclusive (B,nc,Q,H)
    A_tot = A_cum[:, :, -1, :]                            # (B, nc, H)

    # --- intra-chunk (diagonal block)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)        # (B,nc,Q,Q)
    seg = A_cum[:, :, :, None, :] - A_cum[:, :, None, :, :]  # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp on the (s > t) side can overflow to inf and poison
    # the backward pass (inf * 0 = nan in the where-grad).
    seg = jnp.where(tri[None, None, :, :, None], seg, -1e30)
    Lmask = jnp.exp(seg)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, Lmask, xc)

    # --- chunk states
    decay_to_end = jnp.exp(A_tot[:, :, None, :] - A_cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_to_end, xc)

    # --- inter-chunk recurrence
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )

    def scan_fn(carry, inp):
        st_c, atot_c = inp  # (B,H,P,N), (B,H)
        new = carry * jnp.exp(atot_c)[:, :, None, None] + st_c
        return new, carry  # emit state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        scan_fn,
        s0,
        (states.transpose(1, 0, 2, 3, 4), A_tot.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # --- off-diagonal contribution
    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Cc, prev_states, jnp.exp(A_cum)
    )
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), final_state


def ssm_block_apply(
    cfg: ArchConfig,
    p: Dict,
    x: jnp.ndarray,                   # (B, S, D)
    state: Optional[Dict] = None,     # decode: {"ssm": (B,H,P,N), "conv": (B,K-1,C)}
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    d_in, H, N, conv_ch = _dims(cfg)
    P = cfg.ssm_head_dim

    h = L.apply_norm(cfg, p["norm"], x)
    proj = h @ p["in_proj"]                                # (B,S,2d_in+2N+H)
    z, xBC, dt_raw = jnp.split(proj, [d_in, d_in + conv_ch], axis=-1)

    new_state = None
    if state is None:
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    else:
        # one-token decode: roll the conv buffer
        buf = jnp.concatenate([state["conv"], xBC], axis=1)  # (B, K, C)
        conv_out = jnp.einsum("bkc,kc->bc", buf, p["conv_w"]) + p["conv_b"]
        xBC = jax.nn.silu(conv_out)[:, None, :]
        new_conv = buf[:, 1:, :]

    x_ssm, Bv, Cv = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    x_ssm = x_ssm.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                # (H,)
    a = dt * A                                              # log decay
    x_bar = x_ssm.astype(jnp.float32) * dt[..., None]

    if state is None:
        y, _final = _ssd_scan(x_bar, a, Bv, Cv, min(cfg.ssm_chunk, S))
    else:
        # recurrent step: S' = exp(a) S + B x̄ᵀ ; y = C·S'
        s_prev = state["ssm"].astype(jnp.float32)
        a1 = jnp.exp(a[:, 0, :])                            # (B,H)
        outer = jnp.einsum("bn,bhp->bhpn", Bv[:, 0].astype(jnp.float32), x_bar[:, 0])
        s_new = s_prev * a1[:, :, None, None] + outer
        y = jnp.einsum("bn,bhpn->bhp", Cv[:, 0].astype(jnp.float32), s_new)[:, None]
        new_state = {"ssm": s_new, "conv": new_conv}

    y = y + p["D_skip"][None, None, :, None] * x_ssm.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"]["scale"])
    out = x + y @ p["out_proj"]
    if state is None:  # training/prefill path only
        out = L.act_constraint(cfg, out)
    return out, new_state


def init(cfg: ArchConfig, key: jax.Array) -> Dict:
    ke, kb = jax.random.split(key)
    block_keys = jax.random.split(kb, cfg.n_layers)
    return {
        "embed": L.init_embed(cfg, ke),
        "blocks": jax.vmap(lambda k: init_ssm_block(cfg, k))(block_keys),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def hidden_states(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
                  positions=None) -> jnp.ndarray:
    x = L.act_constraint(cfg, L.embed_tokens(params["embed"], tokens))

    body = functools.partial(ssm_block_apply, cfg)
    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        body = jax.checkpoint(body, policy=policy)

    x, _ = jax.lax.scan(lambda c, lp: (body(lp, c)[0], None), x, params["blocks"])
    return L.apply_norm(cfg, params["final_norm"], x)


def forward(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
            positions=None) -> jnp.ndarray:
    return L.lm_logits(cfg, params["embed"], hidden_states(cfg, params, tokens))


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    x = hidden_states(cfg, params, batch["tokens"])
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"])


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    """Constant-size recurrent state — max_len doesn't appear (that IS the
    point of running long_500k on this family)."""
    d_in, H, N, conv_ch = _dims(cfg)
    P = cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch),
                          L.dtype_of(cfg)),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens: jnp.ndarray):
    x = L.embed_tokens(params["embed"], tokens)  # (B, 1, D)

    def scan_fn(carry, inputs):
        x = carry
        lp, s_ssm, s_conv = inputs
        out, new_state = ssm_block_apply(cfg, lp, x, state={"ssm": s_ssm, "conv": s_conv})
        return out, (new_state["ssm"], new_state["conv"])

    x, (ns, ncv) = jax.lax.scan(
        scan_fn, x, (params["blocks"], cache["ssm"], cache["conv"])
    )
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, {"ssm": ns, "conv": ncv, "pos": cache["pos"] + 1}
