"""Encoder-decoder transformer (whisper-tiny backbone).

Per the assignment, the audio conv frontend is a STUB: the encoder consumes
precomputed frame embeddings (batch, enc_len, d_model) from input_specs().
Encoder: bidirectional self-attention blocks. Decoder: causal self-attention
(with KV cache for decode) + cross-attention over the encoder output (cross
K/V precomputed once per session) + MLP.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def init_enc_block(cfg: ArchConfig, key: jax.Array) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, k2),
    }


def init_dec_block(cfg: ArchConfig, key: jax.Array) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "self_attn": L.init_attention(cfg, k1),
        "ln_x": L.init_norm(cfg, cfg.d_model),
        "cross_attn": L.init_attention(cfg, k2),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(cfg, k3),
    }


def init(cfg: ArchConfig, key: jax.Array) -> Dict:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embed(cfg, ke),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(cfg, k))(enc_keys),
        "enc_norm": L.init_norm(cfg, cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(cfg, k))(dec_keys),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def encode(cfg: ArchConfig, params: Dict, enc_embeds: jnp.ndarray) -> jnp.ndarray:
    x = enc_embeds.astype(L.dtype_of(cfg))
    B, T = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(carry, lp):
        x = carry
        h, _ = L.attention(
            cfg, lp["attn"], L.apply_norm(cfg, lp["ln1"], x), positions,
            causal=False,
        )
        x = x + h
        x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], x))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def _dec_block(cfg, lp, x, positions, enc_out, self_cache=None, cross_kv=None):
    h, new_cache = L.attention(
        cfg, lp["self_attn"], L.apply_norm(cfg, lp["ln1"], x), positions,
        cache=self_cache,
    )
    x = x + h
    if cross_kv is not None:
        k, v = cross_kv
        hx = _cross_from_cached(cfg, lp["cross_attn"], L.apply_norm(cfg, lp["ln_x"], x), k, v)
    else:
        hx, _ = L.attention(
            cfg, lp["cross_attn"], L.apply_norm(cfg, lp["ln_x"], x), positions,
            kv=(enc_out, enc_out), causal=False, use_rope=False,
        )
    x = x + hx
    x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], x))
    return x, new_cache


def _cross_from_cached(cfg, p, x, k, v):
    """Cross-attention where K/V (B,T,KV,hd) are precomputed."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    q = (x @ p["wq"]).reshape(B, S, KV, G, hd)
    mask = jnp.ones((1, 1, 1, 1, k.shape[1]), bool)
    out = L._gqa_scores_softmax_v(q, k, v, mask, 1.0 / jnp.sqrt(jnp.float32(hd)))
    return out.reshape(B, S, H * hd) @ p["wo"]


def hidden_states(cfg: ArchConfig, params: Dict, enc_embeds: jnp.ndarray,
                  tokens: jnp.ndarray) -> jnp.ndarray:
    enc_out = encode(cfg, params, enc_embeds)
    x = L.embed_tokens(params["embed"], tokens)
    B, Sd = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32), (B, Sd))

    def body(carry, lp):
        x = carry
        x, _ = _dec_block(cfg, lp, x, positions, enc_out)
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return L.apply_norm(cfg, params["final_norm"], x)


def forward(cfg: ArchConfig, params: Dict, enc_embeds: jnp.ndarray,
            tokens: jnp.ndarray) -> jnp.ndarray:
    return L.lm_logits(
        cfg, params["embed"], hidden_states(cfg, params, enc_embeds, tokens)
    )


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    x = hidden_states(cfg, params, batch["enc_embeds"], batch["tokens"])
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"])


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               params: Optional[Dict] = None,
               enc_embeds: Optional[jnp.ndarray] = None) -> Dict:
    hd = cfg.resolved_head_dim()
    dt = L.dtype_of(cfg)
    Ld = cfg.n_layers
    cache = {
        "k": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }
    if params is not None and enc_embeds is not None:
        enc_out = encode(cfg, params, enc_embeds)

        def proj(lp):
            k = (enc_out @ lp["cross_attn"]["wk"]).reshape(
                batch, enc_out.shape[1], cfg.n_kv_heads, hd)
            v = (enc_out @ lp["cross_attn"]["wv"]).reshape(
                batch, enc_out.shape[1], cfg.n_kv_heads, hd)
            return k, v

        xk, xv = jax.vmap(proj)(params["dec_blocks"])
        cache["cross_k"], cache["cross_v"] = xk, xv
    else:
        cache["cross_k"] = jnp.zeros(
            (Ld, batch, cfg.enc_len, cfg.n_kv_heads, hd), dt)
        cache["cross_v"] = jnp.zeros(
            (Ld, batch, cfg.enc_len, cfg.n_kv_heads, hd), dt)
    return cache


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens: jnp.ndarray):
    x = L.embed_tokens(params["embed"], tokens)
    pos = cache["pos"]

    def body(l, carry):
        x, ck, cv = carry
        lp = L.index_layer(params["dec_blocks"], l)
        h, ck, cv = L.attention_decode_inplace(
            cfg, lp["self_attn"], L.apply_norm(cfg, lp["ln1"], x), pos, ck, cv, l)
        x = x + h
        xk = jax.lax.dynamic_index_in_dim(cache["cross_k"], l, 0, keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache["cross_v"], l, 0, keepdims=False)
        hx = _cross_from_cached(
            cfg, lp["cross_attn"], L.apply_norm(cfg, lp["ln_x"], x), xk, xv)
        x = x + hx
        x = x + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], x))
        return (x, ck, cv)

    x, nk, nv = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["k"], cache["v"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    new_cache = dict(cache)
    new_cache.update({"k": nk, "v": nv, "pos": pos + 1})
    return logits, new_cache
