"""Shared model-layer primitives (pure JAX, pytree params, no flax).

Conventions used across the zoo:
  * params are nested dicts of jnp arrays; per-layer weights are STACKED on
    a leading L axis and consumed with lax.scan (small HLO — critical for
    the 512-fake-device dry-run compiles);
  * activations flow as (batch, seq, d_model) in the config's param_dtype
    (bf16 by default), reductions/softmax in f32;
  * attention supports GQA (n_kv_heads <= n_heads), RoPE, causal masking,
    and a decode path with a static-shape KV cache updated at a dynamic
    position (one-token serve_step).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------------- norms
# Statistics accumulate in f32 via ``dtype=`` on the reduction instead of
# upcasting the whole tensor: an explicit x.astype(f32) node gets hoisted by
# XLA into the layer-scan's saved buffers, doubling every stacked residual
# (observed: 3.4 GiB -> 1.7 GiB per nemotron microbatch).
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    xc = x - mu.astype(x.dtype)
    var = jnp.mean(jnp.square(xc), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return xc * inv * w + b


def apply_norm(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(cfg: ArchConfig, d: int) -> Dict:
    p = {"scale": jnp.ones((d,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype_of(cfg))
    return p


# ------------------------------------------------------------------ rope
def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, n, head_dim); cos/sin: (..., S, half) broadcast over n."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ------------------------------------------------------------- attention
def init_attention(cfg: ArchConfig, key: jax.Array, d_model: Optional[int] = None):
    D = d_model or cfg.d_model
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sc = lambda fan_in: 1.0 / jnp.sqrt(jnp.float32(fan_in))
    return {
        "wq": (jax.random.normal(k1, (D, H * hd)) * sc(D)).astype(dt),
        "wk": (jax.random.normal(k2, (D, KV * hd)) * sc(D)).astype(dt),
        "wv": (jax.random.normal(k3, (D, KV * hd)) * sc(D)).astype(dt),
        "wo": (jax.random.normal(k4, (H * hd, D)) * sc(H * hd)).astype(dt),
    }


def _gqa_scores_softmax_v(q, k, v, mask, scale):
    """q: (B,S,KV,G,hd)  k/v: (B,T,KV,hd)  mask: broadcastable (B,1,1,S,T).

    Returns (B,S,KV,G,hd). Softmax in f32.
    """
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


def _attn_chunked(cfg: ArchConfig, q, k, v, positions, scale):
    """Flash-style query-chunked causal attention.

    Full S x T score materialization at 32k+ sequence lengths is the single
    largest activation in the prefill cells (tens of GB/device); chunking
    the query axis bounds the live score block to (B, H, chunk, T). The
    scan output is just the (B,S,KV,G,hd) attention output.
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    chunk = cfg.attn_chunk
    nq = S // chunk
    t_idx = jnp.arange(T, dtype=jnp.int32)
    qs = q.reshape(B, nq, chunk, KV, G, hd).swapaxes(0, 1)    # (nq, B, C, ...)
    ps = positions.reshape(B, nq, chunk).swapaxes(0, 1)

    @jax.checkpoint  # recompute chunk scores in backward — otherwise the
    def body(_, qp):  # scan saves every chunk's (B,H,C,T) f32 probs
        qc, pc = qp
        mask = pc[:, None, None, :, None] >= t_idx[None, None, None, None, :]
        return None, _gqa_scores_softmax_v(qc, k, v, mask, scale)

    _, outs = jax.lax.scan(body, None, (qs, ps))
    return outs.swapaxes(0, 1).reshape(B, S, KV, G, hd)


def attention(
    cfg: ArchConfig,
    p: Dict,
    x: jnp.ndarray,                    # (B, S, D)
    positions: jnp.ndarray,            # (B, S) int32
    *,
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn K/V source
    causal: bool = True,
    use_rope: bool = True,
    cache: Optional[Dict] = None,      # decode: {"k","v": (B,T,KV,hd), "pos": ()}
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, D = x.shape
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV

    q = (x @ p["wq"]).reshape(B, S, KV, G, hd)
    if kv is None:
        k = (x @ p["wk"]).reshape(B, S, KV, hd)
        v = (x @ p["wv"]).reshape(B, S, KV, hd)
    else:
        src_k, src_v = kv
        k = (src_k @ p["wk"]).reshape(B, src_k.shape[1], KV, hd)
        v = (src_v @ p["wv"]).reshape(B, src_v.shape[1], KV, hd)

    if use_rope and kv is None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q.reshape(B, S, KV * G, hd), cos, sin).reshape(B, S, KV, G, hd)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        # one-token decode: S == 1; write k/v at cache["pos"].
        T = cache["k"].shape[1]
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
        t_idx = jnp.arange(T, dtype=jnp.int32)
        mask = (t_idx[None, None, None, None, :] <= pos)  # attend to filled prefix
    elif causal:
        scale = 1.0 / jnp.sqrt(jnp.float32(hd))
        if cfg.attn_chunk and S > cfg.attn_chunk and S % cfg.attn_chunk == 0:
            out = _attn_chunked(cfg, q, k, v, positions, scale)
            out = out.reshape(B, S, H * hd) @ p["wo"]
            return out, new_cache
        T = k.shape[1]
        t_idx = jnp.arange(T, dtype=jnp.int32)
        mask = positions[:, None, None, :, None] >= t_idx[None, None, None, None, :]
    else:
        mask = jnp.ones((1, 1, 1, 1, k.shape[1]), dtype=bool)

    out = _gqa_scores_softmax_v(q, k, v, mask, 1.0 / jnp.sqrt(jnp.float32(hd)))
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


def attention_decode_inplace(
    cfg: ArchConfig,
    p: Dict,                 # per-layer attention params (already indexed)
    x: jnp.ndarray,          # (B, 1, D)
    pos: jnp.ndarray,        # scalar int32
    k_all: jnp.ndarray,      # (L, B, T, KV, hd) — full stacked cache
    v_all: jnp.ndarray,
    layer: jnp.ndarray,      # scalar int32
    use_rope: bool = True,
    scales: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # int8 cache
) -> Tuple[jnp.ndarray, ...]:
    """One-token decode that updates the stacked KV cache IN PLACE.

    Used inside a fori_loop over layers (dense/moe/encdec decode): unlike a
    lax.scan over (cache_k, cache_v) — whose stacked ys allocate a second
    full cache — dynamic_update_slice on a loop-carried (donated) buffer
    aliases, so decode peak memory stays ~1x cache. See EXPERIMENTS.md
    §Dry-run for the measured 3x -> 1x effect.
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    T = k_all.shape[2]

    q = (x @ p["wq"]).reshape(B, S, KV, G, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if use_rope:
        positions = jnp.broadcast_to(pos[None, None], (B, S)).astype(jnp.int32)
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q.reshape(B, S, KV * G, hd), cos, sin).reshape(B, S, KV, G, hd)
        k = apply_rope(k, cos, sin)

    # Read the (stale) prefix slice BEFORE the update and attend over
    # [prefix ; current]: the dynamic_update_slice is then write-only, so
    # XLA can alias the loop-carried cache buffer in place instead of
    # double-buffering it (a ~2x decode-memory difference at 32k).
    if scales is not None:
        # int8 cache: absmax-quantize this token's K/V over (KV, hd),
        # store int8 + per-(b, pos) bf16 scale; dequantize the prefix.
        ks_all, vs_all = scales
        k_sc = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=(2, 3),
                       keepdims=False) / 127.0 + 1e-30        # (B, 1)
        v_sc = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=(2, 3),
                       keepdims=False) / 127.0 + 1e-30
        k_q = jnp.clip(jnp.round(k.astype(jnp.float32) / k_sc[..., None, None]),
                       -127, 127).astype(jnp.int8)
        v_q = jnp.clip(jnp.round(v.astype(jnp.float32) / v_sc[..., None, None]),
                       -127, 127).astype(jnp.int8)
        k_l = jax.lax.dynamic_index_in_dim(k_all, layer, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(v_all, layer, 0, keepdims=False)
        ks_l = jax.lax.dynamic_index_in_dim(ks_all, layer, 0, keepdims=False)
        vs_l = jax.lax.dynamic_index_in_dim(vs_all, layer, 0, keepdims=False)
        k_all = jax.lax.dynamic_update_slice(k_all, k_q[None], (layer, 0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(v_all, v_q[None], (layer, 0, pos, 0, 0))
        ks_all = jax.lax.dynamic_update_slice(
            ks_all, k_sc[None].astype(ks_all.dtype), (layer, 0, pos))
        vs_all = jax.lax.dynamic_update_slice(
            vs_all, v_sc[None].astype(vs_all.dtype), (layer, 0, pos))
        dt = x.dtype
        k_l = (k_l.astype(jnp.float32) * ks_l[..., None, None].astype(jnp.float32)).astype(dt)
        v_l = (v_l.astype(jnp.float32) * vs_l[..., None, None].astype(jnp.float32)).astype(dt)
        k_cat = jnp.concatenate([k_l, k.astype(dt)], axis=1)
        v_cat = jnp.concatenate([v_l, v.astype(dt)], axis=1)
        T = k_l.shape[1]
        t_idx = jnp.arange(T + 1, dtype=jnp.int32)
        mask = (t_idx[None, None, None, None, :] < pos) | (
            t_idx == T)[None, None, None, None, :]
        out = _gqa_scores_softmax_v(q, k_cat, v_cat, mask,
                                    1.0 / jnp.sqrt(jnp.float32(hd)))
        out = out.reshape(B, S, H * hd) @ p["wo"]
        return out, k_all, v_all, ks_all, vs_all

    k_l = jax.lax.dynamic_index_in_dim(k_all, layer, 0, keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(v_all, layer, 0, keepdims=False)
    k_all = jax.lax.dynamic_update_slice(
        k_all, k[None].astype(k_all.dtype), (layer, 0, pos, 0, 0))
    v_all = jax.lax.dynamic_update_slice(
        v_all, v[None].astype(v_all.dtype), (layer, 0, pos, 0, 0))

    k_cat = jnp.concatenate([k_l, k.astype(k_l.dtype)], axis=1)  # (B, T+1, ...)
    v_cat = jnp.concatenate([v_l, v.astype(v_l.dtype)], axis=1)
    t_idx = jnp.arange(T + 1, dtype=jnp.int32)
    # prefix entries valid for t < pos; the appended slot (t == T) is the
    # current token and always valid.
    mask = (t_idx[None, None, None, None, :] < pos) | (t_idx == T)[None, None, None, None, :]
    out = _gqa_scores_softmax_v(q, k_cat, v_cat, mask, 1.0 / jnp.sqrt(jnp.float32(hd)))
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out, k_all, v_all


def index_layer(tree, layer):
    """Dynamic per-layer slice of a stacked param pytree (fori_loop body)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, layer, 0, keepdims=False), tree
    )


# ------------------------------------------------------------------- mlp
def init_mlp(cfg: ArchConfig, key: jax.Array, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    sc_in = 1.0 / jnp.sqrt(jnp.float32(D))
    sc_out = 1.0 / jnp.sqrt(jnp.float32(F))
    p = {
        "w_up": (jax.random.normal(k1, (D, F)) * sc_in).astype(dt),
        "w_down": (jax.random.normal(k2, (F, D)) * sc_out).astype(dt),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (D, F)) * sc_in).astype(dt)
    return p


def mlp(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ------------------------------------------------------------- embedding
def init_embed(cfg: ArchConfig, key: jax.Array):
    dt = dtype_of(cfg)
    emb = (jax.random.normal(key, (cfg.vocab, cfg.d_model)) * 0.02).astype(dt)
    p = {"tok": emb}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["lm_head"] = (
            jax.random.normal(k2, (cfg.d_model, cfg.vocab))
            * (1.0 / jnp.sqrt(jnp.float32(cfg.d_model)))
        ).astype(dt)
    return p


def embed_tokens(p: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["lm_head"]


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token-level cross entropy; logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_xent(cfg: ArchConfig, embed_p: Dict, x: jnp.ndarray,
                 labels: jnp.ndarray) -> jnp.ndarray:
    """Cross entropy with the LM head folded in, chunked over the sequence.

    Never materializes the full (B, S, V) logits — per chunk the transient
    is (B, chunk, V), and jax.checkpoint on the chunk body keeps the
    backward pass from saving per-chunk logits either. This is what lets the
    256k-vocab archs fit the memory roofline (EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    chunk = min(cfg.loss_chunk, S)
    if S % chunk != 0:
        chunk = S
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)         # (n, B, chunk, D)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        xc, lc = inp
        logits = lm_logits(cfg, embed_p, xc).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (B * S)


def act_constraint(cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Residual-stream sharding between blocks (cfg.act_shard).

    "seq":   batch -> act_dp_axes, sequence -> "model" (Megatron-style
             sequence parallelism; the >=7B archs).
    "batch": batch -> act_dp_axes (re-pins pure-DP sharding so XLA never
             drifts to replicated activations inside the layer scan; the
             dp-profile archs with all-axis DP).
    """
    if x.ndim != 3 or cfg.act_shard == "none":
        return x
    from jax.sharding import PartitionSpec as P

    bdim = cfg.act_dp_axes if len(cfg.act_dp_axes) > 1 else cfg.act_dp_axes[0]
    if cfg.act_shard == "seq":
        return jax.lax.with_sharding_constraint(x, P(bdim, "model", None))
    if cfg.act_shard == "batch":
        return jax.lax.with_sharding_constraint(x, P(bdim, None, None))
    return x


def act_entry(cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Megatron-SP entry point: all-gather the sequence dim at the
    attention/MLP input so weight-grad matmuls contract over LOCAL tokens
    with the FFN dim sharded — otherwise XLA computes full-size (D, F) f32
    weight-grad partials per device (5.4 GB each for nemotron-340b)."""
    if x.ndim != 3 or cfg.act_shard != "seq":
        return x
    from jax.sharding import PartitionSpec as P

    bdim = cfg.act_dp_axes if len(cfg.act_dp_axes) > 1 else cfg.act_dp_axes[0]
    return jax.lax.with_sharding_constraint(x, P(bdim, None, None))
