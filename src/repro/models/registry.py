"""Family -> model-module dispatch + the uniform step API every launcher,
test, benchmark, and the dry-run use.

API per family module:
  init(cfg, key) -> params
  loss_fn(cfg, params, batch) -> scalar
  init_cache(cfg, batch, max_len[, ...]) -> cache
  decode_step(cfg, params, cache, tokens) -> (logits, cache)

Batch contents by family (see launch/specs.py for the ShapeDtypeStruct
versions used by the dry-run):
  dense/moe/ssm/hybrid: {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm:    {"embeds": (B,S,D) bf16, "labels": (B,S) i32}   (stub frontend)
  encdec: {"enc_embeds": (B,enc_len,D) bf16, "tokens", "labels"}
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import dense, encdec, hybrid, moe, ssm

_FAMILIES = {
    "dense": dense,
    "vlm": dense,      # backbone only; embeds_in=True switches the input path
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
}


def model_for(cfg: ArchConfig):
    return _FAMILIES[cfg.family]


def init_params(cfg: ArchConfig, key: jax.Array):
    return model_for(cfg).init(cfg, key)


def loss_fn(cfg: ArchConfig, params, batch: Dict):
    return model_for(cfg).loss_fn(cfg, params, batch)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, **kw):
    return model_for(cfg).init_cache(cfg, batch, max_len, **kw)


def decode_step(cfg: ArchConfig, params, cache, tokens):
    """tokens: (B,1) i32 for LMs; (B,1,D) embeds for VLM."""
    return model_for(cfg).decode_step(cfg, params, cache, tokens)


def make_batch(cfg: ArchConfig, shape, key: jax.Array) -> Dict:
    """Concrete random batch (smoke tests / examples)."""
    B, S = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "vlm" or cfg.embeds_in:
        return {
            "embeds": jax.random.normal(k1, (B, S, cfg.d_model), jnp.float32) * 0.02,
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab, jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "enc_embeds": jax.random.normal(
                k1, (B, cfg.enc_len, cfg.d_model), jnp.float32) * 0.02,
            "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab, jnp.int32),
            "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab, jnp.int32),
        }
    return {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab, jnp.int32),
    }
