"""Mixture-of-Experts transformer (deepseek-moe-16b, grok-1-314b).

Token-choice top-k routing with GShard-style capacity dispatch, expressed
as grouped one-hot einsums so the whole layer is dense, statically-shaped,
and shardable:

  * tokens are processed in groups of ``moe_group_size`` (the group axis
    shards over "data"; the expert axis shards over "model" — the dispatch
    einsum is where the expert-parallel all-to-all materializes);
  * per (token, slot) the routed expert gets a capacity slot by ranked
    cumsum; tokens over capacity drop to the residual path (standard
    capacity-factor semantics);
  * experts: SwiGLU/GELU MLPs with stacked (E, D, F) weights;
    deepseek-style shared experts run densely on every token;
  * aux load-balance loss (Switch-style f·p) is returned in metrics.

Attention/embedding reuse the dense-model primitives; layers scan with the
same remat policy.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


# --------------------------------------------------------------- routing
def _route(
    cfg: ArchConfig, router_w: jnp.ndarray, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (G, T, D) -> (gates (G,T,k), idx (G,T,k) int32, probs (G,T,E))."""
    logits = (x @ router_w).astype(jnp.float32)          # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)          # (G, T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx, probs


def _dispatch_tensors(
    cfg: ArchConfig, gates: jnp.ndarray, idx: jnp.ndarray, capacity: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Build dispatch/combine one-hots.

    Returns (dispatch (G,T,E,C) 0/1, combine (G,T,E,C) f32, kept (G,T,k)).
    Slots are ranked token-major then slot-major (GShard order).
    """
    G, T, k = idx.shape
    E, _ = _eff_experts(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    # rank computation in f32 (cumsum over T*k elements must be exact)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)    # (G, T, k, E)
    onehot_flat = onehot.reshape(G, T * k, E)             # token-major (t, s) priority
    ranks = jnp.cumsum(onehot_flat, axis=1) - onehot_flat  # rank within expert queue
    keep = (ranks < capacity) * onehot_flat               # (G, T*k, E)
    rank_idx = jnp.sum(ranks * onehot_flat, axis=-1).astype(jnp.int32)
    # one-hots cast down to the compute dtype before the big outer product
    rank_oh = jax.nn.one_hot(rank_idx, capacity, dtype=dt)  # (G, T*k, C)
    disp_flat = keep.astype(dt)[..., None] * rank_oh[:, :, None, :]
    dispatch = disp_flat.reshape(G, T, k, E, capacity).sum(axis=2)
    gate_flat = gates.reshape(G, T * k).astype(dt)
    comb_flat = disp_flat * gate_flat[..., None, None]
    combine = comb_flat.reshape(G, T, k, E, capacity).sum(axis=2)
    kept_any = keep.reshape(G, T, k, E).sum(-1)
    return dispatch, combine, kept_any


def moe_capacity(cfg: ArchConfig, group_tokens: int) -> int:
    c = int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(4, (c + 3) // 4 * 4)


def _eff_experts(cfg: ArchConfig):
    """(E_eff, F_eff) after expert slicing."""
    s = max(cfg.expert_slices, 1)
    return cfg.n_experts * s, cfg.expert_d_ff // s


def init_moe_mlp(cfg: ArchConfig, key: jax.Array) -> Dict:
    D, E_ = cfg.d_model, cfg.n_experts
    E, F = _eff_experts(cfg)
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    sc_in = 1.0 / jnp.sqrt(jnp.float32(D))
    sc_out = 1.0 / jnp.sqrt(jnp.float32(F))
    p = {
        "router": (jax.random.normal(k1, (D, E_)) * sc_in).astype(jnp.float32),
        "w_up": (jax.random.normal(k2, (E, D, F)) * sc_in).astype(dt),
        "w_down": (jax.random.normal(k3, (E, F, D)) * sc_out).astype(dt),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k4, (E, D, F)) * sc_in).astype(dt)
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * cfg.expert_d_ff
        p["shared"] = L.init_mlp(cfg, k5, d_ff=Fs)
    return p


def _expert_act(cfg: ArchConfig, p: Dict, h_in: jnp.ndarray) -> jnp.ndarray:
    """h_in: (G, E, C, D) -> (G, E, C, D) through per-expert MLPs."""
    # pin the compute dtype: an f32 h_in would silently promote the expert
    # weights to f32 (XLA materializes full converted copies of every
    # expert matrix — 24 GiB for grok before this cast).
    h_in = h_in.astype(jnp.dtype(cfg.param_dtype))
    up = jnp.einsum("gecd,edf->gecf", h_in, p["w_up"])
    if cfg.mlp == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", h_in, p["w_gate"])
        h = jax.nn.silu(gate) * up
    elif cfg.mlp == "geglu":
        gate = jnp.einsum("gecd,edf->gecf", h_in, p["w_gate"])
        h = jax.nn.gelu(gate) * up
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("gecf,efd->gecd", h, p["w_down"])


def moe_mlp(cfg: ArchConfig, p: Dict, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss)."""
    Bsz, S, D = x.shape
    T_all = Bsz * S
    Tg = min(cfg.moe_group_size, T_all)
    assert T_all % Tg == 0, (T_all, Tg)
    G = T_all // Tg
    xg = x.reshape(G, Tg, D)

    if cfg.moe_token_axes:
        # few-expert models (E < model axis): token-groups shard over ALL
        # requested axes; expert weights FSDP-gather per layer instead of
        # colliding with the groups' model-axis sharding (DESIGN.md §5).
        # Divisibility is pre-validated by launch.dryrun._adjust_cfg, which
        # clears the field when G doesn't divide.
        from jax.sharding import PartitionSpec as P

        xg = jax.lax.with_sharding_constraint(
            xg, P(tuple(cfg.moe_token_axes), None, None))

    gates, idx, probs = _route(cfg, p["router"], xg)
    s = max(cfg.expert_slices, 1)
    if s > 1:
        # expert slicing: a token routed to expert e visits every slice
        # e*s+j with the SAME gate (slice outputs sum to the expert output).
        idx = (idx[..., None] * s + jnp.arange(s, dtype=idx.dtype)).reshape(
            idx.shape[0], idx.shape[1], -1)
        gates = jnp.repeat(gates, s, axis=-1)
    C = moe_capacity(cfg, Tg)
    dispatch, combine, _ = _dispatch_tensors(cfg, gates, idx, C)

    h_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    h_out = _expert_act(cfg, p, h_in)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), h_out)

    # Switch-style aux loss: E * mean_e(frac_tokens_e * mean_prob_e)
    frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0], cfg.n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(frac * pmean)

    if cfg.n_shared_experts:
        y = y + L.mlp(cfg, p["shared"], xg)
    return y.reshape(Bsz, S, D), aux


# ----------------------------------------------------------------- blocks
def init_block(cfg: ArchConfig, key: jax.Array) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "moe": init_moe_mlp(cfg, k2),
    }


def init(cfg: ArchConfig, key: jax.Array) -> Dict:
    ke, kb = jax.random.split(key)
    block_keys = jax.random.split(kb, cfg.n_layers)
    return {
        "embed": L.init_embed(cfg, ke),
        "blocks": jax.vmap(lambda k: init_block(cfg, k))(block_keys),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def _block_apply(cfg, lp, carry, positions):
    x, aux = carry
    h, _ = L.attention(
        cfg, lp["attn"], L.act_entry(cfg, L.apply_norm(cfg, lp["ln1"], x)),
        positions)
    x = L.act_constraint(cfg, x + h)
    m, a = moe_mlp(cfg, lp["moe"], L.apply_norm(cfg, lp["ln2"], x))
    return L.act_constraint(cfg, x + m), aux + a


def hidden_states(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
                  positions: Optional[jnp.ndarray] = None):
    x = L.embed_tokens(params["embed"], tokens)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = L.act_constraint(cfg, x)

    body = functools.partial(_block_apply, cfg)
    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        body = jax.checkpoint(body, policy=policy)

    def scan_fn(carry, lp):
        return body(lp, carry, positions), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    return L.apply_norm(cfg, params["final_norm"], x), aux / cfg.n_layers


def forward(cfg: ArchConfig, params: Dict, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None):
    x, aux = hidden_states(cfg, params, tokens, positions)
    return L.lm_logits(cfg, params["embed"], x), aux


def loss_fn(cfg: ArchConfig, params: Dict, batch: Dict) -> jnp.ndarray:
    x, aux = hidden_states(cfg, params, batch["tokens"])
    return L.chunked_xent(cfg, params["embed"], x, batch["labels"]) + 0.01 * aux


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Dict:
    from repro.models import dense as _dense

    return _dense.init_cache(cfg, batch, max_len)


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens: jnp.ndarray):
    x = L.embed_tokens(params["embed"], tokens)
    pos = cache["pos"]
    quant = cfg.kv_cache_dtype == "int8"

    def body(l, carry):
        if quant:
            x, ck, cv, ks, vs = carry
        else:
            x, ck, cv = carry
        lp = L.index_layer(params["blocks"], l)
        res = L.attention_decode_inplace(
            cfg, lp["attn"], L.apply_norm(cfg, lp["ln1"], x), pos, ck, cv, l,
            scales=(ks, vs) if quant else None)
        if quant:
            h, ck, cv, ks, vs = res
        else:
            h, ck, cv = res
        x = x + h
        m, _ = moe_mlp(cfg, lp["moe"], L.apply_norm(cfg, lp["ln2"], x))
        x = x + m
        return (x, ck, cv, ks, vs) if quant else (x, ck, cv)

    carry0 = (
        (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
        if quant else (x, cache["k"], cache["v"])
    )
    if cfg.decode_unroll:
        carry = carry0
        for l in range(cfg.n_layers):
            carry = body(l, carry)
    else:
        carry = jax.lax.fori_loop(0, cfg.n_layers, body, carry0)
    x = carry[0]
    new_cache = {"k": carry[1], "v": carry[2], "pos": pos + 1}
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = carry[3], carry[4]
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, new_cache
