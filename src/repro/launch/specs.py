"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

Weak-type-correct, shardable, zero allocation. For train/prefill cells the
spec is the batch dict; for decode cells it is (cache, tokens) with the KV
cache as a donated input of seq_len capacity, per the assignment:
"decode_* / long_* lower serve_step (one new token with a KV cache of
seq_len), NOT train_step".
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import registry

PyTree = Any


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs_sds(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.param_dtype
    if cfg.family == "vlm" or cfg.embeds_in:
        return {
            "embeds": _sds((B, S, cfg.d_model), dt),
            "labels": _sds((B, S), jnp.int32),
        }
    if cfg.family == "encdec":
        return {
            "enc_embeds": _sds((B, cfg.enc_len, cfg.d_model), dt),
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
    return {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}


def params_sds(cfg: ArchConfig) -> PyTree:
    return jax.eval_shape(
        functools.partial(registry.init_params, cfg), jax.random.PRNGKey(0)
    )


def cache_sds(cfg: ArchConfig, shape: ShapeSpec) -> PyTree:
    """Decode cache spec sized to the cell's seq_len."""
    B, T = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(
            functools.partial(registry.init_cache, cfg, B, T)
        )
    return jax.eval_shape(functools.partial(registry.init_cache, cfg, B, T))


def decode_tokens_sds(cfg: ArchConfig, shape: ShapeSpec):
    B = shape.global_batch
    if cfg.family == "vlm" or cfg.embeds_in:
        return _sds((B, 1, cfg.d_model), cfg.param_dtype)
    return _sds((B, 1), jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, PyTree]:
    """Everything a cell needs, keyed by role."""
    out: Dict[str, PyTree] = {"params": params_sds(cfg)}
    if shape.kind in ("train", "prefill"):
        out["batch"] = batch_specs_sds(cfg, shape)
    else:
        out["cache"] = cache_sds(cfg, shape)
        out["tokens"] = decode_tokens_sds(cfg, shape)
    return out
