"""Serving driver: batched decode with a KV cache (the decode_* path, run
for real on whatever devices exist).

  PYTHONPATH=src python -m repro.launch.serve --preset tiny --batch 8 \
      --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, smoke_config
from repro.models import registry
from repro.launch.train import TINY


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--preset", default="tiny", choices=["tiny", "smoke"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = TINY if (args.preset == "tiny" or args.arch is None) else smoke_config(args.arch)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("token-LM families only in this driver; see examples/")

    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    cache = registry.init_cache(cfg, args.batch, max_len)
    prompt = jax.random.randint(
        jax.random.fold_in(key, 1), (args.batch, args.prompt_len), 0, cfg.vocab,
        jnp.int32,
    )

    step = jax.jit(lambda p, c, t: registry.decode_step(cfg, p, c, t),
                   donate_argnums=(1,))

    # prefill token-by-token (same step fn; production would batch-prefill)
    t0 = time.time()
    tok = prompt[:, :1]
    for i in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, i : i + 1])
    t_prefill = time.time() - t0

    # autoregressive generation
    t0 = time.time()
    out = []
    rng = jax.random.fold_in(key, 2)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        out.append(np.asarray(tok))
        logits, cache = step(params, cache, tok)
        rng = jax.random.fold_in(rng, i)
        if args.temperature > 0:
            tok = jax.random.categorical(
                rng, logits[:, -1].astype(jnp.float32) / args.temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_gen = time.time() - t0

    gen = np.concatenate(out, axis=1)
    tok_s = args.batch * args.gen / max(t_gen, 1e-9)
    print(f"prefill {args.prompt_len} tokens x {args.batch} reqs: {t_prefill:.2f}s")
    print(f"generated {args.gen} tokens x {args.batch} reqs: {t_gen:.2f}s "
          f"({tok_s:,.0f} tok/s)")
    print("first request tokens:", gen[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
