"""Training driver: config -> mesh -> sharded train loop with fault
tolerance.

Runs REAL steps on whatever devices exist (CPU smoke: --preset tiny; TPU
pod: the full config), with:
  * automatic resume from the latest atomic checkpoint (--resume),
  * periodic checkpointing (--ckpt-every) through train/checkpoint.py,
  * deterministic shard-recomputable data (data/pipeline.py),
  * elastic restart: the checkpoint restores onto whatever mesh this
    process was launched with (train/elastic.py),
  * a step watchdog (--step-timeout) that aborts the run (exit code 75)
    so the scheduler restarts it from the checkpoint — the straggler
    escape hatch when a host goes sick mid-step.

Examples:
  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --steps 50 \
      --batch 8 --seq 256            # reduced run of a real config
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, smoke_config
from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.parallel import sharding as shd
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import make_opt_init, make_train_step

TINY = ArchConfig(
    name="tiny-lm",
    family="dense",
    source="(reduced in-repo preset)",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab=512,
    head_dim=32,
    mlp="swiglu",
    norm="rmsnorm",
    param_dtype="float32",
    optimizer="adamw",
    remat="none",
    loss_chunk=128,
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--preset", default=None, choices=["tiny", "smoke"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/run")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data-kind", default="markov", choices=["markov", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--step-timeout", type=float, default=0.0,
                    help="abort (exit 75) if one step exceeds this many seconds")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    if args.preset == "tiny" or args.arch is None:
        cfg = TINY
    elif args.preset == "smoke":
        cfg = smoke_config(args.arch)
    else:
        cfg = dataclasses.replace(
            get_arch(args.arch), num_microbatches=1, act_shard="none",
            param_dtype="float32",
        )
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("this driver trains token-LM families; see examples/")

    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    data = TokenPipeline(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                   seed=args.seed, kind=args.data_kind)
    )

    opt_cfg = OptimizerConfig(name=cfg.optimizer, lr=args.lr,
                              warmup_steps=min(50, args.steps // 4),
                              total_steps=args.steps)
    params = registry.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = make_opt_init(cfg, opt_cfg)(params)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        start_step, state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    with mesh:
        pspecs = shd.param_specs(cfg, mesh, jax.eval_shape(lambda t: t, params))
        params_sh = shd.named(mesh, pspecs)
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg), donate_argnums=(0, 1),
            in_shardings=(params_sh, None, None), out_shardings=None,
        )

        t_run = time.time()
        losses = []
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if args.step_timeout and dt > args.step_timeout and step > start_step:
                print(f"[watchdog] step {step} took {dt:.1f}s > "
                      f"{args.step_timeout}s — aborting for restart")
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"aborted": True})
                return 75
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                tok_s = args.batch * args.seq / max(dt, 1e-9)
                print(f"step {step:5d}  loss {loss:7.4f}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {tok_s:,.0f} tok/s",
                      flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})

        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        bound = data.entropy_bound_nats()
        print(f"done in {time.time()-t_run:.1f}s; final loss "
              f"{np.mean(losses[-10:]):.4f} (entropy bound {bound:.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
