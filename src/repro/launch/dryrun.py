import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).
# (No `from __future__ import annotations` here — the XLA_FLAGS lines above
# are required to be the first statements of the module.)

#: Multi-pod dry-run docs follow
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the step function (train_step for train_4k, forward-loss for
     prefill_32k, serve_step for decode_32k / long_500k),
  2. jit's it with explicit in/out shardings from parallel/sharding.py,
  3. ``.lower(**ShapeDtypeStruct inputs).compile()`` on the production mesh
     — 16x16 ("data","model") single-pod and 2x16x16 ("pod","data","model")
     multi-pod,
  4. prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and
     ``cost_analysis()`` (FLOPs/bytes for §Roofline), parses collective
     wire bytes from the compiled HLO,
  5. writes reports/dryrun/<mesh>/<arch>__<shape>.json for
     benchmarks/roofline.py.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the run aborts non-zero unless --keep-going.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single          # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --resume
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.launch import specs as S
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.parallel import sharding as shd
from repro.parallel.hlo_analysis import summarize_compiled
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (
    default_opt_config, make_opt_init, make_prefill_step, make_serve_step,
    make_train_step,
)


def _adjust_cfg(cfg: ArchConfig, shape: ShapeSpec, mesh) -> ArchConfig:
    """Mesh/shape-dependent config fix-ups: act-shard axes, microbatch
    divisibility, MoE group divisibility."""
    dp = shd.dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    changes: Dict = {"act_dp_axes": tuple(dp)}
    if shape.kind == "train":
        n_mb = max(cfg.num_microbatches, 1)
        while n_mb > 1 and (shape.global_batch % (n_mb * n_dp) != 0):
            n_mb //= 2
        changes["num_microbatches"] = n_mb
    else:
        changes["num_microbatches"] = 1
    if shd.profile_of(cfg) in ("dp", "fsdp_pure") and cfg.act_shard == "none":
        # dp-profile: re-pin pure-DP activation sharding between blocks so
        # XLA never drifts to replicated activations inside the layer scan.
        n_mb = changes["num_microbatches"]
        per_mb = shape.global_batch // max(n_mb, 1)
        bdim = shd.batch_dim(cfg, mesh, per_mb)
        if bdim is not None:
            axes = bdim if isinstance(bdim, tuple) else (bdim,)
            changes["act_shard"] = "batch"
            changes["act_dp_axes"] = tuple(axes)
    if cfg.family == "moe":
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        g = min(cfg.moe_group_size, max(tokens // max(n_dp, 1), 1))
        changes["moe_group_size"] = g
        if cfg.moe_token_axes:
            # per-microbatch token count determines the group count G
            if shape.kind == "train":
                mb_tokens = (shape.global_batch // changes["num_microbatches"]) * shape.seq_len
            elif shape.kind == "prefill":
                mb_tokens = (shape.global_batch // max(cfg.prefill_microbatches, 1)) * shape.seq_len
            else:
                mb_tokens = shape.global_batch
            axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
            n_all = 1
            for a in axes:
                n_all *= mesh.shape[a]
            # shrink the group so G divides the full device count
            while g > 1 and (mb_tokens // g) % n_all != 0:
                g //= 2
            if g >= 1 and mb_tokens >= g and (mb_tokens // g) % n_all == 0:
                changes["moe_group_size"] = g
                changes["moe_token_axes"] = axes
            else:
                changes["moe_token_axes"] = ()
    return dataclasses.replace(cfg, **changes)


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               opt_override: Optional[OptimizerConfig] = None,
               cfg_override: Optional[ArchConfig] = None,
               compress_pod: bool = False):
    """Returns (lowered, compiled, summary_dict)."""
    shape = SHAPES[shape_name]
    cfg = cfg_override or get_arch(arch)
    if shape.name in cfg.skip_shapes:
        raise ValueError(f"{arch} skips {shape_name} (see DESIGN.md)")
    cfg = _adjust_cfg(cfg, shape, mesh)
    n_dev = mesh.devices.size

    t0 = time.time()
    with mesh:
        params_sds = S.params_sds(cfg)
        pspecs = shd.param_specs(cfg, mesh, params_sds)
        params_sh = shd.named(mesh, pspecs)

        if shape.kind == "train":
            opt_cfg = opt_override or default_opt_config(cfg)
            opt_init = make_opt_init(cfg, opt_cfg)
            opt_sds = jax.eval_shape(opt_init, params_sds)
            ospecs = shd.opt_state_specs(cfg, mesh, opt_sds, pspecs)
            opt_sh = shd.named(mesh, ospecs)
            batch_sh = shd.named(mesh, shd.batch_specs(cfg, mesh, shape))
            batch_sds = S.batch_specs_sds(cfg, shape)
            cp = None
            if compress_pod:
                cp = (mesh, shd.batch_specs(cfg, mesh, shape))
                # inside the shard_map body the pod axis is Manual: sharding
                # constraints in the loss may only reference Auto axes.
                cfg = dataclasses.replace(
                    cfg, act_dp_axes=tuple(
                        a for a in cfg.act_dp_axes if a != "pod"))
            step = make_train_step(
                cfg, opt_cfg, compress_pod=cp,
                grad_specs=shd.named(mesh, shd.grad_specs(cfg, mesh, params_sds)))
            metrics_sh = {
                "loss": NamedSharding(mesh, P()),
                "grad_norm": NamedSharding(mesh, P()),
                "lr": NamedSharding(mesh, P()),
            }
            jf = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            )
            lowered = jf.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sh = shd.named(mesh, shd.batch_specs(cfg, mesh, shape))
            batch_sds = S.batch_specs_sds(cfg, shape)
            step = make_prefill_step(cfg)
            jf = jax.jit(
                step,
                in_shardings=(params_sh, batch_sh),
                out_shardings=NamedSharding(mesh, P()),
            )
            lowered = jf.lower(params_sds, batch_sds)
        else:  # decode
            cache_sds = S.cache_sds(cfg, shape)
            cspecs = shd.cache_specs(cfg, mesh, shape, cache_sds)
            cache_sh = shd.named(mesh, cspecs)
            tok_sds = S.decode_tokens_sds(cfg, shape)
            tok_sh = NamedSharding(mesh, shd.decode_tokens_spec(cfg, mesh, shape))
            step = make_serve_step(cfg)
            jf = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jf.lower(params_sds, cache_sds, tok_sds)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    summary = summarize_compiled(compiled, n_dev)
    summary.update({
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "num_microbatches": cfg.num_microbatches,
        "act_shard": cfg.act_shard,
        "profile": shd.profile_of(cfg),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    })
    mem = summary.get("memory", {})
    if isinstance(mem.get("peak_bytes"), int):
        summary["fits_hbm"] = bool(mem["peak_bytes"] <= HBM_BYTES)
    return lowered, compiled, summary


def cells_for(arch: str):
    cfg = get_arch(arch)
    return [s.name for s in cfg.shapes()]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists")
    ap.add_argument("--keep-going", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ARCHS)
    failures = []
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            shapes = [args.shape] if args.shape else cells_for(arch)
            for shape_name in shapes:
                if shape_name in get_arch(arch).skip_shapes:
                    print(f"[skip] {arch} x {shape_name} (sub-quadratic required)")
                    continue
                path = os.path.join(outdir, f"{arch}__{shape_name}.json")
                if args.resume and os.path.exists(path):
                    print(f"[resume] {arch} x {shape_name} exists")
                    continue
                print(f"[lower+compile] {mesh_name}: {arch} x {shape_name} ...",
                      flush=True)
                try:
                    _, compiled, summary = lower_cell(arch, shape_name, mesh, mesh_name)
                    mem = summary["memory"]
                    print(
                        f"  ok: flops/dev={summary['flops_per_device']:.3e} "
                        f"bytes/dev={summary['bytes_per_device']:.3e} "
                        f"coll_wire/dev={summary['collective_wire_bytes_per_device']:.3e} "
                        f"peak={mem.get('peak_bytes', -1)/2**30:.2f}GiB "
                        f"fits={summary.get('fits_hbm')} "
                        f"compile={summary['compile_s']}s",
                        flush=True,
                    )
                    with open(path, "w") as f:
                        json.dump(summary, f, indent=1)
                    del compiled
                except Exception as e:
                    failures.append((mesh_name, arch, shape_name, repr(e)))
                    traceback.print_exc()
                    if not args.keep_going:
                        return 1
    if failures:
        print(f"{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("dry-run complete: all cells lowered + compiled.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
