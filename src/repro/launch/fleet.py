"""Elastic multi-tenant fabric fleet: bucketed geometry pools, tenant
routing, LRU eviction and golden-image re-admission.

The paper's proof-of-concept serves ONE classifier on one eFPGA; the
production question is thousands of distinct tenant configs. The fleet
answers it with three mechanisms layered on machinery that already
exists:

* **Bucketed geometry pools** (kernels.lut_eval.ops.bucket_envelope /
  pack_fabric_pool): every tenant config quantizes to a coarse padded
  envelope (levels, level width, inputs, outputs, band — each snapped
  to a grid point), and the fleet runs ONE ``ReadoutServer`` per
  envelope, pinned to it (``ReadoutServer(envelope=...)``). All static
  kernel dimensions are functions of the envelope alone, so the fleet
  compiles one kernel per BUCKET, not per tenant — and an arbitrary
  new tenant whose envelope matches a warm bucket admits through the
  established ``reconfigure`` -> ``swap_chip`` path with ZERO jit
  retraces and zero dropped frames for incumbents (pending work is
  flushed and delivered, never discarded).

* **LRU eviction + golden re-admission** (core.bitstream.
  GoldenImageStore): a bucket has a fixed number of chip slots; when
  every slot is seated the least-recently-used tenant is evicted. Its
  golden image (the CRC-framed bitstream snapshotted at admission, the
  same store the scrub loop heals from) stays in the fleet store, and
  the tenant transparently re-admits FROM that image on its next
  request — the seated config is decoded from golden bytes, not from
  whatever host object happens to be around, so an evicted tenant
  returns exactly as verified. ``retire`` discards the golden image;
  subsequent requests raise the named ``GoldenSlotError``.

* **Grow/shrink** (launch.mesh.make_fleet_meshes + train.elastic.
  reshard_replicated): buckets are created on demand (``admit`` /
  ``prewarm``) and retired when empty (``shrink``); after every
  resize the per-bucket device slabs are re-planned and any bucket
  whose slab moved re-places its stack via
  ``ReadoutServer.rebind_mesh`` — replicated serving state reshards
  onto any slab size, the same property elastic train restarts rely
  on. Resizing is a control-plane event (it MAY retrace); tenant
  admission into an existing bucket never does.

Per-tenant accounting (``report()["tenants"]``) closes the identity::

    events_in == events_out + shed + quota_shed
               + evicted_while_queued + outstanding

where ``shed`` is the bucket server's two-predictor deadline admission,
``quota_shed`` is the per-tenant outstanding-events quota
(``ServerConfig.tenant_quota_queued``), ``evicted_while_queued`` counts
events cancelled by a non-draining eviction, and ``outstanding`` drains
to zero at ``flush``. SEU-disagreement and scrub counters are folded
from the tenant's slot (baselined at seat time, so slot reuse never
bleeds one tenant's counters into another's).

The network front door (net/ingress.py) targets a fleet exactly like a
single server, with ``FrontDoorConfig.sensor_tenants`` mapping wire
sensor ids onto tenant keys.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, Hashable, List, Optional

import numpy as np

from repro.core.bitstream import GoldenImageStore, encode
from repro.core.fabric import StackGeometry
from repro.core.tmr import replica_table_images
from repro.core.readout import ReadoutChip
from repro.kernels.lut_eval.ops import bucket_envelope
from repro.launch.mesh import make_fleet_meshes
from repro.launch.readout_server import (
    ReadoutServer, ScoredEvent, ServerConfig,
)


class UnknownTenantError(KeyError):
    """A fleet request named a tenant that was never admitted.

    Named (like ``GoldenSlotError`` and the wire ``ProtocolError``
    family) so routing layers can answer "no such tenant" instead of
    crashing on a raw KeyError.
    """

    def __init__(self, tenant):
        self.tenant = tenant
        super().__init__(f"unknown tenant {tenant!r} (admit() it first)")

    def __str__(self) -> str:
        return self.args[0]


@dataclasses.dataclass(frozen=True)
class TenantScoredEvent:
    """One scored event leaving the fleet: fleet-global seq (monotone,
    unique across every bucket — the front door routes by it), the
    owning tenant, and the same integer score / keep decision a
    single-server ``ScoredEvent`` carries."""

    seq: int
    tenant: Hashable
    score_raw: int
    keep: bool


@dataclasses.dataclass
class _TenantState:
    tenant: Hashable
    chip: ReadoutChip
    envelope: StackGeometry
    state: str = "resident"            # resident | evicted | retired
    bucket: Optional[int] = None
    slot: Optional[int] = None
    last_used: float = 0.0
    # fleet-owned cumulative counters (survive evict/re-admit cycles)
    events_in: int = 0
    events_out: int = 0
    n_kept: int = 0
    shed: int = 0
    quota_shed: int = 0
    evicted_while_queued: int = 0
    admissions: int = 0
    evictions: int = 0
    readmissions: int = 0
    # server seq -> fleet seq for every admitted-but-undrained event
    outstanding: Dict[int, int] = dataclasses.field(default_factory=dict)
    # accumulated slot-folded health counters + seat-time baselines
    seu_disagreements: List[int] = dataclasses.field(default_factory=list)
    scrub_frames: int = 0
    _base_dis: List[int] = dataclasses.field(default_factory=list)
    _base_scrub: int = 0


class _Bucket:
    """One geometry bucket: a pinned ReadoutServer plus slot ownership."""

    def __init__(self, envelope: StackGeometry, server: ReadoutServer):
        self.envelope = envelope
        self.server = server
        self.slots: List[Optional[Hashable]] = [None] * server.n_chips
        # server seq -> tenant, for routing drained results
        self.route: Dict[int, Hashable] = {}


class TenantFleet:
    """Serve MANY tenants' chips from a small set of bucketed servers.

    ``config`` is the per-bucket ``ServerConfig`` template (every bucket
    server shares it; ``tenant_quota_queued`` is read HERE, by the
    fleet). ``bucket_slots`` is the fixed chip-slot count of every
    bucket server — the residency capacity per envelope; vacant slots
    hold a clone of the bucket's founding chip and receive no traffic.
    ``clock`` is injectable for deterministic tests, exactly like
    ``ReadoutServer``.

    Lifecycle: ``admit`` seats a tenant (creating its bucket cold if no
    warm one matches), ``submit``/``submit_batch``/``submit_frames``
    score events (transparently re-admitting an evicted tenant from its
    golden image), ``evict`` frees the slot, ``retire`` additionally
    discards the golden image, ``shrink`` retires empty buckets, and
    ``report()["tenants"]`` carries the per-tenant ledger.
    """

    def __init__(
        self,
        config: ServerConfig = ServerConfig(),
        clock=time.monotonic,
        bucket_slots: int = 4,
    ):
        if not (isinstance(bucket_slots, int) and bucket_slots >= 1):
            raise ValueError(
                f"bucket_slots must be an int >= 1, got {bucket_slots!r}")
        if config.sparse:
            raise ValueError(
                "the fleet needs its bucket servers dense (sparse=False): "
                "tenant routing is by per-event seq; sparse egress belongs "
                "at the wire (net/ingress.py)")
        self.config = config
        self._clock = clock
        self.bucket_slots = bucket_slots
        self._buckets: List[_Bucket] = []
        self._by_envelope: Dict[StackGeometry, int] = {}
        self._tenants: Dict[Hashable, _TenantState] = {}
        self._golden = GoldenImageStore()      # keyed by TENANT, not slot
        self._seq = 0
        self._ready: Deque[TenantScoredEvent] = collections.deque()
        self._net_stats_provider: Optional[Callable[[], Dict]] = None
        self._admission_retraces = 0    # warm admissions that retraced (0!)

    # --------------------------------------------------------- inventory
    @property
    def n_buckets(self) -> int:
        return len(self._buckets)

    @property
    def n_tenants(self) -> int:
        return len(self._tenants)

    def has_tenant(self, tenant: Hashable) -> bool:
        """True while the tenant can serve traffic (resident OR evicted
        — an evicted tenant re-admits on its next request). False for
        never-admitted and retired tenants; the front door uses this to
        answer bad-sensor instead of submitting."""
        t = self._tenants.get(tenant)
        return t is not None and t.state != "retired"

    def tenant_state(self, tenant: Hashable) -> str:
        t = self._tenants.get(tenant)
        if t is None:
            raise UnknownTenantError(tenant)
        return t.state

    def attach_net_stats(self, provider: Callable[[], Dict]) -> None:
        """Same contract as ``ReadoutServer.attach_net_stats``: the
        front door's counters surface under ``report()["net"]``."""
        self._net_stats_provider = provider

    # --------------------------------------------------------- admission
    def admit(self, tenant: Hashable, chip: ReadoutChip) -> Dict[str, object]:
        """Seat a tenant's chip; returns admission info.

        The chip's ``bucket_envelope`` picks the bucket: a matching warm
        bucket admits through ``reconfigure`` (array swap, zero
        retraces, incumbents' pending work flushed and DELIVERED — via
        the next ``poll``); no match grows the fleet by one cold bucket
        (that one compiles on its first dispatch). A full bucket first
        LRU-evicts its least-recently-used tenant. Admitting an already
        resident tenant re-seats its (possibly new) chip in place.

        The tenant's golden image (CRC-framed bitstream + per-replica
        digests at the bucket's image geometry) is (re)registered in
        the fleet store — the source of truth eviction returns to.

        Returned info: ``bucket`` (index), ``slot``, ``cold`` (True if
        the bucket was created by this admission), ``evicted`` (the
        tenant LRU-evicted to make room, or None).
        """
        t = self._tenants.get(tenant)
        if t is not None and t.state == "resident" and t.chip is not chip:
            # config push: re-seat in place (stays in the same bucket iff
            # the envelope matches; otherwise move buckets via evict)
            if bucket_envelope(chip.config, self.config.band) == t.envelope:
                b = self._buckets[t.bucket]
                self._deliver(b, b.server.reconfigure(t.slot, chip))
                t.chip = chip
                t.admissions += 1
                self._register_golden(t, b)
                return {"bucket": t.bucket, "slot": t.slot, "cold": False,
                        "evicted": None}
            self.evict(tenant)
            t = self._tenants[tenant]
        if t is None:
            t = _TenantState(
                tenant=tenant, chip=chip,
                envelope=bucket_envelope(chip.config, self.config.band),
                last_used=self._clock(),
            )
            self._tenants[tenant] = t
        else:
            t.chip = chip
            t.envelope = bucket_envelope(chip.config, self.config.band)
        return self._seat(t, chip)

    def prewarm(self, chip: ReadoutChip, warmup: bool = True) -> int:
        """Ensure the bucket for ``chip``'s envelope exists; returns its
        index. ``warmup=True`` additionally runs one throwaway dispatch
        through the founding clone so the bucket's kernel is traced —
        after which any tenant admission into it is retrace-free. The
        explicit GROW half of the fleet's elasticity."""
        env = bucket_envelope(chip.config, self.config.band)
        idx = self._by_envelope.get(env)
        if idx is None:
            idx = self._grow_bucket(env, chip)
        if warmup:
            srv = self._buckets[idx].server
            n_feat = srv.geometry.frontend.n_features
            srv.submit(0, np.zeros(n_feat))
            # throwaway: the founding clone is not a tenant, so the
            # result is unrouted and dropped by _deliver
            self._deliver(self._buckets[idx], srv.flush())
        return idx

    def _grow_bucket(self, env: StackGeometry, chip: ReadoutChip) -> int:
        srv = ReadoutServer(
            [chip] * self.bucket_slots, self.config, self._clock,
            envelope=env)
        self._buckets.append(_Bucket(env, srv))
        idx = len(self._buckets) - 1
        self._by_envelope[env] = idx
        self._replan_meshes()
        return idx

    def _seat(self, t: _TenantState, chip: ReadoutChip) -> Dict[str, object]:
        env = t.envelope
        idx = self._by_envelope.get(env)
        cold = idx is None
        evicted = None
        if cold:
            idx = self._grow_bucket(env, chip)
            slot = 0
        else:
            b = self._buckets[idx]
            if None not in b.slots:
                evicted = self._lru_victim(b)
                self.evict(evicted)
            slot = b.slots.index(None)
        b = self._buckets[idx]
        if not (cold and slot == 0):
            # warm admission: the no-retrace hot-swap path (flushed
            # incumbents' results are delivered on the next poll)
            self._deliver(b, b.server.reconfigure(slot, chip))
        b.slots[slot] = t.tenant
        was_evicted = t.state == "evicted"
        t.state, t.bucket, t.slot = "resident", idx, slot
        t.last_used = self._clock()
        t.admissions += 1
        if was_evicted:
            t.readmissions += 1
        self._baseline_slot(t, b)
        self._register_golden(t, b)
        return {"bucket": idx, "slot": slot, "cold": cold,
                "evicted": evicted}

    def _lru_victim(self, b: _Bucket) -> Hashable:
        seated = [self._tenants[x] for x in b.slots if x is not None]
        return min(seated, key=lambda t: t.last_used).tenant

    def _register_golden(self, t: _TenantState, b: _Bucket) -> None:
        srv = b.server
        self._golden.register(
            t.tenant, t.chip.config,
            replica_table_images(
                t.chip.config, srv._img_levels, srv._img_m_pad,
                srv.n_replicas))

    def _baseline_slot(self, t: _TenantState, b: _Bucket) -> None:
        srv, slot = b.server, t.slot
        t._base_dis = list(srv._stats[slot].disagreements)
        if not t.seu_disagreements:
            t.seu_disagreements = [0] * srv.n_replicas
        lo = slot * srv.n_replicas
        t._base_scrub = int(
            sum(srv._scrub_per_frame[lo : lo + srv.n_replicas]))

    def _fold_slot(self, t: _TenantState, b: _Bucket) -> None:
        """Fold the slot's cumulative health counters into the tenant's
        ledger as deltas since seat time."""
        srv, slot = b.server, t.slot
        for r, d in enumerate(srv._stats[slot].disagreements):
            t.seu_disagreements[r] += d - t._base_dis[r]
        t._base_dis = list(srv._stats[slot].disagreements)
        lo = slot * srv.n_replicas
        now = int(sum(srv._scrub_per_frame[lo : lo + srv.n_replicas]))
        t.scrub_frames += now - t._base_scrub
        t._base_scrub = now

    # ---------------------------------------------------------- eviction
    def evict(self, tenant: Hashable, drain: bool = True) -> None:
        """Free the tenant's slot (LRU calls this; operators may too).

        ``drain=True`` (default) flushes the bucket first, so every one
        of the tenant's admitted events is scored and delivered — the
        zero-loss eviction. ``drain=False`` cancels the tenant's QUEUED
        events (counted as ``evicted_while_queued``) and only waits for
        batches already on the device. Either way the golden image
        STAYS registered: the next request re-admits from it.
        """
        t = self._tenants.get(tenant)
        if t is None:
            raise UnknownTenantError(tenant)
        if t.state != "resident":
            return
        b = self._buckets[t.bucket]
        if not drain:
            n = b.server.cancel_queued(t.slot)
            t.evicted_while_queued += n
        self._deliver(b, b.server.flush())
        # anything still outstanding was cancelled above — unroute it
        for srv_seq in t.outstanding:
            b.route.pop(srv_seq, None)
        t.outstanding.clear()
        self._fold_slot(t, b)
        b.slots[t.slot] = None
        t.state, t.bucket, t.slot = "evicted", None, None
        t.evictions += 1

    def retire(self, tenant: Hashable) -> None:
        """Evict (draining) AND discard the golden image — the terminal
        state. Further requests for this tenant raise ``GoldenSlotError``
        (no golden image to re-admit from)."""
        t = self._tenants.get(tenant)
        if t is None:
            raise UnknownTenantError(tenant)
        if t.state == "resident":
            self.evict(tenant, drain=True)
        self._golden.discard(tenant)
        self._tenants[tenant].state = "retired"

    def shrink(self) -> int:
        """Retire every bucket with no resident tenants; returns how
        many were dropped. The SHRINK half of the fleet's elasticity:
        surviving buckets' device slabs are re-planned
        (make_fleet_meshes) and re-placed via ``rebind_mesh`` /
        ``reshard_replicated`` where they moved."""
        keep = [b for b in self._buckets
                if any(s is not None for s in b.slots)]
        dropped = len(self._buckets) - len(keep)
        if not dropped:
            return 0
        for b in self._buckets:
            if b not in keep:
                self._deliver(b, b.server.flush())
        self._buckets = keep
        self._by_envelope = {b.envelope: i for i, b in enumerate(keep)}
        # re-index resident tenants' bucket pointers
        for i, b in enumerate(self._buckets):
            for slot, tenant in enumerate(b.slots):
                if tenant is not None:
                    self._tenants[tenant].bucket = i
        self._replan_meshes()
        return dropped

    def _replan_meshes(self) -> None:
        if self.config.backend != "kernel" or not self._buckets:
            return
        meshes = make_fleet_meshes(
            [b.server.n_chips for b in self._buckets])
        for b, m in zip(self._buckets, meshes):
            self._deliver(b, b.server.rebind_mesh(m))

    # --------------------------------------------------------- scoring
    def _resident(self, tenant: Hashable) -> _TenantState:
        t = self._tenants.get(tenant)
        if t is None:
            raise UnknownTenantError(tenant)
        if t.state != "resident":
            # re-admit from the golden image (GoldenSlotError if retired)
            golden_cfg = self._golden.golden_config(tenant)
            assert encode(golden_cfg) == encode(t.chip.config), \
                "golden image diverged from tenant chip"
            chip = dataclasses.replace(t.chip, config=golden_cfg)
            t.chip = chip
            self._seat(t, chip)
        return t

    def _quota_room(self, t: _TenantState, want: int) -> int:
        q = self.config.tenant_quota_queued
        if q is None:
            return want
        return max(0, min(want, q - len(t.outstanding)))

    def _issue(self, t: _TenantState, srv_seq: Optional[int],
               b: _Bucket) -> Optional[int]:
        if srv_seq is None:
            t.shed += 1
            return None
        fseq = self._seq
        self._seq += 1
        t.outstanding[srv_seq] = fseq
        b.route[srv_seq] = t.tenant
        return fseq

    def submit(self, tenant: Hashable,
               features: np.ndarray) -> Optional[int]:
        """Score one pre-featurized event for a tenant; returns the
        fleet-global seq, or None when shed (deadline admission or the
        per-tenant quota — both counted in the tenant's ledger). An
        evicted tenant is transparently re-admitted first."""
        t = self._resident(tenant)
        b = self._buckets[t.bucket]
        t.events_in += 1
        t.last_used = self._clock()
        if self._quota_room(t, 1) < 1:
            t.quota_shed += 1
            return None
        return self._issue(t, b.server.submit(t.slot, features), b)

    def submit_batch(self, tenant: Hashable,
                     X: np.ndarray) -> List[Optional[int]]:
        return [self.submit(tenant, row) for row in np.asarray(X)]

    def submit_frames(self, tenant: Hashable, frames: np.ndarray,
                      y0: np.ndarray) -> List[Optional[int]]:
        """Raw-frames ingestion for one tenant (the front door's path);
        shed/quota-shed rows yield None, exactly like the server."""
        t = self._resident(tenant)
        b = self._buckets[t.bucket]
        frames = np.asarray(frames, np.float32)
        n = len(frames)
        t.events_in += n
        t.last_used = self._clock()
        room = self._quota_room(t, n)
        t.quota_shed += n - room
        seqs: List[Optional[int]] = []
        if room:
            for s in b.server.submit_frames(
                    t.slot, frames[:room], np.asarray(y0)[:room]):
                seqs.append(self._issue(t, s, b))
        seqs.extend([None] * (n - room))
        return seqs

    # ---------------------------------------------------------- results
    def _deliver(self, b: _Bucket, results: List[ScoredEvent]) -> None:
        """Route a bucket's drained results into the ready queue (events
        of vacant clones / warmups are unrouted and dropped)."""
        for r in results:
            tenant = b.route.pop(r.seq, None)
            if tenant is None:
                continue
            t = self._tenants[tenant]
            fseq = t.outstanding.pop(r.seq)
            t.events_out += 1
            t.n_kept += bool(r.keep)
            self._ready.append(TenantScoredEvent(
                seq=fseq, tenant=tenant,
                score_raw=int(r.score_raw), keep=bool(r.keep)))

    def _take_ready(self) -> List[TenantScoredEvent]:
        out = list(self._ready)
        self._ready.clear()
        return out

    def poll(self) -> List[TenantScoredEvent]:
        """One non-blocking turn over every bucket server, plus any
        results drained internally by admissions/evictions."""
        for b in self._buckets:
            self._deliver(b, b.server.poll())
        return self._take_ready()

    def flush(self) -> List[TenantScoredEvent]:
        """Force everything out of every bucket (blocking)."""
        for b in self._buckets:
            self._deliver(b, b.server.flush())
        return self._take_ready()

    # ----------------------------------------------------------- report
    def report(self) -> Dict[str, object]:
        """Fleet-level accounting. ``"tenants"`` maps every tenant (also
        evicted/retired ones — history is part of the ledger) to its
        per-tenant trigger / SEU-disagreement / scrub / shed section;
        ``"buckets"`` carries each bucket's envelope, seating and full
        per-server report. Top-level counters aggregate over tenants and
        close the same accounting identity the per-tenant ledgers do."""
        tenants: Dict = {}
        for key, t in self._tenants.items():
            if t.state == "resident":
                self._fold_slot(t, self._buckets[t.bucket])
            tenants[key] = {
                "state": t.state,
                "bucket": t.bucket,
                "slot": t.slot,
                "events_in": t.events_in,
                "events_out": t.events_out,
                "n_kept": t.n_kept,
                "fraction_kept": (
                    t.n_kept / t.events_out if t.events_out else 1.0),
                "shed": t.shed,
                "quota_shed": t.quota_shed,
                "evicted_while_queued": t.evicted_while_queued,
                "outstanding": len(t.outstanding),
                "admissions": t.admissions,
                "evictions": t.evictions,
                "readmissions": t.readmissions,
                "seu_disagreements": list(t.seu_disagreements),
                "scrub_frames": t.scrub_frames,
            }
        buckets = []
        for b in self._buckets:
            env = b.envelope
            buckets.append({
                "envelope": {
                    "n_levels": env.n_levels,
                    "max_level_size": env.max_level_size,
                    "n_inputs": env.n_inputs,
                    "n_outputs": env.n_outputs,
                    "fanin_reach": env.fanin_reach,
                },
                "slots": list(b.slots),
                "n_resident": sum(s is not None for s in b.slots),
                "server": b.server.report(),
            })
        ts = self._tenants.values()
        return {
            "backend": self.config.backend,
            "layout": self.config.effective_layout,
            "bucket_slots": self.bucket_slots,
            "n_buckets": self.n_buckets,
            "n_tenants": self.n_tenants,
            "n_resident": sum(t.state == "resident" for t in ts),
            "n_evicted": sum(t.state == "evicted" for t in ts),
            "events_in": sum(t.events_in for t in ts),
            "events_out": sum(t.events_out for t in ts),
            "shed": sum(t.shed for t in ts),
            "quota_shed": sum(t.quota_shed for t in ts),
            "evicted_while_queued": sum(
                t.evicted_while_queued for t in ts),
            "tenants": tenants,
            "buckets": buckets,
            "net": (self._net_stats_provider()
                    if self._net_stats_provider is not None
                    else {"attached": False}),
        }
