"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — tests and benches must keep seeing one CPU
device; only launch/dryrun.py sets the 512-device XLA flag.

Production topology (TPU v5e): a pod is a 16x16 mesh (256 chips) with axes
("data", "model"); the multi-pod config prepends a pure-DP "pod" axis of
size 2 (512 chips) that crosses the DCN — the axis the compressed gradient
all-reduce targets (parallel/compression.py). Designs generalize to N pods
by growing the pod axis; nothing in the sharding rules hard-codes 2.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def make_mesh_compat(shape, axes) -> Mesh:
    """jax.make_mesh across JAX versions: axis_types (and AxisType itself)
    only exist in newer releases; all our meshes want Auto axes, which is
    also the older versions' only behavior."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests on CPU)."""
    return make_mesh_compat((data, model), ("data", "model"))


def make_readout_mesh(n_chips: int) -> Mesh:
    """One-axis "chips" mesh for the fused readout frontend.

    The chip axis of the fused frames->score dispatch shards across the
    largest device count that divides it evenly — every device then owns
    an identical (C/d, B) slab, so the shard_map body stays shape-uniform
    and swap-friendly. On a single-device host (tests, CI) this degrades
    to a size-1 axis: same code path, no data movement.
    """
    if n_chips < 1:
        raise ValueError(f"need n_chips >= 1, got {n_chips}")
    n_dev = jax.local_device_count()
    d = max(k for k in range(1, min(n_dev, n_chips) + 1) if n_chips % k == 0)
    return make_mesh_compat((d,), ("chips",))


def make_fleet_meshes(bucket_chip_counts: Sequence[int]) -> List[Mesh]:
    """One "chips" readout mesh per fleet bucket, over DISJOINT devices.

    The multi-tenant fleet (launch/fleet.py) runs one ReadoutServer per
    geometry bucket; each wants its own device slab so buckets never
    contend. Local devices are split into contiguous slices proportional
    to each bucket's chip count (every bucket gets at least one device;
    with fewer devices than buckets the slices wrap, which on the
    single-device CI host degrades every bucket to the same size-1 mesh
    — same code path, no movement). Within its slice a bucket uses the
    largest divisor of its chip count, the same rule as
    ``make_readout_mesh``, so the shard_map body stays shape-uniform.

    Called again after every grow/shrink: because jax ``Mesh`` equality
    is by device assignment, an unchanged bucket's re-planned mesh
    compares equal to its old one and its compiled dispatch is reused —
    only buckets whose device slab actually moved pay a re-place (and
    retrace) through ``ReadoutServer.rebind_mesh``.
    """
    if not bucket_chip_counts:
        return []
    for n in bucket_chip_counts:
        if n < 1:
            raise ValueError(
                f"every bucket needs >= 1 chip, got {bucket_chip_counts!r}")
    devices = jax.local_devices()
    n_dev, n_buckets = len(devices), len(bucket_chip_counts)
    total = sum(bucket_chip_counts)
    meshes: List[Mesh] = []
    start = 0
    for b, n_chips in enumerate(bucket_chip_counts):
        if n_dev >= n_buckets:
            # proportional contiguous slice, >= 1 device per bucket
            width = max(1, (n_chips * n_dev) // total)
            width = min(width, n_dev - start - (n_buckets - 1 - b))
            slab = devices[start : start + width]
            start += width
        else:
            slab = [devices[b % n_dev]]
        d = max(k for k in range(1, min(len(slab), n_chips) + 1)
                if n_chips % k == 0)
        meshes.append(Mesh(np.asarray(slab[:d]), ("chips",)))
    return meshes


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (~ per-direction)
HBM_BYTES = 16 * 1024**3      # 16 GiB
