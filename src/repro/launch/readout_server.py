"""Multi-chip streaming readout server (the scaled-up §5 front end).

One deployed detector is not one chip: many sensors feed many configured
eFPGAs, all filtering the same 40 MHz bunch-crossing stream before the
off-detector links. This server models that as a serving system:

    submit(chip, features)            (sensor hits arrive, per chip)
      -> micro-batch queue            (coalesce: max_batch / max_latency)
      -> host featurization           (quantize + offset-binary bit packing)
      -> ONE chip-batched dispatch    (kernels/lut_eval fabric_eval_multi:
                                       all chips' events in a single Pallas
                                       call over a (chips, events) grid)
      -> keep/drop per event          (integer-domain threshold, exact)
      -> per-chip trigger report      (rates, reduction, link budget)

Key properties:

  * Loading a bitstream stays an array swap: all chips share one padded
    geometry (core.fabric.StackGeometry), so ``reconfigure`` hot-swaps a
    chip's arrays into the stack with no recompile.
  * Double buffering: device dispatch is asynchronous (JAX), so the host
    featurizes and enqueues batch k+1 while the device scores batch k; the
    previous batch is only materialized when the next one is in flight.
  * The host-oracle backend (core.fabric.MultiFabricSim) is swappable in
    per server (backend="host") and is bit-identical to the kernel path —
    the basis of tests/test_readout_server.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fabric import (
    MultiFabricSim,
    StackGeometry,
    check_stackable,
    stack_event_bits,
)
from repro.core.readout import ReadoutChip


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Micro-batching knobs.

    max_batch: coalesce at most this many events (across all chips) into
        one dispatch; a full queue triggers a dispatch immediately.
    max_latency_s: a partial batch is dispatched once its oldest event has
        waited this long (the trigger-latency budget).
    backend: "kernel" (chip-batched Pallas dispatch) or "host" (numpy
        MultiFabricSim oracle, bit-identical).
    band: banded routing for the kernel stack — None auto-selects it
        whenever the chips' shared fan-in reach K is smaller than the
        level count (per-level routing cost drops from the full padded
        net buffer to the input segment + a K-level window); True/False
        force banded/dense. The host oracle is unaffected.
    bits_per_hit / hit_rate_hz: link-budget accounting for the report.
    """

    max_batch: int = 2048
    max_latency_s: float = 5e-3
    backend: str = "kernel"
    batch_tile: int = 128
    band: Optional[bool] = None
    bits_per_hit: int = 256
    hit_rate_hz: float = 40e6


@dataclasses.dataclass(frozen=True)
class ScoredEvent:
    seq: int          # submission order (global, monotone)
    chip: int
    score_raw: int    # integer-domain fabric score
    keep: bool        # False = classified as pileup, dropped at source


@dataclasses.dataclass
class ChipStreamStats:
    """Running trigger/reduction accounting for one chip slot."""

    n_in: int = 0
    n_kept: int = 0
    n_dispatches: int = 0

    def fraction_kept(self) -> float:
        return self.n_kept / self.n_in if self.n_in else 1.0


_Event = Tuple[int, int, np.ndarray, float]  # (seq, chip, features, t_enqueue)


class ReadoutServer:
    """Serves N configured ReadoutChips from one micro-batched event loop."""

    def __init__(
        self,
        chips: Sequence[ReadoutChip],
        config: ServerConfig = ServerConfig(),
        clock=time.monotonic,
    ):
        if not chips:
            raise ValueError("need at least one chip")
        self.chips: List[ReadoutChip] = list(chips)
        self.config = config
        self._clock = clock
        # the server's FIXED envelope: set at construction, never shrinks.
        # Both backends validate hot-swaps against it — including the
        # fan-in-reach budget a banded kernel stack depends on — so a
        # deployment validated on the host oracle behaves identically on
        # the kernel. The budget mirrors the stack's actual band choice:
        # a dense stack (config.band=False, or reach >= levels) carries
        # none, so forcing dense keeps full hot-swap flexibility.
        geo = check_stackable([c.config for c in self.chips])
        banded = (
            config.band is not False
            and (geo.fanin_reach or geo.n_levels) < geo.n_levels
        )
        self.geometry: StackGeometry = (
            geo if banded else dataclasses.replace(geo, fanin_reach=None)
        )
        self._stack = None
        if config.backend == "kernel":
            from repro.kernels.lut_eval import ops as lut_ops

            self._lut_ops = lut_ops
            self._stack = lut_ops.pack_fabrics(
                [c.config for c in self.chips], band=config.band
            )
        elif config.backend == "host":
            self._multisim = MultiFabricSim(
                [c.config for c in self.chips], geometry=self.geometry)
        else:
            raise ValueError(f"unknown backend {config.backend!r}")

        self._queue: Deque[_Event] = collections.deque()
        self._seq = 0
        # double buffer: the one batch currently on the device
        self._inflight: Optional[Tuple[object, List[List[int]], List[int]]] = None
        self._stats = [ChipStreamStats() for _ in self.chips]
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None
        self._n_scored = 0

    # ------------------------------------------------------------- intake
    @property
    def n_chips(self) -> int:
        return len(self.chips)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def submit(self, chip: int, features: np.ndarray) -> int:
        """Enqueue one event for one chip; returns its seq number."""
        assert 0 <= chip < self.n_chips, chip
        seq = self._seq
        self._seq += 1
        self._queue.append(
            (seq, chip, np.asarray(features, np.float64), self._clock())
        )
        return seq

    def submit_batch(self, chip: int, X: np.ndarray) -> List[int]:
        """Enqueue a block of events (rows of X) for one chip."""
        return [self.submit(chip, row) for row in np.asarray(X)]

    # ------------------------------------------------------------ the loop
    def poll(self) -> List[ScoredEvent]:
        """One turn of the event loop: dispatch if a micro-batch is due,
        and return any newly completed results (seq-ordered)."""
        out: List[ScoredEvent] = []
        if self._due():
            out.extend(self._dispatch(self._coalesce()))
        return out

    def flush(self) -> List[ScoredEvent]:
        """Force out everything: queued events and in-flight results."""
        out: List[ScoredEvent] = []
        while self._queue:
            out.extend(self._dispatch(self._coalesce()))
        out.extend(self._drain())
        return out

    def score_stream(
        self, batches: Iterable[Tuple[int, np.ndarray]]
    ) -> Iterable[List[ScoredEvent]]:
        """Drive the loop over an iterable of (chip, features-block) pairs,
        yielding completed results as they become available."""
        for chip, X in batches:
            self.submit_batch(chip, X)
            got = self.poll()
            if got:
                yield got
        tail = self.flush()
        if tail:
            yield tail

    def _due(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.config.max_batch:
            return True
        oldest = self._queue[0][3]
        return (self._clock() - oldest) >= self.config.max_latency_s

    def _coalesce(self) -> List[_Event]:
        take = min(len(self._queue), self.config.max_batch)
        return [self._queue.popleft() for _ in range(take)]

    def _dispatch(self, events: List[_Event]) -> List[ScoredEvent]:
        """Featurize + launch one chip-batched scoring call.

        Returns the *previous* batch's results: with the kernel backend the
        new dispatch is asynchronous, so draining the old batch after
        launching the new one overlaps host featurization with device
        scoring (double buffering).
        """
        if not events:
            return []
        if self._t_start is None:
            self._t_start = self._clock()

        per_chip_seq: List[List[int]] = [[] for _ in self.chips]
        per_chip_X: List[List[np.ndarray]] = [[] for _ in self.chips]
        for seq, chip, feats, _ in events:
            per_chip_seq[chip].append(seq)
            per_chip_X[chip].append(feats)

        # host featurization: float features -> quantized fabric input bits
        per_chip_bits: List[np.ndarray] = []
        for i, chip in enumerate(self.chips):
            if per_chip_X[i]:
                bits = chip.encode_features(np.stack(per_chip_X[i]))
            else:
                bits = np.zeros(
                    (0, chip.config.n_inputs), np.uint8
                )
            per_chip_bits.append(bits)

        if self.config.backend == "kernel":
            stacked = self._lut_ops.stack_input_bits(self._stack, per_chip_bits)
            pending = self._lut_ops.fabric_eval_multi(
                self._stack, stacked, batch_tile=self.config.batch_tile
            )  # async on device; NOT materialized yet
        else:
            stacked = stack_event_bits(per_chip_bits, self.geometry.n_inputs)
            pending = self._multisim.run(stacked)

        prev = self._drain()
        counts = [len(s) for s in per_chip_seq]
        self._inflight = (pending, per_chip_seq, counts)
        for i, n in enumerate(counts):
            if n:
                self._stats[i].n_dispatches += 1
        return prev

    def _drain(self) -> List[ScoredEvent]:
        """Materialize the in-flight batch and fold it into the reports."""
        if self._inflight is None:
            return []
        pending, per_chip_seq, counts = self._inflight
        self._inflight = None
        outs = np.asarray(pending)  # (C, B, n_outputs_max) — blocks here

        results: List[ScoredEvent] = []
        for i, chip in enumerate(self.chips):
            n = counts[i]
            if not n:
                continue
            n_out = len(chip.config.output_nets)
            scores = chip.synth.decode_outputs(outs[i, :n, :n_out])
            keep = scores <= chip.score_threshold_raw
            st = self._stats[i]
            st.n_in += n
            st.n_kept += int(keep.sum())
            for j, seq in enumerate(per_chip_seq[i]):
                results.append(
                    ScoredEvent(seq=seq, chip=i, score_raw=int(scores[j]),
                                keep=bool(keep[j]))
                )
        self._n_scored += len(results)
        self._t_last = self._clock()
        results.sort(key=lambda r: r.seq)
        return results

    # ------------------------------------------------------- reconfigure
    def reconfigure(self, slot: int, new_chip: ReadoutChip) -> List[ScoredEvent]:
        """Hot-swap slot's bitstream: array swap, no recompile.

        Pending events are flushed first (they were submitted against the
        old configuration); returns their results. The new config must fit
        the server's fixed envelope — enforced identically on both
        backends, and ``self.geometry`` never changes, so callers can keep
        pre-checking candidates with ``server.geometry.admits(cfg)``.
        """
        assert 0 <= slot < self.n_chips, slot
        cfg = new_chip.config
        if cfg.n_ffs or not self.geometry.admits(cfg):
            raise ValueError(
                f"new config does not fit server envelope {self.geometry} "
                f"(levels={len(cfg.level_sizes)}, "
                f"widest={max(cfg.level_sizes, default=1)}, "
                f"inputs={cfg.n_inputs}, outputs={len(cfg.output_nets)}, "
                f"ffs={cfg.n_ffs}, fanin_reach={cfg.fanin_reach()})"
            )
        done = self.flush()
        if self.config.backend == "kernel":
            self._stack = self._stack.swap_chip(slot, cfg)
        self.chips[slot] = new_chip
        if self.config.backend == "host":
            self._multisim = MultiFabricSim(
                [c.config for c in self.chips], geometry=self.geometry)
        return done

    # ------------------------------------------------------------ report
    def report(self) -> Dict[str, object]:
        """Per-chip trigger/reduction accounting aggregated over the stream."""
        cfg = self.config
        per_chip = []
        for i, st in enumerate(self._stats):
            frac = st.fraction_kept()
            per_chip.append({
                "chip": i,
                "n_in": st.n_in,
                "n_kept": st.n_kept,
                "n_dispatches": st.n_dispatches,
                "fraction_kept": frac,
                "data_reduction_factor": 1.0 / max(frac, 1e-9),
                "link_rate_in_gbps": cfg.hit_rate_hz * cfg.bits_per_hit / 1e9,
                "link_rate_out_gbps":
                    cfg.hit_rate_hz * cfg.bits_per_hit * frac / 1e9,
            })
        n_in = sum(s.n_in for s in self._stats)
        n_kept = sum(s.n_kept for s in self._stats)
        dt = (
            (self._t_last - self._t_start)
            if (self._t_start is not None and self._t_last is not None)
            else 0.0
        )
        return {
            "backend": cfg.backend,
            "n_chips": self.n_chips,
            "n_in": n_in,
            "n_kept": n_kept,
            "fraction_kept": n_kept / n_in if n_in else 1.0,
            "events_per_s": n_in / dt if dt > 0 else float("nan"),
            "queue_depth": self.queue_depth,
            "per_chip": per_chip,
        }
