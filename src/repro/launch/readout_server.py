"""Multi-chip streaming readout server (the scaled-up §5 front end).

One deployed detector is not one chip: many sensors feed many configured
eFPGAs, all filtering the same 40 MHz bunch-crossing stream before the
off-detector links. This server models that as a serving system with TWO
ingestion stages, one per deployment style:

    submit(chip, features)            pre-computed features (host frontend)
    submit_frames(chip, frames, y0)   RAW charge frames (fused frontend)
      -> micro-batch queue            (coalesce: max_batch / max_latency)
      -> scoring dispatch
           features ... host featurize (quantize + bit pack) -> ONE
                        sharded chip-batched dispatch that evaluates,
                        votes (TMR), decodes scores and applies the
                        trigger cut on device (fabric_eval_multi_scored)
           frames ..... ONE fused dispatch (kernels/frontend.py):
                        yprofile -> quantize -> bit pack -> lut_eval ->
                        vote -> score -> keep/drop, all on device, chip
                        axis sharded over the "chips" mesh — no host
                        materialization between stages
      -> sparse trigger compression   (optional: only keep-flagged events
                                       cross the host link as a packed
                                       (indices, scores) pair)
      -> background config scrubbing  (optional: readback -> CRC verify ->
                                       heal of the served configuration
                                       memory, interleaved with dispatches)
      -> per-chip trigger report      (rates, reduction, link budget,
                                       per-stage host timing, per-replica
                                       SEU disagreement counters, scrub
                                       detections / healed bits / latency)

Key properties:

  * Loading a bitstream stays an array swap: all chips share one padded
    geometry (core.fabric.StackGeometry, which also carries the
    feature-stage metadata for frames ingestion), so ``reconfigure``
    hot-swaps a chip's arrays — lut_eval stack AND fused encode plan —
    with no recompile. Under ``redundancy="tmr"`` the swap re-encodes all
    three replica slots; still no retrace.
  * SEU resilience as a serving mode: ``ServerConfig.redundancy="tmr"``
    serves every chip as three placement-distinct replica encodings
    (core.tmr.replicate_config) voted on device with a 2-of-3 majority
    before decode. A single configuration-bit upset in any one replica
    cannot change any served output (tests/test_seu.py sweeps every
    bit); the per-replica disagreement counters in the report are the
    SEU health monitor, and ``inject_seu`` is the fault-injection port
    (flips one bit of one served replica, both backends).
  * Scrubbing closes the SEU loop (mask -> detect -> repair): TMR only
    masks a fault until a second upset lands in the same logical LUT
    (tests/test_seu.py's double-fault controls prove that is fatal), so
    ``ServerConfig(scrub_interval=k)`` runs a background scrub task every
    k dispatches: read back one replica frame's LIVE truth-table image
    (device arrays on the kernel backend, the MultiFabricSim scrub twin
    on the host oracle), CRC-verify it against the golden store
    (core.bitstream.GoldenImageStore, snapshotted at (re)configuration),
    and on mismatch re-encode ONLY the corrupted replica from the golden
    bitstream via the existing no-retrace swap machinery. Frames are
    scrubbed round-robin; ``scrub_mode="steered"`` additionally jumps to
    the replica whose disagreement counters climbed since its last scrub
    (the PR 4 SEU health monitor steering the repair), while the
    round-robin turn still advances every step — steering can never
    starve a frame. Kernel-backend readbacks are issued as ASYNC
    device->host copies and verified one scrub step later, so the scrub
    task interleaves behind the in-flight dispatches instead of stalling
    the triple-buffered pipeline (a synchronous readback costs ~25%
    events/s; the async split keeps the measured overhead under the 5%
    budget — BENCH_fabric.json ``fabric.scrub_overhead``). Works without
    redundancy too: CRC-only detection heals an unprotected chip
    (outputs may be wrong until the heal — exactly the window scrubbing
    bounds).
  * At-source link compression: ``ServerConfig.sparse=True`` drops
    rejected events *before* the host link — the drain materializes only
    the packed (flat index, score) pairs of keep-flagged events
    (parallel.compression.sparse_trigger_pack), and the report carries
    the measured bytes-on-wire vs the dense equivalent.
  * Pipelined host/device overlap: device dispatch is asynchronous (JAX),
    and up to ``pipeline_depth`` batches stay in flight while the host
    prepares the next one. The default depth of 2 is triple buffering
    (host builds batch k+2 while the device holds k and k+1); depth 1 is
    the classic double buffer. ``poll()`` never blocks: a batch is
    retired as soon as its device arrays are actually ready
    (``jax.Array.is_ready``), and while the pipeline is at capacity new
    dispatches are DEFERRED — backlog accumulates in the submit queue
    where admission control can see (and shed) it, instead of silently
    backpressuring the caller. Only ``flush()`` blocks.
  * Deadline-aware serving: the trigger chain gives every event a hard
    latency budget — data that misses the window is physics lost, so
    overload must degrade gracefully instead of queueing unboundedly.
    Per-event latency is measured end to end (enqueue -> coalesce ->
    launch -> drain, one injected monotonic clock everywhere) into
    fixed-bucket log-scale histograms with p50/p99/p99.9 and a CDF in
    the report. ``ServerConfig(deadline_us=, overload_policy=)`` then
    makes the loop ACT on it: admission control sheds new submissions
    when the queue's oldest-event slack (deadline minus wait minus the
    EWMA service estimate) goes negative — every shed is counted per
    chip, never silent; the micro-batch coalescer adaptively shrinks
    ``max_batch``/``max_latency_s`` under pressure and re-grows them
    when slack recovers; and under ``overload_policy="degrade"`` a
    hysteretic ladder steps through configurable rungs on sustained
    deadline misses (widen the scrub interval -> CRC-only scrub with
    deferred heals -> sparse-only egress), every transition counted and
    timestamped. Keep/drop decisions on admitted events stay bit-exact
    vs the host oracle at every rung — the rungs trade repair latency
    and link bytes, never correctness (tests/test_deadline.py).
  * The host-oracle backend (backend="host") is bit-identical to the
    kernel path on BOTH ingestion stages and under every redundancy /
    sparse mode — the numpy path votes with the same
    core.tmr.majority_vote and packs with the same compaction rule — the
    basis of tests/test_readout_server.py, test_frontend.py and
    test_seu.py.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import math
import time
from typing import (
    Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple,
)

import numpy as np

from repro.core.bitstream import GoldenImageStore
from repro.core.fabric import (
    FabricSim,
    FrontendSpec,
    MultiFabricSim,
    StackGeometry,
    check_stackable,
    packed_table_image,
    stack_event_bits,
)
from repro.core.readout import ReadoutChip
from repro.core.tmr import (
    N_REPLICAS,
    inject_seu as _inject_seu_config,
    majority_vote,
    replica_table_images,
    replicate_config,
)
from repro.data.smartpixel import N_T, N_X, N_Y
from repro.data.smartpixel import N_FEATURES as _N_FEATURES
from repro.parallel.compression import (
    DENSE_BYTES_PER_EVENT,
    SPARSE_BYTES_PER_EVENT,
    SPARSE_HEADER_BYTES,
)

# The documented default scrub budget: one readback->verify step every
# this many scoring dispatches. Chosen so the benchmark's sustained-stream
# throughput cost stays under 5% (benchmarks/bench_fabric.py
# fabric.scrub_overhead); deployments trade detection latency against
# overhead by setting ServerConfig(scrub_interval=...) directly.
DEFAULT_SCRUB_INTERVAL = 4

# The degrade ladder's known rungs, in the order the default ladder steps
# down through them (cheapest concession first). Every rung trades repair
# latency or link bytes, NEVER the correctness of admitted events:
#   scrub_relax     widen the scrub interval by SCRUB_RELAX_FACTOR
#                   (slower repair; TMR keeps masking, CRC still detects)
#   scrub_crc_only  keep CRC detection live but defer the heals (the
#                   re-encode + array swap) until the rung exits, so the
#                   repair cost leaves the overloaded critical path
#   sparse_egress   ship only keep-flagged events on the host link (the
#                   scores of non-keeps are dropped at source), even on a
#                   dense-configured server
DEGRADE_RUNGS = ("scrub_relax", "scrub_crc_only", "sparse_egress")
SCRUB_RELAX_FACTOR = 4

_LOG = logging.getLogger("repro.launch.readout_server")


# --------------------------------------------------------------------------
# Latency observability: fixed log-scale histograms
# --------------------------------------------------------------------------

# One shared bucket grid for every histogram: 8 log-scale buckets per
# decade from 1 us to 100 s, plus an underflow and an overflow slot. A
# FIXED grid (rather than per-stream quantile sketches) keeps the state
# O(1) no matter how many events stream through, makes histograms
# mergeable across chips and runs, and gives the bench JSON a stable,
# machine-comparable CDF axis.
_HIST_BUCKETS_PER_DECADE = 8
_HIST_DECADES = 8
_HIST_N = _HIST_BUCKETS_PER_DECADE * _HIST_DECADES
_HIST_EDGES_US = np.power(
    10.0, np.arange(_HIST_N + 1) / _HIST_BUCKETS_PER_DECADE)


class LatencyHistogram:
    """Streaming latency histogram on the shared log-scale grid.

    ``add_many`` is one vectorized bincount per drained batch; percentile
    queries interpolate log-linearly inside the owning bucket, so
    p50/p99/p99.9 are exact to within one bucket width (~33% at 8
    buckets/decade) — tail-shape fidelity at O(1) memory, which is what a
    long-running trigger service can actually afford to keep per chip.
    """

    __slots__ = ("counts", "_sum_us", "_max_us")

    def __init__(self):
        # counts[0] = underflow (<1 us), [1..N] = grid, [N+1] = overflow
        self.counts = np.zeros(_HIST_N + 2, np.int64)
        self._sum_us = 0.0
        self._max_us = 0.0

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def add(self, us: float) -> None:
        self.add_many(np.asarray([us], np.float64))

    def add_many(self, us: np.ndarray) -> None:
        us = np.asarray(us, np.float64)
        if us.size == 0:
            return
        idx = np.zeros(us.shape, np.int64)
        pos = us >= 1.0
        if pos.any():
            idx[pos] = 1 + np.minimum(
                (np.log10(us[pos]) * _HIST_BUCKETS_PER_DECADE).astype(
                    np.int64),
                _HIST_N,  # >= the top edge lands in the overflow slot
            )
        self.counts += np.bincount(idx, minlength=_HIST_N + 2)
        self._sum_us += float(us.sum())
        self._max_us = max(self._max_us, float(us.max()))

    def merge(self, other: "LatencyHistogram") -> None:
        self.counts += other.counts
        self._sum_us += other._sum_us
        self._max_us = max(self._max_us, other._max_us)

    def percentile(self, q: float) -> float:
        """q in [0, 100] -> latency in us, log-interpolated in-bucket."""
        total = int(self.counts.sum())
        if total == 0:
            return 0.0
        target = total * (q / 100.0)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, target, side="left"))
        if b <= 0:
            return float(_HIST_EDGES_US[0])     # underflow: "< 1 us"
        if b >= _HIST_N + 1:
            return float(self._max_us)          # overflow: observed max
        lo, hi = float(_HIST_EDGES_US[b - 1]), float(_HIST_EDGES_US[b])
        inside = int(self.counts[b])
        frac = ((target - float(cum[b - 1])) / inside) if inside else 0.0
        return lo * (hi / lo) ** min(max(frac, 0.0), 1.0)

    def cdf(self) -> List[List[float]]:
        """[[upper edge us, cumulative fraction], ...] over the non-empty
        buckets — the machine-readable CDF exported to the bench JSON.
        Underflow folds into the first emitted point; the final point is
        the observed max at fraction 1.0."""
        total = int(self.counts.sum())
        if total == 0:
            return []
        cum = np.cumsum(self.counts)
        out: List[List[float]] = []
        prev = -1
        for i in range(1, _HIST_N + 2):
            c = int(cum[i])
            if c != prev:
                edge = (float(_HIST_EDGES_US[i - 1]) if i <= _HIST_N
                        else float(self._max_us))
                out.append([round(edge, 3), round(c / total, 6)])
                prev = c
            if c == total:
                break
        return out

    def summary(self) -> Dict[str, float]:
        n = self.count
        return {
            "count": n,
            "mean_us": (self._sum_us / n) if n else 0.0,
            "max_us": self._max_us,
            "p50_us": self.percentile(50.0),
            "p99_us": self.percentile(99.0),
            "p999_us": self.percentile(99.9),
        }


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Micro-batching knobs. Validated on construction — a bad knob fails
    HERE with a named error, not three layers down as a shape mismatch.

    max_batch: coalesce at most this many events (across all chips) into
        one dispatch; a full queue triggers a dispatch immediately.
    max_latency_s: a partial batch is dispatched once its oldest event has
        waited this long (the trigger-latency budget).
    backend: "kernel" (chip-batched Pallas dispatch) or "host" (numpy
        MultiFabricSim oracle, bit-identical).
    batch_tile: Pallas batch tile — every stage of the fused frames
        dispatch tiles with it, so it must be a multiple of 128 (the TPU
        lane width both kernels assume).
    band: the fan-in-reach *envelope* of the kernel stack — None
        auto-selects it whenever the chips' shared fan-in reach K is
        smaller than the level count; True/False force banded/dense.
        The band is layout-independent (a hardware routing constraint,
        not a kernel structure): with layout="matmul" it additionally
        selects the windowed selection tensor (per-level routing cost
        drops from the full padded net buffer to the input segment + a
        K-level window); with layout="bitsliced" the gather kernel is
        unchanged and the band is a pure reach budget, validated at
        pack time and enforced on every hot-swap (swap_chip/
        swap_replica reject configs whose reach exceeds it). The host
        oracle is unaffected.
    layout: device layout of the kernel stack. None (default) selects
        "bitsliced" — the word-parallel serving path, band or no band.
        "matmul" is the Pallas selection-matmul kernel, banded/dense per
        ``band``. "bitsliced" evaluates 32 events per uint32 word as
        pure bitwise mux logic with the TMR vote folded into the same
        pass (kernels/lut_eval/bitsliced.py) — the cheap-TMR, genuinely
        chip-parallel serving mode. Bit-identical to the host oracle
        either way; hot-swap stays a retrace-free array swap in both
        layouts.
    redundancy: "none" or "tmr". TMR serves three placement-distinct
        replica encodings of every chip, votes 2-of-3 on device before
        decode, and surfaces per-replica disagreement counters in the
        report (the SEU health monitor). Cost: 3x the fabric-evaluation
        work plus the (elementwise) voter.
    sparse: only keep-flagged events cross the host link, as a packed
        (flat index, score) pair; dropped events never materialize on the
        host and the report carries measured bytes-on-wire. Drained
        results then contain ONLY kept events.
    scrub_interval: None disables scrubbing; an int k runs one background
        scrub step (readback -> CRC verify -> heal of one replica frame,
        plus the steered extra below) every k scoring dispatches.
        DEFAULT_SCRUB_INTERVAL is the documented <5%-overhead budget.
    scrub_mode: "round_robin" scrubs frames strictly in slot order;
        "steered" (default) additionally CRC-checks the replica frame
        whose SEU disagreement counters climbed most since its last
        scrub, BEFORE taking the round-robin turn — so an active fault is
        repaired within ~one scrub interval of its first voted-against
        dispatch instead of waiting for its round-robin turn. The
        round-robin turn always advances, so steering never starves a
        frame (every frame is scrubbed within one full cycle —
        tests/test_scrub.py's fairness property).
    pipeline_depth: batches kept in flight on the device while the host
        prepares the next (2 = triple buffering, 1 = double buffering).
    threshold_electrons: per-pixel zero suppression of the frames->
        features stage (frames ingestion only).
    bits_per_hit / hit_rate_hz: link-budget accounting for the report.
    deadline_us: per-event latency budget (enqueue -> drained result) in
        microseconds, or None (no deadline — latency is still measured,
        never acted on). With a deadline every drained event is scored
        met/missed in the report's deadline ledger.
    overload_policy: what the loop DOES about the deadline.
        "observe" (default) measures misses but never sheds or adapts;
        "shed" adds admission control (submissions are rejected — seq
        None — while the queue's oldest-event slack is negative, every
        shed counted per chip) and adaptive micro-batch sizing (the
        effective max_batch/max_latency_s halve when a drained batch
        blows the budget and re-grow once batches clear half of it);
        "degrade" adds the hysteretic rung ladder below on top of
        shedding. Policies other than "observe" require deadline_us.
    degrade_rungs: the ladder, stepped through in order under
        ``overload_policy="degrade"`` (see DEGRADE_RUNGS for the rung
        semantics). Must be non-empty, known names, no duplicates —
        validated even when the ladder is inactive.
    degrade_window: drained (admitted) events per ladder evaluation.
    degrade_enter_frac / degrade_exit_frac: a window whose deadline-miss
        fraction is >= enter steps DOWN one rung; <= exit steps back UP.
        enter >> exit is the hysteresis — at most one transition per
        window, so the ladder cannot flap within a window.
    min_batch: floor of the adaptive micro-batch shrink (clamped to
        max_batch when max_batch is smaller).
    tenant_quota_queued: per-TENANT cap on outstanding (queued, not yet
        drained) events, enforced by the fleet layer (launch/fleet.py)
        on top of the server's own two-predictor deadline admission —
        a submission past the quota is shed and counted in the tenant's
        ``quota_shed``, so one chatty tenant cannot starve the bucket's
        queue. None (default) disables the per-tenant cap; the server
        itself never reads this knob (a standalone server has no
        tenants), it simply rides the ServerConfig so a fleet is
        configured in one place.
    """

    max_batch: int = 2048
    max_latency_s: float = 5e-3
    backend: str = "kernel"
    batch_tile: int = 128
    band: Optional[bool] = None
    layout: Optional[str] = None
    redundancy: str = "none"
    sparse: bool = False
    scrub_interval: Optional[int] = None
    scrub_mode: str = "steered"
    pipeline_depth: int = 2
    threshold_electrons: float = 800.0
    bits_per_hit: int = 256
    hit_rate_hz: float = 40e6
    deadline_us: Optional[float] = None
    overload_policy: str = "observe"
    degrade_rungs: Tuple[str, ...] = DEGRADE_RUNGS
    degrade_window: int = 64
    degrade_enter_frac: float = 0.5
    degrade_exit_frac: float = 0.05
    min_batch: int = 32
    tenant_quota_queued: Optional[int] = None

    def __post_init__(self):
        if not (isinstance(self.max_batch, int) and self.max_batch > 0):
            raise ValueError(f"max_batch must be a positive int, got "
                             f"{self.max_batch!r}")
        if self.max_latency_s <= 0:
            raise ValueError(f"max_latency_s must be > 0, got "
                             f"{self.max_latency_s!r}")
        if not (isinstance(self.batch_tile, int) and self.batch_tile > 0
                and self.batch_tile % 128 == 0):
            raise ValueError(
                f"batch_tile must be a positive multiple of 128 (the TPU "
                f"lane width), got {self.batch_tile!r}")
        if self.backend not in ("kernel", "host"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'kernel' or 'host')")
        if self.band is not None and not isinstance(self.band, bool):
            raise ValueError(
                f"band must be True, False or None (auto), got "
                f"{self.band!r}")
        if self.layout is not None and self.layout not in (
                "matmul", "bitsliced"):
            raise ValueError(f"unknown layout {self.layout!r} "
                             "(expected 'matmul' or 'bitsliced', or None "
                             "= auto-select)")
        if self.redundancy not in ("none", "tmr"):
            raise ValueError(f"unknown redundancy {self.redundancy!r} "
                             "(expected 'none' or 'tmr')")
        if not isinstance(self.sparse, bool):
            raise ValueError(f"sparse must be a bool, got {self.sparse!r}")
        if self.scrub_interval is not None and not (
                isinstance(self.scrub_interval, int)
                and not isinstance(self.scrub_interval, bool)
                and self.scrub_interval > 0):
            raise ValueError(
                f"scrub_interval must be a positive int (dispatches between "
                f"scrub steps) or None to disable, got "
                f"{self.scrub_interval!r}")
        if self.scrub_mode not in ("round_robin", "steered"):
            raise ValueError(
                f"unknown scrub_mode {self.scrub_mode!r} "
                "(expected 'round_robin' or 'steered')")
        if not (isinstance(self.pipeline_depth, int)
                and self.pipeline_depth >= 1):
            raise ValueError(f"pipeline_depth must be an int >= 1, got "
                             f"{self.pipeline_depth!r}")
        if self.threshold_electrons < 0:
            raise ValueError(f"threshold_electrons must be >= 0, got "
                             f"{self.threshold_electrons!r}")
        if self.deadline_us is not None and not (
                isinstance(self.deadline_us, (int, float))
                and not isinstance(self.deadline_us, bool)
                and math.isfinite(self.deadline_us)
                and self.deadline_us > 0):
            raise ValueError(
                f"deadline_us must be a positive finite number (per-event "
                f"latency budget in microseconds) or None to disable, got "
                f"{self.deadline_us!r}")
        if self.overload_policy not in ("observe", "shed", "degrade"):
            raise ValueError(
                f"unknown overload_policy {self.overload_policy!r} "
                "(expected 'observe', 'shed' or 'degrade')")
        if self.overload_policy != "observe" and self.deadline_us is None:
            raise ValueError(
                f"overload_policy={self.overload_policy!r} needs "
                "deadline_us set — without a deadline there is no slack "
                "to act on")
        rungs = self.degrade_rungs
        if isinstance(rungs, list):
            rungs = tuple(rungs)
            object.__setattr__(self, "degrade_rungs", rungs)
        if not (isinstance(rungs, tuple) and rungs):
            raise ValueError(
                f"degrade_rungs must be a non-empty tuple of rung names, "
                f"got {self.degrade_rungs!r}")
        for r in rungs:
            if r not in DEGRADE_RUNGS:
                raise ValueError(
                    f"unknown degrade rung {r!r} "
                    f"(known rungs: {list(DEGRADE_RUNGS)})")
        if len(set(rungs)) != len(rungs):
            raise ValueError(f"duplicate degrade rungs in {rungs!r}")
        if not (isinstance(self.degrade_window, int)
                and not isinstance(self.degrade_window, bool)
                and self.degrade_window >= 1):
            raise ValueError(
                f"degrade_window must be an int >= 1 (drained events per "
                f"ladder evaluation), got {self.degrade_window!r}")
        if not (0.0 < self.degrade_exit_frac
                < self.degrade_enter_frac <= 1.0):
            raise ValueError(
                "need 0 < degrade_exit_frac < degrade_enter_frac <= 1 "
                "(the hysteresis gap), got "
                f"exit={self.degrade_exit_frac!r} "
                f"enter={self.degrade_enter_frac!r}")
        if not (isinstance(self.min_batch, int)
                and not isinstance(self.min_batch, bool)
                and self.min_batch > 0):
            raise ValueError(f"min_batch must be a positive int, got "
                             f"{self.min_batch!r}")
        if self.tenant_quota_queued is not None and not (
                isinstance(self.tenant_quota_queued, int)
                and not isinstance(self.tenant_quota_queued, bool)
                and self.tenant_quota_queued > 0):
            raise ValueError(
                f"tenant_quota_queued must be a positive int (max "
                f"outstanding events per tenant) or None to disable, got "
                f"{self.tenant_quota_queued!r}")

    @property
    def n_replicas(self) -> int:
        return N_REPLICAS if self.redundancy == "tmr" else 1

    @property
    def effective_layout(self) -> str:
        """The layout actually served. ``layout=None`` selects
        "bitsliced" (the fast, cheap-TMR word-parallel evaluator)
        unconditionally — the band is a layout-independent reach
        envelope, so forcing it no longer forces the matmul kernel."""
        return self.layout if self.layout is not None else "bitsliced"

    @property
    def deadline_s(self) -> Optional[float]:
        return None if self.deadline_us is None else self.deadline_us * 1e-6


@dataclasses.dataclass(frozen=True)
class ScoredEvent:
    seq: int          # submission order (global, monotone)
    chip: int
    score_raw: int    # integer-domain fabric score (voted under TMR)
    keep: bool        # False = classified as pileup, dropped at source


@dataclasses.dataclass
class ChipStreamStats:
    """Running trigger/reduction accounting for one chip slot."""

    n_in: int = 0
    n_kept: int = 0
    n_dispatches: int = 0
    # events rejected by deadline admission control at submit time (the
    # shed traffic — always visible in the report, never silent)
    n_shed: int = 0
    # per-replica SEU health: events where replica r's output word was
    # voted against (always zeros on a healthy or non-redundant server)
    disagreements: List[int] = dataclasses.field(default_factory=list)

    def fraction_kept(self) -> float:
        return self.n_kept / self.n_in if self.n_in else 1.0


# (seq, chip, kind, payload, t_enqueue); payload is a features row for
# kind="features", an (frame, y0) pair for kind="frames".
_Event = Tuple[int, int, str, object, float]
# (kind, pending, per_chip_seq, counts, meta). Both ingestion stages
# converge on the same two inflight kinds:
#   "scored": pending = (score (C,B), keep (C,B), disagree (C,R)) —
#       device arrays on the kernel backend (materialized at drain),
#       numpy on the host oracle;
#   "sparse": pending = (count, idx, vals, disagree (C,R), B) — the
#       packed keep-flagged events; only the count-prefix of idx/vals
#       crosses the host link at drain time.
# meta = {"t_enq": per-chip enqueue-time lists (every admitted event,
# kept or not — the latency ledger), "trace": the batch's monotonic
# stage timestamps}.
_Inflight = Tuple[str, object, List[List[int]], List[int], Dict]


class ReadoutServer:
    """Serves N configured ReadoutChips from one micro-batched event loop."""

    def __init__(
        self,
        chips: Sequence[ReadoutChip],
        config: ServerConfig = ServerConfig(),
        clock=time.monotonic,
        envelope: Optional[StackGeometry] = None,
    ):
        """``envelope`` pins the server's fixed geometry to a GIVEN
        StackGeometry instead of the chips' union — the bucketed-pool
        mode (kernels.lut_eval.ops.bucket_envelope / launch/fleet.py):
        every chip must fit it, the kernel stack pads to it, and its
        fan-in-reach budget decides banded-vs-dense (``config.band`` is
        ignored for the band choice, since the envelope IS the band
        contract). Servers sharing an envelope share every static
        kernel dimension, so a chip can move between them — or a new
        tenant can admit — via ``reconfigure`` with zero retraces."""
        if not chips:
            raise ValueError("need at least one chip")
        self.chips: List[ReadoutChip] = list(chips)
        self.config = config
        self._clock = clock
        # Scores decode on DEVICE (two's-complement int32) on the kernel
        # backend; enforce the width bound on both backends so a
        # deployment validated on the host oracle cannot overflow on the
        # kernel.
        for i, c in enumerate(self.chips):
            if len(c.config.output_nets) > 31:
                raise ValueError(
                    f"device score decode is int32: chip {i} has "
                    f"{len(c.config.output_nets)} output bits > 31")
        # the server's FIXED envelope: set at construction, never shrinks.
        # Both backends validate hot-swaps against it — including the
        # fan-in-reach budget a banded kernel stack depends on — so a
        # deployment validated on the host oracle behaves identically on
        # the kernel. The budget mirrors the stack's actual band choice:
        # a dense stack (config.band=False, or reach >= levels) carries
        # none, so forcing dense keeps full hot-swap flexibility. The
        # envelope also carries the feature-stage contract: every server
        # can ingest raw frames, so a hot-swapped chip must be encodable
        # from the featurizer's output (checked in ``reconfigure``).
        # TMR replication is envelope-invariant (placement rotation
        # changes neither level sizes, widths nor reach), so one geometry
        # covers every replica slot.
        geo = check_stackable([c.config for c in self.chips])
        if envelope is not None:
            for i, c in enumerate(self.chips):
                if not envelope.admits(c.config):
                    raise ValueError(
                        f"chip {i} does not fit the pinned envelope "
                        f"{envelope} (levels={len(c.config.level_sizes)}, "
                        f"widest={max(c.config.level_sizes, default=1)}, "
                        f"inputs={c.config.n_inputs}, "
                        f"outputs={len(c.config.output_nets)}, "
                        f"fanin_reach={c.config.fanin_reach()})")
            geo = envelope
            banded = (envelope.fanin_reach is not None
                      and envelope.fanin_reach < envelope.n_levels)
        else:
            banded = (
                config.band is not False
                and (geo.fanin_reach or geo.n_levels) < geo.n_levels
            )
        # resolve layout=None here, once — everything downstream (stack
        # packing, the fused frontend, the report) uses the resolved
        # value. There is no matmul fallback: the band is a layout-
        # independent reach envelope, so a banded geometry serves
        # bit-sliced like everything else.
        self.layout = config.effective_layout
        self.geometry: StackGeometry = dataclasses.replace(
            geo if banded else dataclasses.replace(geo, fanin_reach=None),
            frontend=FrontendSpec(
                n_features=_N_FEATURES,
                frame_shape=(N_T, N_Y, N_X),
                threshold_electrons=config.threshold_electrons,
            ),
        )
        self.n_replicas = config.n_replicas
        # the SERVED replica encodings, slot-major: replica r of chip c is
        # _replica_configs[c*R + r]. This is the injection surface of
        # ``inject_seu`` and the source of the host oracle's simulators,
        # so both backends agree on every replica's config image.
        self._replica_configs: List = [
            replicate_config(c.config, r)
            for c in self.chips for r in range(self.n_replicas)
        ]
        # integer trigger cuts, baked per slot (refreshed on reconfigure)
        # so both backends cut on the same value for a given dispatch.
        self._thr_raw = np.array(
            [c.score_threshold_raw for c in self.chips], np.int32)
        self._stack = None
        self._frontend = None  # fused frames dispatch, built on first use
        self._mesh = None
        if config.backend == "kernel":
            from repro.kernels.lut_eval import ops as lut_ops
            from repro.launch.mesh import make_readout_mesh

            self._lut_ops = lut_ops
            self._stack = lut_ops.pack_fabrics(
                [c.config for c in self.chips], band=config.band,
                redundancy=config.redundancy, layout=self.layout,
                geometry=(None if envelope is None else
                          dataclasses.replace(self.geometry, frontend=None)),
            )
            # ONE readout mesh for both ingestion stages: the features
            # path shards its scoring dispatch over the same "chips" axis
            # as the fused frames frontend.
            self._mesh = make_readout_mesh(self.n_chips)
            self._out_weight = lut_ops.decode_plan(
                [c.config for c in self.chips], self._stack.n_outputs)
        else:
            self._multisim = MultiFabricSim(
                self._replica_configs, geometry=self.geometry)

        self._queue: Deque[_Event] = collections.deque()
        self._seq = 0
        # per-slot FabricSim cache (one sim per replica) for the staged
        # (host) frames path — pure function of the slot's replica
        # configs, invalidated on reconfigure/inject_seu, so repeated
        # dispatches don't re-pay construction (and the staged_score
        # stage timing stays honest).
        self._frame_sims: List[Optional[List[FabricSim]]] = (
            [None] * len(self.chips))
        # the pipeline: up to config.pipeline_depth batches on the device
        self._inflight: Deque[_Inflight] = collections.deque()
        self._stats = [
            ChipStreamStats(disagreements=[0] * self.n_replicas)
            for _ in self.chips
        ]
        self._stage_s: Dict[str, float] = collections.defaultdict(float)
        self._stage_n: Dict[str, int] = collections.defaultdict(int)
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None
        self._n_scored = 0
        # measured host-link accounting: bytes actually materialized on
        # the wire (sparse packs when a batch drains sparse, dense rows
        # otherwise — the sparse_egress rung can mix both on one server)
        # vs the dense equivalent for the same events
        self._link_bytes_wire = 0
        self._link_bytes_dense = 0

        # ---- latency observability (module doc: deadline-aware serving).
        # End-to-end latency (enqueue -> drained result) per chip and
        # total, plus the queue-wait (enqueue -> coalesce) and service
        # (coalesce -> drained) attributions of the same batches — the
        # full/no-transfer-style overlay that says WHERE a tail lives.
        self._hist_total = LatencyHistogram()
        self._hist_queue = LatencyHistogram()
        self._hist_service = LatencyHistogram()
        self._hist_chip = [LatencyHistogram() for _ in self.chips]
        # the newest drained batch's monotonic stage timestamps
        # (enqueue-oldest -> coalesce -> encode/stack -> launch -> drain)
        self._last_batch_trace: Dict[str, float] = {}
        self._n_batches_drained = 0

        # ---- deadline enforcement state.
        self._deadline_met = 0
        self._deadline_missed = 0
        # EWMA of the batch service time (coalesce -> drained): the
        # admission controller's estimate of how long a newly admitted
        # event will wait beyond the queue's current oldest-event wait
        self._service_ewma_s = 0.0
        # (t_drained, n_events) of recent retired batches — the sliding
        # window behind _drain_rate(), admission's backlog-drain term
        self._drain_hist: Deque[Tuple[float, int]] = collections.deque(
            maxlen=16)
        # adaptive micro-batch knobs: the coalescer reads THESE, the
        # config fields stay the (immutable) ceilings
        self._eff_max_batch = config.max_batch
        self._min_batch = min(config.min_batch, config.max_batch)
        if (config.deadline_s is not None
                and config.overload_policy != "observe"):
            # never coalesce past half the budget — the other half is
            # for service (the EWMA refines this cap adaptively)
            self._lat_cap_s = min(config.max_latency_s,
                                  config.deadline_s / 2.0)
        else:
            self._lat_cap_s = config.max_latency_s
        self._eff_max_latency_s = self._lat_cap_s
        self._batch_shrinks = 0
        self._batch_grows = 0

        # ---- degrade ladder state (overload_policy="degrade").
        # level k = the first k rungs of config.degrade_rungs are active;
        # evaluated once per degrade_window drained events, hysteretically
        self._rung_level = 0
        self._ladder_transitions: List[Dict[str, object]] = []
        self._window_missed = 0
        self._window_drained = 0
        # (slot, replica) frames whose CRC failed while the
        # scrub_crc_only rung deferred the heal — repaired on rung exit
        self._deferred_heals: List[Tuple[int, int]] = []

        # ---- scrubbing state (readback -> verify -> heal; module doc).
        # One shared image layout for readbacks AND golden digests: the
        # kernel stack's padded (levels, m_pad) geometry, mirrored by the
        # same formula on the host backend so either backend's readback
        # verifies against the same digest.
        if self._stack is not None:
            self._img_levels = self._stack.n_levels
            self._img_m_pad = self._stack.m_pad
        else:
            self._img_levels = self.geometry.n_levels
            self._img_m_pad = -(-self.geometry.max_level_size // 128) * 128
        self._golden = GoldenImageStore()
        for i in range(self.n_chips):
            self._register_golden(i)
        self._dispatch_idx = 0
        n_frames = self.n_chips * self.n_replicas
        self._scrub_rr = 0          # round-robin frame pointer
        self._scrub_cycles = 0      # completed full round-robin passes
        self._scrub_steps = 0
        self._scrub_detections = 0
        self._scrub_healed_bits = 0
        # per-detection staleness window: dispatches since the corrupted
        # frame's last clean scrub — the measured detection latency
        self._scrub_latencies: List[int] = []
        self._scrub_per_frame = [0] * n_frames
        # disagreement snapshot at each frame's last scrub (steering key)
        self._scrub_last_dis = [0] * n_frames
        # dispatch index at each frame's last scrub (latency reference)
        self._scrub_last_pass = [0] * n_frames
        # kernel-backend readbacks in flight: (frame, generation, device
        # array, prev_pass, issue_idx). The device->host copy is issued
        # async and
        # VERIFIED on a later scrub step, so scrubbing never blocks on
        # the dispatch just launched (a synchronous readback would stall
        # the triple-buffered pipeline every interval — measured at ~25%
        # events/s, 5x the scrub budget).
        self._scrub_pending: Deque[Tuple[int, int, object, int, int]] = (
            collections.deque())
        # bumped whenever a frame's served arrays are re-encoded (inject,
        # heal, reconfigure): a pending readback sampled before the bump
        # is stale and must not be verified against the new truth
        self._frame_gen = [0] * n_frames

        # ---- network front door accounting (net/ingress.py attaches a
        # stats provider; report()["net"] surfaces it — per-client drop/
        # reorder/resync counters live with the front door, not here)
        self._net_stats_provider: Optional[Callable[[], Dict]] = None

    def attach_net_stats(self, provider: Callable[[], Dict]) -> None:
        """Register the network front door's ``stats`` callable; its
        snapshot appears under ``report()["net"]``. Pass None to detach."""
        self._net_stats_provider = provider

    # ------------------------------------------------------------- intake
    @property
    def n_chips(self) -> int:
        return len(self.chips)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def _admit(self, chip: int, now: float) -> bool:
        """Deadline admission control (overload_policy "shed"/"degrade"):
        a new submission is shed — counted per chip, never silent — when
        its predicted completion blows the deadline. Two predictors, the
        worse one decides:

        * oldest-event slack: the queue head has waited ``wait``; it
          still needs ~one EWMA service time. If the HEAD is already
          blowing the budget, everything behind it is too.
        * backlog drain: a newcomer joins the BACK of the queue — it
          waits ~queue_len / drain_rate before its batch even coalesces.
          Under a fast-building burst this term trips long before the
          head's elapsed wait does.

        Rejecting at submit is the only place the loss is cheap. With
        both predictors under budget (or no deadline / "observe") every
        submission is admitted — tests/test_deadline.py's admission
        property."""
        dl = self.config.deadline_s
        if dl is None or self.config.overload_policy == "observe":
            return True
        if not self._queue and not self._inflight:
            # idle probe: with nothing queued or in flight a lone event
            # can only miss if service itself exceeds the deadline — and
            # admitting it is the ONLY way to refresh a stale EWMA (one
            # slow batch, e.g. a jit compile, would otherwise lock the
            # server into shedding everything forever)
            return True
        wait = (now - self._queue[0][4]) if self._queue else 0.0
        rate = self._drain_rate()
        backlog = (len(self._queue) / rate) if rate > 0.0 else 0.0
        if max(wait, backlog) + self._service_ewma_s < dl:
            return True
        self._stats[chip].n_shed += 1
        return False

    def submit(self, chip: int, features: np.ndarray) -> Optional[int]:
        """Enqueue one pre-featurized event for one chip; returns its seq,
        or None when deadline admission control shed it (the shed is
        counted in the chip's ``n_shed``)."""
        assert 0 <= chip < self.n_chips, chip
        now = self._clock()
        if not self._admit(chip, now):
            return None
        seq = self._seq
        self._seq += 1
        self._queue.append(
            (seq, chip, "features", np.asarray(features, np.float64), now)
        )
        return seq

    def submit_batch(self, chip: int, X: np.ndarray) -> List[Optional[int]]:
        """Enqueue a block of pre-featurized events (rows of X); shed
        rows yield None in the returned seq list."""
        return [self.submit(chip, row) for row in np.asarray(X)]

    def cancel_queued(self, chip: int) -> int:
        """Drop every QUEUED (admitted, not yet coalesced) event of one
        chip slot; returns how many were dropped.

        The eviction port of the fleet layer (launch/fleet.py): when a
        tenant is evicted without draining, its queued events are
        cancelled here — and counted by the fleet as
        ``evicted_while_queued``, so the per-tenant accounting identity
        still closes. Events already coalesced into an in-flight batch
        are NOT cancelled (the device is already scoring them); they
        drain normally and are delivered before the slot is reused.
        Other chips' events are untouched.
        """
        assert 0 <= chip < self.n_chips, chip
        n0 = len(self._queue)
        self._queue = collections.deque(
            e for e in self._queue if e[1] != chip)
        return n0 - len(self._queue)

    def submit_frames(
        self, chip: int, frames: np.ndarray, y0: np.ndarray
    ) -> List[Optional[int]]:
        """Enqueue raw-frame events: (n, T, Y, X) charge + (n,) y0.

        These score through the frames pipeline — on the kernel backend
        the FUSED single-dispatch frontend, on the host backend the same
        pipeline staged. Mixing frames and features for the same chip in
        one micro-batch is allowed but scores as two dispatch groups, so
        cross-kind result order within that batch follows the groups, not
        the global seq order (every event stays seq-tagged).
        """
        assert 0 <= chip < self.n_chips, chip
        frames = np.asarray(frames, np.float32)
        y0 = np.asarray(y0, np.float32)
        assert frames.ndim == 4 and frames.shape[1:] == (N_T, N_Y, N_X), \
            frames.shape
        assert len(frames) == len(y0), (len(frames), len(y0))
        seqs: List[Optional[int]] = []
        now = self._clock()
        for i in range(len(frames)):
            if not self._admit(chip, now):
                seqs.append(None)
                continue
            seq = self._seq
            self._seq += 1
            self._queue.append(
                (seq, chip, "frames", (frames[i], float(y0[i])), now))
            seqs.append(seq)
        return seqs

    # ------------------------------------------------------------ the loop
    def poll(self) -> List[ScoredEvent]:
        """One turn of the event loop: retire any in-flight batches that
        finished, dispatch if a micro-batch is due and the pipeline has
        room, and return completed results (seq-ordered per batch).

        Never blocks. When the pipeline is at capacity the due batch
        stays in the queue — its wait is then visible to `_admit`, so
        overload turns into counted sheds instead of an invisible stall
        of the submitting thread."""
        out = self._drain_ready()
        if self._due() and len(self._inflight) <= self.config.pipeline_depth:
            out.extend(self._dispatch(self._coalesce()))
        return out

    def flush(self) -> List[ScoredEvent]:
        """Force out everything: queued events and in-flight results.

        With scrubbing enabled the flush also settles the scrub loop:
        readback samples still in flight are resolved (the device is
        idle now, so this blocks on nothing), and a final steered check
        chases any disagreement counters that only folded during this
        drain — so a fault implicated by the stream's last batches is
        healed at flush instead of waiting for the next stream."""
        out: List[ScoredEvent] = []
        while self._queue:
            out.extend(self._dispatch(self._coalesce()))
            while len(self._inflight) > self.config.pipeline_depth:
                out.extend(self._drain_one())       # flush MAY block
        out.extend(self._drain_all())
        if self.config.scrub_interval is not None:
            t0 = self._clock()
            self.scrub_flush()
            if self.config.scrub_mode == "steered":
                self._scrub_steered_check()
                self.scrub_flush()      # device idle: resolve it now
            self._stage("scrub", t0)
        return out

    def score_stream(
        self, batches: Iterable[Tuple[int, np.ndarray]]
    ) -> Iterable[List[ScoredEvent]]:
        """Drive the loop over an iterable of (chip, features-block) pairs,
        yielding completed results as they become available."""
        for chip, X in batches:
            self.submit_batch(chip, X)
            got = self.poll()
            if got:
                yield got
        tail = self.flush()
        if tail:
            yield tail

    def _due(self) -> bool:
        # the EFFECTIVE knobs, not the config ceilings: under deadline
        # pressure the adaptive sizer shrinks both (see _adapt_batch)
        if not self._queue:
            return False
        if len(self._queue) >= self._eff_max_batch:
            return True
        oldest = self._queue[0][4]
        return (self._clock() - oldest) >= self._eff_max_latency_s

    def _coalesce(self) -> List[_Event]:
        take = min(len(self._queue), self._eff_max_batch)
        return [self._queue.popleft() for _ in range(take)]

    def _stage(self, key: str, t0: float) -> None:
        self._stage_s[key] += self._clock() - t0
        self._stage_n[key] += 1

    def _dispatch(self, events: List[_Event]) -> List[ScoredEvent]:
        """Launch one micro-batch and return any batches the pipeline
        retired: with the kernel backend dispatches are asynchronous, so
        up to ``pipeline_depth`` batches stay on the device while the
        host prepares the next (triple buffering at the default depth 2).
        Retirement is non-blocking — a batch comes off only once its
        device arrays are ready; ``flush`` settles the rest.
        """
        if not events:
            return []
        if self._t_start is None:
            self._t_start = self._clock()

        frame_events = [e for e in events if e[2] == "frames"]
        feat_events = [e for e in events if e[2] == "features"]
        if frame_events:
            self._inflight.append(self._launch_frames(frame_events))
        if feat_events:
            self._inflight.append(self._launch_features(feat_events))

        done = self._drain_ready()
        # background scrub task, interleaved with dispatches: runs after
        # the drain so freshly-folded disagreement counters can steer it,
        # while the just-launched batch is still computing on the device
        self._dispatch_idx += 1
        si = self._effective_scrub_interval()
        if si is not None and self._dispatch_idx % si == 0:
            self.scrub_step()
        return done

    def _effective_scrub_interval(self) -> Optional[int]:
        """The configured scrub interval, widened by SCRUB_RELAX_FACTOR
        while the ladder's scrub_relax rung is active (slower repair
        buys dispatch headroom; TMR keeps masking meanwhile)."""
        si = self.config.scrub_interval
        if si is not None and self._rung_active("scrub_relax"):
            si = si * SCRUB_RELAX_FACTOR
        return si

    def _group(
        self, events: List[_Event]
    ) -> Tuple[List[List[int]], List[List[object]], List[int],
               List[List[float]]]:
        per_chip_seq: List[List[int]] = [[] for _ in self.chips]
        per_chip_payload: List[List[object]] = [[] for _ in self.chips]
        per_chip_t: List[List[float]] = [[] for _ in self.chips]
        for seq, chip, _, payload, t_enq in events:
            per_chip_seq[chip].append(seq)
            per_chip_payload[chip].append(payload)
            per_chip_t[chip].append(t_enq)
        counts = [len(s) for s in per_chip_seq]
        for i, n in enumerate(counts):
            if n:
                self._stats[i].n_dispatches += 1
        return per_chip_seq, per_chip_payload, counts, per_chip_t

    @staticmethod
    def _pad_batch(B: int) -> int:
        """Round a kernel-backend batch width up to the next power of
        two. The jit signature of a dispatch is its padded shape: with
        raw ``max(counts)`` widths every queue wobble (and every move of
        the adaptive batch sizer) mints a fresh shape and pays a fresh
        compile — ~150 ms, i.e. many deadlines — exactly when the server
        is under pressure. Bucketing bounds the compiled set to
        log2(max_batch) shapes, all touched during warmup."""
        return 1 << (max(int(B), 1) - 1).bit_length()

    def _valid_mask(self, counts: List[int], B: int) -> np.ndarray:
        """(C, B) bool: True on real event rows, False on zero-padding —
        the mask that keeps phantom padded events out of the keep/drop
        decisions, the sparse pack and the disagreement counters."""
        return (np.arange(max(B, 1))[None, :]
                < np.asarray(counts)[:, None])

    def _sparse_active(self) -> bool:
        """Sparse egress is on when configured OR forced by the degrade
        ladder's sparse_egress rung (keep/drop stays bit-exact — only the
        NON-kept scores stop crossing the link)."""
        return self.config.sparse or self._rung_active("sparse_egress")

    def _word_sparse_active(self) -> bool:
        """True when a launch should use the WORD-domain sparse dispatch:
        sparse egress on a bit-sliced kernel stack. There the keep cut,
        SEU counters and compaction all run on sliced words inside the
        scoring jit itself — dropped events are never transposed back to
        event order, so there is no separate pack dispatch at all."""
        return (self._sparse_active()
                and self.config.backend == "kernel"
                and self._stack is not None and self._stack.bitsliced)

    def _finish_launch_sparse(
        self, count, idx, vals, disagree, B, per_chip_seq, counts, meta
    ) -> _Inflight:
        """Output stage of the word-domain sparse dispatches: the packed
        (count, idx, vals) wire tuple came straight out of the scoring
        jit (same format as sparse_trigger_pack), so there is nothing
        left to pack — just record the launch and enqueue."""
        meta["trace"]["t_launched"] = self._clock()
        return ("sparse", (count, idx, vals, disagree, int(B)),
                per_chip_seq, counts, meta)

    def _finish_launch(
        self, score, keep, disagree, per_chip_seq, counts, meta
    ) -> _Inflight:
        """Common output stage: dense (score, keep) or the sparse packed
        (indices, scores) pair. On the kernel backend the pack is one
        extra device dispatch, still asynchronous — nothing materializes
        until the drain (bit-sliced kernel launches never get here with
        sparse on: their pack is fused into the scoring jit, see
        ``_word_sparse_active``)."""
        meta["trace"]["t_launched"] = self._clock()
        sparse = self._sparse_active()
        if not sparse:
            return ("scored", (score, keep, disagree), per_chip_seq,
                    counts, meta)
        t0 = self._clock()
        B = int(np.shape(keep)[1])
        if self.config.backend == "kernel":
            from repro.parallel.compression import sparse_trigger_pack_jit

            count, idx, vals = sparse_trigger_pack_jit(score, keep)
        else:
            flat = np.asarray(keep).ravel()
            idx = np.flatnonzero(flat).astype(np.int32)
            vals = np.asarray(score).ravel()[idx].astype(np.int32)
            count = len(idx)
        self._stage("sparse_pack", t0)
        return ("sparse", (count, idx, vals, disagree, B),
                per_chip_seq, counts, meta)

    def _launch_features(self, events: List[_Event]) -> _Inflight:
        """Features path: host featurization (quantize + offset-binary bit
        packing, timed as ``encode_host``) into ONE sharded chip-batched
        scoring dispatch — fabric evaluation (all replicas), majority
        vote, score decode and trigger cut all on device
        (lut_eval.ops.fabric_eval_multi_scored), chip axis over the
        readout mesh."""
        per_chip_seq, per_chip_X, counts, per_chip_t = self._group(events)
        trace = {"t_enqueued": min(e[4] for e in events),
                 "t_coalesced": self._clock()}
        meta = {"t_enq": per_chip_t, "trace": trace}

        t0 = self._clock()
        per_chip_bits: List[np.ndarray] = []
        for i, chip in enumerate(self.chips):
            if per_chip_X[i]:
                bits = chip.encode_features(np.stack(per_chip_X[i]))
            else:
                bits = np.zeros((0, chip.config.n_inputs), np.uint8)
            per_chip_bits.append(bits)
        self._stage("encode_host", t0)
        trace["t_encoded"] = self._clock()

        t0 = self._clock()
        B = max(counts) if counts else 0
        if self.config.backend == "kernel":
            B = self._pad_batch(B)      # stable jit signatures (pow2)
            lead = per_chip_bits[0]
            if len(lead) < B:           # stack_event_bits pads to the max
                per_chip_bits[0] = np.vstack(
                    [lead, np.zeros((B - len(lead), lead.shape[1]),
                                    np.uint8)])
            valid = self._valid_mask(counts, B)
            stacked = self._lut_ops.stack_input_bits(self._stack, per_chip_bits)
            if self._word_sparse_active():
                count, idx, vals, dis = (
                    self._lut_ops.fabric_eval_multi_scored_sparse(
                        self._stack, stacked, self._out_weight,
                        self._thr_raw, valid=valid, mesh=self._mesh,
                        batch_tile=self.config.batch_tile,
                    ))  # async; keep cut + compaction fused in the jit
                self._stage("launch_score", t0)
                return self._finish_launch_sparse(
                    count, idx, vals, dis, B, per_chip_seq, counts, meta)
            score, keep, dis = self._lut_ops.fabric_eval_multi_scored(
                self._stack, stacked, self._out_weight, self._thr_raw,
                valid=valid, mesh=self._mesh,
                batch_tile=self.config.batch_tile,
            )  # async on device; NOT materialized yet
        else:
            valid = self._valid_mask(counts, B)
            stacked = stack_event_bits(per_chip_bits, self.geometry.n_inputs)
            score, keep, dis = self._score_bits_host(stacked, valid)
        self._stage("launch_score", t0)
        return self._finish_launch(score, keep, dis, per_chip_seq, counts,
                                   meta)

    def _score_bits_host(
        self, stacked: np.ndarray, valid: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The numpy oracle of the device scoring stage: evaluate every
        replica (MultiFabricSim over the served replica configs), vote
        with THE SAME core.tmr.majority_vote, decode two's-complement
        scores, cut, count disagreements — bit-identical by construction."""
        C, B = stacked.shape[0], stacked.shape[1]
        R = self.n_replicas
        rep = np.repeat(stacked, R, axis=0) if R > 1 else stacked
        outs = self._multisim.run(rep)                  # (R*C, B, O)
        g = outs.reshape(C, R, B, outs.shape[-1])
        if R > 1:
            voted = majority_vote(g[:, 0], g[:, 1], g[:, 2])
            disagree = (g != voted[:, None]).any(-1)    # (C, R, B)
        else:
            voted = g[:, 0]
            disagree = np.zeros((C, 1, B), bool)
        score = np.zeros((C, B), np.int64)
        for i, chip in enumerate(self.chips):
            n_out = len(chip.config.output_nets)
            score[i] = chip.synth.decode_outputs(voted[i, :, :n_out])
        keep = (score <= self._thr_raw[:, None]) & valid
        dis = (disagree & valid[:, None, :]).sum(-1).astype(np.int64)
        return score, keep, dis

    def _launch_frames(self, events: List[_Event]) -> _Inflight:
        """Frames path. Kernel backend: ONE fused dispatch over the
        sharded chip axis (timed ``launch_fused`` — featurize, quantize,
        pack, replica evaluation, vote and score all live inside it,
        invisible to the host by design). Host backend: the same
        pipeline STAGED, each stage materialized and timed
        (``staged_featurize`` / ``staged_encode`` / ``staged_score``) —
        the breakdown the fused path removes.
        """
        per_chip_seq, per_chip_fy, counts, per_chip_t = self._group(events)
        trace = {"t_enqueued": min(e[4] for e in events),
                 "t_coalesced": self._clock()}
        meta = {"t_enq": per_chip_t, "trace": trace}
        cfg = self.config
        B = max(counts) if counts else 0
        if cfg.backend == "kernel":
            B = self._pad_batch(B)      # stable jit signatures (pow2)
        valid = self._valid_mask(counts, B)

        if cfg.backend == "kernel":
            t0 = self._clock()
            frames = np.zeros((self.n_chips, B, N_T, N_Y, N_X), np.float32)
            y0 = np.zeros((self.n_chips, B), np.float32)
            for i, rows in enumerate(per_chip_fy):
                if rows:  # one vectorized copy per chip, not per event
                    frames[i, : len(rows)] = np.stack([fr for fr, _ in rows])
                    y0[i, : len(rows)] = [z for _, z in rows]
            self._stage("stack_frames", t0)
            trace["t_encoded"] = self._clock()

            t0 = self._clock()
            # frames/y0 are freshly staged numpy buffers, dead after this
            # call — exactly the donation contract of the fused dispatch.
            if self._word_sparse_active():
                count, idx, vals, dis = (
                    self._get_frontend().score_frames_sparse(
                        frames, y0, valid=valid))
                self._stage("launch_fused", t0)
                return self._finish_launch_sparse(
                    count, idx, vals, dis, B, per_chip_seq, counts, meta)
            score, keep, dis = self._get_frontend().score_frames_voted(
                frames, y0, valid=valid)
            self._stage("launch_fused", t0)
            return self._finish_launch(score, keep, dis, per_chip_seq,
                                       counts, meta)

        # host backend: staged oracle, per chip, one sim per replica
        R = self.n_replicas
        score = np.zeros((self.n_chips, B), np.int64)
        disagree = np.zeros((self.n_chips, R, B), bool)
        for i, chip in enumerate(self.chips):
            if not per_chip_fy[i]:
                continue
            n = counts[i]
            frames_i = np.stack([fr for fr, _ in per_chip_fy[i]])
            y0_i = np.asarray([z for _, z in per_chip_fy[i]], np.float32)
            t0 = self._clock()
            from repro.kernels.yprofile import ops as yp_ops

            feats = np.asarray(yp_ops.yprofile(
                frames_i, y0_i, threshold_electrons=cfg.threshold_electrons,
                batch_tile=cfg.batch_tile))
            self._stage("staged_featurize", t0)
            t0 = self._clock()
            bits = chip.encode_features(feats)
            self._stage("staged_encode", t0)
            t0 = self._clock()
            if self._frame_sims[i] is None:
                self._frame_sims[i] = [
                    FabricSim(self._replica_configs[i * R + r])
                    for r in range(R)
                ]
            g = np.stack(
                [np.asarray(sim.run(bits)[0]) for sim in self._frame_sims[i]]
            )                                           # (R, n, O_i)
            if R > 1:
                voted = majority_vote(g[0], g[1], g[2])
                disagree[i, :, :n] = (g != voted[None]).any(-1)
            else:
                voted = g[0]
            score[i, :n] = chip.synth.decode_outputs(voted)
            self._stage("staged_score", t0)
        keep = (score <= self._thr_raw[:, None]) & valid
        dis = (disagree & valid[:, None, :]).sum(-1).astype(np.int64)
        return self._finish_launch(score, keep, dis, per_chip_seq, counts,
                                   meta)

    def _get_frontend(self):
        if self._frontend is None:
            from repro.kernels import frontend as fe

            self._frontend = fe.pack_frontend(
                [c.config for c in self.chips],
                [c.frontend_spec() for c in self.chips],
                band=self.config.band,
                redundancy=self.config.redundancy,
                layout=self.layout,
                batch_tile=self.config.batch_tile,
                threshold_electrons=self.config.threshold_electrons,
                mesh=self._mesh,
                stack=self._stack,  # share the server's packed arrays
            )
        return self._frontend

    @staticmethod
    def _result_ready(x: object) -> bool:
        """True when materializing ``x`` will not block: jax Arrays
        answer via ``is_ready()``; host-backend results are plain numpy
        (or Python ints) and are always ready."""
        probe = getattr(x, "is_ready", None)
        return True if probe is None else bool(probe())

    def _head_ready(self) -> bool:
        """Non-blocking probe: is the OLDEST in-flight batch finished?"""
        if not self._inflight:
            return False
        kind, pending = self._inflight[0][0], self._inflight[0][1]
        parts = pending[:4] if kind == "sparse" else pending  # drop int B
        return all(self._result_ready(p) for p in parts)

    def _drain_ready(self) -> List[ScoredEvent]:
        """Retire every finished in-flight batch, oldest first, never
        blocking. Retirement must NOT wait for the pipeline to go over
        capacity: a ready batch lingering in flight would count its idle
        time as service, inflating the EWMA that admission control
        subtracts from the deadline — under shedding (no new dispatches
        to push it out) that feedback locks the server into rejecting
        everything. Batches whose device arrays are still cooking stay
        put — the capacity gate in ``poll`` then defers new dispatches so
        backlog lands in the submit queue, in admission's line of sight."""
        out: List[ScoredEvent] = []
        while self._head_ready():
            out.extend(self._drain_one())
        return out

    def _drain_one(self) -> List[ScoredEvent]:
        """Materialize the OLDEST in-flight batch and fold it into the
        reports (``drain_wait`` is the host-visible blocking time). With
        sparse readout only the count-prefix of the packed (idx, score)
        pair crosses the host link — the measured wire bytes."""
        if not self._inflight:
            return []
        kind, pending, per_chip_seq, counts, meta = self._inflight.popleft()
        t0 = self._clock()

        results: List[ScoredEvent] = []
        n_events = int(sum(counts))
        if kind == "sparse":
            count, idx, vals, dis, B = pending
            n_kept = int(np.asarray(count))             # blocks here
            idx_h = np.asarray(idx[:n_kept]).astype(np.int64)
            vals_h = np.asarray(vals[:n_kept]).astype(np.int64)
            self._link_bytes_wire += (
                SPARSE_HEADER_BYTES + SPARSE_BYTES_PER_EVENT * n_kept)
            self._link_bytes_dense += DENSE_BYTES_PER_EVENT * n_events
            kept_per_chip = np.bincount(
                idx_h // max(B, 1), minlength=self.n_chips)
            for i, st in enumerate(self._stats):
                st.n_in += counts[i]
                st.n_kept += int(kept_per_chip[i])
            for k, v in zip(idx_h, vals_h):
                chip, pos = int(k) // B, int(k) % B
                results.append(ScoredEvent(
                    seq=per_chip_seq[chip][pos], chip=chip,
                    score_raw=int(v), keep=True))
            self._fold_disagreements(dis)
        else:  # "scored"
            score, keep, dis = pending
            score = np.asarray(score)                   # blocks here
            keep = np.asarray(keep)
            self._link_bytes_wire += DENSE_BYTES_PER_EVENT * n_events
            self._link_bytes_dense += DENSE_BYTES_PER_EVENT * n_events
            for i in range(self.n_chips):
                n = counts[i]
                if not n:
                    continue
                self._fold_chip(results, i, per_chip_seq[i],
                                score[i, :n].astype(np.int64), keep[i, :n])
            self._fold_disagreements(dis)

        self._stage("drain_wait", t0)
        self._n_scored += len(results)
        t_done = self._clock()
        self._t_last = t_done
        self._observe_batch(meta, t_done)
        results.sort(key=lambda r: r.seq)
        return results

    # ------------------------------------------- latency / deadline loop
    def reset_latency_metrics(self) -> None:
        """Zero the latency/deadline ledger (histograms, met/missed/shed
        counters, the EWMA seed and the throughput window) without
        touching trigger accounting, scrub state or the ladder level —
        for measuring a warmed-up server: jit compilation of the first
        dispatch otherwise dominates every percentile of a short run."""
        self._hist_total = LatencyHistogram()
        self._hist_queue = LatencyHistogram()
        self._hist_service = LatencyHistogram()
        self._hist_chip = [LatencyHistogram() for _ in self.chips]
        self._last_batch_trace = {}
        self._n_batches_drained = 0
        self._deadline_met = 0
        self._deadline_missed = 0
        self._service_ewma_s = 0.0
        self._drain_hist.clear()
        self._window_missed = 0
        self._window_drained = 0
        self._batch_shrinks = 0
        self._batch_grows = 0
        self._t_start = None
        self._t_last = None
        for st in self._stats:
            st.n_shed = 0

    def _observe_batch(self, meta: Dict, t_done: float) -> None:
        """Fold one drained batch into the latency ledger, then let the
        deadline machinery act: EWMA service update (feeds admission),
        adaptive micro-batch sizing, and the degrade-ladder evaluation.
        Every ADMITTED event is observed — kept or not, sparse or dense —
        so the histograms and the met/missed ledger cover exactly the
        traffic admission control let through."""
        trace = meta["trace"]
        trace["t_drained"] = t_done
        self._last_batch_trace = trace
        self._n_batches_drained += 1
        t_co = trace.get("t_coalesced", t_done)
        dl = self.config.deadline_s
        worst_s = 0.0
        n_batch = 0
        for i, ts in enumerate(meta["t_enq"]):
            if not ts:
                continue
            t_enq = np.asarray(ts, np.float64)
            lat_s = np.maximum(t_done - t_enq, 0.0)
            us = lat_s * 1e6
            self._hist_chip[i].add_many(us)
            self._hist_total.add_many(us)
            self._hist_queue.add_many(
                np.maximum(t_co - t_enq, 0.0) * 1e6)
            worst_s = max(worst_s, float(lat_s.max()))
            n_batch += len(ts)
            if dl is not None:
                missed = int((lat_s > dl).sum())
                self._deadline_missed += missed
                self._deadline_met += len(ts) - missed
                self._window_missed += missed
        self._hist_service.add(max(t_done - t_co, 0.0) * 1e6)
        self._window_drained += n_batch
        # EWMA of the batch service time — the admission controller's
        # look-ahead: how long will a newly admitted event take AFTER
        # the queue's current wait. Seeded with the first batch.
        svc = max(t_done - t_co, 0.0)
        self._service_ewma_s = (
            svc if self._n_batches_drained == 1
            else 0.7 * self._service_ewma_s + 0.3 * svc)
        # sliding drain-rate window — the admission controller's backlog
        # term: how fast does the queue in front of a newcomer drain
        self._drain_hist.append((t_done, n_batch))
        if dl is None or self.config.overload_policy == "observe":
            return
        self._adapt_batch(svc, dl)
        if self.config.overload_policy == "degrade":
            self._ladder_evaluate(t_done)

    def _drain_rate(self) -> float:
        """Recent drain throughput (events/s) over the sliding window of
        retired batches; 0.0 until two drains have landed."""
        h = self._drain_hist
        if len(h) < 2:
            return 0.0
        span = h[-1][0] - h[0][0]
        if span <= 0.0:
            return 0.0
        return (sum(n for _, n in h) - h[0][1]) / span

    def _adapt_batch(self, svc_s: float, dl: float) -> None:
        """Adaptive micro-batch sizing, keyed on the SERVICE component
        (coalesce -> drain) — the only part of an event's latency the
        batch size controls. A batch whose service ate over half the
        budget halves the effective max_batch AND max_latency_s (smaller
        batches drain sooner — latency traded against per-dispatch
        efficiency); service back under a quarter of the budget grows
        both toward the config ceilings. Keying on total event latency
        instead would shrink batches when the QUEUE is long — cutting
        throughput exactly when capacity is short. Floors: min_batch and
        deadline/8 — the coalescer never degenerates to one-event
        dispatches."""
        if svc_s > dl / 2.0:
            nb = max(self._min_batch, self._eff_max_batch // 2)
            nl = max(dl / 8.0, self._eff_max_latency_s / 2.0)
            if nb < self._eff_max_batch or nl < self._eff_max_latency_s:
                self._batch_shrinks += 1
            self._eff_max_batch, self._eff_max_latency_s = nb, nl
        elif svc_s <= dl / 4.0:
            nb = min(self.config.max_batch, self._eff_max_batch * 2)
            nl = min(self._lat_cap_s, self._eff_max_latency_s * 2.0)
            if nb > self._eff_max_batch or nl > self._eff_max_latency_s:
                self._batch_grows += 1
            self._eff_max_batch, self._eff_max_latency_s = nb, nl

    def _rung_active(self, rung: str) -> bool:
        """Ladder level k activates the FIRST k configured rungs."""
        return rung in self.config.degrade_rungs[: self._rung_level]

    def _ladder_evaluate(self, now: float) -> None:
        """One hysteretic ladder evaluation per degrade_window drained
        events: a window missing at >= enter_frac steps DOWN one rung, at
        <= exit_frac steps back UP; in between the ladder holds. One
        transition per window at most — the ladder cannot flap."""
        if self._window_drained < self.config.degrade_window:
            return
        miss_frac = self._window_missed / self._window_drained
        self._window_missed = 0
        self._window_drained = 0
        level = self._rung_level
        if miss_frac >= self.config.degrade_enter_frac:
            new = min(level + 1, len(self.config.degrade_rungs))
        elif miss_frac <= self.config.degrade_exit_frac:
            new = max(level - 1, 0)
        else:
            new = level
        if new != level:
            self._set_rung_level(new, miss_frac, now)

    def _set_rung_level(self, new: int, miss_frac: float,
                        now: float) -> None:
        old = self._rung_level
        rungs = self.config.degrade_rungs
        crc_was_active = self._rung_active("scrub_crc_only")
        self._rung_level = new
        self._ladder_transitions.append({
            "t": now,
            "from_level": old,
            "to_level": new,
            "rung": rungs[new - 1] if new > old else rungs[old - 1],
            "direction": "down" if new > old else "up",
            "miss_frac": round(miss_frac, 4),
        })
        if crc_was_active and not self._rung_active("scrub_crc_only"):
            self._apply_deferred_heals()

    def _apply_deferred_heals(self) -> None:
        """Repair every frame whose heal the scrub_crc_only rung
        deferred: fresh readback, re-verify (the fault may have been
        healed by a reconfigure meanwhile), heal on mismatch."""
        pending, self._deferred_heals = self._deferred_heals, []
        for slot, replica in pending:
            image = np.asarray(
                self.readback_frame(slot, replica)).astype(np.uint8)
            if not self._golden.verify(slot, replica, image):
                self._scrub_healed_bits += self._heal_frame(
                    slot, replica, image)

    def _fold_chip(self, results, i, seqs, scores, keep) -> None:
        st = self._stats[i]
        st.n_in += len(seqs)
        st.n_kept += int(np.asarray(keep).sum())
        for j, seq in enumerate(seqs):
            results.append(
                ScoredEvent(seq=seq, chip=i, score_raw=int(scores[j]),
                            keep=bool(keep[j]))
            )

    def _fold_disagreements(self, dis) -> None:
        dis = np.asarray(dis)                           # (C, R)
        for i, st in enumerate(self._stats):
            st.disagreements = [
                a + int(b) for a, b in zip(st.disagreements, dis[i])
            ]

    def _drain_all(self) -> List[ScoredEvent]:
        out: List[ScoredEvent] = []
        while self._inflight:
            out.extend(self._drain_one())
        return out

    # ------------------------------------------------------- reconfigure
    def reconfigure(self, slot: int, new_chip: ReadoutChip) -> List[ScoredEvent]:
        """Hot-swap slot's bitstream: array swap, no recompile.

        Pending events are flushed first (they were submitted against the
        old configuration); returns their results. The new config must fit
        the server's fixed envelope — enforced identically on both
        backends, and ``self.geometry`` never changes, so callers can keep
        pre-checking candidates with ``server.geometry.admits(cfg)``. When
        the fused frames frontend is live, the swap also replaces the
        chip's encode-plan row (used features, ap_fixed spec, trigger
        cut), still with no retrace. Under TMR all three replica slots
        are re-encoded from the new bitstream.
        """
        assert 0 <= slot < self.n_chips, slot
        cfg = new_chip.config
        if cfg.n_ffs or not self.geometry.admits(cfg):
            raise ValueError(
                f"new config does not fit server envelope {self.geometry} "
                f"(levels={len(cfg.level_sizes)}, "
                f"widest={max(cfg.level_sizes, default=1)}, "
                f"inputs={cfg.n_inputs}, outputs={len(cfg.output_nets)}, "
                f"ffs={cfg.n_ffs}, fanin_reach={cfg.fanin_reach()})"
            )
        # feature-stage contract: enforced on BOTH backends at swap time
        # (same promise as admits, for the featurizer axes) — not deferred
        # to an index error inside a later frames dispatch.
        from repro.kernels.frontend import validate_chip_frontend

        validate_chip_frontend(cfg, new_chip.frontend_spec(),
                               self.geometry.frontend.n_features)
        done = self.flush()
        R = self.n_replicas
        self._replica_configs[slot * R : (slot + 1) * R] = [
            replicate_config(cfg, r) for r in range(R)
        ]
        self.chips[slot] = new_chip
        self._thr_raw = np.array(
            [c.score_threshold_raw for c in self.chips], np.int32)
        if self.config.backend == "kernel":
            self._stack = self._stack.swap_chip(slot, cfg)
            self._out_weight = self._lut_ops.decode_plan(
                [c.config for c in self.chips], self._stack.n_outputs)
            if self._frontend is not None:
                self._frontend = self._frontend.swap_chip(
                    slot, cfg, new_chip.frontend_spec(), stack=self._stack)
        self._frame_sims[slot] = None
        if self.config.backend == "host":
            self._multisim = MultiFabricSim(
                self._replica_configs, geometry=self.geometry)
        # the slot's golden truth IS the new bitstream now; re-snapshot the
        # digests and re-baseline the steering counters so stale
        # disagreements from the old configuration don't attract scrubs
        self._register_golden(slot)
        for r in range(self.n_replicas):
            fi = self._frame_index(slot, r)
            self._frame_gen[fi] += 1    # pending samples of the old
            self._scrub_last_dis[fi] = (   # bitstream are stale now
                self._stats[slot].disagreements[r])
        return done

    def rebind_mesh(self, mesh) -> List[ScoredEvent]:
        """Re-place the kernel stack onto a (possibly different) device
        mesh — the fleet grow/shrink port (launch/fleet.py).

        Pending work is flushed first (returned, like ``reconfigure``),
        then the packed stack (and the fused frontend, if live) is
        replicated onto the new mesh via
        ``train.elastic.reshard_replicated`` — serving state is
        replicated, so any slab size works, the same reason elastic
        train restarts can reshard onto a shrunken mesh. Rebinding to a
        mesh EQUAL to the current one (same devices, same axes) is free:
        jit static-arg caching compares meshes by value, so nothing
        retraces. A genuinely different slab retraces once on the next
        dispatch — grow/shrink is a control-plane event, not the
        zero-retrace tenant-admission path. No-op on the host backend.
        """
        if self.config.backend != "kernel":
            return []
        from repro.train.elastic import reshard_replicated

        done = self.flush()
        rebound = self._mesh is None or mesh != self._mesh
        if rebound:
            self._stack = reshard_replicated(self._stack, mesh)
        self._mesh = mesh
        if self._frontend is not None and rebound:
            self._frontend = dataclasses.replace(
                self._frontend,
                stack=self._stack,
                mesh=mesh,
            )
        return done

    # ----------------------------------------------------- fault injection
    def inject_seu(self, slot: int, replica: int, lut_index: int,
                   bit: int) -> None:
        """Flip one configuration bit of ONE served replica — the
        fault-injection port of the SEU campaign (tests/test_seu.py).

        ``lut_index``/``bit`` address the replica's OWN decoded bitstream
        (its placement-rotated encoding), exactly as a configuration-
        memory upset would. Takes effect on the next dispatch; batches
        already in flight scored against the pre-fault arrays, which is
        what a real upset does too. Works on both backends (the host
        oracle's simulators are rebuilt from the same perturbed config),
        and on a non-redundant server (replica 0) as the unprotected
        negative control. Repeated calls accumulate flips.
        """
        assert 0 <= slot < self.n_chips, slot
        R = self.n_replicas
        if not 0 <= replica < R:
            raise ValueError(f"replica must be in [0, {R}), got {replica!r}")
        i = slot * R + replica
        self._frame_gen[i] += 1     # invalidates pre-flip scrub samples
        self._replica_configs[i] = _inject_seu_config(
            self._replica_configs[i], lut_index, bit)
        if self.config.backend == "kernel":
            if R > 1:
                self._stack = self._stack.swap_replica(
                    slot, replica, self._replica_configs[i])
            else:
                self._stack = self._stack.swap_chip(
                    slot, self._replica_configs[i])
            if self._frontend is not None:
                self._frontend = dataclasses.replace(
                    self._frontend, stack=self._stack)
        else:
            # only the flipped replica's simulator rebuilds — a sweep
            # flips thousands of bits, a fleet rebuild per flip won't do
            self._multisim.swap_config(i, self._replica_configs[i])
        self._frame_sims[slot] = None

    # ----------------------------------------------------------- scrubbing
    def _register_golden(self, slot: int) -> None:
        """Snapshot slot's golden truth (bitstream + per-replica digests)
        — at construction and again on every reconfigure."""
        cfg = self.chips[slot].config
        self._golden.register(slot, cfg, replica_table_images(
            cfg, self._img_levels, self._img_m_pad, self.n_replicas))

    def _frame_index(self, slot: int, replica: int) -> int:
        return slot * self.n_replicas + replica

    def readback_frame(self, slot: int, replica: int = 0) -> np.ndarray:
        """LIVE truth-table image of one served replica frame, in the
        shared padded scrub layout: the device stack's arrays on the
        kernel backend (PackedFabricStack.readback_replica), the
        MultiFabricSim scrub twin on the host oracle — both return what
        is actually being evaluated with, including any injected upset."""
        assert 0 <= slot < self.n_chips, slot
        R = self.n_replicas
        if not 0 <= replica < R:
            raise ValueError(f"replica must be in [0, {R}), got {replica!r}")
        if self.config.backend == "kernel":
            return self._stack.readback_replica(slot, replica)
        return self._multisim.readback_tables(
            self._frame_index(slot, replica),
            self._img_levels, self._img_m_pad)

    def verify_frame(self, slot: int, replica: int = 0) -> bool:
        """CRC-check one replica frame's readback against its golden
        digest (no heal) — the detection half of the scrub loop alone."""
        return self._golden.verify(
            slot, replica, self.readback_frame(slot, replica))

    def scrub_step(self) -> List[Dict[str, int]]:
        """ONE background scrub step: resolve earlier readbacks, then
        sample the next frames (readback -> CRC verify -> heal).

        Always samples the next round-robin frame; in ``steered`` mode a
        replica frame whose disagreement counters climbed since its last
        scrub is sampled FIRST (the health monitor pointing the repair at
        the likely upset), without consuming the round-robin turn — so
        steering accelerates repair but can never starve a frame. On the
        kernel backend the sample is an ASYNC device->host copy verified
        on a later step (see ``_scrub_pending``); the host oracle
        verifies in place. Returns one record per healed frame:
        {"slot", "replica", "healed_bits", "detection_latency_dispatches"}.
        """
        t0 = self._clock()
        healed: List[Dict[str, int]] = []
        # resolve readbacks whose device->host copies have completed —
        # and ONLY those: with a short interval the sampled batch can
        # still be in flight behind the pipeline, and blocking on it
        # here would stall exactly the overlap scrubbing must not touch.
        # A copy that never reports ready is force-resolved once the
        # queue exceeds one full frame cycle (bounded staleness).
        n_frames = self.n_chips * self.n_replicas
        still_pending = collections.deque()
        while self._scrub_pending:
            entry = self._scrub_pending.popleft()
            arr = entry[2]
            ready = not hasattr(arr, "is_ready") or arr.is_ready()
            if ready or len(self._scrub_pending) >= n_frames:
                rec = self._resolve_readback(*entry)
                if rec:
                    healed.append(rec)
            else:
                still_pending.append(entry)
        self._scrub_pending = still_pending
        R = self.n_replicas
        if self.config.scrub_mode == "steered":
            healed.extend(self._scrub_steered_check())
        f = self._scrub_rr
        self._scrub_rr = (f + 1) % n_frames
        if self._scrub_rr == 0:
            self._scrub_cycles += 1
        rec = self._issue_scrub(f // R, f % R)
        if rec:
            healed.append(rec)
        self._scrub_steps += 1
        self._stage("scrub", t0)
        return healed

    def scrub_flush(self) -> List[Dict[str, int]]:
        """Resolve every readback still in flight (blocks on the copies)
        — the scrub analogue of ``flush``."""
        healed: List[Dict[str, int]] = []
        while self._scrub_pending:
            rec = self._resolve_readback(*self._scrub_pending.popleft())
            if rec:
                healed.append(rec)
        return healed

    def scrub_cycle(self) -> List[Dict[str, int]]:
        """Force one full verified pass over every replica frame
        (n_chips x n_replicas scrub steps, then resolve the tail) —
        e.g. before a controlled handover."""
        out: List[Dict[str, int]] = []
        for _ in range(self.n_chips * self.n_replicas):
            out.extend(self.scrub_step())
        out.extend(self.scrub_flush())
        return out

    def _scrub_steered_check(self) -> List[Dict[str, int]]:
        """Sample the replica frame whose disagreement counters climbed
        most since its last scrub (no-op when none climbed) — the health
        monitor pointing the repair at the likely upset. Does not consume
        the round-robin turn."""
        R = self.n_replicas
        n_frames = self.n_chips * R
        deltas = [
            self._stats[f // R].disagreements[f % R]
            - self._scrub_last_dis[f]
            for f in range(n_frames)
        ]
        hot = int(np.argmax(deltas))
        if deltas[hot] <= 0:
            return []
        rec = self._issue_scrub(hot // R, hot % R)
        return [rec] if rec else []

    def _issue_scrub(self, slot: int, replica: int) -> Optional[Dict[str, int]]:
        """Sample one frame's live truth-table image. Host backend: a
        numpy view — verify right here. Kernel backend: enqueue the
        device->host copy asynchronously and verify on a later step, so
        the scrub task never synchronizes with the dispatch it just
        interleaved behind."""
        fi = self._frame_index(slot, replica)
        self._scrub_per_frame[fi] += 1
        # snapshot the health counter: future steering reacts to NEW
        # disagreements only (a healed fault stops attracting scrubs)
        self._scrub_last_dis[fi] = self._stats[slot].disagreements[replica]
        prev_pass = self._scrub_last_pass[fi]
        self._scrub_last_pass[fi] = self._dispatch_idx
        if self.config.backend != "kernel":
            return self._verify_heal(
                slot, replica,
                self._multisim.readback_tables(
                    fi, self._img_levels, self._img_m_pad),
                prev_pass)
        arr = self._stack.tables[fi]
        if hasattr(arr, "copy_to_host_async"):
            arr.copy_to_host_async()
        self._scrub_pending.append(
            (fi, self._frame_gen[fi], arr, prev_pass, self._dispatch_idx))
        return None

    def _resolve_readback(
        self, fi: int, gen: int, arr, prev_pass: int, issue_idx: int
    ) -> Optional[Dict[str, int]]:
        if gen != self._frame_gen[fi]:
            # the frame was re-encoded (inject/heal/reconfigure) after
            # this sample was taken: drop it, and roll back the issue-time
            # bookkeeping so the report never counts an unverified sample
            # as a completed scrub (the frame's next turn re-samples it).
            # Roll the latency reference back ONLY if no newer sample of
            # this frame has advanced it since — a later issue's
            # timestamp must win over this dropped one.
            self._scrub_per_frame[fi] -= 1
            if self._scrub_last_pass[fi] == issue_idx:
                self._scrub_last_pass[fi] = prev_pass
            return None
        R = self.n_replicas
        return self._verify_heal(
            fi // R, fi % R, np.asarray(arr).astype(np.uint8), prev_pass)

    def _verify_heal(
        self, slot: int, replica: int, image: np.ndarray, prev_pass: int
    ) -> Optional[Dict[str, int]]:
        """CRC-verify one sampled image against the golden digest and
        heal on mismatch. ``prev_pass`` is the frame's previous scrub
        dispatch — the detection latency is measured from there."""
        if self._golden.verify(slot, replica, image):
            return None
        latency = self._dispatch_idx - prev_pass
        self._scrub_detections += 1
        self._scrub_latencies.append(latency)
        if self._rung_active("scrub_crc_only"):
            # the ladder's CRC-only rung: detection stays live (the
            # counter above), but the heal — re-encode + array swap on
            # the critical path — is deferred until the rung exits.
            # TMR keeps masking the fault meanwhile.
            key = (slot, replica)
            if key not in self._deferred_heals:
                self._deferred_heals.append(key)
            return {"slot": slot, "replica": replica,
                    "healed_bits": 0, "deferred": 1,
                    "detection_latency_dispatches": latency}
        healed_bits = self._heal_frame(slot, replica, image)
        self._scrub_healed_bits += healed_bits
        return {"slot": slot, "replica": replica,
                "healed_bits": healed_bits,
                "detection_latency_dispatches": latency}

    def _heal_frame(self, slot: int, replica: int, image: np.ndarray) -> int:
        """Re-encode ONE corrupted replica from the golden bitstream —
        the same no-retrace swap machinery as fault injection, pointed
        the other way. Returns the number of healed configuration bits."""
        golden_cfg = self._golden.golden_config(slot)
        rep_cfg = replicate_config(golden_cfg, replica)
        golden_img = packed_table_image(
            rep_cfg, self._img_levels, self._img_m_pad)
        healed_bits = int(np.count_nonzero(image != golden_img))
        i = self._frame_index(slot, replica)
        self._frame_gen[i] += 1
        self._replica_configs[i] = rep_cfg
        if self.config.backend == "kernel":
            self._stack = self._stack.swap_replica(slot, replica, rep_cfg)
            if self._frontend is not None:
                self._frontend = dataclasses.replace(
                    self._frontend, stack=self._stack)
        else:
            self._multisim.swap_config(i, rep_cfg)
        self._frame_sims[slot] = None
        return healed_bits

    # ------------------------------------------------------------ report
    def report(self) -> Dict[str, object]:
        """Per-chip trigger/reduction accounting aggregated over the
        stream, plus the per-stage host-side timing breakdown (seconds and
        call counts per pipeline stage — for fused frames dispatches the
        featurize/quantize/pack/vote/score stages are a single
        ``launch_fused`` entry by design; the staged host path itemizes
        them), the per-replica SEU disagreement counters, the measured
        host-link bytes (sparse wire vs dense equivalent), and the scrub
        accounting (steps/cycles/frames, CRC detections, healed config
        bits, per-detection latency in dispatches). The deadline-aware
        additions: per-chip and total latency histograms (p50/p99/p99.9
        + CDF), the last drained batch's stage trace, the met/missed/
        shed deadline ledger, the adaptive coalescer's effective knobs,
        and the degrade ladder's level + timestamped transitions. With a
        network front door attached (net/ingress.py), ``"net"`` carries
        its per-client drop/reorder/resync accounting snapshot;
        otherwise ``{"attached": False}``."""
        cfg = self.config
        per_chip = []
        for i, st in enumerate(self._stats):
            frac = st.fraction_kept()
            per_chip.append({
                "chip": i,
                "n_in": st.n_in,
                "n_kept": st.n_kept,
                "n_dispatches": st.n_dispatches,
                "n_shed": st.n_shed,
                "fraction_kept": frac,
                "data_reduction_factor": 1.0 / max(frac, 1e-9),
                "link_rate_in_gbps": cfg.hit_rate_hz * cfg.bits_per_hit / 1e9,
                "link_rate_out_gbps":
                    cfg.hit_rate_hz * cfg.bits_per_hit * frac / 1e9,
                "seu_disagreements": list(st.disagreements),
                "latency_p99_us": self._hist_chip[i].percentile(99.0),
            })
        n_in = sum(s.n_in for s in self._stats)
        n_kept = sum(s.n_kept for s in self._stats)
        dt = (
            (self._t_last - self._t_start)
            if (self._t_start is not None and self._t_last is not None)
            else 0.0
        )
        t_base = self._last_batch_trace.get("t_enqueued")
        trace_us = {
            k: (v - t_base) * 1e6
            for k, v in self._last_batch_trace.items()
        } if t_base is not None else {}
        n_shed = sum(s.n_shed for s in self._stats)
        return {
            "backend": cfg.backend,
            "layout": self.layout,
            "redundancy": cfg.redundancy,
            "n_replicas": self.n_replicas,
            "sparse": cfg.sparse,
            "n_chips": self.n_chips,
            "n_in": n_in,
            "n_kept": n_kept,
            "fraction_kept": n_kept / n_in if n_in else 1.0,
            "events_per_s": n_in / dt if dt > 0 else float("nan"),
            "queue_depth": self.queue_depth,
            "inflight_batches": len(self._inflight),
            "seu_disagreement_total": int(
                sum(sum(s.disagreements) for s in self._stats)),
            "scrub": {
                "enabled": cfg.scrub_interval is not None,
                "interval": cfg.scrub_interval,
                "mode": cfg.scrub_mode,
                "steps": self._scrub_steps,
                "cycles": self._scrub_cycles,
                "frames_scrubbed": int(sum(self._scrub_per_frame)),
                "detections": self._scrub_detections,
                "healed_bits": self._scrub_healed_bits,
                "detection_latency_dispatches": {
                    "mean": (float(np.mean(self._scrub_latencies))
                             if self._scrub_latencies else 0.0),
                    "max": int(max(self._scrub_latencies, default=0)),
                },
                "per_frame_scrubs": list(self._scrub_per_frame),
            },
            "link_bytes": {
                "on_wire": self._link_bytes_wire,
                "dense_equivalent": self._link_bytes_dense,
                "wire_reduction": (
                    self._link_bytes_dense / self._link_bytes_wire
                    if self._link_bytes_wire
                    and self._link_bytes_wire != self._link_bytes_dense
                    else 1.0),
            },
            "latency": {
                "total": self._hist_total.summary(),
                "queue_wait": self._hist_queue.summary(),
                "service": self._hist_service.summary(),
                "cdf_us": self._hist_total.cdf(),
                "last_batch_trace_us": trace_us,
            },
            "deadline": {
                "deadline_us": cfg.deadline_us,
                "policy": cfg.overload_policy,
                "met": self._deadline_met,
                "missed": self._deadline_missed,
                "shed": n_shed,
                "miss_fraction": (
                    self._deadline_missed
                    / max(self._deadline_met + self._deadline_missed, 1)),
                "service_ewma_us": self._service_ewma_s * 1e6,
                "drain_rate_ev_s": self._drain_rate(),
                "effective_max_batch": self._eff_max_batch,
                "effective_max_latency_s": self._eff_max_latency_s,
                "batch_shrinks": self._batch_shrinks,
                "batch_grows": self._batch_grows,
                "ladder": {
                    "level": self._rung_level,
                    "active_rungs": list(
                        cfg.degrade_rungs[: self._rung_level]),
                    "transitions": list(self._ladder_transitions),
                    "deferred_heals_pending": len(self._deferred_heals),
                },
            },
            "stages": {
                k: {"seconds": self._stage_s[k], "calls": self._stage_n[k]}
                for k in sorted(self._stage_s)
            },
            "net": (self._net_stats_provider()
                    if self._net_stats_provider is not None
                    else {"attached": False}),
            "per_chip": per_chip,
        }
