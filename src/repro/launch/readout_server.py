"""Multi-chip streaming readout server (the scaled-up §5 front end).

One deployed detector is not one chip: many sensors feed many configured
eFPGAs, all filtering the same 40 MHz bunch-crossing stream before the
off-detector links. This server models that as a serving system with TWO
ingestion stages, one per deployment style:

    submit(chip, features)            pre-computed features (host frontend)
    submit_frames(chip, frames, y0)   RAW charge frames (fused frontend)
      -> micro-batch queue            (coalesce: max_batch / max_latency)
      -> scoring dispatch
           features ... host featurize (quantize + bit pack) -> ONE
                        chip-batched lut_eval call over (chips, events)
           frames ..... ONE fused dispatch (kernels/frontend.py):
                        yprofile -> quantize -> bit pack -> lut_eval ->
                        keep/drop, all on device, chip axis sharded over
                        the "chips" mesh — no host materialization
                        between stages
      -> keep/drop per event          (integer-domain threshold, exact)
      -> per-chip trigger report      (rates, reduction, link budget,
                                       per-stage host timing)

Key properties:

  * Loading a bitstream stays an array swap: all chips share one padded
    geometry (core.fabric.StackGeometry, which also carries the
    feature-stage metadata for frames ingestion), so ``reconfigure``
    hot-swaps a chip's arrays — lut_eval stack AND fused encode plan —
    with no recompile.
  * Pipelined host/device overlap: device dispatch is asynchronous (JAX),
    and up to ``pipeline_depth`` batches stay in flight while the host
    prepares the next one. The default depth of 2 is triple buffering
    (host builds batch k+2 while the device holds k and k+1); depth 1 is
    the classic double buffer.
  * The host-oracle backend (backend="host") is bit-identical to the
    kernel path on BOTH ingestion stages — frames run the same pipeline
    staged (featurize dispatch materialized, numpy quantize+pack, numpy
    MultiFabricSim) — the basis of tests/test_readout_server.py and
    tests/test_frontend.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fabric import (
    FabricSim,
    FrontendSpec,
    MultiFabricSim,
    StackGeometry,
    check_stackable,
    stack_event_bits,
)
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import N_T, N_X, N_Y
from repro.data.smartpixel import N_FEATURES as _N_FEATURES


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Micro-batching knobs. Validated on construction — a bad knob fails
    HERE with a named error, not three layers down as a shape mismatch.

    max_batch: coalesce at most this many events (across all chips) into
        one dispatch; a full queue triggers a dispatch immediately.
    max_latency_s: a partial batch is dispatched once its oldest event has
        waited this long (the trigger-latency budget).
    backend: "kernel" (chip-batched Pallas dispatch) or "host" (numpy
        MultiFabricSim oracle, bit-identical).
    batch_tile: Pallas batch tile — every stage of the fused frames
        dispatch tiles with it, so it must be a multiple of 128 (the TPU
        lane width both kernels assume).
    band: banded routing for the kernel stack — None auto-selects it
        whenever the chips' shared fan-in reach K is smaller than the
        level count (per-level routing cost drops from the full padded
        net buffer to the input segment + a K-level window); True/False
        force banded/dense. The host oracle is unaffected.
    pipeline_depth: batches kept in flight on the device while the host
        prepares the next (2 = triple buffering, 1 = double buffering).
    threshold_electrons: per-pixel zero suppression of the frames->
        features stage (frames ingestion only).
    bits_per_hit / hit_rate_hz: link-budget accounting for the report.
    """

    max_batch: int = 2048
    max_latency_s: float = 5e-3
    backend: str = "kernel"
    batch_tile: int = 128
    band: Optional[bool] = None
    pipeline_depth: int = 2
    threshold_electrons: float = 800.0
    bits_per_hit: int = 256
    hit_rate_hz: float = 40e6

    def __post_init__(self):
        if not (isinstance(self.max_batch, int) and self.max_batch > 0):
            raise ValueError(f"max_batch must be a positive int, got "
                             f"{self.max_batch!r}")
        if self.max_latency_s <= 0:
            raise ValueError(f"max_latency_s must be > 0, got "
                             f"{self.max_latency_s!r}")
        if not (isinstance(self.batch_tile, int) and self.batch_tile > 0
                and self.batch_tile % 128 == 0):
            raise ValueError(
                f"batch_tile must be a positive multiple of 128 (the TPU "
                f"lane width), got {self.batch_tile!r}")
        if self.backend not in ("kernel", "host"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'kernel' or 'host')")
        if not (isinstance(self.pipeline_depth, int)
                and self.pipeline_depth >= 1):
            raise ValueError(f"pipeline_depth must be an int >= 1, got "
                             f"{self.pipeline_depth!r}")
        if self.threshold_electrons < 0:
            raise ValueError(f"threshold_electrons must be >= 0, got "
                             f"{self.threshold_electrons!r}")


@dataclasses.dataclass(frozen=True)
class ScoredEvent:
    seq: int          # submission order (global, monotone)
    chip: int
    score_raw: int    # integer-domain fabric score
    keep: bool        # False = classified as pileup, dropped at source


@dataclasses.dataclass
class ChipStreamStats:
    """Running trigger/reduction accounting for one chip slot."""

    n_in: int = 0
    n_kept: int = 0
    n_dispatches: int = 0

    def fraction_kept(self) -> float:
        return self.n_kept / self.n_in if self.n_in else 1.0


# (seq, chip, kind, payload, t_enqueue); payload is a features row for
# kind="features", an (frame, y0) pair for kind="frames".
_Event = Tuple[int, int, str, object, float]
# (kind, pending, per_chip_seq, counts); kind "bits" holds a lazily
# materialized (C, B, n_outputs) tensor, kind "fused" the (score, keep)
# device pair of a fused frames dispatch.
_Inflight = Tuple[str, object, List[List[int]], List[int]]


class ReadoutServer:
    """Serves N configured ReadoutChips from one micro-batched event loop."""

    def __init__(
        self,
        chips: Sequence[ReadoutChip],
        config: ServerConfig = ServerConfig(),
        clock=time.monotonic,
    ):
        if not chips:
            raise ValueError("need at least one chip")
        self.chips: List[ReadoutChip] = list(chips)
        self.config = config
        self._clock = clock
        # the server's FIXED envelope: set at construction, never shrinks.
        # Both backends validate hot-swaps against it — including the
        # fan-in-reach budget a banded kernel stack depends on — so a
        # deployment validated on the host oracle behaves identically on
        # the kernel. The budget mirrors the stack's actual band choice:
        # a dense stack (config.band=False, or reach >= levels) carries
        # none, so forcing dense keeps full hot-swap flexibility. The
        # envelope also carries the feature-stage contract: every server
        # can ingest raw frames, so a hot-swapped chip must be encodable
        # from the featurizer's output (checked in ``reconfigure``).
        geo = check_stackable([c.config for c in self.chips])
        banded = (
            config.band is not False
            and (geo.fanin_reach or geo.n_levels) < geo.n_levels
        )
        self.geometry: StackGeometry = dataclasses.replace(
            geo if banded else dataclasses.replace(geo, fanin_reach=None),
            frontend=FrontendSpec(
                n_features=_N_FEATURES,
                frame_shape=(N_T, N_Y, N_X),
                threshold_electrons=config.threshold_electrons,
            ),
        )
        self._stack = None
        self._frontend = None  # fused frames dispatch, built on first use
        if config.backend == "kernel":
            from repro.kernels.lut_eval import ops as lut_ops

            self._lut_ops = lut_ops
            self._stack = lut_ops.pack_fabrics(
                [c.config for c in self.chips], band=config.band
            )
        else:
            self._multisim = MultiFabricSim(
                [c.config for c in self.chips], geometry=self.geometry)

        self._queue: Deque[_Event] = collections.deque()
        self._seq = 0
        # per-slot FabricSim cache for the staged (host) frames path —
        # pure function of the slot's config, invalidated on reconfigure,
        # so repeated dispatches don't re-pay construction (and the
        # staged_score stage timing stays honest).
        self._frame_sims: List[Optional[FabricSim]] = [None] * len(self.chips)
        # the pipeline: up to config.pipeline_depth batches on the device
        self._inflight: Deque[_Inflight] = collections.deque()
        self._stats = [ChipStreamStats() for _ in self.chips]
        self._stage_s: Dict[str, float] = collections.defaultdict(float)
        self._stage_n: Dict[str, int] = collections.defaultdict(int)
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None
        self._n_scored = 0

    # ------------------------------------------------------------- intake
    @property
    def n_chips(self) -> int:
        return len(self.chips)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def submit(self, chip: int, features: np.ndarray) -> int:
        """Enqueue one pre-featurized event for one chip; returns its seq."""
        assert 0 <= chip < self.n_chips, chip
        seq = self._seq
        self._seq += 1
        self._queue.append(
            (seq, chip, "features", np.asarray(features, np.float64),
             self._clock())
        )
        return seq

    def submit_batch(self, chip: int, X: np.ndarray) -> List[int]:
        """Enqueue a block of pre-featurized events (rows of X)."""
        return [self.submit(chip, row) for row in np.asarray(X)]

    def submit_frames(
        self, chip: int, frames: np.ndarray, y0: np.ndarray
    ) -> List[int]:
        """Enqueue raw-frame events: (n, T, Y, X) charge + (n,) y0.

        These score through the frames pipeline — on the kernel backend
        the FUSED single-dispatch frontend, on the host backend the same
        pipeline staged. Mixing frames and features for the same chip in
        one micro-batch is allowed but scores as two dispatch groups, so
        cross-kind result order within that batch follows the groups, not
        the global seq order (every event stays seq-tagged).
        """
        assert 0 <= chip < self.n_chips, chip
        frames = np.asarray(frames, np.float32)
        y0 = np.asarray(y0, np.float32)
        assert frames.ndim == 4 and frames.shape[1:] == (N_T, N_Y, N_X), \
            frames.shape
        assert len(frames) == len(y0), (len(frames), len(y0))
        seqs = []
        now = self._clock()
        for i in range(len(frames)):
            seq = self._seq
            self._seq += 1
            self._queue.append(
                (seq, chip, "frames", (frames[i], float(y0[i])), now))
            seqs.append(seq)
        return seqs

    # ------------------------------------------------------------ the loop
    def poll(self) -> List[ScoredEvent]:
        """One turn of the event loop: dispatch if a micro-batch is due,
        and return any newly completed results (seq-ordered per batch)."""
        out: List[ScoredEvent] = []
        if self._due():
            out.extend(self._dispatch(self._coalesce()))
        return out

    def flush(self) -> List[ScoredEvent]:
        """Force out everything: queued events and in-flight results."""
        out: List[ScoredEvent] = []
        while self._queue:
            out.extend(self._dispatch(self._coalesce()))
        out.extend(self._drain_all())
        return out

    def score_stream(
        self, batches: Iterable[Tuple[int, np.ndarray]]
    ) -> Iterable[List[ScoredEvent]]:
        """Drive the loop over an iterable of (chip, features-block) pairs,
        yielding completed results as they become available."""
        for chip, X in batches:
            self.submit_batch(chip, X)
            got = self.poll()
            if got:
                yield got
        tail = self.flush()
        if tail:
            yield tail

    def _due(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.config.max_batch:
            return True
        oldest = self._queue[0][4]
        return (self._clock() - oldest) >= self.config.max_latency_s

    def _coalesce(self) -> List[_Event]:
        take = min(len(self._queue), self.config.max_batch)
        return [self._queue.popleft() for _ in range(take)]

    def _stage(self, key: str, t0: float) -> None:
        self._stage_s[key] += self._clock() - t0
        self._stage_n[key] += 1

    def _dispatch(self, events: List[_Event]) -> List[ScoredEvent]:
        """Launch one micro-batch and return any batches the pipeline
        retired: with the kernel backend dispatches are asynchronous, so
        up to ``pipeline_depth`` batches stay on the device while the
        host prepares the next (triple buffering at the default depth 2).
        """
        if not events:
            return []
        if self._t_start is None:
            self._t_start = self._clock()

        frame_events = [e for e in events if e[2] == "frames"]
        feat_events = [e for e in events if e[2] == "features"]
        if frame_events:
            self._inflight.append(self._launch_frames(frame_events))
        if feat_events:
            self._inflight.append(self._launch_features(feat_events))

        done: List[ScoredEvent] = []
        while len(self._inflight) > self.config.pipeline_depth:
            done.extend(self._drain_one())
        return done

    def _group(
        self, events: List[_Event]
    ) -> Tuple[List[List[int]], List[List[object]], List[int]]:
        per_chip_seq: List[List[int]] = [[] for _ in self.chips]
        per_chip_payload: List[List[object]] = [[] for _ in self.chips]
        for seq, chip, _, payload, _ in events:
            per_chip_seq[chip].append(seq)
            per_chip_payload[chip].append(payload)
        counts = [len(s) for s in per_chip_seq]
        for i, n in enumerate(counts):
            if n:
                self._stats[i].n_dispatches += 1
        return per_chip_seq, per_chip_payload, counts

    def _launch_features(self, events: List[_Event]) -> _Inflight:
        """Features path: host featurization (quantize + offset-binary bit
        packing, timed as ``encode_host``) into ONE chip-batched
        lut_eval/MultiFabricSim scoring call."""
        per_chip_seq, per_chip_X, counts = self._group(events)

        t0 = self._clock()
        per_chip_bits: List[np.ndarray] = []
        for i, chip in enumerate(self.chips):
            if per_chip_X[i]:
                bits = chip.encode_features(np.stack(per_chip_X[i]))
            else:
                bits = np.zeros((0, chip.config.n_inputs), np.uint8)
            per_chip_bits.append(bits)
        self._stage("encode_host", t0)

        t0 = self._clock()
        if self.config.backend == "kernel":
            stacked = self._lut_ops.stack_input_bits(self._stack, per_chip_bits)
            pending = self._lut_ops.fabric_eval_multi(
                self._stack, stacked, batch_tile=self.config.batch_tile
            )  # async on device; NOT materialized yet
        else:
            stacked = stack_event_bits(per_chip_bits, self.geometry.n_inputs)
            pending = self._multisim.run(stacked)
        self._stage("launch_score", t0)
        return ("bits", pending, per_chip_seq, counts)

    def _launch_frames(self, events: List[_Event]) -> _Inflight:
        """Frames path. Kernel backend: ONE fused dispatch over the
        sharded chip axis (timed ``launch_fused`` — featurize, quantize,
        pack and score all live inside it, invisible to the host by
        design). Host backend: the same pipeline STAGED, each stage
        materialized and timed (``staged_featurize`` / ``staged_encode``
        / ``staged_score``) — the breakdown the fused path removes.
        """
        per_chip_seq, per_chip_fy, counts = self._group(events)
        cfg = self.config

        if cfg.backend == "kernel":
            t0 = self._clock()
            B = max(counts) if counts else 0
            frames = np.zeros((self.n_chips, B, N_T, N_Y, N_X), np.float32)
            y0 = np.zeros((self.n_chips, B), np.float32)
            for i, rows in enumerate(per_chip_fy):
                if rows:  # one vectorized copy per chip, not per event
                    frames[i, : len(rows)] = np.stack([fr for fr, _ in rows])
                    y0[i, : len(rows)] = [z for _, z in rows]
            self._stage("stack_frames", t0)

            t0 = self._clock()
            pending = self._get_frontend().score_frames(frames, y0)
            self._stage("launch_fused", t0)
            return ("fused", pending, per_chip_seq, counts)

        # host backend: staged oracle, per chip
        scores: List[np.ndarray] = []
        for i, chip in enumerate(self.chips):
            if not per_chip_fy[i]:
                scores.append(np.zeros(0, np.int64))
                continue
            frames_i = np.stack([fr for fr, _ in per_chip_fy[i]])
            y0_i = np.asarray([z for _, z in per_chip_fy[i]], np.float32)
            t0 = self._clock()
            from repro.kernels.yprofile import ops as yp_ops

            feats = np.asarray(yp_ops.yprofile(
                frames_i, y0_i, threshold_electrons=cfg.threshold_electrons,
                batch_tile=cfg.batch_tile))
            self._stage("staged_featurize", t0)
            t0 = self._clock()
            bits = chip.encode_features(feats)
            self._stage("staged_encode", t0)
            t0 = self._clock()
            if self._frame_sims[i] is None:
                self._frame_sims[i] = FabricSim(chip.config)
            outs, _ = self._frame_sims[i].run(bits)
            scores.append(chip.synth.decode_outputs(np.asarray(outs)))
            self._stage("staged_score", t0)
        return ("host_frames", scores, per_chip_seq, counts)

    def _get_frontend(self):
        if self._frontend is None:
            from repro.kernels import frontend as fe

            self._frontend = fe.pack_frontend(
                [c.config for c in self.chips],
                [c.frontend_spec() for c in self.chips],
                band=self.config.band,
                batch_tile=self.config.batch_tile,
                threshold_electrons=self.config.threshold_electrons,
                stack=self._stack,  # share the server's packed arrays
            )
        return self._frontend

    def _drain_one(self) -> List[ScoredEvent]:
        """Materialize the OLDEST in-flight batch and fold it into the
        reports (``drain_wait`` is the host-visible blocking time)."""
        if not self._inflight:
            return []
        kind, pending, per_chip_seq, counts = self._inflight.popleft()
        t0 = self._clock()

        results: List[ScoredEvent] = []
        if kind == "fused":
            score_dev, keep_dev = pending
            score = np.asarray(score_dev)   # blocks here
            keep_all = np.asarray(keep_dev)
            for i in range(self.n_chips):
                n = counts[i]
                if not n:
                    continue
                self._fold_chip(results, i, per_chip_seq[i],
                                score[i, :n].astype(np.int64),
                                keep_all[i, :n])
        elif kind == "host_frames":
            for i in range(self.n_chips):
                n = counts[i]
                if not n:
                    continue
                s = pending[i]
                keep = s <= self.chips[i].score_threshold_raw
                self._fold_chip(results, i, per_chip_seq[i], s, keep)
        else:  # "bits"
            outs = np.asarray(pending)  # (C, B, n_outputs_max) — blocks here
            for i, chip in enumerate(self.chips):
                n = counts[i]
                if not n:
                    continue
                n_out = len(chip.config.output_nets)
                s = chip.synth.decode_outputs(outs[i, :n, :n_out])
                keep = s <= chip.score_threshold_raw
                self._fold_chip(results, i, per_chip_seq[i], s, keep)

        self._stage("drain_wait", t0)
        self._n_scored += len(results)
        self._t_last = self._clock()
        results.sort(key=lambda r: r.seq)
        return results

    def _fold_chip(self, results, i, seqs, scores, keep) -> None:
        st = self._stats[i]
        st.n_in += len(seqs)
        st.n_kept += int(np.asarray(keep).sum())
        for j, seq in enumerate(seqs):
            results.append(
                ScoredEvent(seq=seq, chip=i, score_raw=int(scores[j]),
                            keep=bool(keep[j]))
            )

    def _drain_all(self) -> List[ScoredEvent]:
        out: List[ScoredEvent] = []
        while self._inflight:
            out.extend(self._drain_one())
        return out

    # ------------------------------------------------------- reconfigure
    def reconfigure(self, slot: int, new_chip: ReadoutChip) -> List[ScoredEvent]:
        """Hot-swap slot's bitstream: array swap, no recompile.

        Pending events are flushed first (they were submitted against the
        old configuration); returns their results. The new config must fit
        the server's fixed envelope — enforced identically on both
        backends, and ``self.geometry`` never changes, so callers can keep
        pre-checking candidates with ``server.geometry.admits(cfg)``. When
        the fused frames frontend is live, the swap also replaces the
        chip's encode-plan row (used features, ap_fixed spec, trigger
        cut), still with no retrace.
        """
        assert 0 <= slot < self.n_chips, slot
        cfg = new_chip.config
        if cfg.n_ffs or not self.geometry.admits(cfg):
            raise ValueError(
                f"new config does not fit server envelope {self.geometry} "
                f"(levels={len(cfg.level_sizes)}, "
                f"widest={max(cfg.level_sizes, default=1)}, "
                f"inputs={cfg.n_inputs}, outputs={len(cfg.output_nets)}, "
                f"ffs={cfg.n_ffs}, fanin_reach={cfg.fanin_reach()})"
            )
        # feature-stage contract: enforced on BOTH backends at swap time
        # (same promise as admits, for the featurizer axes) — not deferred
        # to an index error inside a later frames dispatch.
        from repro.kernels.frontend import validate_chip_frontend

        validate_chip_frontend(cfg, new_chip.frontend_spec(),
                               self.geometry.frontend.n_features)
        done = self.flush()
        if self.config.backend == "kernel":
            self._stack = self._stack.swap_chip(slot, cfg)
            if self._frontend is not None:
                self._frontend = self._frontend.swap_chip(
                    slot, cfg, new_chip.frontend_spec(), stack=self._stack)
        self.chips[slot] = new_chip
        self._frame_sims[slot] = None
        if self.config.backend == "host":
            self._multisim = MultiFabricSim(
                [c.config for c in self.chips], geometry=self.geometry)
        return done

    # ------------------------------------------------------------ report
    def report(self) -> Dict[str, object]:
        """Per-chip trigger/reduction accounting aggregated over the
        stream, plus the per-stage host-side timing breakdown (seconds and
        call counts per pipeline stage — for fused frames dispatches the
        featurize/quantize/pack/score stages are a single ``launch_fused``
        entry by design; the staged host path itemizes them)."""
        cfg = self.config
        per_chip = []
        for i, st in enumerate(self._stats):
            frac = st.fraction_kept()
            per_chip.append({
                "chip": i,
                "n_in": st.n_in,
                "n_kept": st.n_kept,
                "n_dispatches": st.n_dispatches,
                "fraction_kept": frac,
                "data_reduction_factor": 1.0 / max(frac, 1e-9),
                "link_rate_in_gbps": cfg.hit_rate_hz * cfg.bits_per_hit / 1e9,
                "link_rate_out_gbps":
                    cfg.hit_rate_hz * cfg.bits_per_hit * frac / 1e9,
            })
        n_in = sum(s.n_in for s in self._stats)
        n_kept = sum(s.n_kept for s in self._stats)
        dt = (
            (self._t_last - self._t_start)
            if (self._t_start is not None and self._t_last is not None)
            else 0.0
        )
        return {
            "backend": cfg.backend,
            "n_chips": self.n_chips,
            "n_in": n_in,
            "n_kept": n_kept,
            "fraction_kept": n_kept / n_in if n_in else 1.0,
            "events_per_s": n_in / dt if dt > 0 else float("nan"),
            "queue_depth": self.queue_depth,
            "inflight_batches": len(self._inflight),
            "stages": {
                k: {"seconds": self._stage_s[k], "calls": self._stage_n[k]}
                for k in sorted(self._stage_s)
            },
            "per_chip": per_chip,
        }
