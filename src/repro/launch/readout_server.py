"""Multi-chip streaming readout server (the scaled-up §5 front end).

One deployed detector is not one chip: many sensors feed many configured
eFPGAs, all filtering the same 40 MHz bunch-crossing stream before the
off-detector links. This server models that as a serving system with TWO
ingestion stages, one per deployment style:

    submit(chip, features)            pre-computed features (host frontend)
    submit_frames(chip, frames, y0)   RAW charge frames (fused frontend)
      -> micro-batch queue            (coalesce: max_batch / max_latency)
      -> scoring dispatch
           features ... host featurize (quantize + bit pack) -> ONE
                        sharded chip-batched dispatch that evaluates,
                        votes (TMR), decodes scores and applies the
                        trigger cut on device (fabric_eval_multi_scored)
           frames ..... ONE fused dispatch (kernels/frontend.py):
                        yprofile -> quantize -> bit pack -> lut_eval ->
                        vote -> score -> keep/drop, all on device, chip
                        axis sharded over the "chips" mesh — no host
                        materialization between stages
      -> sparse trigger compression   (optional: only keep-flagged events
                                       cross the host link as a packed
                                       (indices, scores) pair)
      -> per-chip trigger report      (rates, reduction, link budget,
                                       per-stage host timing, per-replica
                                       SEU disagreement counters)

Key properties:

  * Loading a bitstream stays an array swap: all chips share one padded
    geometry (core.fabric.StackGeometry, which also carries the
    feature-stage metadata for frames ingestion), so ``reconfigure``
    hot-swaps a chip's arrays — lut_eval stack AND fused encode plan —
    with no recompile. Under ``redundancy="tmr"`` the swap re-encodes all
    three replica slots; still no retrace.
  * SEU resilience as a serving mode: ``ServerConfig.redundancy="tmr"``
    serves every chip as three placement-distinct replica encodings
    (core.tmr.replicate_config) voted on device with a 2-of-3 majority
    before decode. A single configuration-bit upset in any one replica
    cannot change any served output (tests/test_seu.py sweeps every
    bit); the per-replica disagreement counters in the report are the
    SEU health monitor, and ``inject_seu`` is the fault-injection port
    (flips one bit of one served replica, both backends).
  * At-source link compression: ``ServerConfig.sparse=True`` drops
    rejected events *before* the host link — the drain materializes only
    the packed (flat index, score) pairs of keep-flagged events
    (parallel.compression.sparse_trigger_pack), and the report carries
    the measured bytes-on-wire vs the dense equivalent.
  * Pipelined host/device overlap: device dispatch is asynchronous (JAX),
    and up to ``pipeline_depth`` batches stay in flight while the host
    prepares the next one. The default depth of 2 is triple buffering
    (host builds batch k+2 while the device holds k and k+1); depth 1 is
    the classic double buffer.
  * The host-oracle backend (backend="host") is bit-identical to the
    kernel path on BOTH ingestion stages and under every redundancy /
    sparse mode — the numpy path votes with the same
    core.tmr.majority_vote and packs with the same compaction rule — the
    basis of tests/test_readout_server.py, test_frontend.py and
    test_seu.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fabric import (
    FabricSim,
    FrontendSpec,
    MultiFabricSim,
    StackGeometry,
    check_stackable,
    stack_event_bits,
)
from repro.core.readout import ReadoutChip
from repro.core.tmr import (
    N_REPLICAS,
    inject_seu as _inject_seu_config,
    majority_vote,
    replicate_config,
)
from repro.data.smartpixel import N_T, N_X, N_Y
from repro.data.smartpixel import N_FEATURES as _N_FEATURES
from repro.parallel.compression import (
    DENSE_BYTES_PER_EVENT,
    SPARSE_BYTES_PER_EVENT,
    SPARSE_HEADER_BYTES,
)


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Micro-batching knobs. Validated on construction — a bad knob fails
    HERE with a named error, not three layers down as a shape mismatch.

    max_batch: coalesce at most this many events (across all chips) into
        one dispatch; a full queue triggers a dispatch immediately.
    max_latency_s: a partial batch is dispatched once its oldest event has
        waited this long (the trigger-latency budget).
    backend: "kernel" (chip-batched Pallas dispatch) or "host" (numpy
        MultiFabricSim oracle, bit-identical).
    batch_tile: Pallas batch tile — every stage of the fused frames
        dispatch tiles with it, so it must be a multiple of 128 (the TPU
        lane width both kernels assume).
    band: banded routing for the kernel stack — None auto-selects it
        whenever the chips' shared fan-in reach K is smaller than the
        level count (per-level routing cost drops from the full padded
        net buffer to the input segment + a K-level window); True/False
        force banded/dense. The host oracle is unaffected.
    redundancy: "none" or "tmr". TMR serves three placement-distinct
        replica encodings of every chip, votes 2-of-3 on device before
        decode, and surfaces per-replica disagreement counters in the
        report (the SEU health monitor). Cost: 3x the fabric-evaluation
        work plus the (elementwise) voter.
    sparse: only keep-flagged events cross the host link, as a packed
        (flat index, score) pair; dropped events never materialize on the
        host and the report carries measured bytes-on-wire. Drained
        results then contain ONLY kept events.
    pipeline_depth: batches kept in flight on the device while the host
        prepares the next (2 = triple buffering, 1 = double buffering).
    threshold_electrons: per-pixel zero suppression of the frames->
        features stage (frames ingestion only).
    bits_per_hit / hit_rate_hz: link-budget accounting for the report.
    """

    max_batch: int = 2048
    max_latency_s: float = 5e-3
    backend: str = "kernel"
    batch_tile: int = 128
    band: Optional[bool] = None
    redundancy: str = "none"
    sparse: bool = False
    pipeline_depth: int = 2
    threshold_electrons: float = 800.0
    bits_per_hit: int = 256
    hit_rate_hz: float = 40e6

    def __post_init__(self):
        if not (isinstance(self.max_batch, int) and self.max_batch > 0):
            raise ValueError(f"max_batch must be a positive int, got "
                             f"{self.max_batch!r}")
        if self.max_latency_s <= 0:
            raise ValueError(f"max_latency_s must be > 0, got "
                             f"{self.max_latency_s!r}")
        if not (isinstance(self.batch_tile, int) and self.batch_tile > 0
                and self.batch_tile % 128 == 0):
            raise ValueError(
                f"batch_tile must be a positive multiple of 128 (the TPU "
                f"lane width), got {self.batch_tile!r}")
        if self.backend not in ("kernel", "host"):
            raise ValueError(f"unknown backend {self.backend!r} "
                             "(expected 'kernel' or 'host')")
        if self.redundancy not in ("none", "tmr"):
            raise ValueError(f"unknown redundancy {self.redundancy!r} "
                             "(expected 'none' or 'tmr')")
        if not isinstance(self.sparse, bool):
            raise ValueError(f"sparse must be a bool, got {self.sparse!r}")
        if not (isinstance(self.pipeline_depth, int)
                and self.pipeline_depth >= 1):
            raise ValueError(f"pipeline_depth must be an int >= 1, got "
                             f"{self.pipeline_depth!r}")
        if self.threshold_electrons < 0:
            raise ValueError(f"threshold_electrons must be >= 0, got "
                             f"{self.threshold_electrons!r}")

    @property
    def n_replicas(self) -> int:
        return N_REPLICAS if self.redundancy == "tmr" else 1


@dataclasses.dataclass(frozen=True)
class ScoredEvent:
    seq: int          # submission order (global, monotone)
    chip: int
    score_raw: int    # integer-domain fabric score (voted under TMR)
    keep: bool        # False = classified as pileup, dropped at source


@dataclasses.dataclass
class ChipStreamStats:
    """Running trigger/reduction accounting for one chip slot."""

    n_in: int = 0
    n_kept: int = 0
    n_dispatches: int = 0
    # per-replica SEU health: events where replica r's output word was
    # voted against (always zeros on a healthy or non-redundant server)
    disagreements: List[int] = dataclasses.field(default_factory=list)

    def fraction_kept(self) -> float:
        return self.n_kept / self.n_in if self.n_in else 1.0


# (seq, chip, kind, payload, t_enqueue); payload is a features row for
# kind="features", an (frame, y0) pair for kind="frames".
_Event = Tuple[int, int, str, object, float]
# (kind, pending, per_chip_seq, counts). Both ingestion stages converge
# on the same two inflight kinds:
#   "scored": pending = (score (C,B), keep (C,B), disagree (C,R)) —
#       device arrays on the kernel backend (materialized at drain),
#       numpy on the host oracle;
#   "sparse": pending = (count, idx, vals, disagree (C,R), B) — the
#       packed keep-flagged events; only the count-prefix of idx/vals
#       crosses the host link at drain time.
_Inflight = Tuple[str, object, List[List[int]], List[int]]


class ReadoutServer:
    """Serves N configured ReadoutChips from one micro-batched event loop."""

    def __init__(
        self,
        chips: Sequence[ReadoutChip],
        config: ServerConfig = ServerConfig(),
        clock=time.monotonic,
    ):
        if not chips:
            raise ValueError("need at least one chip")
        self.chips: List[ReadoutChip] = list(chips)
        self.config = config
        self._clock = clock
        # Scores decode on DEVICE (two's-complement int32) on the kernel
        # backend; enforce the width bound on both backends so a
        # deployment validated on the host oracle cannot overflow on the
        # kernel.
        for i, c in enumerate(self.chips):
            if len(c.config.output_nets) > 31:
                raise ValueError(
                    f"device score decode is int32: chip {i} has "
                    f"{len(c.config.output_nets)} output bits > 31")
        # the server's FIXED envelope: set at construction, never shrinks.
        # Both backends validate hot-swaps against it — including the
        # fan-in-reach budget a banded kernel stack depends on — so a
        # deployment validated on the host oracle behaves identically on
        # the kernel. The budget mirrors the stack's actual band choice:
        # a dense stack (config.band=False, or reach >= levels) carries
        # none, so forcing dense keeps full hot-swap flexibility. The
        # envelope also carries the feature-stage contract: every server
        # can ingest raw frames, so a hot-swapped chip must be encodable
        # from the featurizer's output (checked in ``reconfigure``).
        # TMR replication is envelope-invariant (placement rotation
        # changes neither level sizes, widths nor reach), so one geometry
        # covers every replica slot.
        geo = check_stackable([c.config for c in self.chips])
        banded = (
            config.band is not False
            and (geo.fanin_reach or geo.n_levels) < geo.n_levels
        )
        self.geometry: StackGeometry = dataclasses.replace(
            geo if banded else dataclasses.replace(geo, fanin_reach=None),
            frontend=FrontendSpec(
                n_features=_N_FEATURES,
                frame_shape=(N_T, N_Y, N_X),
                threshold_electrons=config.threshold_electrons,
            ),
        )
        self.n_replicas = config.n_replicas
        # the SERVED replica encodings, slot-major: replica r of chip c is
        # _replica_configs[c*R + r]. This is the injection surface of
        # ``inject_seu`` and the source of the host oracle's simulators,
        # so both backends agree on every replica's config image.
        self._replica_configs: List = [
            replicate_config(c.config, r)
            for c in self.chips for r in range(self.n_replicas)
        ]
        # integer trigger cuts, baked per slot (refreshed on reconfigure)
        # so both backends cut on the same value for a given dispatch.
        self._thr_raw = np.array(
            [c.score_threshold_raw for c in self.chips], np.int32)
        self._stack = None
        self._frontend = None  # fused frames dispatch, built on first use
        self._mesh = None
        if config.backend == "kernel":
            from repro.kernels.lut_eval import ops as lut_ops
            from repro.launch.mesh import make_readout_mesh

            self._lut_ops = lut_ops
            self._stack = lut_ops.pack_fabrics(
                [c.config for c in self.chips], band=config.band,
                redundancy=config.redundancy,
            )
            # ONE readout mesh for both ingestion stages: the features
            # path shards its scoring dispatch over the same "chips" axis
            # as the fused frames frontend.
            self._mesh = make_readout_mesh(self.n_chips)
            self._out_weight = lut_ops.decode_plan(
                [c.config for c in self.chips], self._stack.n_outputs)
        else:
            self._multisim = MultiFabricSim(
                self._replica_configs, geometry=self.geometry)

        self._queue: Deque[_Event] = collections.deque()
        self._seq = 0
        # per-slot FabricSim cache (one sim per replica) for the staged
        # (host) frames path — pure function of the slot's replica
        # configs, invalidated on reconfigure/inject_seu, so repeated
        # dispatches don't re-pay construction (and the staged_score
        # stage timing stays honest).
        self._frame_sims: List[Optional[List[FabricSim]]] = (
            [None] * len(self.chips))
        # the pipeline: up to config.pipeline_depth batches on the device
        self._inflight: Deque[_Inflight] = collections.deque()
        self._stats = [
            ChipStreamStats(disagreements=[0] * self.n_replicas)
            for _ in self.chips
        ]
        self._stage_s: Dict[str, float] = collections.defaultdict(float)
        self._stage_n: Dict[str, int] = collections.defaultdict(int)
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None
        self._n_scored = 0
        # measured host-link accounting (bytes actually materialized on
        # the sparse wire vs the dense equivalent for the same events)
        self._link_bytes_sparse = 0
        self._link_bytes_dense = 0

    # ------------------------------------------------------------- intake
    @property
    def n_chips(self) -> int:
        return len(self.chips)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def submit(self, chip: int, features: np.ndarray) -> int:
        """Enqueue one pre-featurized event for one chip; returns its seq."""
        assert 0 <= chip < self.n_chips, chip
        seq = self._seq
        self._seq += 1
        self._queue.append(
            (seq, chip, "features", np.asarray(features, np.float64),
             self._clock())
        )
        return seq

    def submit_batch(self, chip: int, X: np.ndarray) -> List[int]:
        """Enqueue a block of pre-featurized events (rows of X)."""
        return [self.submit(chip, row) for row in np.asarray(X)]

    def submit_frames(
        self, chip: int, frames: np.ndarray, y0: np.ndarray
    ) -> List[int]:
        """Enqueue raw-frame events: (n, T, Y, X) charge + (n,) y0.

        These score through the frames pipeline — on the kernel backend
        the FUSED single-dispatch frontend, on the host backend the same
        pipeline staged. Mixing frames and features for the same chip in
        one micro-batch is allowed but scores as two dispatch groups, so
        cross-kind result order within that batch follows the groups, not
        the global seq order (every event stays seq-tagged).
        """
        assert 0 <= chip < self.n_chips, chip
        frames = np.asarray(frames, np.float32)
        y0 = np.asarray(y0, np.float32)
        assert frames.ndim == 4 and frames.shape[1:] == (N_T, N_Y, N_X), \
            frames.shape
        assert len(frames) == len(y0), (len(frames), len(y0))
        seqs = []
        now = self._clock()
        for i in range(len(frames)):
            seq = self._seq
            self._seq += 1
            self._queue.append(
                (seq, chip, "frames", (frames[i], float(y0[i])), now))
            seqs.append(seq)
        return seqs

    # ------------------------------------------------------------ the loop
    def poll(self) -> List[ScoredEvent]:
        """One turn of the event loop: dispatch if a micro-batch is due,
        and return any newly completed results (seq-ordered per batch)."""
        out: List[ScoredEvent] = []
        if self._due():
            out.extend(self._dispatch(self._coalesce()))
        return out

    def flush(self) -> List[ScoredEvent]:
        """Force out everything: queued events and in-flight results."""
        out: List[ScoredEvent] = []
        while self._queue:
            out.extend(self._dispatch(self._coalesce()))
        out.extend(self._drain_all())
        return out

    def score_stream(
        self, batches: Iterable[Tuple[int, np.ndarray]]
    ) -> Iterable[List[ScoredEvent]]:
        """Drive the loop over an iterable of (chip, features-block) pairs,
        yielding completed results as they become available."""
        for chip, X in batches:
            self.submit_batch(chip, X)
            got = self.poll()
            if got:
                yield got
        tail = self.flush()
        if tail:
            yield tail

    def _due(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.config.max_batch:
            return True
        oldest = self._queue[0][4]
        return (self._clock() - oldest) >= self.config.max_latency_s

    def _coalesce(self) -> List[_Event]:
        take = min(len(self._queue), self.config.max_batch)
        return [self._queue.popleft() for _ in range(take)]

    def _stage(self, key: str, t0: float) -> None:
        self._stage_s[key] += self._clock() - t0
        self._stage_n[key] += 1

    def _dispatch(self, events: List[_Event]) -> List[ScoredEvent]:
        """Launch one micro-batch and return any batches the pipeline
        retired: with the kernel backend dispatches are asynchronous, so
        up to ``pipeline_depth`` batches stay on the device while the
        host prepares the next (triple buffering at the default depth 2).
        """
        if not events:
            return []
        if self._t_start is None:
            self._t_start = self._clock()

        frame_events = [e for e in events if e[2] == "frames"]
        feat_events = [e for e in events if e[2] == "features"]
        if frame_events:
            self._inflight.append(self._launch_frames(frame_events))
        if feat_events:
            self._inflight.append(self._launch_features(feat_events))

        done: List[ScoredEvent] = []
        while len(self._inflight) > self.config.pipeline_depth:
            done.extend(self._drain_one())
        return done

    def _group(
        self, events: List[_Event]
    ) -> Tuple[List[List[int]], List[List[object]], List[int]]:
        per_chip_seq: List[List[int]] = [[] for _ in self.chips]
        per_chip_payload: List[List[object]] = [[] for _ in self.chips]
        for seq, chip, _, payload, _ in events:
            per_chip_seq[chip].append(seq)
            per_chip_payload[chip].append(payload)
        counts = [len(s) for s in per_chip_seq]
        for i, n in enumerate(counts):
            if n:
                self._stats[i].n_dispatches += 1
        return per_chip_seq, per_chip_payload, counts

    def _valid_mask(self, counts: List[int], B: int) -> np.ndarray:
        """(C, B) bool: True on real event rows, False on zero-padding —
        the mask that keeps phantom padded events out of the keep/drop
        decisions, the sparse pack and the disagreement counters."""
        return (np.arange(max(B, 1))[None, :]
                < np.asarray(counts)[:, None])

    def _finish_launch(
        self, score, keep, disagree, per_chip_seq, counts
    ) -> _Inflight:
        """Common output stage: dense (score, keep) or the sparse packed
        (indices, scores) pair. On the kernel backend the pack is one
        extra device dispatch, still asynchronous — nothing materializes
        until the drain."""
        if not self.config.sparse:
            return ("scored", (score, keep, disagree), per_chip_seq, counts)
        t0 = self._clock()
        B = int(np.shape(keep)[1])
        if self.config.backend == "kernel":
            from repro.parallel.compression import sparse_trigger_pack_jit

            count, idx, vals = sparse_trigger_pack_jit(score, keep)
        else:
            flat = np.asarray(keep).ravel()
            idx = np.flatnonzero(flat).astype(np.int32)
            vals = np.asarray(score).ravel()[idx].astype(np.int32)
            count = len(idx)
        self._stage("sparse_pack", t0)
        return ("sparse", (count, idx, vals, disagree, B),
                per_chip_seq, counts)

    def _launch_features(self, events: List[_Event]) -> _Inflight:
        """Features path: host featurization (quantize + offset-binary bit
        packing, timed as ``encode_host``) into ONE sharded chip-batched
        scoring dispatch — fabric evaluation (all replicas), majority
        vote, score decode and trigger cut all on device
        (lut_eval.ops.fabric_eval_multi_scored), chip axis over the
        readout mesh."""
        per_chip_seq, per_chip_X, counts = self._group(events)

        t0 = self._clock()
        per_chip_bits: List[np.ndarray] = []
        for i, chip in enumerate(self.chips):
            if per_chip_X[i]:
                bits = chip.encode_features(np.stack(per_chip_X[i]))
            else:
                bits = np.zeros((0, chip.config.n_inputs), np.uint8)
            per_chip_bits.append(bits)
        self._stage("encode_host", t0)

        t0 = self._clock()
        B = max(counts) if counts else 0
        valid = self._valid_mask(counts, B)
        if self.config.backend == "kernel":
            stacked = self._lut_ops.stack_input_bits(self._stack, per_chip_bits)
            score, keep, dis = self._lut_ops.fabric_eval_multi_scored(
                self._stack, stacked, self._out_weight, self._thr_raw,
                valid=valid, mesh=self._mesh,
                batch_tile=self.config.batch_tile,
            )  # async on device; NOT materialized yet
        else:
            stacked = stack_event_bits(per_chip_bits, self.geometry.n_inputs)
            score, keep, dis = self._score_bits_host(stacked, valid)
        self._stage("launch_score", t0)
        return self._finish_launch(score, keep, dis, per_chip_seq, counts)

    def _score_bits_host(
        self, stacked: np.ndarray, valid: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The numpy oracle of the device scoring stage: evaluate every
        replica (MultiFabricSim over the served replica configs), vote
        with THE SAME core.tmr.majority_vote, decode two's-complement
        scores, cut, count disagreements — bit-identical by construction."""
        C, B = stacked.shape[0], stacked.shape[1]
        R = self.n_replicas
        rep = np.repeat(stacked, R, axis=0) if R > 1 else stacked
        outs = self._multisim.run(rep)                  # (R*C, B, O)
        g = outs.reshape(C, R, B, outs.shape[-1])
        if R > 1:
            voted = majority_vote(g[:, 0], g[:, 1], g[:, 2])
            disagree = (g != voted[:, None]).any(-1)    # (C, R, B)
        else:
            voted = g[:, 0]
            disagree = np.zeros((C, 1, B), bool)
        score = np.zeros((C, B), np.int64)
        for i, chip in enumerate(self.chips):
            n_out = len(chip.config.output_nets)
            score[i] = chip.synth.decode_outputs(voted[i, :, :n_out])
        keep = (score <= self._thr_raw[:, None]) & valid
        dis = (disagree & valid[:, None, :]).sum(-1).astype(np.int64)
        return score, keep, dis

    def _launch_frames(self, events: List[_Event]) -> _Inflight:
        """Frames path. Kernel backend: ONE fused dispatch over the
        sharded chip axis (timed ``launch_fused`` — featurize, quantize,
        pack, replica evaluation, vote and score all live inside it,
        invisible to the host by design). Host backend: the same
        pipeline STAGED, each stage materialized and timed
        (``staged_featurize`` / ``staged_encode`` / ``staged_score``) —
        the breakdown the fused path removes.
        """
        per_chip_seq, per_chip_fy, counts = self._group(events)
        cfg = self.config
        B = max(counts) if counts else 0
        valid = self._valid_mask(counts, B)

        if cfg.backend == "kernel":
            t0 = self._clock()
            frames = np.zeros((self.n_chips, B, N_T, N_Y, N_X), np.float32)
            y0 = np.zeros((self.n_chips, B), np.float32)
            for i, rows in enumerate(per_chip_fy):
                if rows:  # one vectorized copy per chip, not per event
                    frames[i, : len(rows)] = np.stack([fr for fr, _ in rows])
                    y0[i, : len(rows)] = [z for _, z in rows]
            self._stage("stack_frames", t0)

            t0 = self._clock()
            score, keep, dis = self._get_frontend().score_frames_voted(
                frames, y0, valid=valid)
            self._stage("launch_fused", t0)
            return self._finish_launch(score, keep, dis, per_chip_seq, counts)

        # host backend: staged oracle, per chip, one sim per replica
        R = self.n_replicas
        score = np.zeros((self.n_chips, B), np.int64)
        disagree = np.zeros((self.n_chips, R, B), bool)
        for i, chip in enumerate(self.chips):
            if not per_chip_fy[i]:
                continue
            n = counts[i]
            frames_i = np.stack([fr for fr, _ in per_chip_fy[i]])
            y0_i = np.asarray([z for _, z in per_chip_fy[i]], np.float32)
            t0 = self._clock()
            from repro.kernels.yprofile import ops as yp_ops

            feats = np.asarray(yp_ops.yprofile(
                frames_i, y0_i, threshold_electrons=cfg.threshold_electrons,
                batch_tile=cfg.batch_tile))
            self._stage("staged_featurize", t0)
            t0 = self._clock()
            bits = chip.encode_features(feats)
            self._stage("staged_encode", t0)
            t0 = self._clock()
            if self._frame_sims[i] is None:
                self._frame_sims[i] = [
                    FabricSim(self._replica_configs[i * R + r])
                    for r in range(R)
                ]
            g = np.stack(
                [np.asarray(sim.run(bits)[0]) for sim in self._frame_sims[i]]
            )                                           # (R, n, O_i)
            if R > 1:
                voted = majority_vote(g[0], g[1], g[2])
                disagree[i, :, :n] = (g != voted[None]).any(-1)
            else:
                voted = g[0]
            score[i, :n] = chip.synth.decode_outputs(voted)
            self._stage("staged_score", t0)
        keep = (score <= self._thr_raw[:, None]) & valid
        dis = (disagree & valid[:, None, :]).sum(-1).astype(np.int64)
        return self._finish_launch(score, keep, dis, per_chip_seq, counts)

    def _get_frontend(self):
        if self._frontend is None:
            from repro.kernels import frontend as fe

            self._frontend = fe.pack_frontend(
                [c.config for c in self.chips],
                [c.frontend_spec() for c in self.chips],
                band=self.config.band,
                redundancy=self.config.redundancy,
                batch_tile=self.config.batch_tile,
                threshold_electrons=self.config.threshold_electrons,
                mesh=self._mesh,
                stack=self._stack,  # share the server's packed arrays
            )
        return self._frontend

    def _drain_one(self) -> List[ScoredEvent]:
        """Materialize the OLDEST in-flight batch and fold it into the
        reports (``drain_wait`` is the host-visible blocking time). With
        sparse readout only the count-prefix of the packed (idx, score)
        pair crosses the host link — the measured wire bytes."""
        if not self._inflight:
            return []
        kind, pending, per_chip_seq, counts = self._inflight.popleft()
        t0 = self._clock()

        results: List[ScoredEvent] = []
        n_events = int(sum(counts))
        if kind == "sparse":
            count, idx, vals, dis, B = pending
            n_kept = int(np.asarray(count))             # blocks here
            idx_h = np.asarray(idx[:n_kept]).astype(np.int64)
            vals_h = np.asarray(vals[:n_kept]).astype(np.int64)
            self._link_bytes_sparse += (
                SPARSE_HEADER_BYTES + SPARSE_BYTES_PER_EVENT * n_kept)
            self._link_bytes_dense += DENSE_BYTES_PER_EVENT * n_events
            kept_per_chip = np.bincount(
                idx_h // max(B, 1), minlength=self.n_chips)
            for i, st in enumerate(self._stats):
                st.n_in += counts[i]
                st.n_kept += int(kept_per_chip[i])
            for k, v in zip(idx_h, vals_h):
                chip, pos = int(k) // B, int(k) % B
                results.append(ScoredEvent(
                    seq=per_chip_seq[chip][pos], chip=chip,
                    score_raw=int(v), keep=True))
            self._fold_disagreements(dis)
        else:  # "scored"
            score, keep, dis = pending
            score = np.asarray(score)                   # blocks here
            keep = np.asarray(keep)
            self._link_bytes_dense += DENSE_BYTES_PER_EVENT * n_events
            for i in range(self.n_chips):
                n = counts[i]
                if not n:
                    continue
                self._fold_chip(results, i, per_chip_seq[i],
                                score[i, :n].astype(np.int64), keep[i, :n])
            self._fold_disagreements(dis)

        self._stage("drain_wait", t0)
        self._n_scored += len(results)
        self._t_last = self._clock()
        results.sort(key=lambda r: r.seq)
        return results

    def _fold_chip(self, results, i, seqs, scores, keep) -> None:
        st = self._stats[i]
        st.n_in += len(seqs)
        st.n_kept += int(np.asarray(keep).sum())
        for j, seq in enumerate(seqs):
            results.append(
                ScoredEvent(seq=seq, chip=i, score_raw=int(scores[j]),
                            keep=bool(keep[j]))
            )

    def _fold_disagreements(self, dis) -> None:
        dis = np.asarray(dis)                           # (C, R)
        for i, st in enumerate(self._stats):
            st.disagreements = [
                a + int(b) for a, b in zip(st.disagreements, dis[i])
            ]

    def _drain_all(self) -> List[ScoredEvent]:
        out: List[ScoredEvent] = []
        while self._inflight:
            out.extend(self._drain_one())
        return out

    # ------------------------------------------------------- reconfigure
    def reconfigure(self, slot: int, new_chip: ReadoutChip) -> List[ScoredEvent]:
        """Hot-swap slot's bitstream: array swap, no recompile.

        Pending events are flushed first (they were submitted against the
        old configuration); returns their results. The new config must fit
        the server's fixed envelope — enforced identically on both
        backends, and ``self.geometry`` never changes, so callers can keep
        pre-checking candidates with ``server.geometry.admits(cfg)``. When
        the fused frames frontend is live, the swap also replaces the
        chip's encode-plan row (used features, ap_fixed spec, trigger
        cut), still with no retrace. Under TMR all three replica slots
        are re-encoded from the new bitstream.
        """
        assert 0 <= slot < self.n_chips, slot
        cfg = new_chip.config
        if cfg.n_ffs or not self.geometry.admits(cfg):
            raise ValueError(
                f"new config does not fit server envelope {self.geometry} "
                f"(levels={len(cfg.level_sizes)}, "
                f"widest={max(cfg.level_sizes, default=1)}, "
                f"inputs={cfg.n_inputs}, outputs={len(cfg.output_nets)}, "
                f"ffs={cfg.n_ffs}, fanin_reach={cfg.fanin_reach()})"
            )
        # feature-stage contract: enforced on BOTH backends at swap time
        # (same promise as admits, for the featurizer axes) — not deferred
        # to an index error inside a later frames dispatch.
        from repro.kernels.frontend import validate_chip_frontend

        validate_chip_frontend(cfg, new_chip.frontend_spec(),
                               self.geometry.frontend.n_features)
        done = self.flush()
        R = self.n_replicas
        self._replica_configs[slot * R : (slot + 1) * R] = [
            replicate_config(cfg, r) for r in range(R)
        ]
        self.chips[slot] = new_chip
        self._thr_raw = np.array(
            [c.score_threshold_raw for c in self.chips], np.int32)
        if self.config.backend == "kernel":
            self._stack = self._stack.swap_chip(slot, cfg)
            self._out_weight = self._lut_ops.decode_plan(
                [c.config for c in self.chips], self._stack.n_outputs)
            if self._frontend is not None:
                self._frontend = self._frontend.swap_chip(
                    slot, cfg, new_chip.frontend_spec(), stack=self._stack)
        self._frame_sims[slot] = None
        if self.config.backend == "host":
            self._multisim = MultiFabricSim(
                self._replica_configs, geometry=self.geometry)
        return done

    # ----------------------------------------------------- fault injection
    def inject_seu(self, slot: int, replica: int, lut_index: int,
                   bit: int) -> None:
        """Flip one configuration bit of ONE served replica — the
        fault-injection port of the SEU campaign (tests/test_seu.py).

        ``lut_index``/``bit`` address the replica's OWN decoded bitstream
        (its placement-rotated encoding), exactly as a configuration-
        memory upset would. Takes effect on the next dispatch; batches
        already in flight scored against the pre-fault arrays, which is
        what a real upset does too. Works on both backends (the host
        oracle's simulators are rebuilt from the same perturbed config),
        and on a non-redundant server (replica 0) as the unprotected
        negative control. Repeated calls accumulate flips.
        """
        assert 0 <= slot < self.n_chips, slot
        R = self.n_replicas
        if not 0 <= replica < R:
            raise ValueError(f"replica must be in [0, {R}), got {replica!r}")
        i = slot * R + replica
        self._replica_configs[i] = _inject_seu_config(
            self._replica_configs[i], lut_index, bit)
        if self.config.backend == "kernel":
            if R > 1:
                self._stack = self._stack.swap_replica(
                    slot, replica, self._replica_configs[i])
            else:
                self._stack = self._stack.swap_chip(
                    slot, self._replica_configs[i])
            if self._frontend is not None:
                self._frontend = dataclasses.replace(
                    self._frontend, stack=self._stack)
        else:
            # only the flipped replica's simulator rebuilds — a sweep
            # flips thousands of bits, a fleet rebuild per flip won't do
            self._multisim.swap_config(i, self._replica_configs[i])
        self._frame_sims[slot] = None

    # ------------------------------------------------------------ report
    def report(self) -> Dict[str, object]:
        """Per-chip trigger/reduction accounting aggregated over the
        stream, plus the per-stage host-side timing breakdown (seconds and
        call counts per pipeline stage — for fused frames dispatches the
        featurize/quantize/pack/vote/score stages are a single
        ``launch_fused`` entry by design; the staged host path itemizes
        them), the per-replica SEU disagreement counters, and the
        measured host-link bytes (sparse wire vs dense equivalent)."""
        cfg = self.config
        per_chip = []
        for i, st in enumerate(self._stats):
            frac = st.fraction_kept()
            per_chip.append({
                "chip": i,
                "n_in": st.n_in,
                "n_kept": st.n_kept,
                "n_dispatches": st.n_dispatches,
                "fraction_kept": frac,
                "data_reduction_factor": 1.0 / max(frac, 1e-9),
                "link_rate_in_gbps": cfg.hit_rate_hz * cfg.bits_per_hit / 1e9,
                "link_rate_out_gbps":
                    cfg.hit_rate_hz * cfg.bits_per_hit * frac / 1e9,
                "seu_disagreements": list(st.disagreements),
            })
        n_in = sum(s.n_in for s in self._stats)
        n_kept = sum(s.n_kept for s in self._stats)
        dt = (
            (self._t_last - self._t_start)
            if (self._t_start is not None and self._t_last is not None)
            else 0.0
        )
        wire = (self._link_bytes_sparse if cfg.sparse
                else self._link_bytes_dense)
        return {
            "backend": cfg.backend,
            "redundancy": cfg.redundancy,
            "n_replicas": self.n_replicas,
            "sparse": cfg.sparse,
            "n_chips": self.n_chips,
            "n_in": n_in,
            "n_kept": n_kept,
            "fraction_kept": n_kept / n_in if n_in else 1.0,
            "events_per_s": n_in / dt if dt > 0 else float("nan"),
            "queue_depth": self.queue_depth,
            "inflight_batches": len(self._inflight),
            "seu_disagreement_total": int(
                sum(sum(s.disagreements) for s in self._stats)),
            "link_bytes": {
                "on_wire": wire,
                "dense_equivalent": self._link_bytes_dense,
                "wire_reduction": (
                    self._link_bytes_dense / self._link_bytes_sparse
                    if cfg.sparse and self._link_bytes_sparse else 1.0),
            },
            "stages": {
                k: {"seconds": self._stage_s[k], "calls": self._stage_n[k]}
                for k in sorted(self._stage_s)
            },
            "per_chip": per_chip,
        }
