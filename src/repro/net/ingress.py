"""Asyncio multi-producer network front door for the readout server.

Many sensor clients — TCP streams and UDP datagrams — feed ONE
``ReadoutServer`` through a bounded ingest queue. The data path is a
synchronous core (``feed`` / ``feed_datagram`` / ``pump``) that the thin
asyncio shell (``start`` / ``stop``) drives, so every queue/accounting
behavior is unit-testable without sockets and the event loop never does
more than move bytes.

Design points (mirroring the serving loop's own rules):

* **Bounded queue, drop-and-count.** The ingest queue is bounded in
  EVENTS (``FrontDoorConfig.queue_events``). A batch arriving at
  capacity is dropped whole and counted per client
  (``events_queue_dropped``) — ``feed`` never blocks the transport and
  the queue never grows unboundedly. Backpressure is loss + accounting,
  exactly like the server's own admission control one layer down.
* **Per-client sequence accounting.** Every client message carries a
  seq; the front door tracks gaps (presumed-lost), reorders (a gap
  later filled by a late arrival — the gap count is repaid), and
  duplicates (dropped). FLUSH participates in the same sequence, so a
  tail drop is visible as a gap when the flush arrives.
* **Dense server, sparse wire.** The front door drives the server with
  ``sparse=False`` — it needs every admitted event's (score, keep) back
  to know when a client batch is complete — and performs the sparse
  (indices, scores) reduction AT THE WIRE via
  ``protocol.encode_trigger_batch`` (byte-compatible with
  ``parallel/compression.py``'s pack). Dropped events still never cross
  the socket; the in-process hop is host RAM, not the scarce link.
* **Accounting surfaces in ``report()["net"]``** via
  ``ReadoutServer.attach_net_stats``.

The accounting identity the tests pin down (per client, once drained)::

    events_in == events_admitted + events_shed
               + events_queue_dropped + events_bad_sensor
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import queue
import threading
from typing import Callable, Deque, Dict, Hashable, List, Mapping, Optional, Tuple

import numpy as np

from repro.net import protocol as P

# a client that falls further than this many messages behind its own
# max-seen seq stops being tracked hole-by-hole (the hole set is
# bounded; older holes become permanent seq_gaps)
_MAX_TRACKED_HOLES = 4096


@dataclasses.dataclass(frozen=True)
class FrontDoorConfig:
    """Knobs of the front door, validated on construction.

    queue_events: ingest queue capacity in EVENTS (not batches) — at
        capacity a whole arriving batch is dropped and counted.
    idle_sleep_s: asyncio pump's sleep when a turn moved nothing.
    offload_decode: run CRC verification + payload decode on a worker
        thread instead of the event loop (asyncio shell only; the
        synchronous ``feed``/``feed_datagram`` API is never offloaded).
        zlib and the numpy payload copy release the GIL, so the wire
        checksum work overlaps the serving loop on another core —
        decoded messages are handed back to the loop thread, so ALL
        accounting still happens single-threaded and stays exact.
    sensor_tenants: wire sensor_id -> serving-target key. ``None``
        (default) keeps the single-server identity routing: sensor_id
        IS the chip slot, bounds-checked against ``server.n_chips``.
        Set it to front a multi-tenant fleet (launch/fleet.py): each
        sensor maps onto a fleet tenant key, unmapped sensors (and
        sensors whose tenant is retired — ``has_tenant`` is consulted
        when the target offers it) count as ``events_bad_sensor``
        instead of crashing the pump.
    """

    queue_events: int = 8192
    idle_sleep_s: float = 500e-6
    offload_decode: bool = True
    sensor_tenants: Optional[Mapping[int, Hashable]] = None

    def __post_init__(self):
        if not (isinstance(self.queue_events, int)
                and self.queue_events > 0):
            raise ValueError(f"queue_events must be a positive int, got "
                             f"{self.queue_events!r}")
        if self.idle_sleep_s <= 0:
            raise ValueError(f"idle_sleep_s must be > 0, got "
                             f"{self.idle_sleep_s!r}")
        if self.sensor_tenants is not None and not isinstance(
                self.sensor_tenants, Mapping):
            raise ValueError(
                f"sensor_tenants must be a mapping (sensor_id -> tenant) "
                f"or None, got {self.sensor_tenants!r}")


class _Client:
    """Per-connection state: decoder, seq window, counters, pending
    (submitted but not yet fully scored) batches."""

    __slots__ = (
        "key", "send", "decoder", "max_seq", "holes", "pending",
        "flush_waiting", "tx_seq", "counters", "udp_errors",
        "bytes_in", "bytes_out", "triggers_out", "events_kept",
        "connected",
    )

    def __init__(self, key: str, send: Callable[[bytes], None],
                 stream: bool):
        self.key = key
        self.send = send
        self.decoder = P.StreamDecoder() if stream else None
        self.max_seq = -1            # highest seq seen from this client
        self.holes: set = set()      # seqs < max_seq never seen (gaps)
        self.pending: Dict[int, "_PendingBatch"] = {}
        self.flush_waiting: List[int] = []
        self.tx_seq = 0
        self.udp_errors = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.triggers_out = 0
        self.events_kept = 0
        self.connected = True
        self.counters = {
            "batches_in": 0, "events_in": 0, "events_admitted": 0,
            "events_shed": 0, "events_queue_dropped": 0,
            "events_bad_sensor": 0, "seq_gaps": 0, "reorders": 0,
            "duplicates": 0,
        }

    def track_seq(self, seq: int) -> bool:
        """Slide the per-client sequence window. Returns False for a
        duplicate (caller drops the message). A hole opened by a skip
        counts as a gap immediately; a late arrival that fills a hole
        repays the gap and counts as a reorder."""
        c = self.counters
        if seq > self.max_seq:
            skipped = seq - self.max_seq - 1
            if skipped:
                c["seq_gaps"] += skipped
                self.holes.update(range(self.max_seq + 1, seq))
                while len(self.holes) > _MAX_TRACKED_HOLES:
                    self.holes.remove(min(self.holes))  # permanent loss
            self.max_seq = seq
            return True
        if seq in self.holes:
            self.holes.remove(seq)
            c["seq_gaps"] -= 1      # not lost after all, just late
            c["reorders"] += 1
            return True
        c["duplicates"] += 1
        return False

    def ack_counters(self) -> Dict[str, int]:
        derr = (self.decoder.errors_total if self.decoder else 0) \
            + self.udp_errors
        rs = self.decoder.resyncs if self.decoder else 0
        out = dict(self.counters)
        out.pop("events_bad_sensor")
        out["decode_errors"] = derr
        out["resyncs"] = rs
        return out


class _PendingBatch:
    """One submitted FRAME_BATCH awaiting its scored events."""

    __slots__ = ("sensor_id", "n_events", "n_admitted", "got")

    def __init__(self, sensor_id: int, n_events: int):
        self.sensor_id = sensor_id
        self.n_events = n_events
        self.n_admitted = 0
        self.got: List[Tuple[int, int, bool]] = []   # (pos, score, keep)


class ReadoutFrontDoor:
    """The multi-producer ingest adapter in front of one ReadoutServer.

    Synchronous core API (unit tests, and what the asyncio shell calls):

    * ``client_connect(key, send)`` / ``client_disconnect(key)``
    * ``feed(key, data)`` — TCP byte stream (any chunking)
    * ``feed_datagram(key, data)`` — one UDP datagram
    * ``pump()`` — one non-blocking turn: submit queued batches, poll
      the server, route finished scores back out as TRIGGER_BATCHes
    * ``drain()`` — force everything through (blocking; end of stream)
    * ``stats()`` — the ``report()["net"]`` payload
    """

    def __init__(self, server, config: FrontDoorConfig = FrontDoorConfig()):
        if server.config.sparse:
            raise ValueError(
                "the front door needs the server dense (sparse=False): "
                "it must see every admitted event's score to complete a "
                "client batch, and performs the sparse reduction at the "
                "wire itself (protocol.encode_trigger_batch)")
        self.server = server
        self.config = config
        self._clients: Dict[str, _Client] = {}
        # (client key, decoded FRAME_BATCH) | (client key, flush seq)
        self._ingest: Deque[Tuple[str, object]] = collections.deque()
        self._ingest_events = 0
        # server seq -> (client key, client batch seq, position in batch)
        self._routes: Dict[int, Tuple[str, int, int]] = {}
        self._tcp_server = None
        self._udp_transport = None
        self._pump_task = None
        self._decode_q: Optional[queue.Queue] = None
        self._decode_thread: Optional[threading.Thread] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        attach = getattr(server, "attach_net_stats", None)
        if attach is not None:
            attach(self.stats)

    # ------------------------------------------------- synchronous core
    def client_connect(self, key: str, send: Callable[[bytes], None],
                       stream: bool = True) -> None:
        if key in self._clients:
            self._clients[key].connected = True
            self._clients[key].send = send
            return
        self._clients[key] = _Client(key, send, stream)

    def client_disconnect(self, key: str) -> None:
        st = self._clients.get(key)
        if st is not None:
            st.connected = False

    def feed(self, key: str, data: bytes) -> None:
        """TCP path: decode whatever the chunk completes; malformed
        frames are counted + resynced inside the decoder, never raised —
        the transport callback cannot crash and never blocks."""
        st = self._clients[key]
        st.bytes_in += len(data)
        for msg in st.decoder.feed(data):
            self._on_message(st, msg)

    def feed_datagram(self, key: str, data: bytes) -> None:
        """UDP path: one frame per datagram; garbage counts, never raises."""
        st = self._clients.get(key)
        if st is None:
            raise KeyError(f"unknown client {key!r} (connect first)")
        st.bytes_in += len(data)
        try:
            msg = P.decode_datagram(data)
        except P.ProtocolError:
            st.udp_errors += 1
            return
        self._on_message(st, msg)

    def _on_message(self, st: _Client, msg: P.Message) -> None:
        if msg.msg_type == P.MSG_FRAME_BATCH:
            if not st.track_seq(msg.seq):
                return                            # duplicate: dropped
            st.counters["batches_in"] += 1
            st.counters["events_in"] += msg.n_events
            if self._ingest_events + msg.n_events > self.config.queue_events:
                st.counters["events_queue_dropped"] += msg.n_events
                return                            # bounded queue: drop
            self._ingest.append((st.key, msg))
            self._ingest_events += msg.n_events
        elif msg.msg_type == P.MSG_FLUSH:
            if not st.track_seq(msg.seq):
                return
            # ordered with the data: the marker rides the same queue, so
            # every batch this client sent before the flush is submitted
            # before the ack fires (markers cost no event capacity)
            self._ingest.append((st.key, int(msg.seq)))
        else:
            # a client sending server-role messages is malformed traffic
            st.udp_errors += 1

    def _submit_key(self, sensor_id: int) -> Optional[Hashable]:
        """Resolve a wire sensor_id to the serving target's submit key:
        identity (bounds-checked chip slot) against a single server, or
        the configured tenant key against a fleet. None = bad sensor."""
        m = self.config.sensor_tenants
        if m is None:
            return sensor_id if sensor_id < self.server.n_chips else None
        tenant = m.get(sensor_id)
        if tenant is None:
            return None
        has = getattr(self.server, "has_tenant", None)
        if has is not None and not has(tenant):
            return None
        return tenant

    def _submit(self, st: _Client, msg: P.Message) -> None:
        key = self._submit_key(msg.sensor_id)
        if key is None:
            st.counters["events_bad_sensor"] += msg.n_events
            return
        pb = _PendingBatch(msg.sensor_id, msg.n_events)
        seqs = self.server.submit_frames(key, msg.frames, msg.y0)
        for pos, s in enumerate(seqs):
            if s is None:
                st.counters["events_shed"] += 1
            else:
                pb.n_admitted += 1
                self._routes[s] = (st.key, msg.seq, pos)
        st.counters["events_admitted"] += pb.n_admitted
        if pb.n_admitted == 0:
            self._emit_trigger(st, msg.seq, pb)   # all shed: answer now
        else:
            st.pending[msg.seq] = pb

    def pump(self) -> int:
        """One non-blocking turn. Returns the number of ingest items +
        scored events moved (0 = idle, the asyncio loop sleeps)."""
        moved = 0
        flush_due = False
        while self._ingest:
            key, item = self._ingest.popleft()
            st = self._clients[key]
            moved += 1
            if isinstance(item, int):
                st.flush_waiting.append(item)
                flush_due = True
                continue
            self._ingest_events -= item.n_events
            self._submit(st, item)
        results = self.server.poll()
        if flush_due or any(
                c.flush_waiting for c in self._clients.values()):
            # a flush marker crossed the queue: force the server to
            # retire everything (blocking — end-of-stream semantics)
            results.extend(self.server.flush())
        moved += self._route(results)
        self._emit_acks()
        return moved

    def drain(self) -> None:
        """Force every queued batch through and answer it (blocking)."""
        while self._ingest:
            self.pump()
        self._route(self.server.flush())
        self._emit_acks()

    def _route(self, results) -> int:
        done: List[Tuple[_Client, int, _PendingBatch]] = []
        for r in results:
            route = self._routes.pop(r.seq, None)
            if route is None:
                continue        # not network traffic (in-process submit)
            key, bseq, pos = route
            st = self._clients[key]
            pb = st.pending[bseq]
            pb.got.append((pos, int(r.score_raw), bool(r.keep)))
            if len(pb.got) == pb.n_admitted:
                done.append((st, bseq, st.pending.pop(bseq)))
        for st, bseq, pb in done:
            self._emit_trigger(st, bseq, pb)
        return len(results)

    def _emit_trigger(self, st: _Client, bseq: int,
                      pb: _PendingBatch) -> None:
        kept = sorted((pos, score) for pos, score, keep in pb.got if keep)
        idx = np.fromiter((p for p, _ in kept), np.int32, len(kept))
        scores = np.fromiter((s for _, s in kept), np.int32, len(kept))
        st.events_kept += len(kept)
        wire = P.encode_trigger_batch(
            pb.sensor_id, st.tx_seq, orig_seq=bseq,
            n_events=pb.n_events, n_admitted=pb.n_admitted,
            idx=idx, scores=scores)
        st.tx_seq += 1
        self._send(st, wire)
        st.triggers_out += 1

    def _emit_acks(self) -> None:
        for st in self._clients.values():
            if not st.flush_waiting or st.pending:
                continue
            for _ in st.flush_waiting:
                wire = P.encode_flush_ack(0, st.tx_seq, st.ack_counters())
                st.tx_seq += 1
                self._send(st, wire)
            st.flush_waiting.clear()

    def _send(self, st: _Client, wire: bytes) -> None:
        st.bytes_out += len(wire)
        if st.connected:
            st.send(wire)

    # -------------------------------------------------------- accounting
    def stats(self) -> Dict[str, object]:
        per_client = {}
        tot = collections.Counter()
        for key, st in sorted(self._clients.items()):
            c = st.ack_counters()
            c["events_bad_sensor"] = st.counters["events_bad_sensor"]
            c.update(bytes_in=st.bytes_in, bytes_out=st.bytes_out,
                     triggers_out=st.triggers_out,
                     events_kept=st.events_kept,
                     pending_batches=len(st.pending),
                     connected=st.connected)
            per_client[key] = c
            for k in ("batches_in", "events_in", "events_admitted",
                      "events_shed", "events_queue_dropped",
                      "events_bad_sensor", "seq_gaps", "reorders",
                      "duplicates", "decode_errors", "resyncs",
                      "bytes_in", "bytes_out", "events_kept"):
                tot[k] += c[k]
        return {
            "attached": True,
            "n_clients": len(self._clients),
            "queue_events": self._ingest_events,
            "queue_capacity": self.config.queue_events,
            "totals": dict(tot),
            "per_client": per_client,
        }

    # ----------------------------------------------------- asyncio shell
    async def start(self, host: str = "127.0.0.1", tcp_port: int = 0,
                    udp_port: Optional[int] = 0) -> None:
        """Bind the TCP listener (always) and the UDP endpoint (unless
        ``udp_port=None``), and start the pump task. Port 0 = ephemeral;
        read back via ``tcp_port`` / ``udp_port`` properties."""
        self._loop = asyncio.get_running_loop()
        self._tcp_server = await asyncio.start_server(
            self._handle_tcp, host, tcp_port, limit=1 << 20)
        if udp_port is not None:
            self._udp_transport, _ = \
                await self._loop.create_datagram_endpoint(
                    lambda: _UdpEndpoint(self), local_addr=(host, udp_port))
        if self.config.offload_decode:
            self._decode_q = queue.Queue()
            self._decode_thread = threading.Thread(
                target=self._decode_worker, name="front-door-decode",
                daemon=True)
            self._decode_thread.start()
        self._pump_task = asyncio.create_task(self._pump_loop())

    async def stop(self) -> None:
        # order: stop ingest first, then drain the decode worker, then
        # let its handed-back messages land, then kill the pump
        if self._tcp_server is not None:
            self._tcp_server.close()
            await self._tcp_server.wait_closed()
            self._tcp_server = None
        if self._udp_transport is not None:
            self._udp_transport.close()
            self._udp_transport = None
        if self._decode_thread is not None:
            self._decode_q.put(None)
            self._decode_thread.join()
            self._decode_thread = None
            self._decode_q = None
            await asyncio.sleep(0)    # run the worker's last callbacks
            self.pump()
        if self._pump_task is not None:
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
            self._pump_task = None

    @property
    def tcp_port(self) -> int:
        return self._tcp_server.sockets[0].getsockname()[1]

    @property
    def udp_port(self) -> int:
        return self._udp_transport.get_extra_info("sockname")[1]

    async def _pump_loop(self) -> None:
        while True:
            moved = self.pump()
            # yield even when busy so transports get to deliver bytes;
            # back off only when a turn moved nothing
            await asyncio.sleep(0 if moved else self.config.idle_sleep_s)

    def _decode_worker(self) -> None:
        """Worker thread: CRC + payload decode off the event loop. The
        queue preserves per-client byte order; decoded messages are
        handed back to the loop thread, so every counter and the ingest
        queue are still touched by ONE thread only."""
        while True:
            item = self._decode_q.get()
            if item is None:
                return
            key, data, is_stream = item
            st = self._clients.get(key)
            if st is None:
                continue
            st.bytes_in += len(data)   # only this thread writes it
            if is_stream:
                msgs = st.decoder.feed(data)
                if msgs:
                    self._loop.call_soon_threadsafe(self._deliver, st, msgs)
            else:
                try:
                    msg = P.decode_datagram(data)
                except P.ProtocolError:
                    self._loop.call_soon_threadsafe(self._udp_error, st)
                    continue
                self._loop.call_soon_threadsafe(self._deliver, st, [msg])

    def _deliver(self, st: _Client, msgs: List[P.Message]) -> None:
        for msg in msgs:
            self._on_message(st, msg)

    @staticmethod
    def _udp_error(st: _Client) -> None:
        st.udp_errors += 1

    def _rx_datagram(self, key: str, data: bytes) -> None:
        if self._decode_q is not None:
            self._decode_q.put((key, data, False))
        else:
            self.feed_datagram(key, data)

    async def _handle_tcp(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        peer = writer.get_extra_info("peername")
        key = f"tcp:{peer[0]}:{peer[1]}" if peer else f"tcp:{id(writer)}"
        self.client_connect(key, writer.write, stream=True)
        try:
            while True:
                data = await reader.read(1 << 20)
                if not data:
                    break
                if self._decode_q is not None:
                    self._decode_q.put((key, data, True))
                else:
                    self.feed(key, data)
        finally:
            self.client_disconnect(key)
            try:
                writer.close()
            except Exception:
                pass


class _UdpEndpoint(asyncio.DatagramProtocol):
    def __init__(self, door: ReadoutFrontDoor):
        self._door = door
        self._transport = None

    def connection_made(self, transport):
        self._transport = transport

    def datagram_received(self, data, addr):
        key = f"udp:{addr[0]}:{addr[1]}"
        if key not in self._door._clients:
            self._door.client_connect(
                key, lambda b, _a=addr: self._transport.sendto(b, _a),
                stream=False)
        self._door._rx_datagram(key, data)
