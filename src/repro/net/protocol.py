"""Versioned little-endian binary wire protocol for the readout link.

The paper's eFPGA sits in a front-end readout chip: sensors stream framed
pixel data in over a serial link and sparse trigger decisions stream back
out. This module is that link's software twin — a packed binary framing
(versioned structs à la lob_v1) shared by the TCP and UDP transports of
the asyncio front door (net/ingress.py) and the replay client
(net/replay.py).

Frame layout (all little-endian)::

    offset  size  field
    0       4     magic        b"eFPG" (resync anchor)
    4       1     version      PROTOCOL_VERSION (= 1)
    5       1     msg_type     MSG_* discriminant
    6       2     sensor_id    u16 producer id -> server chip slot
    8       4     seq          u32 per-client message sequence number
    12      4     payload_len  u32 payload bytes after the header
    16      4     crc32        zlib.crc32 over header[0:16] + payload
    20      ...   payload

The CRC covers the header fields as well as the payload — a bit flip in
``seq`` or ``sensor_id`` is as fatal to trigger accounting as one in the
pixel data, so it must be equally detectable.

Message payloads::

    FRAME_BATCH   u16 n_events + u16 reserved(0), then y0 f32[n], then
                  frames f32[n * N_T * N_Y * N_X] (C order) — the exact
                  arrays ``ReadoutServer.submit_frames`` ingests.
    TRIGGER_BATCH u32 orig_seq (the FRAME_BATCH answered), u16 n_events,
                  u16 n_admitted, u32 count, then count x (i32 flat
                  index, i32 score) — byte-identical to
                  ``parallel/compression.py``'s sparse trigger format
                  (SPARSE_HEADER_BYTES count word + SPARSE_BYTES_PER_EVENT
                  records), indices relative to the original batch.
    FLUSH         empty payload; asks the front door to force pending
                  batches through and answer with FLUSH_ACK. FLUSH takes
                  a seq like any message, so a tail drop in the data
                  stream is visible as a gap when the flush arrives.
    FLUSH_ACK     ACK_COUNTERS u64 each, in order — the per-client
                  accounting snapshot.

Decoder contract (the fuzz suite's property): every malformed input
raises a named :class:`ProtocolError` subclass — never a raw struct or
numpy error, never a silent partial decode — and :class:`StreamDecoder`
resyncs on the next magic so one corrupted frame costs one frame, not
the stream.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.smartpixel import N_T, N_X, N_Y
from repro.parallel.compression import (
    SPARSE_BYTES_PER_EVENT,
    SPARSE_COUNT_STRUCT,
    SPARSE_HEADER_BYTES,
    SPARSE_RECORD_STRUCT,
    WireFormatError,
)

MAGIC = b"eFPG"
PROTOCOL_VERSION = 1

MSG_FRAME_BATCH = 1
MSG_TRIGGER_BATCH = 2
MSG_FLUSH = 3
MSG_FLUSH_ACK = 4
MSG_NAMES = {
    MSG_FRAME_BATCH: "frame_batch",
    MSG_TRIGGER_BATCH: "trigger_batch",
    MSG_FLUSH: "flush",
    MSG_FLUSH_ACK: "flush_ack",
}

# magic[4s] version[B] msg_type[B] sensor_id[H] seq[I] payload_len[I] crc[I]
_HEADER = struct.Struct("<4sBBHII")      # the CRC-covered prefix (16 B)
_CRC = struct.Struct("<I")
HEADER_BYTES = _HEADER.size + _CRC.size  # 20
_CRC_OFFSET = _HEADER.size

_FRAME_VALUES = N_T * N_Y * N_X
FRAME_EVENT_BYTES = 4 + 4 * _FRAME_VALUES     # y0 + one charge frame
_FRAME_PREFIX = struct.Struct("<HH")          # n_events, reserved
_TRIG_PREFIX = struct.Struct("<IHH")          # orig_seq, n_events, n_admitted
assert struct.calcsize(SPARSE_COUNT_STRUCT) == SPARSE_HEADER_BYTES
assert struct.calcsize(SPARSE_RECORD_STRUCT) == SPARSE_BYTES_PER_EVENT
_SPARSE_REC_DT = np.dtype([("idx", "<i4"), ("score", "<i4")])

MAX_EVENTS_PER_BATCH = 1024   # u16 field, but bounded far tighter: one
# FRAME_BATCH at the cap is ~8.5 MB — anything claiming more is a
# corrupted length, and bounding it keeps StreamDecoder's wait-for-more
# state finite so a flipped payload_len cannot stall the stream forever.
MAX_PAYLOAD_BYTES = _FRAME_PREFIX.size + MAX_EVENTS_PER_BATCH * FRAME_EVENT_BYTES

# The classic 64 KiB UDP datagram ceiling: how many frame events fit one
# datagram (the replay client's UDP batch bound).
UDP_MAX_EVENTS = (65507 - HEADER_BYTES - _FRAME_PREFIX.size) // FRAME_EVENT_BYTES

ACK_COUNTERS = (
    "batches_in", "events_in", "events_admitted", "events_shed",
    "events_queue_dropped", "seq_gaps", "reorders", "duplicates",
    "decode_errors", "resyncs",
)
_ACK = struct.Struct("<" + "Q" * len(ACK_COUNTERS))


class ProtocolError(WireFormatError):
    """Base of the named decode-error family (subclasses below). Shares
    the ``WireFormatError`` root with the sparse trigger pack so 'this
    buffer is malformed' is one except-clause across the stack."""


class TruncatedError(ProtocolError):
    """Buffer ends before the frame does. ``needed`` carries the byte
    count that would complete it — StreamDecoder's wait-for-more signal."""

    def __init__(self, msg: str, needed: int = 0):
        super().__init__(msg)
        self.needed = needed


class BadMagicError(ProtocolError):
    """The 4 bytes at the frame boundary are not MAGIC."""


class BadCrcError(ProtocolError):
    """CRC32 over header[0:16]+payload disagrees with the frame's CRC."""


class VersionSkewError(ProtocolError):
    """Frame is well-formed (CRC passes) but speaks another version."""


class FieldBoundsError(ProtocolError):
    """A header or payload field is outside its documented bounds
    (unknown msg_type, oversized payload_len, count past the records,
    index outside the batch, payload length inconsistent with counts)."""


@dataclasses.dataclass(frozen=True)
class Message:
    """One decoded frame. Fields beyond (msg_type, sensor_id, seq) are
    populated per type: frames/y0 for FRAME_BATCH; orig_seq/n_events/
    n_admitted/idx/scores for TRIGGER_BATCH; counters for FLUSH_ACK."""

    msg_type: int
    sensor_id: int
    seq: int
    frames: Optional[np.ndarray] = None   # (n, N_T, N_Y, N_X) f32
    y0: Optional[np.ndarray] = None       # (n,) f32
    orig_seq: int = 0
    n_events: int = 0
    n_admitted: int = 0
    idx: Optional[np.ndarray] = None      # (count,) i32 in-batch indices
    scores: Optional[np.ndarray] = None   # (count,) i32
    counters: Optional[Dict[str, int]] = None


def _check_u16(name: str, v: int) -> int:
    if not (0 <= int(v) <= 0xFFFF):
        raise FieldBoundsError(f"{name} {v} outside u16")
    return int(v)


def _check_u32(name: str, v: int) -> int:
    if not (0 <= int(v) <= 0xFFFFFFFF):
        raise FieldBoundsError(f"{name} {v} outside u32")
    return int(v)


def _frame(msg_type: int, sensor_id: int, seq: int, payload: bytes,
           version: int = PROTOCOL_VERSION) -> bytes:
    head = _HEADER.pack(MAGIC, version, msg_type,
                        _check_u16("sensor_id", sensor_id),
                        _check_u32("seq", seq), len(payload))
    crc = zlib.crc32(payload, zlib.crc32(head))
    return head + _CRC.pack(crc) + payload


def encode_frame_batch(sensor_id: int, seq: int, frames: np.ndarray,
                       y0: np.ndarray,
                       version: int = PROTOCOL_VERSION) -> bytes:
    """Frame a raw-frame batch: exactly the ``submit_frames`` arrays."""
    frames = np.ascontiguousarray(frames, np.float32)
    y0 = np.ascontiguousarray(y0, np.float32)
    if frames.ndim != 4 or frames.shape[1:] != (N_T, N_Y, N_X):
        raise FieldBoundsError(
            f"frames must be (n, {N_T}, {N_Y}, {N_X}), got {frames.shape}")
    n = len(frames)
    if len(y0) != n:
        raise FieldBoundsError(f"{n} frames but {len(y0)} y0 values")
    if not (1 <= n <= MAX_EVENTS_PER_BATCH):
        raise FieldBoundsError(
            f"n_events {n} outside 1..{MAX_EVENTS_PER_BATCH}")
    payload = _FRAME_PREFIX.pack(n, 0) + y0.tobytes() + frames.tobytes()
    return _frame(MSG_FRAME_BATCH, sensor_id, seq, payload, version)


def encode_trigger_batch(sensor_id: int, seq: int, orig_seq: int,
                         n_events: int, n_admitted: int,
                         idx, scores,
                         version: int = PROTOCOL_VERSION) -> bytes:
    """Frame a sparse trigger answer for FRAME_BATCH ``orig_seq``.

    idx/scores are the kept events only (ascending in-batch positions),
    the count-sliced form of the sparse trigger pack."""
    idx = np.ascontiguousarray(idx, "<i4").ravel()
    scores = np.ascontiguousarray(scores, "<i4").ravel()
    if idx.size != scores.size:
        raise FieldBoundsError(
            f"{idx.size} indices but {scores.size} scores")
    n_events = _check_u16("n_events", n_events)
    n_admitted = _check_u16("n_admitted", n_admitted)
    if n_admitted > n_events:
        raise FieldBoundsError(
            f"n_admitted {n_admitted} > n_events {n_events}")
    if idx.size > n_admitted:
        raise FieldBoundsError(
            f"{idx.size} kept events > n_admitted {n_admitted}")
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= n_events):
        raise FieldBoundsError(
            f"kept index outside batch of {n_events} events")
    rec = np.empty(idx.size, _SPARSE_REC_DT)
    rec["idx"] = idx
    rec["score"] = scores
    payload = (_TRIG_PREFIX.pack(_check_u32("orig_seq", orig_seq),
                                 n_events, n_admitted)
               + struct.pack(SPARSE_COUNT_STRUCT, idx.size)
               + rec.tobytes())
    return _frame(MSG_TRIGGER_BATCH, sensor_id, seq, payload, version)


def encode_flush(sensor_id: int, seq: int,
                 version: int = PROTOCOL_VERSION) -> bytes:
    return _frame(MSG_FLUSH, sensor_id, seq, b"", version)


def encode_flush_ack(sensor_id: int, seq: int, counters: Dict[str, int],
                     version: int = PROTOCOL_VERSION) -> bytes:
    vals = [int(counters.get(k, 0)) for k in ACK_COUNTERS]
    return _frame(MSG_FLUSH_ACK, sensor_id, seq, _ACK.pack(*vals), version)


def _parse_frame_batch(sensor_id: int, seq: int, payload: memoryview
                       ) -> Message:
    if len(payload) < _FRAME_PREFIX.size:
        raise FieldBoundsError("frame_batch payload shorter than prefix")
    n, reserved = _FRAME_PREFIX.unpack_from(payload, 0)
    if reserved != 0:
        raise FieldBoundsError(f"frame_batch reserved field {reserved} != 0")
    if not (1 <= n <= MAX_EVENTS_PER_BATCH):
        raise FieldBoundsError(
            f"frame_batch n_events {n} outside 1..{MAX_EVENTS_PER_BATCH}")
    want = _FRAME_PREFIX.size + n * FRAME_EVENT_BYTES
    if len(payload) != want:
        raise FieldBoundsError(
            f"frame_batch payload {len(payload)} B != {want} B "
            f"for {n} events")
    off = _FRAME_PREFIX.size
    y0 = np.frombuffer(payload, "<f4", count=n, offset=off).copy()
    frames = np.frombuffer(
        payload, "<f4", count=n * _FRAME_VALUES, offset=off + 4 * n
    ).reshape(n, N_T, N_Y, N_X).copy()
    return Message(MSG_FRAME_BATCH, sensor_id, seq,
                   frames=frames, y0=y0, n_events=n)


def _parse_trigger_batch(sensor_id: int, seq: int, payload: memoryview
                         ) -> Message:
    prefix = _TRIG_PREFIX.size + SPARSE_HEADER_BYTES
    if len(payload) < prefix:
        raise FieldBoundsError("trigger_batch payload shorter than prefix")
    orig_seq, n_events, n_admitted = _TRIG_PREFIX.unpack_from(payload, 0)
    (count,) = struct.unpack_from(SPARSE_COUNT_STRUCT, payload,
                                  _TRIG_PREFIX.size)
    if n_admitted > n_events:
        raise FieldBoundsError(
            f"trigger_batch n_admitted {n_admitted} > n_events {n_events}")
    avail = (len(payload) - prefix) // SPARSE_BYTES_PER_EVENT
    if count > avail or count > n_admitted:
        # the count-prefix-larger-than-buffer corruption, caught HERE
        # (same family the unpack fix raises for the in-process link)
        raise FieldBoundsError(
            f"trigger_batch count {count} exceeds the {avail} records "
            f"on the wire (n_admitted {n_admitted})")
    if len(payload) != prefix + count * SPARSE_BYTES_PER_EVENT:
        raise FieldBoundsError(
            f"trigger_batch payload {len(payload)} B != "
            f"{prefix + count * SPARSE_BYTES_PER_EVENT} B for count {count}")
    rec = np.frombuffer(payload, _SPARSE_REC_DT, count=count, offset=prefix)
    idx = rec["idx"].astype(np.int32)
    scores = rec["score"].astype(np.int32)
    if count and (int(idx.min()) < 0 or int(idx.max()) >= n_events):
        raise FieldBoundsError(
            f"trigger_batch index outside batch of {n_events} events")
    return Message(MSG_TRIGGER_BATCH, sensor_id, seq, orig_seq=orig_seq,
                   n_events=n_events, n_admitted=n_admitted,
                   idx=idx, scores=scores)


def _parse_flush_ack(sensor_id: int, seq: int, payload: memoryview
                     ) -> Message:
    if len(payload) != _ACK.size:
        raise FieldBoundsError(
            f"flush_ack payload {len(payload)} B != {_ACK.size} B")
    vals = _ACK.unpack_from(payload, 0)
    return Message(MSG_FLUSH_ACK, sensor_id, seq,
                   counters=dict(zip(ACK_COUNTERS, vals)))


def decode_message(buf, offset: int = 0) -> Tuple[Message, int]:
    """Decode one frame at ``offset``; returns (message, bytes consumed).

    Raises the named ProtocolError family on anything malformed; raises
    TruncatedError (with ``.needed``) when the buffer simply ends early —
    the only error that means 'feed me more bytes', every other one means
    'this frame is garbage, resync'."""
    view = memoryview(buf)[offset:]
    if len(view) < len(MAGIC):
        raise TruncatedError("short of the magic",
                             needed=len(MAGIC) - len(view))
    if bytes(view[:len(MAGIC)]) != MAGIC:
        raise BadMagicError(
            f"bad magic {bytes(view[:len(MAGIC)])!r} at offset {offset}")
    if len(view) < HEADER_BYTES:
        raise TruncatedError("short of the header",
                             needed=HEADER_BYTES - len(view))
    magic, version, msg_type, sensor_id, seq, payload_len = \
        _HEADER.unpack_from(view, 0)
    (crc,) = _CRC.unpack_from(view, _CRC_OFFSET)
    if payload_len > MAX_PAYLOAD_BYTES:
        raise FieldBoundsError(
            f"payload_len {payload_len} > MAX_PAYLOAD_BYTES "
            f"{MAX_PAYLOAD_BYTES} (corrupted length)")
    total = HEADER_BYTES + payload_len
    if len(view) < total:
        raise TruncatedError("short of the payload",
                             needed=total - len(view))
    payload = view[HEADER_BYTES:total]
    got_crc = zlib.crc32(payload, zlib.crc32(view[:_CRC_OFFSET]))
    if got_crc != crc:
        raise BadCrcError(
            f"crc mismatch: frame says {crc:#010x}, bytes hash to "
            f"{got_crc:#010x}")
    if version != PROTOCOL_VERSION:
        raise VersionSkewError(
            f"frame speaks version {version}, this decoder speaks "
            f"{PROTOCOL_VERSION}")
    if msg_type == MSG_FRAME_BATCH:
        msg = _parse_frame_batch(sensor_id, seq, payload)
    elif msg_type == MSG_TRIGGER_BATCH:
        msg = _parse_trigger_batch(sensor_id, seq, payload)
    elif msg_type == MSG_FLUSH:
        if payload_len != 0:
            raise FieldBoundsError(
                f"flush payload must be empty, got {payload_len} B")
        msg = Message(MSG_FLUSH, sensor_id, seq)
    elif msg_type == MSG_FLUSH_ACK:
        msg = _parse_flush_ack(sensor_id, seq, payload)
    else:
        raise FieldBoundsError(f"unknown msg_type {msg_type}")
    return msg, total


def decode_datagram(data: bytes) -> Message:
    """Decode a datagram holding exactly one frame (the UDP contract)."""
    msg, consumed = decode_message(data, 0)
    if consumed != len(data):
        raise FieldBoundsError(
            f"datagram has {len(data) - consumed} trailing bytes after "
            "the frame")
    return msg


class StreamDecoder:
    """Incremental TCP-side decoder: buffer, decode, resync.

    ``feed(data)`` returns every complete message now decodable. A
    malformed frame is counted (``errors`` by class name), the buffer
    scans forward to the next MAGIC (``resyncs``) and decoding
    continues — one corrupted frame never takes down the connection.
    TruncatedError is NOT an error: it just means wait for more bytes
    (bounded: payload_len is capped, so at most MAX_PAYLOAD_BYTES +
    header are ever held back)."""

    def __init__(self):
        self._buf = bytearray()
        self.messages = 0
        self.resyncs = 0
        self.errors: Dict[str, int] = {}

    @property
    def errors_total(self) -> int:
        return sum(self.errors.values())

    @property
    def buffered(self) -> int:
        return len(self._buf)

    def _count(self, exc: ProtocolError) -> None:
        name = type(exc).__name__
        self.errors[name] = self.errors.get(name, 0) + 1

    def feed(self, data: bytes) -> List[Message]:
        # decode IN PLACE on the bytearray — snapshotting it to bytes
        # would copy the whole backlog on every feed, O(backlog^2) under
        # a flood. Safe because nothing keeps a view alive past this
        # call: a caught exception (and the memoryviews its traceback
        # pins) is released when its except block exits, and every
        # decoded Message holds .copy()'d arrays.
        buf = self._buf
        buf.extend(data)
        pos = 0
        out: List[Message] = []
        while pos < len(buf):
            try:
                msg, consumed = decode_message(buf, pos)
            except TruncatedError:
                break                     # wait for more bytes
            except ProtocolError as exc:
                self._count(exc)
                # resync: skip to the NEXT magic (scan starts one byte
                # in, else a frame with a valid magic but corrupt body
                # would loop forever)
                nxt = buf.find(MAGIC, pos + 1)
                pos = nxt if nxt >= 0 else len(buf)
                self.resyncs += 1
                continue
            pos += consumed
            self.messages += 1
            out.append(msg)
        if pos:
            try:
                del buf[:pos]
            except BufferError:
                # some traceback still pins a view over the buffer (a
                # resize would invalidate it) — fall back to rebuilding,
                # which copies instead of resizing
                self._buf = bytearray(memoryview(buf)[pos:])
        return out
