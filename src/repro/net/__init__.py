"""Network front door for the readout server (ROADMAP item 3).

``protocol``  — the versioned little-endian binary wire format
                (FrameBatch ingest, sparse TriggerBatch egress, CRC32
                framing, strict named-error decoder with resync).
``ingress``   — asyncio multi-producer TCP/UDP front door feeding one
                ``ReadoutServer`` through a bounded drop-and-count queue.
``replay``    — closed-loop replay client: streams recorded smartpixel
                frames at controlled Poisson/square-wave rates and
                verifies returned trigger decisions bit-exact against a
                host oracle.
"""
