"""Closed-loop replay load generator for the network front door.

Streams recorded smartpixel frames (``data/pipeline.FrameStream`` or any
``source(batch_index) -> (frames, y0)`` callable) against a live
front-door socket at a controlled rate — Poisson or square-wave arrivals,
the same traffic shapes as the open-loop deadline bench — and CLOSES the
loop: every returned TRIGGER_BATCH is checked bit-exact against a host
oracle (``host_oracle(chip)`` builds one from ``MultiFabricSim``), end-
to-end latency lands in the serving stack's own ``LatencyHistogram``,
and the final FLUSH_ACK's counters are cross-checked against what the
client actually sent. This is the load harness every scale claim after
ROADMAP item 3 is measured under.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.launch.readout_server import LatencyHistogram
from repro.net import protocol as P

# (frames (n, T, Y, X) f32, y0 (n,) f32) per replayed batch index
Source = Callable[[int], Tuple[np.ndarray, np.ndarray]]
# (frames, y0) -> (scores (n,) int, keep (n,) bool)
Oracle = Callable[[np.ndarray, np.ndarray], Tuple[np.ndarray, np.ndarray]]


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    """Replay traffic shape.

    rate_hz: target EVENT rate; 0 = unpaced (send as fast as the loop
        accepts — the loopback-throughput configuration).
    pattern: "poisson" (exponential inter-batch gaps) or "square"
        (rate toggles hi/lo every half period — bursty).
    n_batches / events_per_batch: total traffic volume.
    sensor: sensor id stamped on every batch (= server chip slot).
    transport: "tcp" or "udp". UDP batches must fit one datagram
        (events_per_batch <= protocol.UDP_MAX_EVENTS).
    pre_encode: frame every batch to wire bytes BEFORE the clock starts
        (a recorded stream can live on disk already wire-framed) — the
        harness then only moves bytes inside the measured window, so a
        throughput number isn't bottlenecked by the load generator's
        own encode cost.
    """

    rate_hz: float = 0.0
    pattern: str = "poisson"
    n_batches: int = 64
    events_per_batch: int = 8
    sensor: int = 0
    transport: str = "tcp"
    seed: int = 0
    square_period_s: float = 0.1
    burst_factor: float = 2.0
    timeout_s: float = 60.0
    pre_encode: bool = False

    def __post_init__(self):
        if self.pattern not in ("poisson", "square"):
            raise ValueError(f"unknown pattern {self.pattern!r}")
        if self.transport not in ("tcp", "udp"):
            raise ValueError(f"unknown transport {self.transport!r}")
        if self.transport == "udp" \
                and self.events_per_batch > P.UDP_MAX_EVENTS:
            raise ValueError(
                f"events_per_batch {self.events_per_batch} won't fit a "
                f"datagram (max {P.UDP_MAX_EVENTS})")
        if self.rate_hz < 0 or self.burst_factor < 1:
            raise ValueError("rate_hz >= 0 and burst_factor >= 1 required")


@dataclasses.dataclass
class ReplayReport:
    """What one replay run measured (and whether it verified)."""

    n_batches: int
    n_events: int
    target_ev_s: float
    achieved_ev_s: float
    latency: Dict[str, float]          # LatencyHistogram.summary()
    ack: Dict[str, int]                # final FLUSH_ACK counters
    verified: bool
    mismatches: List[str]
    n_triggers: int
    n_kept: int
    n_admitted: int
    unanswered: int                    # sent batches with no trigger back
    bytes_out: int
    bytes_in: int

    @property
    def wire_bytes_per_event(self) -> float:
        return self.bytes_out / max(self.n_events, 1)


def frame_stream_source(stream, sensor: int, events_per_batch: int
                        ) -> Source:
    """Adapt a ``FrameStream`` to the replay source contract: batch b is
    the first ``events_per_batch`` events of ``batch_at(b, sensor)`` —
    (seed, step, sensor)-pure, so the oracle side can regenerate it."""
    if events_per_batch > stream.cfg.batch:
        raise ValueError(
            f"events_per_batch {events_per_batch} > stream batch "
            f"{stream.cfg.batch}")

    def source(b: int) -> Tuple[np.ndarray, np.ndarray]:
        blk = stream.batch_at(b, sensor)
        return (blk["frames"][:events_per_batch],
                blk["y0"][:events_per_batch])

    return source


def array_source(frames: np.ndarray, y0: np.ndarray,
                 events_per_batch: int) -> Source:
    """Replay a preloaded (n, T, Y, X) array, wrapping around — the
    bench path (no per-batch generation cost in the measured rate)."""
    n = len(frames)

    def source(b: int) -> Tuple[np.ndarray, np.ndarray]:
        lo = (b * events_per_batch) % n
        idx = (lo + np.arange(events_per_batch)) % n
        return frames[idx], y0[idx]

    return source


def host_oracle(chip, threshold_electrons: float = 800.0,
                batch_tile: int = 128) -> Oracle:
    """The bit-exact host decision path for one chip: frames -> yprofile
    features -> fabric input bits -> ``MultiFabricSim`` -> decoded raw
    score, keep = score <= the chip's trigger cut. This is the oracle
    the closed loop compares EVERY returned trigger against."""
    from repro.core.fabric import MultiFabricSim
    from repro.kernels.yprofile import ops as yp_ops

    sim = MultiFabricSim([chip.config])

    def oracle(frames: np.ndarray, y0: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
        feats = np.asarray(yp_ops.yprofile(
            np.asarray(frames, np.float32), np.asarray(y0, np.float32),
            threshold_electrons=threshold_electrons,
            batch_tile=batch_tile))
        bits = chip.encode_features(feats)
        outs = sim.run(bits[None])[0]
        score = np.asarray(chip.synth.decode_outputs(outs), np.int64)
        return score, score <= chip.score_threshold_raw

    return oracle


def batch_arrival_times(cfg: ReplayConfig) -> np.ndarray:
    """Seconds-from-start send time of each batch (all 0 when unpaced)."""
    n = cfg.n_batches
    if cfg.rate_hz <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(cfg.seed)
    batch_rate = cfg.rate_hz / cfg.events_per_batch
    if cfg.pattern == "poisson":
        return np.cumsum(rng.exponential(1.0 / batch_rate, n))
    # square wave: rate toggles hi/lo every half period (mean = rate_hz)
    hi = batch_rate * cfg.burst_factor
    lo = batch_rate / cfg.burst_factor
    half = cfg.square_period_s / 2.0
    t, out = 0.0, []
    for _ in range(n):
        r = hi if int(t / half) % 2 == 0 else lo
        t += 1.0 / r
        out.append(t)
    return np.asarray(out)


class _TriggerCollector:
    """Client-side receive state: decoded triggers by orig_seq (with the
    receive timestamp — the e2e latency endpoint), the ack, byte count."""

    def __init__(self, clock):
        self._clock = clock
        self.decoder = P.StreamDecoder()
        self.triggers: Dict[int, Tuple[P.Message, float]] = {}
        self.ack: Optional[P.Message] = None
        self.bytes_in = 0
        self.event = asyncio.Event()

    def on_bytes(self, data: bytes) -> None:
        self.bytes_in += len(data)
        for msg in self.decoder.feed(data):
            self.on_message(msg)

    def on_message(self, msg: P.Message) -> None:
        if msg.msg_type == P.MSG_TRIGGER_BATCH:
            self.triggers[msg.orig_seq] = (msg, self._clock())
        elif msg.msg_type == P.MSG_FLUSH_ACK:
            self.ack = msg
        self.event.set()


class _UdpClient(asyncio.DatagramProtocol):
    def __init__(self, collector: _TriggerCollector):
        self._c = collector

    def datagram_received(self, data, addr):
        self._c.bytes_in += len(data)
        try:
            self._c.on_message(P.decode_datagram(data))
        except P.ProtocolError:
            pass


async def replay(host: str, port: int, source: Source, cfg: ReplayConfig,
                 oracle: Optional[Oracle] = None,
                 clock=None) -> ReplayReport:
    """Run one closed-loop replay against a live front door.

    Sends ``n_batches`` FRAME_BATCHes at the configured rate, then a
    FLUSH; awaits every TRIGGER_BATCH plus the FLUSH_ACK; verifies each
    trigger bit-exact against ``oracle`` (positions AND scores of kept
    events — an event the oracle keeps that the trigger missed is a
    mismatch, unless admission shed part of that batch, which the
    report counts as unanswered-verification instead)."""
    loop = asyncio.get_running_loop()
    clock = clock or loop.time
    coll = _TriggerCollector(clock)
    writer = None
    transport = None
    if cfg.transport == "tcp":
        reader, writer = await asyncio.open_connection(host, port)

        async def _read():
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    return
                coll.on_bytes(data)

        reader_task = asyncio.create_task(_read())

        async def send(wire: bytes):
            writer.write(wire)
            await writer.drain()
    else:
        transport, _ = await loop.create_datagram_endpoint(
            lambda: _UdpClient(coll), remote_addr=(host, port))
        reader_task = None

        async def send(wire: bytes):
            transport.sendto(wire)

    arrivals = batch_arrival_times(cfg)
    sent: Dict[int, Tuple[float, np.ndarray, np.ndarray]] = {}
    pre: Optional[List[Tuple[bytes, np.ndarray, np.ndarray]]] = None
    if cfg.pre_encode:
        pre = []
        for b in range(cfg.n_batches):
            frames, y0 = source(b)
            pre.append((P.encode_frame_batch(cfg.sensor, b, frames, y0),
                        frames, y0))
    bytes_out = 0
    t0 = clock()
    try:
        for b in range(cfg.n_batches):
            due = t0 + float(arrivals[b])
            delay = due - clock()
            if delay > 0:
                await asyncio.sleep(delay)
            if pre is not None:
                wire, frames, y0 = pre[b]
            else:
                frames, y0 = source(b)
                wire = P.encode_frame_batch(cfg.sensor, b, frames, y0)
            sent[b] = (clock(), frames, y0)
            bytes_out += len(wire)
            await send(wire)
        flush_wire = P.encode_flush(cfg.sensor, cfg.n_batches)
        bytes_out += len(flush_wire)
        await send(flush_wire)

        deadline = clock() + cfg.timeout_s
        while coll.ack is None or len(coll.triggers) < cfg.n_batches:
            remaining = deadline - clock()
            if remaining <= 0:
                break
            coll.event.clear()
            try:
                await asyncio.wait_for(coll.event.wait(), remaining)
            except asyncio.TimeoutError:
                break
        t_end = clock()
    finally:
        if writer is not None:
            writer.close()
        if reader_task is not None:
            reader_task.cancel()
        if transport is not None:
            transport.close()

    # ---- close the loop: verify + measure
    hist = LatencyHistogram()
    mismatches: List[str] = []
    n_kept = n_admitted = 0
    for bseq in sorted(coll.triggers):
        trig, t_recv = coll.triggers[bseq]
        t_send, frames, y0 = sent[bseq]
        # latency is per EVENT: every event in the batch got its
        # keep/drop decision when this trigger landed
        hist.add_many(
            np.full(trig.n_events, max(t_recv - t_send, 0.0) * 1e6))
        n_admitted += trig.n_admitted
        n_kept += len(trig.idx)
        if trig.n_events != len(frames):
            mismatches.append(
                f"batch {bseq}: trigger says {trig.n_events} events, "
                f"sent {len(frames)}")
            continue
        if oracle is None:
            continue
        if trig.n_admitted < trig.n_events:
            continue    # partially shed: positions unknowable, skip
        score, keep = oracle(frames, y0)
        want = {(int(p), int(score[p])) for p in np.nonzero(keep)[0]}
        got = {(int(p), int(s)) for p, s in zip(trig.idx, trig.scores)}
        if want != got:
            mismatches.append(
                f"batch {bseq}: kept (pos, score) set differs — "
                f"oracle-only {sorted(want - got)[:3]} "
                f"wire-only {sorted(got - want)[:3]}")

    n_events = cfg.n_batches * cfg.events_per_batch
    unanswered = cfg.n_batches - len(coll.triggers)
    span = max(t_end - t0, 1e-9)
    ack = dict(coll.ack.counters) if coll.ack is not None else {}
    verified = (oracle is not None and not mismatches and unanswered == 0
                and coll.ack is not None)
    return ReplayReport(
        n_batches=cfg.n_batches,
        n_events=n_events,
        target_ev_s=cfg.rate_hz,
        achieved_ev_s=n_events / span,
        latency=hist.summary(),
        ack=ack,
        verified=verified,
        mismatches=mismatches,
        n_triggers=len(coll.triggers),
        n_kept=n_kept,
        n_admitted=n_admitted,
        unanswered=unanswered,
        bytes_out=bytes_out,
        bytes_in=coll.bytes_in,
    )
