"""Generic train/serve steps: microbatched grad accumulation + optimizer.

``make_train_step`` builds the jit-able function the launcher and the
dry-run lower:

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

Microbatching (cfg.num_microbatches > 1) reshapes the global batch leaf-wise
to (n_mb, B/n_mb, ...) and accumulates grads with lax.scan — the standard
activation-memory lever for the big archs (activations scale 1/n_mb; see
EXPERIMENTS.md §Perf for the measured effect on the memory roofline term).

``make_serve_step`` builds the one-token decode step lowered by the
decode_* / long_* dry-run cells:

    serve_step(params, cache, tokens) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import registry
from repro.train.optimizer import OptimizerConfig, make_optimizer

PyTree = Any


def _split_microbatches(batch: Dict, n_mb: int) -> Dict:
    def resh(x):
        assert x.shape[0] % n_mb == 0, (x.shape, n_mb)
        return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

    return jax.tree.map(resh, batch)


def make_loss_fn(cfg: ArchConfig) -> Callable:
    return functools.partial(registry.loss_fn, cfg)


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    grad_specs=None, compress_pod=None):
    """grad_specs: optional PartitionSpec tree matching params. Without it,
    XLA is free to REPLICATE the microbatch gradient accumulator (a scan
    carry with unconstrained sharding) — for a 14B model that is a
    replicated 56 GB f32 buffer. The dry-run/launcher always passes the
    param specs so accumulators stay sharded like the params."""
    _, opt_update = make_optimizer(opt_cfg)
    loss_fn = make_loss_fn(cfg)
    n_mb = max(cfg.num_microbatches, 1)
    acc_dt = jnp.dtype(cfg.grad_accum_dtype)

    vag = jax.value_and_grad(loss_fn, argnums=0)
    if compress_pod is not None:
        # paper-themed at-source compression: per-pod partial grads are
        # int8-quantized before crossing the DCN (parallel/compression.py).
        from repro.parallel.compression import make_compressed_value_and_grad

        mesh, batch_spec_tree = compress_pod
        inner_specs = None
        if grad_specs is not None:
            inner_specs = jax.tree.map(
                lambda s: s.spec if hasattr(s, "spec") else s, grad_specs,
                is_leaf=lambda x: hasattr(x, "spec") or type(x).__name__ == "PartitionSpec")
        vag = make_compressed_value_and_grad(
            loss_fn, mesh, batch_spec_tree, grad_specs=inner_specs)

    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, grad_specs
        )

    def train_step(params, opt_state, batch):
        if n_mb == 1:
            loss, grads = vag(params, batch)
            grads = constrain(grads)
        else:
            mbs = _split_microbatches(batch, n_mb)

            def body(acc, mb):
                acc_loss, acc_g = acc
                l, g = vag(params, mb)
                g = constrain(jax.tree.map(lambda a: a.astype(acc_dt), g))
                acc_g = constrain(jax.tree.map(jnp.add, acc_g, g))
                return (acc_loss + l, acc_g), None

            zero_g = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            ))
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), mbs
            )
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)

        new_params, new_opt, om = opt_update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32), **om}
        return new_params, new_opt, metrics

    return train_step


def make_opt_init(cfg: ArchConfig, opt_cfg: OptimizerConfig):
    opt_init, _ = make_optimizer(opt_cfg)
    return opt_init


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, tokens):
        return registry.decode_step(cfg, params, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ArchConfig):
    """Full-sequence forward (the *prefill_32k* cells), returning the
    loss-shaped summary so outputs stay small.

    cfg.prefill_microbatches > 1 processes the request batch in sequential
    waves (standard serving throughput-batching) — halves peak activation
    memory per wave for the archs whose 32k-prefill transients exceed HBM.
    """
    loss_fn = make_loss_fn(cfg)
    n_mb = max(cfg.prefill_microbatches, 1)

    def prefill_step(params, batch):
        if n_mb == 1:
            return loss_fn(params, batch)
        mbs = _split_microbatches(batch, n_mb)

        def body(acc, mb):
            return acc + loss_fn(params, mb), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), mbs)
        return total / n_mb

    return prefill_step


def default_opt_config(cfg: ArchConfig, total_steps: int = 10_000) -> OptimizerConfig:
    return OptimizerConfig(
        name=cfg.optimizer,
        lr=3e-4 if cfg.param_count() < 20e9 else 1e-4,
        total_steps=total_steps,
    )
