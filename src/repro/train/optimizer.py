"""Optimizers from scratch (no optax in this container): AdamW + Adafactor.

Both are expressed as (init, update) pairs over arbitrary pytrees, with
global-norm clipping and a linear-warmup cosine schedule. Optimizer state
inherits the parameter sharding (parallel/sharding.py maps specs over the
state pytree), so ZeRO-style sharded optimizer state falls out of FSDP
parameter sharding for free.

Adafactor (factored second moment, no first moment by default) is the
memory-fit choice for the >=70B assigned archs: state is O(rows + cols)
per matrix instead of O(rows * cols) — see DESIGN.md §5 and the dry-run
memory analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.999            # adafactor uses a step-dependent decay
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# ------------------------------------------------------------------ AdamW
def adamw_init(cfg: OptimizerConfig, params: PyTree) -> Dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads: PyTree, state: Dict, params: PyTree):
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * gf
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * gf * gf
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------- Adafactor
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(cfg: OptimizerConfig, params: PyTree) -> Dict:
    def make(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),          # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(make, params, is_leaf=lambda x: isinstance(x, jnp.ndarray)),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptimizerConfig, grads: PyTree, state: Dict, params: PyTree):
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    beta2t = 1.0 - t ** (-0.8)  # Adafactor's step-dependent decay
    eps = 1e-30

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p.shape):
            vr = v["vr"] * beta2t + jnp.mean(g2, axis=-1) * (1 - beta2t)
            vc = v["vc"] * beta2t + jnp.mean(g2, axis=-2) * (1 - beta2t)
            rfac = vr / jnp.mean(vr, axis=-1, keepdims=True)
            denom = jnp.sqrt(rfac[..., None] * vc[..., None, :])
            update = gf / (denom + cfg.eps)
            newv = {"vr": vr, "vc": vc}
        else:
            vv = v["v"] * beta2t + g2 * (1 - beta2t)
            update = gf / (jnp.sqrt(vv) + cfg.eps)
            newv = {"v": vv}
        # relative step-size clipping (RMS-based, as in the paper)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), newv

    def upd_chunked(p, g, v):
        # stacked (L, ...) leaves update via lax.map over the layer axis:
        # whole-leaf f32 transients (gf, g2, update) would otherwise cost
        # 4x leaf-size f32 each (8 GiB live for nemotron's FFN weights).
        if p.ndim >= 3 and _factored(p.shape) and p.shape[0] > 1:
            def one(args):
                return upd(*args)

            newp, newv = jax.lax.map(one, (p, g, v))
            return newp, newv
        return upd(p, g, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd_chunked(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_params, {"v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}


# ----------------------------------------------------------------- facade
def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return (lambda p: adamw_init(cfg, p),
                lambda g, s, p: adamw_update(cfg, g, s, p))
    if cfg.name == "adafactor":
        return (lambda p: adafactor_init(cfg, p),
                lambda g, s, p: adafactor_update(cfg, g, s, p))
    raise ValueError(f"unknown optimizer {cfg.name!r}")
