"""Elastic scaling + failure handling helpers.

Scenario this supports (DESIGN.md §5): a pod (or some hosts) drops out
mid-run. Recovery path:

  1. the run restarts from the latest atomic checkpoint (launch/train.py
     --resume does this automatically);
  2. ``reshard`` places the checkpointed state onto the NEW mesh — any DP
     degree works because checkpoints are stored unsharded and the sharding
     rules are pure functions of (config, mesh);
  3. the data pipeline needs no state migration at all: batches are pure
     functions of (seed, step, shard) (data/pipeline.py), so the surviving
     hosts simply recompute their shards from the restored step.

Straggler mitigation at this layer: the synchronous SPMD step makes
per-host stragglers a hardware-level concern (the TPU runtime handles ICI
retries); at the job level the mitigations are (a) deterministic shard
reassignment — a slow host's data shard can be handed to any other host,
(b) checkpoint/restart with elastic reshard onto the shrunken mesh, and
(c) bounded step timeout in the driver loop (launch/train.py --step-timeout)
that triggers (b) rather than waiting on a sick host forever.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.parallel import sharding as shd

PyTree = Any


def reshard_params(cfg: ArchConfig, mesh: Mesh, params_host: PyTree) -> PyTree:
    """Place host (numpy) params onto a (possibly different) mesh."""
    specs = shd.param_specs(cfg, mesh, jax.eval_shape(lambda t: t, params_host))
    sh = shd.named(mesh, specs)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), params_host, sh)


def reshard_opt_state(cfg: ArchConfig, mesh: Mesh, opt_host: PyTree,
                      params_template: PyTree) -> PyTree:
    pspecs = shd.param_specs(cfg, mesh, params_template)
    ospecs = shd.opt_state_specs(
        cfg, mesh, jax.eval_shape(lambda t: t, opt_host), pspecs
    )
    sh = shd.named(mesh, ospecs)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), opt_host, sh)


def gather_to_host(tree: PyTree) -> PyTree:
    """Fully replicate/gather device arrays back to host numpy (pre-save)."""
    import numpy as np

    return jax.tree.map(lambda x: np.asarray(x), tree)


def reshard_replicated(tree: PyTree, mesh: Mesh) -> PyTree:
    """Replicate a pytree's arrays onto every device of a (new) mesh.

    The serving-side analogue of ``reshard_params``: the multi-tenant
    fleet (launch/fleet.py) re-plans its per-bucket device slabs on every
    grow/shrink (launch.mesh.make_fleet_meshes), and a bucket whose slab
    moved re-places its packed kernel stack here — the stack is
    replicated (the chip axis is split by shard_map at dispatch, not by
    layout), so the placement spec is pure replication and any slab size
    works, exactly like checkpointed train state resharding onto a
    shrunken mesh. Static pytree fields and ``None`` leaves pass through
    untouched.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    sharding = NamedSharding(mesh, PartitionSpec())
    return jax.tree.map(
        lambda x: jax.device_put(x, sharding) if x is not None else None,
        tree)
