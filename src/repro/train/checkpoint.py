"""Fault-tolerant checkpointing: atomic writes, integrity hashes, retention,
and elastic restore onto a different mesh.

Layout (one directory per step):

    <dir>/step_000120/
        arrays.npz          flattened pytree ("/"-joined paths -> arrays)
        MANIFEST.json       {step, keys, sha256, framework_version}
    <dir>/LATEST            text file: "step_000120"

Guarantees:
  * atomicity — arrays + manifest are written into step_XXXX.tmp and
    os.replace()'d into place; a crash mid-write never corrupts LATEST
    (restart-after-failure test: tests/test_checkpoint.py);
  * integrity — sha256 over the npz payload is verified on restore;
  * elasticity — arrays are stored UNSHARDED (gathered); restore takes a
    target sharding tree and device_puts leaves onto the new mesh, so a
    checkpoint written on mesh A restores onto mesh B with a different DP
    degree. (At true multi-pod scale this becomes per-shard tensorstore
    writes; the single-host container stores full arrays.)
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(template: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointError(RuntimeError):
    pass


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "sha256": _sha256(npz_path),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.isdir(os.path.join(self.dir, name)):
                return int(m.group(1))
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: PyTree,
        step: Optional[int] = None,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[int, PyTree]:
        """Restore into the structure of ``template``. If ``shardings`` is
        given (a pytree of jax.sharding.Sharding matching template), leaves
        are device_put onto it — this is the elastic-reshard path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise CheckpointError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        npz_path = os.path.join(d, "arrays.npz")
        if _sha256(npz_path) != manifest["sha256"]:
            raise CheckpointError(f"integrity failure (sha256) in {d}")
        with np.load(npz_path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings
            )
        return step, tree
