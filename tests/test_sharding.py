"""Sharding rules: spec validity per arch, ZeRO-1 moments, dry-run cell on a
small fake-device mesh (subprocess keeps this process at 1 device)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.configs.base import SHAPES
from repro.launch import specs as S
from repro.parallel import sharding as shd


class _FakeMesh:
    """Duck-typed mesh: shape dict + axis names (no devices needed for
    spec computation)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = _FakeMesh({"data": 16, "model": 16})
MESH2 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must divide by its mesh axes — the exact check jit
    performs at lower time."""
    cfg = get_arch(arch)
    params = S.params_sds(cfg)
    specs = shd.param_specs(cfg, mesh, params)

    def check(leaf, spec):
        for d, s in enumerate(spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[d] % n == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["gemma-7b", "grok-1-314b", "mamba2-130m"])
def test_zero1_moment_specs_use_idle_axes(arch):
    import functools

    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import make_opt_init

    cfg = get_arch(arch)
    params = S.params_sds(cfg)
    pspecs = shd.param_specs(cfg, MESH1, params)
    opt_cfg = OptimizerConfig(name="adamw")
    opt_shape = jax.eval_shape(make_opt_init(cfg, opt_cfg), params)
    ospecs = shd.opt_state_specs(cfg, MESH1, opt_shape, pspecs)

    # moments of large matrices must be sharded on at least one more axis
    n_extra = 0
    for spec_p, spec_m, leaf in zip(
        jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(ospecs["m"], is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(params),
    ):
        used_p = sum(x is not None for x in spec_p)
        used_m = sum(x is not None for x in spec_m)
        if leaf.size > 1e6:
            assert used_m >= used_p
            n_extra += used_m > used_p
    assert n_extra > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_batch_and_cache_specs_divisible(arch):
    cfg = get_arch(arch)
    for mesh in (MESH1, MESH2):
        for shape in cfg.shapes():
            bs = shd.batch_specs(cfg, mesh, shape)
            sds = S.batch_specs_sds(cfg, shape)

            def check(leaf, spec):
                for d, s in enumerate(spec):
                    if s is None:
                        continue
                    axes = s if isinstance(s, tuple) else (s,)
                    n = int(np.prod([mesh.shape[a] for a in axes]))
                    assert leaf.shape[d] % n == 0, (arch, shape.name, leaf.shape, spec)

            jax.tree.map(check, sds, bs, is_leaf=lambda x: isinstance(x, P))
            if shape.kind == "decode":
                cs = S.cache_sds(cfg, shape)
                cspec = shd.cache_specs(cfg, mesh, shape, cs)
                jax.tree.map(check, cs, cspec, is_leaf=lambda x: isinstance(x, P))


_DRYRUN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
import dataclasses
from repro.configs import smoke_config
cfg = dataclasses.replace(smoke_config("gemma-7b"), num_microbatches=2)
_, compiled, summary = lower_cell("gemma-7b", "train_4k", mesh, "test_2x2x2",
                                  cfg_override=cfg)
assert summary["flops_per_device"] > 0
assert summary["collective_count"] > 0, "expected collectives in SPMD step"
print("MINI_DRYRUN_OK", summary["collective_count"])
"""


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """A reduced train cell lowers+compiles on a 2x2x2 mesh with collectives
    present — the structural core of the multi-pod dry-run, in miniature."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "MINI_DRYRUN_OK" in r.stdout


def test_hlo_collective_parser():
    from repro.parallel.hlo_analysis import parse_collectives

    text = """
  %ar = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
  %ag.1 = bf16[16,512]{1,0} all-gather(%y), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], to_apply=%sum
  %cp = s8[64]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
"""
    st = parse_collectives(text, 8)
    assert st.count == 4
    assert st.by_op["all-reduce"] == pytest.approx(2 * 4096 * 3 / 4)
    assert st.by_op["all-gather"] == pytest.approx(16 * 512 * 2 * 1 / 2)
    assert st.by_op["reduce-scatter"] == pytest.approx(128 * 4 * 7)
    assert st.by_op["collective-permute"] == pytest.approx(64)
