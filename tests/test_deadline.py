"""Deadline-aware serving (launch/readout_server.py).

Covers the deadline/overload machinery end to end:
  * ServerConfig validation of the deadline knobs (budget, policy, rungs,
    window, hysteresis gap, min_batch);
  * the layout auto-select default ("bitsliced") for every band value
    (the band is a reach envelope, not a layout knob — no fallback);
  * LatencyHistogram percentiles / CDF / merge on the fixed log grid;
  * the admission-control property (seeded sweeps via tests/_propshim):
    a submission whose predicted completion still has positive slack is
    NEVER shed, and a blown prediction is always shed AND counted;
  * the hysteretic degrade ladder: deterministic down/up transitions
    under a fake clock, one per window, with the scrub_relax rung
    actually widening the effective scrub interval;
  * keep/drop bit-exactness vs the host oracle at EVERY ladder rung
    (sparse_egress returns only the kept events — none mislabeled);
  * service-keyed adaptive micro-batch sizing (shrink/hold/grow bands,
    floors and ceilings);
  * the single injected monotonic clock: wall time passing does NOT
    advance the server's notion of time (satellite: coalesce clock);
  * report() exposing the latency histograms, stage trace, deadline
    ledger and ladder state, and the committed BENCH_fabric.json
    carrying the gated latency/deadline records.
"""
import inspect
import json
import logging
import pathlib
import time

import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch import readout_server as rs_mod
from repro.launch.readout_server import (
    DEGRADE_RUNGS, LatencyHistogram, ReadoutServer, ServerConfig,
)
from tests._propshim import given, settings, strategies as st


class FakeClock:
    """Deterministic injected clock (mirrors test_readout_server)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# Module-level chip cache instead of only a fixture: the _propshim
# property tests are zero-argument wrappers (no fixture injection), so
# they pull the same two chips through this memo.
_CACHE = {}


def _duo():
    if "chips" not in _CACHE:
        d = generate(SmartPixelConfig(n_events=8_000, seed=11))
        tr, te = train_test_split(d)
        chips = []
        for depth, leaves in [(4, 8), (3, 5)]:
            clf = GradientBoostedClassifier(
                n_estimators=1, max_depth=depth, max_leaf_nodes=leaves,
                min_samples_leaf=200,
            ).fit(tr["features"], tr["label"])
            chip = ReadoutChip.build(clf)
            chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
            chips.append(chip)
        _CACHE["chips"] = chips
        _CACHE["X"] = te["features"]
    return _CACHE["chips"], _CACHE["X"]


@pytest.fixture(scope="module")
def duo():
    return _duo()


# ------------------------------------------------------- config validation
@pytest.mark.parametrize(
    "kw,msg",
    [
        (dict(deadline_us=0), "deadline_us must be a positive finite"),
        (dict(deadline_us=-3.5), "deadline_us must be a positive finite"),
        (dict(deadline_us=float("nan")),
         "deadline_us must be a positive finite"),
        (dict(deadline_us=float("inf")),
         "deadline_us must be a positive finite"),
        (dict(deadline_us=True), "deadline_us must be a positive finite"),
        (dict(deadline_us=500.0, overload_policy="panic"),
         "unknown overload_policy"),
        (dict(overload_policy="shed"), "needs deadline_us set"),
        (dict(overload_policy="degrade"), "needs deadline_us set"),
        (dict(degrade_rungs=()), "non-empty tuple"),
        (dict(degrade_rungs=("scrub_relax", "scrub_relax")),
         "duplicate degrade rungs"),
        (dict(degrade_rungs=("warp_core",)), "unknown degrade rung"),
        (dict(degrade_window=0), "degrade_window must be an int >= 1"),
        (dict(degrade_window=True), "degrade_window must be an int >= 1"),
        (dict(degrade_enter_frac=0.05, degrade_exit_frac=0.05),
         "hysteresis gap"),
        (dict(degrade_enter_frac=0.2, degrade_exit_frac=0.5),
         "hysteresis gap"),
        (dict(min_batch=0), "min_batch must be a positive int"),
        (dict(min_batch=True), "min_batch must be a positive int"),
    ],
)
def test_serverconfig_rejects_bad_deadline_knobs(kw, msg):
    with pytest.raises(ValueError, match=msg):
        ServerConfig(**kw)


def test_serverconfig_accepts_deadline_knobs():
    cfg = ServerConfig(
        deadline_us=750.0, overload_policy="degrade",
        degrade_rungs=["sparse_egress", "scrub_relax"],  # list coerces
        degrade_window=64, degrade_enter_frac=0.4, degrade_exit_frac=0.1,
        min_batch=16,
    )
    assert cfg.deadline_s == pytest.approx(7.5e-4)
    # rung ORDER is the ladder order — a custom order is preserved
    assert cfg.degrade_rungs == ("sparse_egress", "scrub_relax")
    # no deadline (the default) is fine with the default observe policy
    assert ServerConfig().deadline_s is None


# -------------------------------------------------- layout default (sat b)
def test_layout_defaults_bitsliced_for_every_band(duo, caplog):
    chips, _ = duo
    # auto-select: bit-sliced regardless of band — the band is a fan-in
    # reach envelope, not a kernel-structure knob, so banded geometry
    # packs bit-sliced directly and the matmul fallback no longer exists
    assert ServerConfig().effective_layout == "bitsliced"
    assert ServerConfig(band=True).effective_layout == "bitsliced"
    assert ServerConfig(band=False).effective_layout == "bitsliced"
    assert ServerConfig(layout="matmul").effective_layout == "matmul"

    logger = "repro.launch.readout_server"
    for cfg in (ServerConfig(backend="host"),
                ServerConfig(backend="host", band=False),
                ServerConfig(backend="host", band=True)):
        caplog.clear()
        with caplog.at_level(logging.INFO, logger=logger):
            srv = ReadoutServer(chips, cfg)
        assert srv.layout == "bitsliced", cfg.band
        assert not any("falling back" in r.getMessage()
                       for r in caplog.records), cfg.band


# --------------------------------------------------------- histogram unit
_BUCKET_W = 10.0 ** (1.0 / 8.0)     # one log bucket: the stated precision


def test_latency_histogram_percentiles_within_one_bucket():
    h = LatencyHistogram()
    h.add_many(np.asarray([10.0] * 90 + [10_000.0] * 10))
    assert h.count == 100
    assert 10.0 / _BUCKET_W <= h.percentile(50.0) <= 10.0 * _BUCKET_W
    assert (10_000.0 / _BUCKET_W <= h.percentile(99.0)
            <= 10_000.0 * _BUCKET_W)
    s = h.summary()
    assert s["count"] == 100
    assert s["max_us"] == 10_000.0
    assert s["mean_us"] == pytest.approx((90 * 10 + 10 * 10_000) / 100)
    # in-bucket interpolation may overshoot the observed max by up to
    # one bucket width — but never more
    assert s["p50_us"] <= s["p99_us"] <= s["p999_us"]
    assert s["p999_us"] <= s["max_us"] * _BUCKET_W


def test_latency_histogram_underflow_overflow_and_merge():
    a, b = LatencyHistogram(), LatencyHistogram()
    a.add_many(np.asarray([5.0, 50.0, 500.0]))
    b.add(0.2)          # underflow: below the 1 us grid floor
    b.add(2e9)          # overflow: above the 100 s grid ceiling
    b.add(7.0)
    a.merge(b)
    assert a.count == 6
    # overflow percentiles report the observed max, not a bucket edge
    assert a.percentile(100.0) == 2e9
    cdf = a.cdf()
    edges = [e for e, _ in cdf]
    fracs = [f for _, f in cdf]
    assert edges == sorted(edges)
    assert fracs == sorted(fracs)
    assert fracs[-1] == 1.0
    assert edges[-1] == 2e9    # final CDF point is the observed max
    # the underflow event is folded into the first point, never dropped
    assert fracs[0] >= 1.0 / 6.0
    assert LatencyHistogram().cdf() == []
    assert LatencyHistogram().percentile(99.0) == 0.0


# ------------------------------------------------ admission property (sat d)
@settings(max_examples=25)
@given(
    deadline_ms=st.floats(5.0, 50.0),
    ewma_ms=st.floats(0.0, 60.0),
    age_ms=st.floats(0.0, 60.0),
    depth=st.integers(0, 32),
)
def test_admission_never_sheds_positive_slack(
    deadline_ms, ewma_ms, age_ms, depth
):
    """The admission controller's contract, swept over (deadline, EWMA,
    queue age, queue depth): an event whose predicted completion
    (max(oldest wait, backlog drain) + service EWMA) is inside the
    budget is NEVER shed; a blown prediction is always shed and counted
    in the chip's n_shed — no silent drops either way."""
    chips, X = _duo()
    clock = FakeClock()
    srv = ReadoutServer(
        chips[:1],
        ServerConfig(backend="host", max_batch=4096, max_latency_s=1e9,
                     deadline_us=deadline_ms * 1e3, overload_policy="shed"),
        clock=clock,
    )
    if depth:
        seqs = srv.submit_batch(0, X[:depth])
        # queue was empty and the EWMA unseeded: all of these had slack
        assert all(s is not None for s in seqs)
    srv._service_ewma_s = ewma_ms * 1e-3
    clock.advance(age_ms * 1e-3)

    # recompute the controller's prediction independently: no drains
    # have landed, so the backlog term is 0 and the oldest-event wait
    # is exactly the fake-clock age of the queue head
    wait_s = age_ms * 1e-3 if depth else 0.0
    predicted_s = wait_s + srv._service_ewma_s

    seq = srv.submit(0, X[depth])
    n_shed = srv.report()["per_chip"][0]["n_shed"]
    if depth == 0 or predicted_s < deadline_ms * 1e-3:
        # positive slack (or the idle probe): must admit
        assert seq is not None
        assert n_shed == 0
    else:
        assert seq is None
        assert n_shed == 1


def test_observe_policy_and_no_deadline_never_shed(duo):
    chips, X = duo
    clock = FakeClock()
    srv = ReadoutServer(
        chips[:1],
        ServerConfig(backend="host", max_batch=4096, max_latency_s=1e9,
                     deadline_us=10.0, overload_policy="observe"),
        clock=clock,
    )
    srv.submit_batch(0, X[:16])
    clock.advance(1.0)          # queue head is 100_000 deadlines old
    srv._service_ewma_s = 1.0
    assert all(s is not None for s in srv.submit_batch(0, X[16:32]))
    got = srv.poll() + srv.flush()
    assert len(got) == 32       # observe: counted, never shed
    rep = srv.report()["deadline"]
    # only the first batch aged past the budget; the point is shed == 0
    assert rep["shed"] == 0
    assert rep["missed"] == 16 and rep["met"] == 16


# ----------------------------------------------------- degrade ladder
def test_degrade_ladder_hysteretic_descend_and_recover(duo):
    """Deterministic ladder walk under a fake clock: three all-miss
    windows step down one rung each (scrub_relax -> scrub_crc_only ->
    sparse_egress), three all-met windows step back up one each. The
    scrub_relax rung visibly widens the effective scrub interval while
    active, and every transition is timestamped with its miss_frac."""
    chips, X = duo
    clock = FakeClock()
    srv = ReadoutServer(
        chips[:1],
        ServerConfig(backend="host", max_batch=8, min_batch=1,
                     max_latency_s=1e9, deadline_us=1_000.0,
                     overload_policy="degrade", degrade_window=8,
                     degrade_enter_frac=0.5, degrade_exit_frac=0.05,
                     scrub_interval=5),
        clock=clock,
    )
    assert srv._effective_scrub_interval() == 5

    def round_trip(stall_s):
        # 8 submissions land at one instant (queue empty + zero EWMA ->
        # all admitted), then the clock jumps before the batch drains:
        # every event's end-to-end latency == stall_s, all met or all
        # missed vs the 1 ms budget. The drain-rate window is cleared
        # first: these deliberately stalled drains would otherwise teach
        # the admission controller's backlog term to shed mid-test, and
        # admission has its own property test — here the ladder is the
        # subject
        srv._drain_hist.clear()
        seqs = srv.submit_batch(0, X[:8])
        assert all(s is not None for s in seqs)
        clock.advance(stall_s)
        got = srv.poll()
        got += srv.flush()
        return got

    levels = [srv._rung_level]
    for _ in range(3):
        round_trip(0.005)       # 5 ms latency: the whole window misses
        levels.append(srv._rung_level)
    assert levels == [0, 1, 2, 3]
    rep = srv.report()["deadline"]["ladder"]
    assert rep["active_rungs"] == list(DEGRADE_RUNGS)
    # scrub_relax active: configured interval 5 widened by the factor
    assert srv._effective_scrub_interval() == 5 * rs_mod.SCRUB_RELAX_FACTOR

    # a fourth all-miss window cannot go below the last rung
    round_trip(0.005)
    assert srv._rung_level == 3

    for _ in range(3):
        round_trip(0.0)         # instant drains: the whole window meets
        levels.append(srv._rung_level)
    assert levels == [0, 1, 2, 3, 2, 1, 0]
    assert srv._effective_scrub_interval() == 5     # relax rung exited

    trans = srv.report()["deadline"]["ladder"]["transitions"]
    assert [t["direction"] for t in trans] == ["down"] * 3 + ["up"] * 3
    assert [t["rung"] for t in trans] == list(DEGRADE_RUNGS) + list(
        reversed(DEGRADE_RUNGS))
    assert all(t["miss_frac"] in (0.0, 1.0) for t in trans)
    ts = [t["t"] for t in trans]
    assert ts == sorted(ts)     # timestamped on the injected clock


def test_degrade_ladder_holds_between_hysteresis_bands(duo):
    """A window whose miss fraction falls INSIDE the hysteresis gap
    (exit_frac < miss < enter_frac) moves the ladder in neither
    direction — the no-flap guarantee."""
    chips, X = duo
    clock = FakeClock()
    srv = ReadoutServer(
        chips[:1],
        ServerConfig(backend="host", max_batch=8, min_batch=1,
                     max_latency_s=1e9, deadline_us=1_000.0,
                     overload_policy="degrade", degrade_window=8,
                     degrade_enter_frac=0.75, degrade_exit_frac=0.10),
        clock=clock,
    )
    srv._rung_level = 1         # start mid-ladder
    # first four age 0.8 ms before the rest arrive (still inside the
    # 1 ms budget, so admission control admits everything), then the
    # batch drains 0.3 ms later: the first four land at 1.1 ms (miss),
    # the last four at 0.3 ms (met) -> miss_frac 0.5, inside the gap
    srv.submit_batch(0, X[:4])
    clock.advance(0.0008)
    assert all(s is not None for s in srv.submit_batch(0, X[4:8]))
    clock.advance(0.0003)
    got = srv.poll() + srv.flush()
    assert len(got) == 8
    assert srv._rung_level == 1
    assert srv.report()["deadline"]["ladder"]["transitions"] == []


@pytest.mark.parametrize("level", [0, 1, 2, 3])
def test_rung_keep_drop_bit_exact_vs_host_oracle(duo, level):
    """Acceptance bar: keep/drop on ADMITTED events is bit-exact against
    the per-chip host oracle at every ladder rung. Rungs 1-2 touch only
    the scrub loop; rung 3 (sparse_egress) changes the EGRESS — only
    kept events cross the link — but never which events are kept, nor
    their scores."""
    chips, X = duo
    srv = ReadoutServer(
        chips,
        ServerConfig(backend="host", max_batch=64, max_latency_s=1e9,
                     deadline_us=60_000.0, overload_policy="degrade"),
    )
    srv._rung_level = level     # white-box: pin the ladder at this rung
    sub = {}
    for c in range(len(chips)):
        block = X[c * 40:(c + 1) * 40]
        seqs = srv.submit_batch(c, block)
        assert all(s is not None for s in seqs)
        sub[c] = (seqs, block)
    got = srv.poll() + srv.flush()
    by_seq = {r.seq: r for r in got}

    sparse = "sparse_egress" in srv.config.degrade_rungs[:level]
    for c, chip in enumerate(chips):
        seqs, block = sub[c]
        want_raw = chip.infer_raw(block, backend="host")
        want_keep = want_raw <= chip.score_threshold_raw
        if sparse:
            kept = {s for s, k in zip(seqs, want_keep) if k}
            assert set(seqs) & set(by_seq) == kept
            for s, raw, k in zip(seqs, want_raw, want_keep):
                if k:
                    assert by_seq[s].keep
                    assert by_seq[s].score_raw == raw
        else:
            for s, raw, k in zip(seqs, want_raw, want_keep):
                assert by_seq[s].keep == k
                assert by_seq[s].score_raw == raw
    # accounting sees every admitted event even when egress is sparse
    rep = srv.report()
    assert rep["n_in"] == len(chips) * 40
    assert rep["deadline"]["met"] + rep["deadline"]["missed"] == rep["n_in"]


# ------------------------------------------------- adaptive micro-batching
def test_adaptive_sizing_service_keyed_bands(duo):
    chips, _ = duo
    srv = ReadoutServer(
        chips[:1],
        ServerConfig(backend="host", max_batch=64, min_batch=8,
                     max_latency_s=1.0, deadline_us=10_000.0,
                     overload_policy="shed"),
    )
    dl = 0.010
    # construction: the coalesce window is pre-capped at half the budget
    assert srv._eff_max_batch == 64
    assert srv._lat_cap_s == pytest.approx(dl / 2)
    assert srv._eff_max_latency_s == pytest.approx(dl / 2)

    srv._adapt_batch(0.006, dl)             # svc > dl/2: shrink both
    assert srv._eff_max_batch == 32
    assert srv._eff_max_latency_s == pytest.approx(dl / 4)
    assert srv._batch_shrinks == 1

    for _ in range(10):
        srv._adapt_batch(0.006, dl)
    assert srv._eff_max_batch == 8          # floored at min_batch
    assert srv._eff_max_latency_s == pytest.approx(dl / 8)  # floored
    shrinks = srv._batch_shrinks

    srv._adapt_batch(0.004, dl)             # dl/4 < svc <= dl/2: hold
    assert srv._eff_max_batch == 8
    assert srv._batch_shrinks == shrinks and srv._batch_grows == 0

    srv._adapt_batch(0.002, dl)             # svc <= dl/4: grow both
    assert srv._eff_max_batch == 16
    assert srv._batch_grows == 1

    for _ in range(10):
        srv._adapt_batch(0.0, dl)
    assert srv._eff_max_batch == 64         # back at the config ceiling
    assert srv._eff_max_latency_s == pytest.approx(dl / 2)  # lat cap


# ------------------------------------------------ injected clock (sat c)
def test_single_injected_clock_ignores_wall_time(duo):
    """Coalesce-window and deadline decisions run on the ONE injected
    clock: real wall time passing moves nothing, advancing the fake
    clock moves everything, and the recorded latencies are fake-clock
    quantities."""
    chips, X = duo
    clock = FakeClock()
    srv = ReadoutServer(
        chips[:1],
        ServerConfig(backend="host", max_batch=64, max_latency_s=0.010,
                     deadline_us=20_000.0, overload_policy="shed"),
        clock=clock,
    )
    assert all(s is not None for s in srv.submit_batch(0, X[:4]))
    time.sleep(0.03)            # 3x the coalesce window of REAL time
    assert srv.poll() == []     # fake clock unmoved: batch not due
    assert srv.queue_depth == 4

    clock.advance(0.011)        # now due on the injected clock
    got = srv.poll()
    assert sorted(r.seq for r in got) == [0, 1, 2, 3]
    total = srv.report()["latency"]["total"]
    assert total["count"] == 4
    # 11 ms of fake time, NOT the 30+ ms of wall time we slept
    assert total["max_us"] == pytest.approx(11_000.0)
    rep = srv.report()["deadline"]
    assert rep["met"] == 4 and rep["missed"] == 0 and rep["shed"] == 0


def test_server_source_has_no_wall_clock_calls():
    """The injectable default is the ONLY monotonic reference and
    time.time() appears nowhere — mixing clocks is how coalesce-window
    bugs are born."""
    src = inspect.getsource(rs_mod)
    assert "time.time(" not in src
    assert src.count("time.monotonic") == 1     # the __init__ default


# ------------------------------------------------------- report + bench
def test_report_exposes_latency_and_deadline_sections(duo):
    chips, X = duo
    clock = FakeClock()
    srv = ReadoutServer(
        chips,
        ServerConfig(backend="host", max_batch=16, max_latency_s=1e9,
                     deadline_us=5_000.0, overload_policy="degrade"),
        clock=clock,
    )
    for c in range(len(chips)):
        srv.submit_batch(c, X[:8])
    clock.advance(0.001)
    got = srv.poll() + srv.flush()
    assert len(got) == 16

    rep = srv.report()
    lat = rep["latency"]
    for section in ("total", "queue_wait", "service"):
        s = lat[section]
        assert {"count", "mean_us", "max_us",
                "p50_us", "p99_us", "p999_us"} <= set(s)
    assert lat["total"]["count"] == 16
    fracs = [f for _, f in lat["cdf_us"]]
    assert fracs == sorted(fracs) and fracs[-1] == 1.0
    # monotonic stage trace of the last drained batch, offsets from the
    # oldest enqueue
    trace = lat["last_batch_trace_us"]
    stages = ["t_enqueued", "t_coalesced", "t_launched", "t_drained"]
    assert set(stages) <= set(trace)
    offs = [trace[k] for k in stages]
    assert offs[0] == 0.0 and offs == sorted(offs)

    dead = rep["deadline"]
    assert dead["deadline_us"] == 5_000.0 and dead["policy"] == "degrade"
    assert dead["met"] + dead["missed"] == 16
    assert dead["shed"] == 0
    assert {"miss_fraction", "service_ewma_us", "drain_rate_ev_s",
            "effective_max_batch", "effective_max_latency_s",
            "batch_shrinks", "batch_grows", "ladder"} <= set(dead)
    lad = dead["ladder"]
    assert {"level", "active_rungs", "transitions",
            "deferred_heals_pending"} <= set(lad)
    # per-chip tail + shed accounting surface in the per-chip rows too
    for row in rep["per_chip"]:
        assert "latency_p99_us" in row and "n_shed" in row


def test_committed_bench_carries_deadline_records():
    """The committed BENCH_fabric.json must carry the latency/deadline
    records the CI regression gate tracks (check_regression.py)."""
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fabric.json"
    doc = json.loads(path.read_text())
    by_name = {r["name"]: r for r in doc["records"]}
    for name in ("fabric.latency_p99", "fabric.latency_cdf",
                 "fabric.deadline_p99", "fabric.overload_shed_accounting",
                 "fabric.deadline_ladder", "fabric.deadline_square_wave"):
        assert name in by_name, name
    assert by_name["fabric.overload_shed_accounting"]["coverage"] == (
        pytest.approx(1.0))
    assert by_name["fabric.deadline_p99"]["p99_frac_of_deadline"] > 0
    cdf = by_name["fabric.latency_cdf"]["cdf_us"]
    fracs = [f for _, f in cdf]
    assert fracs == sorted(fracs) and fracs[-1] == 1.0
