"""Synthesis -> place&route -> bitstream -> fabric sim: the silicon loop."""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.bitstream import BitstreamError, decode, encode
from repro.core.fabric import (
    CapacityError, FABRIC_130NM, FABRIC_28NM, FabricSim, place_and_route,
)
from repro.core.netlist import NetlistBuilder, counter_netlist
from repro.core.nn_baseline import MLPSpec, lut_cost
from repro.core.synth import synth_ensemble, verify_against_golden
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split


@pytest.fixture(scope="module")
def chip_parts():
    d = generate(SmartPixelConfig(n_events=25_000, seed=9))
    tr, te = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10
    ).fit(tr["features"], tr["label"])
    ens = clf.quantized()
    synth = synth_ensemble(ens)
    return te, clf, ens, synth


def test_fabric_resource_totals_match_paper():
    t130 = FABRIC_130NM.totals()
    assert t130["logic_cells"] == 384          # §2.1
    assert t130["dsp_slices"] == 4
    assert t130["lutram_bits"] == 4 * 32 * 4   # 128 registers x 4b
    t28 = FABRIC_28NM.totals()
    assert t28["logic_cells"] == 448           # §4.1
    assert t28["dsp_slices"] == 4
    assert t28["lutram_bits"] == 0             # RegFile removed in 28nm


def test_bdt_fits_28nm(chip_parts):
    _, _, _, synth = chip_parts
    assert synth.report["luts"] <= 448          # the paper's 294-LUT result class
    cfgf = place_and_route(synth.netlist, FABRIC_28NM)
    assert cfgf.utilization()["lut_utilization"] <= 1.0


def test_nn_does_not_fit():
    cost = lut_cost(MLPSpec())
    assert cost["lut_total"] > 6_000            # §5: "over 6,000 LUTs"
    assert cost["lut_total"] > 448


def test_capacity_error_raised():
    b = NetlistBuilder()
    ins = b.input_bus(8)
    nets = ins
    for _ in range(500):  # ~500 LUTs > 448
        nets = [b.xor_(nets[0], nets[1])] + nets[1:]
    b.mark_output(nets[0])
    with pytest.raises(CapacityError):
        place_and_route(b.build(), FABRIC_28NM)


def test_synth_verifies_100pct(chip_parts):
    te, _, ens, synth = chip_parts
    X_raw = ens.quantize_features(te["features"][:4000])
    v = verify_against_golden(synth, ens, X_raw)
    assert v["accuracy"] == 1.0                 # the paper's headline result


def test_bitstream_roundtrip(chip_parts):
    _, _, _, synth = chip_parts
    cfgf = place_and_route(synth.netlist, FABRIC_28NM)
    bs = encode(cfgf)
    cfg2 = decode(bs)
    np.testing.assert_array_equal(cfgf.lut_inputs, cfg2.lut_inputs)
    np.testing.assert_array_equal(cfgf.lut_tables, cfg2.lut_tables)
    np.testing.assert_array_equal(cfgf.output_nets, cfg2.output_nets)
    assert cfgf.level_sizes == cfg2.level_sizes


@pytest.mark.parametrize("pos", [0, 5, 100, -5])
def test_bitstream_corruption_detected(chip_parts, pos):
    _, _, _, synth = chip_parts
    bs = bytearray(encode(place_and_route(synth.netlist, FABRIC_28NM)))
    bs[pos] ^= 0x40
    with pytest.raises(BitstreamError):
        decode(bytes(bs))


def test_fabric_sim_matches_netlist_eval(chip_parts):
    te, _, ens, synth = chip_parts
    cfgf = place_and_route(synth.netlist, FABRIC_28NM)
    X_raw = ens.quantize_features(te["features"][:512])
    bits = synth.encode_inputs(X_raw)
    want, _ = synth.netlist.evaluate(bits)
    got, _ = FabricSim(cfgf).run(bits)
    np.testing.assert_array_equal(got, want)


def test_counter_runs_on_both_fabrics():
    nl = counter_netlist(16)
    for fabric in (FABRIC_130NM, FABRIC_28NM):
        cfgf = place_and_route(nl, fabric)
        outs, _ = FabricSim(cfgf).run(
            np.zeros((1, 0)), n_cycles=50, trace_outputs=True)
        vals = (outs[0] * (1 << np.arange(16))).sum(-1)
        np.testing.assert_array_equal(vals, np.arange(50))


def test_multi_tree_synthesis(chip_parts):
    te, _, _, _ = chip_parts
    d = generate(SmartPixelConfig(n_events=8_000, seed=11))
    tr, t2 = train_test_split(d)
    clf = GradientBoostedClassifier(n_estimators=3, max_depth=3).fit(
        tr["features"], tr["label"])
    ens = clf.quantized()
    synth = synth_ensemble(ens)
    X_raw = ens.quantize_features(t2["features"][:1500])
    v = verify_against_golden(synth, ens, X_raw)
    assert v["accuracy"] == 1.0                 # adder path exact too


def test_bitstream_roundtrip_random_netlists_property():
    """Property: encode∘decode is identity for arbitrary random netlists,
    and the decoded config executes identically (seeded sweep)."""
    from tests.test_kernels import _random_netlist

    rng = np.random.default_rng(123)
    for seed in range(6):
        nl = _random_netlist(seed, int(rng.integers(4, 20)),
                             int(rng.integers(5, 120)))
        cfg = place_and_route(nl, FABRIC_28NM)
        cfg2 = decode(encode(cfg))
        bits = rng.integers(0, 2, (16, len(nl.inputs))).astype(np.uint8)
        a, _ = FabricSim(cfg).run(bits)
        b, _ = FabricSim(cfg2).run(bits)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(cfg.lut_tables, cfg2.lut_tables)


def test_bitstream_decode_stack_evaluate_all_fabrics_seeded_sweep():
    """Bitstream fidelity through the FULL multi-chip path, for every
    fabric in FABRICS: encode -> decode -> stack (chip-batched padding) ->
    one kernel dispatch == the original configs' per-chip FabricSim
    outputs, bit for bit (seeded sweep over random netlists)."""
    from repro.core.fabric import FABRICS, MultiFabricSim
    from repro.kernels.lut_eval import ops as lut_ops
    from tests.test_kernels import _random_netlist

    # every *distinct* registered fabric (core.tmr registers an XL variant
    # at import time, so the set is open-ended — sweep whatever is there)
    fabric_names = sorted({s.name for s in FABRICS.values()})
    assert {"efpga_130nm", "efpga_28nm"} <= set(fabric_names)
    for fi, name in enumerate(fabric_names):
        spec = FABRICS[name]
        rng = np.random.default_rng(1000 + fi)
        originals, decoded = [], []
        for seed in range(3):
            nl = _random_netlist(100 * fi + seed, int(rng.integers(4, 16)),
                                 int(rng.integers(10, 90)))
            cfg = place_and_route(nl, spec)
            originals.append(cfg)
            decoded.append(decode(encode(cfg)))  # through the wire format

        stack = lut_ops.pack_fabrics(decoded)
        per_chip = [
            rng.integers(0, 2, (11, c.n_inputs)).astype(np.uint8)
            for c in decoded
        ]
        bits = lut_ops.stack_input_bits(stack, per_chip)
        got = np.asarray(lut_ops.fabric_eval_multi(stack, bits))
        # oracle: the ORIGINAL (never-encoded) configs, chip by chip
        want = MultiFabricSim(originals).run(bits)
        np.testing.assert_array_equal(got, want)


def test_fabric_eval_deterministic():
    """Same bitstream + same inputs -> bit-identical outputs across runs
    and across backends (the reproducibility property the 40 MHz trigger
    chain requires)."""
    from repro.kernels.lut_eval import ops as lut_ops
    from tests.test_kernels import _random_netlist

    nl = _random_netlist(5, 10, 80)
    cfg = place_and_route(nl, FABRIC_28NM)
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 2, (128, 10)).astype(np.uint8)
    a, _ = FabricSim(cfg).run(bits)
    b, _ = FabricSim(cfg).run(bits)
    c = np.asarray(lut_ops.fabric_eval(cfg, bits))
    d = np.asarray(lut_ops.fabric_eval(cfg, bits))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(c, d)
    np.testing.assert_array_equal(a, c)
