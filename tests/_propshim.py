"""Seeded-random fallback for the small `hypothesis` subset these tests use.

The container may not ship `hypothesis`; property tests degrade to
deterministic seeded-random parametrized sweeps so the suite always collects
and runs. The API mirrors the subset used in this repo:

    from tests._propshim import given, settings, strategies as st

    @given(a=st.floats(-1, 1), seed=st.integers(0, 100), data=st.data())
    @settings(max_examples=50, deadline=None)
    def test_something(a, seed, data):
        vals = data.draw(st.lists(st.integers(0, 7), min_size=1, max_size=4))

Semantics: `given` runs the test body `max_examples` times (default 25),
drawing each keyword from its strategy with an RNG seeded from the test
name — fully deterministic across runs and machines, no shrinking.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw_fn(rng)


class _DataObject:
    """Stand-in for hypothesis's `data()` value: draw mid-test."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.draw(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


class strategies:
    """The `strategies as st` namespace (subset)."""

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        # hypothesis bounds are inclusive
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value, endpoint=True))
        )

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size, endpoint=True))
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

    @staticmethod
    def data() -> _Strategy:
        return _DataStrategy()


st = strategies


def settings(max_examples: int = 25, deadline=None, **_ignored):
    """Records the sweep size for `given` to pick up; no-op otherwise."""

    def deco(fn):
        fn._propshim_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    """Run the test as a seeded sweep over the given strategies."""

    def deco(fn):
        n = getattr(fn, "_propshim_max_examples", 25)
        # seed from the test name so every test gets a distinct, stable sweep
        base_seed = zlib.crc32(fn.__qualname__.encode())

        # NOTE: deliberately a zero-argument function (and no functools.wraps,
        # whose __wrapped__ would expose the original signature) so pytest
        # does not mistake the strategy keywords for fixtures.
        def wrapper():
            for ex in range(n):
                rng = np.random.default_rng(
                    np.random.SeedSequence([base_seed, ex])
                )
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(**drawn)
                except Exception as e:
                    shown = {k: v for k, v in drawn.items()
                             if not isinstance(v, _DataObject)}
                    raise AssertionError(
                        f"propshim example {ex}/{n} failed with drawn values "
                        f"{shown}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
