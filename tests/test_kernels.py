"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles +
independent numpy oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import FABRIC_28NM, FabricSim, place_and_route
from repro.core.netlist import NetlistBuilder
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels.bdt_infer import ops as bdt_ops
from repro.kernels.bdt_infer.ref import bdt_infer_ref
from repro.kernels.lut_eval import ops as lut_ops
from repro.kernels.lut_eval.ref import fabric_eval_ref


@pytest.fixture(scope="module")
def trained():
    d = generate(SmartPixelConfig(n_events=20_000, seed=3))
    tr, te = train_test_split(d)
    return tr, te


def _random_netlist(seed: int, n_inputs: int, n_luts: int):
    rng = np.random.default_rng(seed)
    b = NetlistBuilder()
    ins = b.input_bus(n_inputs)
    nets = list(ins)
    for _ in range(n_luts):
        srcs = rng.choice(len(nets), size=rng.integers(1, 5), replace=False)
        table = int(rng.integers(0, 2**16))
        nets.append(b.lut(table, [nets[s] for s in srcs]))
    for n in nets[-min(8, len(nets)):]:
        b.mark_output(n)
    return b.build()


@pytest.mark.parametrize("seed,n_inputs,n_luts,batch", [
    (0, 4, 10, 8),
    (1, 16, 60, 64),
    (2, 40, 200, 128),
    (3, 7, 300, 257),   # batch not a tile multiple (padding path)
])
def test_lut_eval_random_netlists(seed, n_inputs, n_luts, batch):
    nl = _random_netlist(seed, n_inputs, n_luts)
    cfgf = place_and_route(nl, FABRIC_28NM)
    rng = np.random.default_rng(seed + 100)
    bits = rng.integers(0, 2, (batch, n_inputs)).astype(np.uint8)
    want, _ = FabricSim(cfgf).run(bits)
    packed = lut_ops.pack_fabric(cfgf)
    ref = np.asarray(fabric_eval_ref(packed, jnp.asarray(bits)))
    got = np.asarray(lut_ops.fabric_eval(packed, bits))
    np.testing.assert_array_equal(ref, want)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("in_dtype", [np.uint8, np.int32, np.float32])
def test_lut_eval_input_dtypes(trained, in_dtype):
    nl = _random_netlist(7, 12, 40)
    cfgf = place_and_route(nl, FABRIC_28NM)
    bits = np.random.default_rng(0).integers(0, 2, (32, 12))
    want, _ = FabricSim(cfgf).run(bits.astype(np.uint8))
    got = np.asarray(lut_ops.fabric_eval(cfgf, bits.astype(in_dtype)))
    np.testing.assert_array_equal(got, want)


def test_lut_eval_rejects_sequential():
    from repro.core.netlist import counter_netlist

    cfgf = place_and_route(counter_netlist(8), FABRIC_28NM)
    with pytest.raises(ValueError, match="combinational"):
        lut_ops.pack_fabric(cfgf)


@pytest.mark.parametrize("n_estimators,max_depth,batch", [
    (1, 5, 64),
    (2, 3, 256),
    (4, 4, 100),
    (3, 6, 513),
])
def test_bdt_infer_sweep(trained, n_estimators, max_depth, batch):
    tr, te = trained
    clf = GradientBoostedClassifier(
        n_estimators=n_estimators, max_depth=max_depth
    ).fit(tr["features"], tr["label"])
    ens = clf.quantized()
    packed = bdt_ops.pack_ensemble(ens, n_features=14)
    X_raw = ens.quantize_features(te["features"][:batch]).astype(np.int32)
    want = ens.decision_function_raw(X_raw)
    ref = np.asarray(bdt_infer_ref(packed, jnp.asarray(X_raw)))
    got = np.asarray(bdt_ops.bdt_infer(packed, X_raw))
    np.testing.assert_array_equal(ref, want)
    np.testing.assert_array_equal(got, want)


def test_bdt_infer_extreme_raw_values(trained):
    """int32 exactness at the edges of the ap_fixed<28,19> raw range."""
    tr, _ = trained
    clf = GradientBoostedClassifier(n_estimators=1, max_depth=5).fit(
        tr["features"], tr["label"])
    ens = clf.quantized()
    packed = bdt_ops.pack_ensemble(ens, n_features=14)
    rng = np.random.default_rng(0)
    X_raw = rng.integers(
        ens.spec.raw_min, ens.spec.raw_max, (256, 14)
    ).astype(np.int32)
    want = ens.decision_function_raw(X_raw)
    got = np.asarray(bdt_ops.bdt_infer(packed, X_raw))
    np.testing.assert_array_equal(got, want)


def test_kernel_matches_fabric_end_to_end(trained):
    """lut_eval(bitstream) == bdt_infer(tree) == golden — all three paths."""
    tr, te = trained
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10
    ).fit(tr["features"], tr["label"])
    ens = clf.quantized()
    synth = synth_ensemble(ens)
    cfgf = place_and_route(synth.netlist, FABRIC_28NM)
    X = te["features"][:300]
    X_raw = ens.quantize_features(X)
    golden = ens.decision_function_raw(X_raw)

    bits = synth.encode_inputs(X_raw)
    fabric_out = synth.decode_outputs(
        np.asarray(lut_ops.fabric_eval(cfgf, bits)))
    tree_out = np.asarray(bdt_ops.bdt_infer(ens, X_raw.astype(np.int32), n_features=14))
    np.testing.assert_array_equal(fabric_out, golden)
    np.testing.assert_array_equal(tree_out, golden)
