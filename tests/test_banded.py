"""Banded lut_eval routing + depth-reducing ensemble synthesis.

Covers the coupled perf optimizations end to end:
  (a) fan-in-reach analysis (netlist / decoded-bitstream level)
  (b) banded-vs-dense bit-exactness across every registered fabric and an
      ensemble chip, through encode -> pack(banded) -> evaluate vs the
      host oracle
  (c) carry-select adders + balanced tree reduction: exhaustive adder
      exactness, ensemble exactness, and the depth/reach reduction itself
  (d) banded stacks keep the hot-swap guarantees: no retrace on swap, and
      configs whose reach exceeds the band are rejected identically at
      the stack and server envelope layers
"""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.bitstream import decode, encode
from repro.core.fabric import (
    FABRICS, FabricSim, MultiFabricSim, StackGeometry, place_and_route,
)
from repro.core.netlist import NetlistBuilder
from repro.core.quantize import FixedSpec
from repro.core.synth import _carry_select_add, synth_ensemble, verify_against_golden
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels.lut_eval import ops as lut_ops
from repro.kernels.lut_eval.ref import fabric_eval_ref
from tests.test_kernels import _random_netlist

import repro.core.tmr  # noqa: F401  (registers efpga_28nm_xl)


# ------------------------------------------------------------ helpers
def _layered_netlist(seed: int, n_inputs: int, width: int, levels: int):
    """Netlist whose every LUT reads only the immediately preceding level
    (or primary inputs at level 0) -> fan-in reach exactly 1."""
    rng = np.random.default_rng(seed)
    b = NetlistBuilder()
    prev = b.input_bus(n_inputs)
    for _ in range(levels):
        nxt = []
        for _ in range(width):
            srcs = rng.choice(len(prev), size=min(4, len(prev)), replace=False)
            nxt.append(b.lut(int(rng.integers(0, 2**16)), [prev[s] for s in srcs]))
        prev = nxt
    for n in prev:
        b.mark_output(n)
    return b.build()


def _long_edge_netlist(n_inputs: int, chain: int):
    """A buffer chain whose last LUT also reads the chain's first LUT
    output -> fan-in reach == chain - 1."""
    b = NetlistBuilder()
    ins = b.input_bus(n_inputs)
    first = b.buf(ins[0])
    cur = first
    for _ in range(chain - 2):
        cur = b.buf(cur)
    out = b.fn(lambda x, y: x ^ y, cur, first)  # spans the whole chain
    b.mark_output(out)
    return b.build()


@pytest.fixture(scope="module")
def ensemble_parts():
    d = generate(SmartPixelConfig(n_events=10_000, seed=21))
    tr, te = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=4, max_depth=3, max_leaf_nodes=6, min_samples_leaf=300,
    ).fit(tr["features"], tr["label"])
    ens = clf.quantized(FixedSpec(width=16, int_bits=8))
    return ens, te["features"]


# ------------------------------------------------------------------ (a)
def test_fanin_reach_layered_is_one():
    nl = _layered_netlist(0, 8, 6, levels=4)
    lv = nl.to_levelized()
    assert lv.fanin_reach() == 1
    cfg = place_and_route(nl, FABRICS["efpga_28nm"])
    assert cfg.fanin_reach() == 1


def test_fanin_reach_long_edge():
    nl = _long_edge_netlist(2, chain=7)
    assert nl.to_levelized().fanin_reach() == 6
    cfg = place_and_route(nl, FABRICS["efpga_28nm"])
    assert cfg.fanin_reach() == 6
    # reach survives the wire format (derived from decoded arrays)
    assert decode(encode(cfg)).fanin_reach() == 6


def test_fanin_reach_in_union_geometry():
    cfgs = [
        place_and_route(_layered_netlist(1, 6, 4, levels=3), FABRICS["efpga_28nm"]),
        place_and_route(_long_edge_netlist(2, chain=5), FABRICS["efpga_28nm"]),
    ]
    geo = StackGeometry.union(cfgs)
    assert geo.fanin_reach == 4
    assert all(geo.admits(c) for c in cfgs)


# ------------------------------------------------------------------ (b)
def test_banded_vs_dense_bit_exact_every_fabric():
    """encode -> decode -> pack(banded / dense) -> evaluate == host oracle,
    for every distinct registered fabric (open-ended set)."""
    import jax.numpy as jnp

    fabric_names = sorted({s.name for s in FABRICS.values()})
    assert {"efpga_130nm", "efpga_28nm", "efpga_28nm_xl"} <= set(fabric_names)
    for fi, name in enumerate(fabric_names):
        nl = _random_netlist(40 + fi, 10, 48)
        cfg = decode(encode(place_and_route(nl, FABRICS[name])))
        rng = np.random.default_rng(fi)
        bits = rng.integers(0, 2, (17, cfg.n_inputs)).astype(np.uint8)
        want, _ = FabricSim(cfg).run(bits)
        for band in (True, False):
            packed = lut_ops.pack_fabric(cfg, band=band)
            assert packed.banded == (band and packed.band_k < packed.n_levels)
            got = np.asarray(lut_ops.fabric_eval(packed, bits))
            ref = np.asarray(fabric_eval_ref(packed, jnp.asarray(bits)))
            np.testing.assert_array_equal(got, want, err_msg=f"{name} band={band}")
            np.testing.assert_array_equal(ref, want, err_msg=f"{name} band={band}")


def test_banded_window_is_smaller_and_aligned():
    nl = _layered_netlist(3, 12, 10, levels=8)
    cfg = place_and_route(nl, FABRICS["efpga_28nm"])
    packed = lut_ops.pack_fabric(cfg)  # auto: reach 1 << 8 levels -> banded
    assert packed.banded and packed.band_k == 1
    assert packed.sel.shape[1] == packed.in_seg + packed.band_k * packed.m_pad
    assert packed.sel.shape[1] < packed.n_nets_pad
    win = np.asarray(packed.win_base)
    assert (win % 128 == 0).all()
    assert win[0] == packed.in_seg
    # window of level l starts at level max(0, l-K)
    want = packed.in_seg + np.maximum(
        np.arange(packed.n_levels) - packed.band_k, 0) * packed.m_pad
    np.testing.assert_array_equal(win, want)


def test_dense_fallback_when_band_not_cheaper():
    # single-level netlist: the window would cover every level, so the
    # auto choice falls back to the dense layout
    b = NetlistBuilder()
    x = b.input_bus(4)
    b.mark_output(b.fn(lambda a, c: a & c, x[0], x[1]))
    b.mark_output(b.fn(lambda a, c: a ^ c, x[2], x[3]))
    cfg = place_and_route(b.build(), FABRICS["efpga_28nm"])
    assert cfg.fanin_reach() == 1 and len(cfg.level_sizes) == 1
    packed = lut_ops.pack_fabric(cfg)  # auto
    assert not packed.banded
    assert packed.sel.shape[1] == packed.n_nets_pad

    # worst-case reach (level L-1 reads level 0) still bands, but the
    # window only drops a single level's worth of rows
    nl = _long_edge_netlist(2, chain=6)
    cfg2 = place_and_route(nl, FABRICS["efpga_28nm"])
    L = len(cfg2.level_sizes)
    assert cfg2.fanin_reach() == L - 1
    p2 = lut_ops.pack_fabric(cfg2)
    assert p2.banded and p2.band_k == L - 1
    assert p2.sel.shape[1] == p2.in_seg + (L - 1) * p2.m_pad
    # forcing dense is always available
    p3 = lut_ops.pack_fabric(cfg2, band=False)
    assert not p3.banded and p3.sel.shape[1] == p3.n_nets_pad


def test_ensemble_chip_banded_through_bitstream(ensemble_parts):
    """The deep-ensemble chip (tree-reduction synthesis), through the wire
    format, evaluated banded — must match the host oracle and the golden
    quantized model on every event."""
    ens, X = ensemble_parts
    synth = synth_ensemble(ens, adder="tree")
    cfg = decode(encode(place_and_route(synth.netlist, FABRICS["efpga_28nm_xl"])))
    packed = lut_ops.pack_fabric(cfg)
    assert packed.banded, "tree-reduction ensembles must band (reach << depth)"

    X_raw = ens.quantize_features(X[:96])
    bits = synth.encode_inputs(X_raw)
    want, _ = FabricSim(cfg).run(bits)
    got = np.asarray(lut_ops.fabric_eval(packed, bits))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        synth.decode_outputs(got), ens.decision_function_raw(X_raw)
    )


@pytest.mark.slow
def test_banded_vs_dense_every_fabric_seeded_sweep():
    """Long sweep: several random netlists per fabric, banded == dense ==
    host oracle bit for bit."""
    fabric_names = sorted({s.name for s in FABRICS.values()})
    for fi, name in enumerate(fabric_names):
        rng = np.random.default_rng(500 + fi)
        for seed in range(4):
            nl = _random_netlist(700 + 10 * fi + seed, int(rng.integers(4, 16)),
                                 int(rng.integers(20, 140)))
            cfg = place_and_route(nl, FABRICS[name])
            bits = rng.integers(0, 2, (65, cfg.n_inputs)).astype(np.uint8)
            want, _ = FabricSim(cfg).run(bits)
            banded = np.asarray(lut_ops.fabric_eval(cfg, bits, band=True))
            dense = np.asarray(lut_ops.fabric_eval(cfg, bits, band=False))
            np.testing.assert_array_equal(banded, want)
            np.testing.assert_array_equal(dense, want)


# ------------------------------------------------------------------ (c)
@pytest.mark.parametrize("block", [1, 2, 3, 4, 5])
def test_carry_select_add_exhaustive(block):
    """All 64x64 6-bit operand pairs, every block size: wraps exactly."""
    b = NetlistBuilder()
    a = b.input_bus(6)
    c = b.input_bus(6)
    for net in _carry_select_add(b, a, c, block=block):
        b.mark_output(net)
    nl = b.build()
    xs = np.arange(64)
    A, C = [m.ravel() for m in np.meshgrid(xs, xs, indexing="ij")]
    bits = np.concatenate(
        [(A[:, None] >> np.arange(6)) & 1, (C[:, None] >> np.arange(6)) & 1],
        axis=1,
    ).astype(np.uint8)
    out, _ = nl.evaluate(bits)
    got = (out * (1 << np.arange(6))).sum(-1)
    np.testing.assert_array_equal(got, (A + C) & 63)


def test_tree_reduction_cuts_depth_and_reach(ensemble_parts):
    ens, X = ensemble_parts
    s_ripple = synth_ensemble(ens, adder="ripple")
    s_tree = synth_ensemble(ens, adder="tree")
    lv_r = s_ripple.netlist.to_levelized()
    lv_t = s_tree.netlist.to_levelized()
    assert len(lv_t.level_sizes) < len(lv_r.level_sizes)
    assert lv_t.fanin_reach() < lv_r.fanin_reach()
    # both summation structures are exact vs the golden quantized model
    X_raw = ens.quantize_features(X[:600])
    assert verify_against_golden(s_ripple, ens, X_raw)["accuracy"] == 1.0
    assert verify_against_golden(s_tree, ens, X_raw)["accuracy"] == 1.0


def test_synth_rejects_unknown_adder(ensemble_parts):
    ens, _ = ensemble_parts
    with pytest.raises(ValueError, match="adder"):
        synth_ensemble(ens, adder="kogge-stone")


# ------------------------------------------------------------------ (d)
def _banded_stack_and_bits(seed=0):
    cfgs = [
        place_and_route(_layered_netlist(seed + i, 8, 6, levels=5 + i),
                        FABRICS["efpga_28nm"])
        for i in range(3)
    ]
    stack = lut_ops.pack_fabrics(cfgs, band=True)
    rng = np.random.default_rng(seed)
    per = [rng.integers(0, 2, (9, c.n_inputs)).astype(np.uint8) for c in cfgs]
    return cfgs, stack, per


def test_banded_stack_matches_host_oracle():
    cfgs, stack, per = _banded_stack_and_bits()
    assert stack.banded and stack.band_k == 1
    bits = lut_ops.stack_input_bits(stack, per)
    got = np.asarray(lut_ops.fabric_eval_multi(stack, bits))
    want = MultiFabricSim(cfgs).run(bits)
    np.testing.assert_array_equal(got, want)


def test_banded_swap_chip_no_retrace():
    """Hot-swap into a *banded* stack: array swap, no recompile, still
    bit-exact (fast tier — the stack is tiny)."""
    if not hasattr(lut_ops._eval_stack_arrays, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    cfgs, stack, per = _banded_stack_and_bits(seed=10)
    bits = lut_ops.stack_input_bits(stack, per)
    np.asarray(lut_ops.fabric_eval_multi(stack, bits))
    n0 = lut_ops._eval_stack_arrays._cache_size()

    new = place_and_route(
        _layered_netlist(99, 6, 5, levels=4), FABRICS["efpga_28nm"])
    assert new.fanin_reach() <= stack.band_k
    stack2 = stack.swap_chip(1, new)
    assert stack2.band_k == stack.band_k
    rng = np.random.default_rng(2)
    per2 = list(per)
    per2[1] = rng.integers(0, 2, (9, new.n_inputs)).astype(np.uint8)
    bits2 = lut_ops.stack_input_bits(stack2, per2)
    got = np.asarray(lut_ops.fabric_eval_multi(stack2, bits2))
    assert lut_ops._eval_stack_arrays._cache_size() == n0, "swap retraced"

    swapped = [cfgs[0], new, cfgs[2]]
    geo = StackGeometry(
        n_levels=stack.n_levels, max_level_size=stack.m_pad,
        n_inputs=stack.n_inputs, n_outputs=stack.n_outputs,
    )
    want = MultiFabricSim(swapped, geometry=geo).run(bits2)
    np.testing.assert_array_equal(got, want)


def test_banded_swap_rejects_reach_exceeding_band():
    cfgs, stack, _ = _banded_stack_and_bits(seed=20)
    deep = place_and_route(_long_edge_netlist(2, chain=4), FABRICS["efpga_28nm"])
    assert deep.fanin_reach() > stack.band_k
    assert len(deep.level_sizes) <= stack.n_levels  # only the band blocks it
    with pytest.raises(ValueError, match="envelope"):
        stack.swap_chip(0, deep)
    # a dense stack over the same configs admits the same chip fine
    dense = lut_ops.pack_fabrics(cfgs, band=False)
    dense.swap_chip(0, deep)


def test_server_envelope_includes_reach_on_both_backends():
    """A reach-exceeding hot-swap is refused by the geometry check on the
    host AND kernel servers — before any backend-specific packing."""
    import types

    from repro.core.readout import ReadoutChip  # noqa: F401 (import check)
    from repro.launch.readout_server import ReadoutServer, ServerConfig

    d = generate(SmartPixelConfig(n_events=6_000, seed=31))
    tr, _ = train_test_split(d)
    chips = []
    for depth in (4, 3):
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=depth, max_leaf_nodes=8,
            min_samples_leaf=200,
        ).fit(tr["features"], tr["label"])
        chips.append(ReadoutChip.build(clf))
    geo = StackGeometry.union([c.config for c in chips])
    deep = place_and_route(
        _long_edge_netlist(2, chain=geo.n_levels), FABRICS["efpga_28nm"])
    assert deep.fanin_reach() > (geo.fanin_reach or 0)
    for backend in ("host", "kernel"):
        # the fan-in-reach envelope is layout-independent: the band is a
        # reach budget, not a kernel structure, so a banded stack refuses
        # the swap identically via the matmul kernel and the bit-sliced
        # word path
        for layout in ("matmul", "bitsliced"):
            srv = ReadoutServer(list(chips), ServerConfig(
                max_batch=1_000, max_latency_s=1e9, backend=backend,
                layout=layout))
            with pytest.raises(ValueError, match="envelope"):
                srv.reconfigure(0, types.SimpleNamespace(config=deep))
        # forcing dense opts out of the band — and of its reach budget, so
        # the same swap is admitted (identically on both backends)
        srv_dense = ReadoutServer(list(chips), ServerConfig(
            max_batch=1_000, max_latency_s=1e9, backend=backend, band=False))
        assert srv_dense.geometry.fanin_reach is None
        assert srv_dense.geometry.admits(deep)
