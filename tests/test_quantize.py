"""Property tests for ap_fixed<W,I> semantics (core/quantize.py)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to the seeded sweep shim (tests/_propshim.py)
    from tests._propshim import given, settings, strategies as st

from repro.core.quantize import (
    AP_FIXED_28_19, FixedSpec, dequantize_raw, fx_add, fx_lt, fx_mul,
    quantize, quantize_raw, to_unsigned_bits, unsigned_bit,
)

SPECS = [
    AP_FIXED_28_19,
    FixedSpec(16, 8),
    FixedSpec(12, 12),          # integer-only
    FixedSpec(10, 2, rounding="rnd"),
    FixedSpec(28, 19, overflow="sat"),
]


@pytest.mark.parametrize("spec", SPECS)
def test_quantize_idempotent(spec):
    x = np.linspace(spec.min_value * 0.9, spec.max_value * 0.9, 1001)
    q1 = quantize(x, spec)
    q2 = quantize(q1, spec)
    np.testing.assert_array_equal(q1, q2)


@pytest.mark.parametrize("spec", SPECS)
def test_raw_range(spec):
    x = np.random.default_rng(0).uniform(-1e7, 1e7, 10_000)
    raw = quantize_raw(x, spec)
    assert raw.min() >= spec.raw_min and raw.max() <= spec.raw_max


def test_trn_floors():
    spec = FixedSpec(16, 8)  # resolution 1/256
    assert quantize_raw(0.999 / 256, spec) == 0
    assert quantize_raw(1.001 / 256, spec) == 1
    assert quantize_raw(-0.5 / 256, spec) == -1  # floor toward -inf


def test_rnd_rounds_half_up():
    spec = FixedSpec(16, 8, rounding="rnd")
    assert quantize_raw(0.5 / 256, spec) == 1
    assert quantize_raw(0.49 / 256, spec) == 0


def test_saturation_vs_wrap():
    sat = FixedSpec(8, 8, overflow="sat")
    wrap = FixedSpec(8, 8, overflow="wrap")
    assert quantize_raw(1000.0, sat) == 127
    assert quantize_raw(-1000.0, sat) == -128
    w = int(quantize_raw(130.0, wrap))
    assert w == 130 - 256  # two's-complement wraparound


@given(
    a=st.floats(-1000, 1000),
    b=st.floats(-1000, 1000),
)
@settings(max_examples=200, deadline=None)
def test_unsigned_order_preserving(a, b):
    """a < b  <=>  u(a) < u(b): the comparator-synthesis invariant."""
    spec = AP_FIXED_28_19
    ra, rb = int(quantize_raw(a, spec)), int(quantize_raw(b, spec))
    ua, ub = int(to_unsigned_bits(ra, spec)), int(to_unsigned_bits(rb, spec))
    assert (ra < rb) == (ua < ub)
    assert (ra == rb) == (ua == ub)


@given(x=st.floats(-100, 100))
@settings(max_examples=200, deadline=None)
def test_bits_roundtrip(x):
    spec = AP_FIXED_28_19
    raw = int(quantize_raw(x, spec))
    u = int(to_unsigned_bits(raw, spec))
    bits = [int(unsigned_bit(u, k)) for k in range(spec.width)]
    u2 = sum(b << k for k, b in enumerate(bits))
    assert u2 == u


@given(a=st.floats(-500, 500), b=st.floats(-500, 500))
@settings(max_examples=200, deadline=None)
def test_fx_add_exact_within_range(a, b):
    spec = AP_FIXED_28_19
    ra, rb = quantize_raw(a, spec), quantize_raw(b, spec)
    s = fx_add(ra, rb, spec)
    expect = float(dequantize_raw(ra, spec) + dequantize_raw(rb, spec))
    if spec.min_value <= expect <= spec.max_value:
        assert float(dequantize_raw(s, spec)) == pytest.approx(expect, abs=1e-9)


@given(a=st.floats(-30, 30), b=st.floats(-30, 30))
@settings(max_examples=100, deadline=None)
def test_fx_mul_truncates_toward_minus_inf(a, b):
    spec = FixedSpec(20, 10)
    ra, rb = quantize_raw(a, spec), quantize_raw(b, spec)
    prod = float(dequantize_raw(ra, spec) * dequantize_raw(rb, spec))
    got = float(dequantize_raw(fx_mul(ra, rb, spec), spec))
    if spec.min_value <= prod <= spec.max_value:
        assert got <= prod + 1e-9
        assert prod - got < spec.resolution


def test_fx_lt_matches_float():
    spec = AP_FIXED_28_19
    rng = np.random.default_rng(1)
    x = quantize_raw(rng.normal(0, 100, 1000), spec)
    y = quantize_raw(rng.normal(0, 100, 1000), spec)
    np.testing.assert_array_equal(fx_lt(x, y), x < y)
