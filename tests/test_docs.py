"""Docs-coverage gate (fast tier): the operator docs cannot silently
drift from the code. Every ``report()`` top-level key (server AND
fleet, plus the per-tenant ledger) must appear in
docs/architecture.md, and every regression-gate key / required bench
prefix must appear in docs/benchmarks.md — keys are derived LIVE from
the running code, so adding a counter without documenting it fails CI.
"""
from pathlib import Path

DOCS = Path(__file__).resolve().parent.parent / "docs"


def test_architecture_documents_every_report_key():
    from repro.launch.fleet import TenantFleet
    from repro.launch.readout_server import ReadoutServer, ServerConfig
    from tests.test_fleet import _get_farm

    chips, X = _get_farm()
    cfg = ServerConfig(max_batch=64, max_latency_s=1e9, backend="host")
    srv = ReadoutServer([chips[0]], cfg)
    srv.submit_batch(0, X[:4])
    srv.flush()
    fleet = TenantFleet(cfg)
    fleet.admit("t", chips[0])
    fleet.submit_batch("t", X[:2])
    fleet.flush()
    frep = fleet.report()
    keys = (list(srv.report()) + list(frep)
            + list(frep["tenants"]["t"]) + list(frep["buckets"][0]))
    text = (DOCS / "architecture.md").read_text()
    missing = sorted({k for k in keys if f"`{k}`" not in text})
    assert not missing, (
        f"report() keys missing from docs/architecture.md: {missing}")


def test_benchmarks_doc_covers_every_gate_key_and_prefix():
    from benchmarks import check_regression as cr

    text = (DOCS / "benchmarks.md").read_text()
    missing = [k for (k, name, field, *_r) in cr.TRACKED
               if f"`{k}`" not in text]
    missing += [name for (_k, name, field, *_r) in cr.TRACKED
                if f"`{name}`" not in text]
    missing += [p for p in cr.REQUIRED_PREFIXES if f"`{p}`" not in text]
    assert not missing, (
        f"gate keys/prefixes missing from docs/benchmarks.md: {missing}")
