"""LUT4 netlist IR: gates, comparators, counter/loopback firmware."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to the seeded sweep shim (tests/_propshim.py)
    from tests._propshim import given, settings, strategies as st

from repro.core.netlist import (
    CONST0, CONST1, Netlist, NetlistBuilder, counter_netlist, loopback_netlist,
    table_from_fn, LUT, TBL_MUX2,
)


def _eval1(nl, bits):
    out, _ = nl.evaluate(np.asarray([bits], np.uint8))
    return out[0].tolist()


def test_basic_gates():
    b = NetlistBuilder()
    x, y = b.input("x"), b.input("y")
    b.mark_output(b.and_(x, y))
    b.mark_output(b.or_(x, y))
    b.mark_output(b.xor_(x, y))
    b.mark_output(b.not_(x))
    nl = b.build()
    for xv in (0, 1):
        for yv in (0, 1):
            got = _eval1(nl, [xv, yv])
            assert got == [xv & yv, xv | yv, xv ^ yv, 1 - xv]


def test_mux2():
    b = NetlistBuilder()
    s, x, y = b.input(), b.input(), b.input()
    b.mark_output(b.mux2(s, x, y))
    nl = b.build()
    for sv in (0, 1):
        for xv in (0, 1):
            for yv in (0, 1):
                assert _eval1(nl, [sv, xv, yv]) == [yv if sv else xv]


def test_wide_and_or():
    b = NetlistBuilder()
    ins = [b.input() for _ in range(9)]
    b.mark_output(b.and_(*ins))
    b.mark_output(b.or_(*ins))
    nl = b.build()
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, (64, 9)).astype(np.uint8)
    out, _ = nl.evaluate(bits)
    np.testing.assert_array_equal(out[:, 0], bits.all(1))
    np.testing.assert_array_equal(out[:, 1], bits.any(1))


@given(const=st.integers(0, 2**12 - 1), data=st.data())
@settings(max_examples=60, deadline=None)
def test_le_const_comparator(const, data):
    W = 12
    b = NetlistBuilder()
    bits = b.input_bus(W)
    b.mark_output(b.le_const(bits, const))
    nl = b.build()
    vals = data.draw(st.lists(st.integers(0, 2**W - 1), min_size=1, max_size=32))
    inp = np.array([[(v >> k) & 1 for k in range(W)] for v in vals], np.uint8)
    out, _ = nl.evaluate(inp)
    np.testing.assert_array_equal(out[:, 0], [int(v <= const) for v in vals])


def test_counter_counts():
    nl = counter_netlist(8)
    outs, _ = nl.evaluate(np.zeros((1, 0)), n_cycles=300, trace_outputs=True)
    vals = (outs[0] * (1 << np.arange(8))).sum(-1)
    np.testing.assert_array_equal(vals, np.arange(300) % 256)


def test_counter_resources_fit_both_fabrics():
    nl = counter_netlist(16)
    r = nl.resource_report()
    assert r["luts"] <= 384 and r["ffs"] <= 384  # fits 130nm (paper bring-up)


def test_loopback_exactness():
    nl = loopback_netlist(8)
    rng = np.random.default_rng(42)
    T = 400
    data = rng.integers(0, 2, (1, T, 8)).astype(np.uint8)
    valid = rng.integers(0, 2, (1, T, 1)).astype(np.uint8)
    ready = rng.integers(0, 2, (1, T, 1)).astype(np.uint8)
    outs, _ = nl.evaluate(
        np.concatenate([data, valid, ready], -1), n_cycles=T, trace_outputs=True
    )
    out_data, out_valid, in_ready = outs[0, :, :8], outs[0, :, 8], outs[0, :, 9]
    sent = [tuple(data[0, t]) for t in range(T) if valid[0, t, 0] and in_ready[t]]
    recv = [tuple(out_data[t]) for t in range(T) if out_valid[t] and ready[0, t, 0]]
    assert len(recv) > 50
    assert recv == sent[: len(recv)]  # zero bit errors (paper §4.4.3)


def test_combinational_cycle_detected():
    # hand-build a 2-LUT cycle
    nl = Netlist(
        n_nets=4, inputs=[], outputs=[2],
        luts=[LUT(inputs=(3, 0, 0, 0), table=TBL_MUX2, out=2),
              LUT(inputs=(2, 0, 0, 0), table=TBL_MUX2, out=3)],
        ffs=[], names={},
    )
    with pytest.raises(ValueError, match="cycle"):
        nl.levelize()


def test_levelized_roundtrip():
    b = NetlistBuilder()
    ins = b.input_bus(6)
    t1 = b.xor_(ins[0], ins[1])
    t2 = b.and_(t1, ins[2], ins[3])
    b.mark_output(b.or_(t2, ins[4], ins[5]))
    nl = b.build()
    lv = nl.to_levelized()
    assert sum(lv.level_sizes) == nl.n_luts
    rng = np.random.default_rng(3)
    bits = rng.integers(0, 2, (32, 6)).astype(np.uint8)
    want, _ = nl.evaluate(bits)
    # evaluate the levelized arrays directly via FabricSim-compatible path
    from repro.core.fabric import FabricConfig, FabricSim, FABRIC_28NM, place_and_route
    cfg = place_and_route(nl, FABRIC_28NM)
    got, _ = FabricSim(cfg).run(bits)
    np.testing.assert_array_equal(got, want)


def test_nn_dsp_schedule_fails_latency_budget():
    """§5 quantified both ways: the NN fails on LUTs AND on DSP latency."""
    from repro.core.nn_baseline import MLPSpec, dsp_schedule

    d = dsp_schedule(MLPSpec())
    assert d["macs"] > 100
    assert d["latency_ns"] > 25.0      # blows the bunch-crossing budget
    assert not d["meets_25ns"]
