"""Elastic multi-tenant fleet (launch/fleet.py) + golden-store errors.

Covers the PR's behavioral acceptance:
  (a) warm admission of a NEW tenant mid-stream: zero jit retraces
      (the PR-4 no-retrace idiom) and zero dropped frames for
      incumbents — every admitted incumbent event is delivered;
  (b) eviction/re-admission property test: random admit/evict/re-admit
      sequences over random fabrics stay keep/drop bit-exact against
      per-tenant host oracles, and the per-tenant ledgers close
      events_in == events_out + shed + quota_shed
                 + evicted_while_queued + outstanding
      on both backends;
  (c) GoldenImageStore raises the NAMED GoldenSlotError (not a raw
      KeyError) on unknown/discarded slots — regression for the old
      behavior — while staying catchable as KeyError.
"""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.bitstream import (
    BitstreamError, GoldenImageStore, GoldenSlotError,
)
from repro.core.readout import ReadoutChip
from repro.core.tmr import replica_table_images
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.fleet import TenantFleet, UnknownTenantError
from repro.launch.readout_server import ServerConfig
from tests._propshim import given, settings, strategies as st


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _get_farm(_cache={}):
    """Four heterogeneous chips: two share a geometry bucket (depth-4
    designs), the others land in their own — so one farm exercises both
    warm (same-envelope) and cold (new-envelope) admission. Memoized so
    the propshim property sweep (which cannot take fixtures) shares the
    fixture's build."""
    if "farm" not in _cache:
        d = generate(SmartPixelConfig(n_events=12_000, seed=5))
        tr, te = train_test_split(d)
        chips = []
        for depth, leaves in [(5, 10), (4, 8), (4, 12), (3, 5)]:
            clf = GradientBoostedClassifier(
                n_estimators=1, max_depth=depth, max_leaf_nodes=leaves,
                min_samples_leaf=200,
            ).fit(tr["features"], tr["label"])
            chip = ReadoutChip.build(clf)
            chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
            chips.append(chip)
        _cache["farm"] = (chips, te["features"])
    return _cache["farm"]


@pytest.fixture(scope="module")
def farm():
    return _get_farm()


def _same_env_pair(chips):
    """Two distinct chip designs sharing a geometry bucket, if the farm
    has them; else the same design twice (two tenants may well ship the
    same classifier — still a distinct tenant admission)."""
    from repro.kernels.lut_eval.ops import bucket_envelope

    envs = [bucket_envelope(c.config) for c in chips]
    for i in range(len(chips)):
        for j in range(i + 1, len(chips)):
            if envs[i] == envs[j]:
                return chips[i], chips[j]
    return chips[1], chips[1]


def _cfg(backend="host", **kw):
    base = dict(max_batch=512, max_latency_s=1e9, backend=backend,
                batch_tile=128)
    base.update(kw)
    return ServerConfig(**base)


def _oracle(chip, rows):
    raw = chip.infer_raw(np.asarray(rows), backend="host")
    return raw, raw <= chip.score_threshold_raw


# ----------------------------------------------------- (a) warm admission
def test_warm_admission_zero_retrace_zero_incumbent_drops(farm):
    """Admit a new tenant into a warm bucket MID-STREAM: the serving
    kernel must not retrace (bucketed envelopes make every tenant's
    arrays congruent) and every incumbent event admitted before the
    reconfigure must still come back scored."""
    from repro.kernels.lut_eval import ops as lut_ops

    if not hasattr(lut_ops._eval_stack_scored, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    chips, X = farm
    ca, cb = _same_env_pair(chips)
    fleet = TenantFleet(_cfg("kernel"), bucket_slots=2)
    assert fleet.admit("pix", ca)["cold"] is True
    # warm the bucket's kernel
    seqs = fleet.submit_batch("pix", X[:16])
    assert all(s is not None for s in seqs)
    fleet.flush()

    n0 = lut_ops._eval_stack_scored._cache_size()
    # incumbent has frames in flight when the new tenant admits
    pending = fleet.submit_batch("pix", X[16:32])
    info = fleet.admit("neu", cb)             # same geometry envelope
    assert info["cold"] is False              # warm path: swap, not build
    more = fleet.submit_batch("neu", X[32:40])
    res = fleet.flush()
    assert lut_ops._eval_stack_scored._cache_size() == n0   # ZERO retraces

    got = {r.seq: r for r in res}
    # zero dropped frames for the incumbent: every pre-admission seq
    # came back, scored bit-exactly as the incumbent's own chip
    raw, keep = _oracle(ca, X[16:32])
    for s, want_raw, want_keep in zip(pending, raw, keep):
        assert s in got
        assert got[s].tenant == "pix"
        assert got[s].score_raw == int(want_raw)
        assert got[s].keep == bool(want_keep)
    raw, keep = _oracle(cb, X[32:40])
    for s, want_raw, want_keep in zip(more, raw, keep):
        assert got[s].tenant == "neu"
        assert got[s].score_raw == int(want_raw)
        assert got[s].keep == bool(want_keep)


def test_cold_iff_new_envelope_and_buckets_group_by_envelope(farm):
    from repro.kernels.lut_eval.ops import bucket_envelope

    chips, X = farm
    fleet = TenantFleet(_cfg(), bucket_slots=4)
    seen = {}
    for i, chip in enumerate(chips):
        env = bucket_envelope(chip.config)
        info = fleet.admit(f"t{i}", chip)
        assert info["cold"] == (env not in seen)   # cold iff NEW envelope
        if env in seen:
            assert info["bucket"] == seen[env]     # warm lands in its pool
        seen.setdefault(env, info["bucket"])
    assert fleet.n_buckets == len(seen)


# ------------------------------------------------ LRU eviction + re-admit
def test_lru_eviction_and_transparent_readmission(farm):
    chips, X = farm
    ca, cb = _same_env_pair(chips)
    clk = FakeClock()
    fleet = TenantFleet(_cfg(), clock=clk, bucket_slots=1)
    fleet.admit("old", ca)
    fleet.submit_batch("old", X[:4])
    fleet.flush()
    clk.advance(1.0)
    # bucket is full (1 slot): admitting a same-envelope tenant evicts LRU
    info = fleet.admit("new", cb)
    assert info["evicted"] == "old"
    assert fleet.tenant_state("old") == "evicted"
    # the evicted tenant re-admits from its golden image on next request
    s = fleet.submit("old", X[5])
    assert s is not None
    assert fleet.tenant_state("old") == "resident"
    assert fleet.tenant_state("new") == "evicted"     # bounced back out
    (r,) = fleet.flush()
    raw, keep = _oracle(ca, X[5:6])
    assert (r.tenant, r.score_raw, r.keep) == ("old", int(raw[0]),
                                               bool(keep[0]))
    rep = fleet.report()["tenants"]
    assert rep["old"]["readmissions"] == 1
    assert rep["old"]["evictions"] == 1
    assert rep["new"]["evictions"] == 1


def test_nondraining_evict_counts_queued_and_closes_identity(farm):
    chips, X = farm
    fleet = TenantFleet(_cfg(max_batch=512), bucket_slots=2)
    fleet.admit("a", chips[1])
    fleet.admit("b", chips[2])
    sa = fleet.submit_batch("a", X[:8])
    sb = fleet.submit_batch("b", X[8:12])
    fleet.evict("a", drain=False)            # a's queued events cancelled
    res = fleet.flush()
    assert {r.tenant for r in res} <= {"b"}  # b unaffected
    ta = fleet.report()["tenants"]["a"]
    assert ta["evicted_while_queued"] == len([s for s in sa if s is not None])
    assert ta["events_in"] == (ta["events_out"] + ta["shed"]
                               + ta["quota_shed"]
                               + ta["evicted_while_queued"]
                               + ta["outstanding"])
    tb = fleet.report()["tenants"]["b"]
    assert tb["events_out"] == len([s for s in sb if s is not None])


def test_tenant_quota_sheds_past_outstanding_cap(farm):
    chips, X = farm
    fleet = TenantFleet(_cfg(tenant_quota_queued=4), bucket_slots=2)
    fleet.admit("a", chips[1])
    seqs = fleet.submit_batch("a", X[:10])
    assert sum(s is not None for s in seqs) == 4
    assert seqs[4:] == [None] * 6
    rep = fleet.report()["tenants"]["a"]
    assert rep["quota_shed"] == 6
    fleet.flush()
    # quota frees as results drain
    seqs = fleet.submit_batch("a", X[:2])
    assert all(s is not None for s in seqs)


# ------------------------------------------------------ grow/shrink wiring
def test_prewarm_then_shrink(farm):
    chips, X = farm
    ca, cb = _same_env_pair(chips)
    fleet = TenantFleet(_cfg(), bucket_slots=2)
    idx = fleet.prewarm(ca)
    assert fleet.n_buckets == 1
    assert fleet.prewarm(cb, warmup=False) == idx   # same envelope
    info = fleet.admit("a", cb)
    assert info["cold"] is False             # prewarmed bucket reused
    fleet.retire("a")
    assert fleet.shrink() == 1
    assert fleet.n_buckets == 0


# ----------------------------------------------- named errors (bugfix)
def test_golden_store_raises_named_error_not_raw_keyerror():
    store = GoldenImageStore()
    for call in (lambda: store.digest(3, 0),
                 lambda: store.n_replicas(3),
                 lambda: store.golden_config(3),
                 lambda: store.verify(3, 0, np.zeros((1, 4, 16)))):
        with pytest.raises(GoldenSlotError, match="no golden image"):
            call()
    # subclasses both families: pre-existing handlers keep working
    assert issubclass(GoldenSlotError, KeyError)
    assert issubclass(GoldenSlotError, BitstreamError)
    # str() is the message, not KeyError's repr of it
    assert "slot 3" in str(GoldenSlotError(3))


def test_golden_store_discard_is_terminal_and_idempotent(farm):
    chips, _ = farm
    cfg = chips[1].config
    store = GoldenImageStore()
    m_pad = -(-max(cfg.level_sizes, default=1) // 128) * 128
    store.register("t", cfg, replica_table_images(
        cfg, len(cfg.level_sizes), m_pad))
    assert "t" in store and len(store) == 1
    assert store.golden_config("t").n_luts == cfg.n_luts
    store.discard("t")
    store.discard("t")                       # idempotent
    assert "t" not in store and len(store) == 0
    with pytest.raises(GoldenSlotError):
        store.golden_config("t")


def test_fleet_unknown_and_retired_tenants_raise_named_errors(farm):
    chips, X = farm
    fleet = TenantFleet(_cfg(), bucket_slots=2)
    with pytest.raises(UnknownTenantError, match="unknown tenant"):
        fleet.submit("ghost", X[0])
    assert issubclass(UnknownTenantError, KeyError)
    fleet.admit("a", chips[1])
    fleet.retire("a")
    assert not fleet.has_tenant("a")
    with pytest.raises(GoldenSlotError):     # no golden image to re-admit
        fleet.submit("a", X[0])


def test_fleet_rejects_sparse_config(farm):
    with pytest.raises(ValueError, match="dense"):
        TenantFleet(ServerConfig(sparse=True))


# ---------------------------------------- (b) eviction/re-admission sweep
@given(backend=st.sampled_from(["host", "kernel"]),
       seed=st.integers(0, 10_000), data=st.data())
@settings(max_examples=8, deadline=None)
def test_random_admit_evict_readmit_bit_exact_and_reconciled(
        backend, seed, data):
    """Random admit/evict/re-admit/submit schedules over random fabrics:
    every delivered event is bit-exact vs its tenant's host oracle, and
    every tenant's ledger closes the accounting identity (both backends
    — the propshim sweep draws the backend per example)."""
    chips, X = _get_farm()
    rng = np.random.default_rng(seed)
    clk = FakeClock()
    fleet = TenantFleet(_cfg(backend), clock=clk, bucket_slots=2)
    tenants = {f"t{i}": chips[int(rng.integers(len(chips)))]
               for i in range(5)}
    expected = {}                            # fleet seq -> (tenant, row)
    for _ in range(data.draw(st.integers(10, 25))):
        clk.advance(0.01)
        t = str(rng.choice(list(tenants)))
        op = rng.random()
        if op < 0.15 and fleet.has_tenant(t):
            st_ = fleet.tenant_state(t)
            if st_ == "resident":
                fleet.evict(t, drain=bool(rng.integers(2)))
            continue
        if not fleet.has_tenant(t):
            fleet.admit(t, tenants[t])
        rows = X[rng.integers(0, len(X) - 8) :][: int(rng.integers(1, 6))]
        for s, row in zip(fleet.submit_batch(t, rows), rows):
            if s is not None:
                expected[s] = (t, row)
    res = fleet.flush()
    got = {r.seq: r for r in res}
    # non-draining evictions cancel queued seqs: those never come back
    n_checked = 0
    for s, (t, row) in expected.items():
        if s not in got:
            continue
        raw, keep = _oracle(tenants[t], row[None])
        assert got[s].tenant == t
        assert got[s].score_raw == int(raw[0])
        assert got[s].keep == bool(keep[0])
        n_checked += 1
    rep = fleet.report()
    for t, led in rep["tenants"].items():
        assert led["outstanding"] == 0       # fully drained
        assert led["events_in"] == (
            led["events_out"] + led["shed"] + led["quota_shed"]
            + led["evicted_while_queued"]), (t, led)
    assert rep["events_out"] == len(res)
    assert n_checked == len(got)
