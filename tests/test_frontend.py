"""Fused on-device frontend: frames -> features -> bits -> score.

Covers the tentpole guarantees of kernels/frontend.py and the server's
frames ingestion:
  (a) the device quantize + offset-binary packer (core/quantize) is
      bit-exact vs the host packer across specs (property sweep via
      tests/_propshim);
  (b) frames -> score through the fused single-dispatch pipeline is
      bit-identical to the staged host oracle (host-materialized yprofile
      + host quantize/pack + FabricSim) on EVERY registered fabric,
      banded and dense — the acceptance bar of the refactor;
  (c) the multi-chip server paths agree across backends, the device
      keep/drop equals the host integer cut, and hot-swapping a chip's
      whole frontend (fabric arrays + encode plan) does not retrace;
  (d) ServerConfig validates on construction with named errors.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import FABRICS, FabricSim, place_and_route
from repro.core.quantize import (
    AP_FIXED_28_19,
    FixedSpec,
    encode_offset_binary_jax,
    quantize_raw,
    quantize_raw_jax,
    to_unsigned_bits,
    to_unsigned_bits_jax,
)
from repro.core.readout import ReadoutChip, get_backend
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels import frontend as fe
from repro.kernels.yprofile import ops as yp_ops
from repro.launch.readout_server import ReadoutServer, ServerConfig
from tests._propshim import given, settings, strategies as st

import repro.core.tmr  # noqa: F401  (registers efpga_28nm_xl)


# ------------------------------------------------------------ helpers
def staged_scores(chip, frames, y0, tile=128, threshold=800.0):
    """THE staged host oracle: featurizer dispatch materialized on host,
    then numpy quantize + offset-binary packing + FabricSim + numpy
    decode. Every integer stage is an independent implementation of what
    the fused path does on device."""
    feats = np.asarray(yp_ops.yprofile(
        frames, y0, threshold_electrons=threshold, batch_tile=tile))
    bits = chip.encode_features(feats)
    outs, _ = FabricSim(chip.config).run(bits)
    return chip.synth.decode_outputs(np.asarray(outs))


def _train(tr, fabric, depth, leaves, n_estimators=1, spec=AP_FIXED_28_19):
    clf = GradientBoostedClassifier(
        n_estimators=n_estimators, max_depth=depth, max_leaf_nodes=leaves,
        min_samples_leaf=200,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf, fabric=fabric, spec=spec)
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
    return chip


@pytest.fixture(scope="module")
def farm():
    """One chip per distinct registered fabric (open-ended set) plus the
    frames to feed them. Heterogeneous on purpose: tree shapes, used
    features AND fixed-point specs differ across chips, so the stacked
    encode plan is exercised, not just the padded fabric envelope."""
    d = generate(SmartPixelConfig(n_events=12_000, seed=5))
    tr, _ = train_test_split(d)
    fabric_names = sorted({s.name for s in FABRICS.values()})
    assert {"efpga_130nm", "efpga_28nm", "efpga_28nm_xl"} <= set(fabric_names)
    chips = {}
    for fi, name in enumerate(fabric_names):
        if name == "efpga_130nm":
            chips[name] = _train(tr, name, depth=3, leaves=5)
        elif name == "efpga_28nm":
            chips[name] = _train(tr, name, depth=4 + fi % 2, leaves=8)
        else:  # the XL fabric fits a small ensemble on a narrower grid
            chips[name] = _train(tr, name, depth=3, leaves=6,
                                 n_estimators=2, spec=FixedSpec(16, 8))
    dd = generate(SmartPixelConfig(n_events=256, seed=9), return_frames=True)
    return chips, dd["frames"], dd["features"][:, 13]


# ------------------------------------------------------------------ (a)
@given(width=st.integers(8, 28), int_frac=st.floats(0.1, 0.9),
       seed=st.integers(0, 10_000), overflow=st.sampled_from(["wrap", "sat"]))
@settings(max_examples=25, deadline=None)
def test_device_quantize_bit_exact_vs_host_packer(width, int_frac, seed,
                                                  overflow):
    """quantize_raw_jax / to_unsigned_bits_jax / encode_offset_binary_jax
    == the numpy host packer, including wraparound and saturation, on
    float32 inputs (the featurizer's output dtype)."""
    int_bits = max(2, int(round(width * int_frac)))
    int_bits = min(int_bits, width)
    spec = FixedSpec(width=width, int_bits=int_bits, overflow=overflow)
    rng = np.random.default_rng(seed)
    span = 2.0 ** (int_bits - 1)
    x = (rng.uniform(-1.6 * span, 1.6 * span, 257)).astype(np.float32)
    x[:3] = [0.0, spec.max_value, spec.min_value]  # grid corners

    want_raw = quantize_raw(x, spec)
    got_raw = np.asarray(quantize_raw_jax(x, spec)).astype(np.int64)
    np.testing.assert_array_equal(got_raw, want_raw)

    want_u = to_unsigned_bits(want_raw, spec)
    got_u = np.asarray(to_unsigned_bits_jax(want_raw.astype(np.int32), spec))
    np.testing.assert_array_equal(got_u.astype(np.int64), want_u)

    want_bits = ((want_u[..., None] >> np.arange(width)) & 1).astype(np.uint8)
    got_bits = np.asarray(encode_offset_binary_jax(x, spec)).astype(np.uint8)
    np.testing.assert_array_equal(got_bits, want_bits)


def test_device_quantize_round_half_up_small_range():
    """AP_RND needs the +0.5 ulp to survive float32 — exact in the
    documented |scaled| < 2**23 regime."""
    spec = FixedSpec(width=16, int_bits=8, rounding="rnd")
    rng = np.random.default_rng(0)
    x = rng.uniform(-120, 120, 1024).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(quantize_raw_jax(x, spec)).astype(np.int64),
        quantize_raw(x, spec))


def test_stacked_yprofile_matches_single_chip_kernel():
    """The chip-batched featurizer == C separate single-chip calls,
    bit-for-bit (identical per-tile dot)."""
    import jax

    rng = np.random.default_rng(3)
    frames = rng.exponential(500.0, (3, 256, 8, 13, 21)).astype(np.float32)
    y0 = rng.normal(0.0, 10.0, (3, 256)).astype(np.float32)
    run = jax.jit(lambda f, z: yp_ops.yprofile_traced(
        f, z, threshold=800.0, batch_tile=128, interpret=True))
    got = np.asarray(run(frames, y0))[:, :, :yp_ops.N_FEATURES]
    want = np.stack([
        np.asarray(yp_ops.yprofile(frames[c], y0[c], batch_tile=128))
        for c in range(3)
    ])
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------ (b)
def test_fused_bit_identical_to_staged_every_fabric(farm):
    """frames -> keep/drop through ONE fused dispatch == the staged host
    oracle, for every registered fabric, banded and dense."""
    chips, frames, y0 = farm
    for name, chip in chips.items():
        want = staged_scores(chip, frames[:48], y0[:48])
        for band in (None, False):
            front = fe.pack_frontend(
                [chip.config], [chip.frontend_spec()], band=band)
            score, keep = front.score_frames(frames[None, :48], y0[None, :48])
            np.testing.assert_array_equal(
                np.asarray(score)[0], want, err_msg=f"{name} band={band}")
            np.testing.assert_array_equal(
                np.asarray(keep)[0], want <= chip.score_threshold_raw,
                err_msg=f"{name} band={band}")


@given(n=st.integers(1, 40), lo=st.integers(0, 200),
       band=st.sampled_from([None, False]))
@settings(max_examples=6, deadline=None)
def test_fused_matches_staged_property(n, lo, band, _farm_cache={}):
    """Property sweep: arbitrary batch sizes/offsets through the fused
    backend path == staged oracle. (Fixtureless by design — _propshim
    wraps the test in a zero-arg sweep, so the farm is module-cached.)"""
    if "farm" not in _farm_cache:
        d = generate(SmartPixelConfig(n_events=12_000, seed=5))
        tr, _ = train_test_split(d)
        dd = generate(SmartPixelConfig(n_events=256, seed=9),
                      return_frames=True)
        _farm_cache["farm"] = (
            _train(tr, "efpga_28nm", depth=4, leaves=8),
            dd["frames"], dd["features"][:, 13],
        )
    chip, frames, y0 = _farm_cache["farm"]
    lo = min(lo, len(frames) - n)
    fr, z = frames[lo:lo + n], y0[lo:lo + n]
    want = staged_scores(chip, fr, z)
    from repro.core.readout import KernelBackend

    backend = KernelBackend(band=band)
    got = backend.score_frames(chip, fr, z)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------ (c)
def test_server_frames_kernel_vs_host_bit_identical(farm):
    """Multi-chip frames ingestion: fused sharded dispatch == staged host
    server, event for event (scores AND device keep/drop decisions)."""
    chips, frames, y0 = farm
    stack_chips = [chips["efpga_28nm"], chips["efpga_130nm"]]
    out = {}
    for backend in ("kernel", "host"):
        srv = ReadoutServer(list(stack_chips), ServerConfig(
            max_batch=64, max_latency_s=1e9, backend=backend))
        srv.submit_frames(0, frames[:90], y0[:90])
        srv.submit_frames(1, frames[90:170], y0[90:170])
        res = sorted(srv.flush(), key=lambda r: r.seq)
        out[backend] = [(r.seq, r.chip, r.score_raw, r.keep) for r in res]
    assert out["kernel"] == out["host"]
    # and both equal the per-chip staged oracle + integer cut
    want0 = staged_scores(stack_chips[0], frames[:90], y0[:90])
    got0 = [s for _, c, s, _ in out["host"] if c == 0]
    np.testing.assert_array_equal(got0, want0)
    keep0 = [k for _, c, _, k in out["kernel"] if c == 0]
    np.testing.assert_array_equal(
        keep0, want0 <= stack_chips[0].score_threshold_raw)


def test_fused_hot_swap_no_retrace_and_correct(farm):
    """Swapping a chip's whole frontend (fabric arrays + encode plan +
    trigger cut) must not grow the fused dispatch's jit cache — the
    'array swap, no recompile' guarantee extended to the full pipeline."""
    chips, frames, y0 = farm
    a, b = chips["efpga_28nm"], chips["efpga_130nm"]
    front = fe.pack_frontend(
        [a.config, b.config], [a.frontend_spec(), b.frontend_spec()])
    fr = np.stack([frames[:32], frames[32:64]])
    z = np.stack([y0[:32], y0[32:64]])
    np.asarray(front.score_frames(fr, z)[0])

    if not hasattr(fe._score_frames, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    n0 = fe._score_frames._cache_size()
    front2 = front.swap_chip(0, b.config, b.frontend_spec())
    score2, keep2 = front2.score_frames(fr, z)
    assert fe._score_frames._cache_size() == n0
    np.testing.assert_array_equal(
        np.asarray(score2)[0], staged_scores(b, frames[:32], y0[:32]))
    # threshold retarget is an array-row update too
    front3 = front2.set_threshold(0, -(10 ** 6))
    assert not np.asarray(front3.score_frames(fr, z)[1])[0].any()
    assert fe._score_frames._cache_size() == n0


def test_server_reconfigure_updates_fused_frontend(farm):
    chips, frames, y0 = farm
    a, b = chips["efpga_28nm"], chips["efpga_130nm"]
    srv = ReadoutServer([a, b], ServerConfig(
        max_batch=10_000, max_latency_s=1e9, backend="kernel"))
    srv.submit_frames(0, frames[:16], y0[:16])
    srv.flush()
    srv.reconfigure(0, b)
    srv.submit_frames(0, frames[16:48], y0[16:48])
    got = [r.score_raw for r in sorted(srv.flush(), key=lambda r: r.seq)]
    np.testing.assert_array_equal(
        got, staged_scores(b, frames[16:48], y0[16:48]))


def test_kernel_backend_honors_per_call_featurizer_threshold(farm):
    """The cached fused frontend is keyed by (config, threshold): a
    different zero-suppression threshold must rebuild, not silently reuse
    a stale dispatch — kernel==host on every call."""
    chips, frames, y0 = farm
    chip = chips["efpga_28nm"]
    from repro.core.readout import KernelBackend

    kb = KernelBackend()
    for thr in (0.0, 20_000.0, 800.0):
        got = kb.score_frames(chip, frames[:32], y0[:32],
                              threshold_electrons=thr)
        want = staged_scores(chip, frames[:32], y0[:32], threshold=thr)
        np.testing.assert_array_equal(got, want, err_msg=f"thr={thr}")


def test_reconfigure_enforces_frontend_contract_on_both_backends(farm):
    """A chip that fits the fabric envelope but violates the featurizer
    contract is rejected at swap time with a named error — on the host
    backend too, and before any frames dispatch has run."""
    import types

    chips, _, _ = farm
    a, b = chips["efpga_28nm"], chips["efpga_130nm"]
    bad_spec = dataclasses.replace(
        b.frontend_spec(),
        used_features=tuple([99] + list(b.frontend_spec().used_features[1:])))
    impostor = types.SimpleNamespace(
        config=b.config, frontend_spec=lambda: bad_spec)
    for backend in ("host", "kernel"):
        srv = ReadoutServer([a, b], ServerConfig(
            max_batch=64, max_latency_s=1e9, backend=backend))
        with pytest.raises(ValueError, match="featurizer"):
            srv.reconfigure(1, impostor)


def test_pack_frontend_validates_chip_contract(farm):
    chips, _, _ = farm
    chip = chips["efpga_28nm"]
    good = chip.frontend_spec()
    with pytest.raises(ValueError, match="int32"):
        fe.pack_frontend(
            [chip.config],
            [dataclasses.replace(good, spec=FixedSpec(width=40, int_bits=20))])
    with pytest.raises(ValueError, match="used features"):
        fe.pack_frontend(
            [chip.config],
            [dataclasses.replace(good,
                                 used_features=good.used_features[:-1])])
    with pytest.raises(ValueError, match="featurizer"):
        bad = tuple([99] + list(good.used_features[1:]))
        fe.pack_frontend([chip.config],
                         [dataclasses.replace(good, used_features=bad)])


# ------------------------------------------------------------------ (d)
def test_server_config_validates_on_construction():
    ServerConfig()  # defaults are valid
    with pytest.raises(ValueError, match="max_batch"):
        ServerConfig(max_batch=0)
    with pytest.raises(ValueError, match="max_batch"):
        ServerConfig(max_batch=-5)
    with pytest.raises(ValueError, match="batch_tile"):
        ServerConfig(batch_tile=100)
    with pytest.raises(ValueError, match="batch_tile"):
        ServerConfig(batch_tile=0)
    with pytest.raises(ValueError, match="max_latency_s"):
        ServerConfig(max_latency_s=0.0)
    with pytest.raises(ValueError, match="backend"):
        ServerConfig(backend="gpu")
    with pytest.raises(ValueError, match="pipeline_depth"):
        ServerConfig(pipeline_depth=0)
    with pytest.raises(ValueError, match="threshold_electrons"):
        ServerConfig(threshold_electrons=-1.0)


def test_readout_mesh_single_device():
    from repro.launch.mesh import make_readout_mesh

    for n in (1, 3, 4):
        mesh = make_readout_mesh(n)
        assert mesh.axis_names == ("chips",)
        assert mesh.devices.size in {d for d in range(1, n + 1) if n % d == 0}
    with pytest.raises(ValueError):
        make_readout_mesh(0)


def test_bench_json_has_frames_fused_scenario():
    """The committed benchmark record must carry the fused-frontend
    scenario, including a measured speedup row vs host-featurize."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_fabric.json")
    with open(path) as f:
        doc = json.load(f)
    names = {r["name"] for r in doc["records"]}
    assert any(n.startswith("fabric.frames_fused_") for n in names), names
    assert any(n.startswith("fabric.frames_host_featurize_") for n in names)
    speedups = [r for r in doc["records"]
                if r["name"] == "fabric.frames_fused_speedup"]
    assert speedups and "speedup" in speedups[0]


# ------------------------------------------------------------- slow tier
@pytest.mark.slow
def test_fused_wide_sweep_all_fabrics_banded_dense(farm):
    """The wide frames->score sweep: every registered fabric x banded/
    dense x several batch shapes, fused vs staged, plus the multi-fabric
    heterogeneous stack through the server on both backends."""
    chips, frames, y0 = farm
    for name, chip in chips.items():
        for band in (None, True, False):
            front = fe.pack_frontend(
                [chip.config], [chip.frontend_spec()], band=band)
            for lo, n in [(0, 1), (7, 129), (60, 196)]:
                fr, z = frames[lo:lo + n], y0[lo:lo + n]
                want = staged_scores(chip, fr, z)
                score, keep = front.score_frames(fr[None], z[None])
                np.testing.assert_array_equal(
                    np.asarray(score)[0], want,
                    err_msg=f"{name} band={band} n={n}")
                np.testing.assert_array_equal(
                    np.asarray(keep)[0], want <= chip.score_threshold_raw)

    stack_chips = list(chips.values())
    out = {}
    for backend in ("kernel", "host"):
        srv = ReadoutServer(list(stack_chips), ServerConfig(
            max_batch=97, max_latency_s=1e9, backend=backend))
        for i in range(len(stack_chips)):
            srv.submit_frames(i, frames[i::4][:40], y0[i::4][:40])
            srv.poll()
        res = sorted(srv.flush(), key=lambda r: r.seq)
        out[backend] = [(r.seq, r.chip, r.score_raw, r.keep) for r in res]
    assert out["kernel"] == out["host"]
