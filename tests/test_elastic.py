"""Elastic reshard + failover data recompute (fault-tolerance pillars)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline


def test_elastic_data_rescale():
    """Changing the number of shards re-partitions the SAME global batch
    stream deterministically (the elastic-rescale property)."""
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=12, seed=5)
    whole = TokenPipeline(cfg, n_shards=1, shard=0).batch_at(3)["tokens"]
    parts = [TokenPipeline(cfg, n_shards=3, shard=s).batch_at(3)["tokens"]
             for s in range(3)]
    for p in parts:
        assert p.shape == (4, 16)
    # shards are distinct (different PRNG streams per shard)
    assert not np.array_equal(parts[0], parts[1])


_RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import smoke_config
from repro.models import registry
from repro.train.elastic import gather_to_host, reshard_params
from repro.parallel import sharding as shd

cfg = smoke_config("gemma-7b")
params = registry.init_params(cfg, jax.random.PRNGKey(0))
host = gather_to_host(params)

from repro.launch.mesh import make_mesh_compat
mesh_a = make_mesh_compat((4, 2), ("data", "model"))
mesh_b = make_mesh_compat((2, 2), ("data", "model"))
pa = reshard_params(cfg, mesh_a, host)
pb = reshard_params(cfg, mesh_b, host)   # "a pod dropped out"
for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("RESHARD_OK")
"""


@pytest.mark.slow
def test_elastic_reshard_across_meshes():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _RESHARD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RESHARD_OK" in r.stdout
