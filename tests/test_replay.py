"""Closed-loop replay soak (net/replay.py + net/ingress.py over sockets).

Loopback replay of a seeded FrameStream against a LIVE asyncio front
door: trigger decisions bit-exact vs the MultiFabricSim host oracle on
both backends, per-client drop accounting exact under an injected
lossy/reordering transport shim, and (slow tier) a paced rate sweep
whose summary lands in the NET-soak nightly artifact.
"""
import asyncio
import json
import os

import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.pipeline import FrameStream, FrameStreamConfig
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.readout_server import ReadoutServer, ServerConfig
from repro.net import protocol as P
from repro.net import replay as R
from repro.net.ingress import FrontDoorConfig, ReadoutFrontDoor


@pytest.fixture(scope="module")
def farm():
    """Two small heterogeneous chips + the recorded frame stream."""
    d = generate(SmartPixelConfig(n_events=8_000, seed=5))
    tr, _ = train_test_split(d)
    chips = []
    for depth, leaves in [(4, 8), (3, 5)]:
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=depth, max_leaf_nodes=leaves,
            min_samples_leaf=200,
        ).fit(tr["features"], tr["label"])
        chip = ReadoutChip.build(clf)
        chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
        chips.append(chip)
    stream = FrameStream(FrameStreamConfig(n_sensors=2, batch=64, seed=701))
    return chips, stream


def _server(chips, backend, **kw):
    return ReadoutServer(chips, ServerConfig(
        max_batch=kw.pop("max_batch", 256), max_latency_s=1e9,
        backend=backend, batch_tile=128, **kw))


async def _run_replay(door, cfgs, sources, oracles):
    await door.start()
    try:
        return await asyncio.gather(*(
            R.replay("127.0.0.1",
                     door.tcp_port if c.transport == "tcp"
                     else door.udp_port,
                     s, c, o)
            for c, s, o in zip(cfgs, sources, oracles)))
    finally:
        await door.stop()


# ------------------------------------------------------ closed-loop live
def test_tcp_loopback_bit_exact_host_backend(farm):
    """TCP loopback, host backend: every trigger decision bit-exact vs
    the MultiFabricSim oracle, ack accounting exact."""
    chips, stream = farm
    srv = _server(chips, "host")
    door = ReadoutFrontDoor(srv)
    cfg = R.ReplayConfig(n_batches=6, events_per_batch=8, sensor=0,
                         transport="tcp")
    (rep,) = asyncio.run(_run_replay(
        door, [cfg], [R.frame_stream_source(stream, 0, 8)],
        [R.host_oracle(chips[0])]))
    assert rep.verified, rep.mismatches
    assert rep.unanswered == 0 and rep.n_triggers == 6
    assert rep.ack["events_in"] == 48 == rep.ack["events_admitted"]
    assert rep.ack["events_shed"] == 0 == rep.ack["events_queue_dropped"]
    assert rep.ack["seq_gaps"] == rep.ack["reorders"] == 0
    assert rep.latency["count"] == 48 and rep.latency["p99_us"] > 0
    # the server report surfaces the same accounting
    net = srv.report()["net"]
    assert net["attached"] and net["totals"]["events_in"] == 48
    assert net["totals"]["events_kept"] == rep.n_kept


def test_both_transports_bit_exact_kernel_backend(farm):
    """Two concurrent clients — one TCP, one UDP, one per chip — against
    the KERNEL backend: decisions bit-exact vs the host oracle for both,
    which closes backend x transport conformance in one loop."""
    chips, stream = farm
    srv = _server(chips, "kernel", max_batch=16)
    door = ReadoutFrontDoor(srv)
    cfgs = [
        R.ReplayConfig(n_batches=4, events_per_batch=4, sensor=0,
                       transport="tcp"),
        R.ReplayConfig(n_batches=4, events_per_batch=4, sensor=1,
                       transport="udp"),
    ]
    reps = asyncio.run(_run_replay(
        door, cfgs,
        [R.frame_stream_source(stream, 0, 4),
         R.frame_stream_source(stream, 1, 4)],
        [R.host_oracle(chips[0]), R.host_oracle(chips[1])]))
    for rep in reps:
        assert rep.verified, rep.mismatches
        assert rep.ack["events_in"] == 16 == rep.ack["events_admitted"]
    # per-chip attribution: each client's events landed on its own chip
    per_chip = srv.report()["per_chip"]
    assert per_chip[0]["n_in"] == 16 and per_chip[1]["n_in"] == 16


# --------------------------------------------- lossy/reordering transport
def test_drop_accounting_exact_under_lossy_reordering_shim(farm):
    """A seeded shim drops, duplicates and swaps datagrams between the
    client and the synchronous core; the per-client counters must equal
    the shim's ground truth EXACTLY, and every delivered batch's trigger
    must still verify bit-exact."""
    chips, stream = farm
    srv = _server(chips, "host")
    door = ReadoutFrontDoor(srv)
    rng = np.random.default_rng(11)
    n_batches, per = 20, 4
    oracle = R.host_oracle(chips[0])

    wires = []
    sent = {}
    for b in range(n_batches):
        blk = stream.batch_at(b, 0)
        fr, y0 = blk["frames"][:per], blk["y0"][:per]
        sent[b] = (fr, y0)
        wires.append((b, P.encode_frame_batch(0, b, fr, y0)))

    # the shim: disjoint drop/dup/swap sets over interior seqs. Rejection
    # -sample so no swap chains with another swap and no swap partner
    # (s+1) is itself dropped/duplicated — keeps the ground truth exact.
    while True:
        seqs = rng.permutation(np.arange(1, n_batches - 1))
        dropped = set(map(int, seqs[:4]))
        duplicated = set(map(int, seqs[4:7]))
        swapped = set(map(int, seqs[7:10]))  # seq s arrives AFTER s+1
        if (not (swapped & {s - 1 for s in swapped})
                and not ({s + 1 for s in swapped}
                         & (dropped | duplicated | swapped))):
            break

    delivery = []
    skip_next = set()
    for b, w in wires:
        if b in dropped:
            continue
        if b in skip_next:
            continue
        if b in swapped and b + 1 not in dropped:
            delivery.append(wires[b + 1])
            delivery.append((b, w))
            skip_next.add(b + 1)
            continue
        delivery.append((b, w))
        if b in duplicated:
            delivery.append((b, w))

    out = []
    door.client_connect("shim", out.append, stream=False)
    for _b, w in delivery:
        door.feed_datagram("shim", w)
        door.pump()
    # FLUSH carries the top seq: tail drops would surface as gaps here
    door.feed_datagram("shim", P.encode_flush(0, n_batches))
    door.drain()

    got = [P.decode_datagram(w) for w in out]
    triggers = {m.orig_seq: m for m in got
                if m.msg_type == P.MSG_TRIGGER_BATCH}
    acks = [m for m in got if m.msg_type == P.MSG_FLUSH_ACK]
    assert len(acks) == 1
    c = acks[0].counters

    delivered = n_batches - len(dropped)
    assert c["batches_in"] == delivered
    assert c["events_in"] == delivered * per
    assert c["seq_gaps"] == len(dropped)          # only true losses
    assert c["duplicates"] == len(duplicated)
    assert c["reorders"] == len(swapped)          # late arrivals, repaid
    assert c["events_admitted"] == delivered * per
    assert c["events_shed"] == 0 == c["events_queue_dropped"]
    assert set(triggers) == set(range(n_batches)) - dropped

    for b, trig in triggers.items():
        fr, y0 = sent[b]
        score, keep = oracle(fr, y0)
        want = {(int(p), int(score[p])) for p in np.nonzero(keep)[0]}
        assert {(int(p), int(s))
                for p, s in zip(trig.idx, trig.scores)} == want, b


# ------------------------------------------------------------- soak sweep
@pytest.mark.slow
def test_soak_rate_sweep_both_backends(farm):
    """Paced Poisson + square-wave replay at increasing rates on both
    backends: verified closed-loop at every point, accounting identity
    holds, and the summary lands in the NET-soak artifact when
    REPRO_NET_SOAK_JSON is set."""
    chips, stream = farm
    points = []
    for backend in ("host", "kernel"):
        for pattern, rate in [("poisson", 2_000.0), ("poisson", 20_000.0),
                              ("square", 8_000.0)]:
            srv = _server(chips, backend, max_batch=64)
            door = ReadoutFrontDoor(srv, FrontDoorConfig())
            cfg = R.ReplayConfig(
                rate_hz=rate, pattern=pattern, n_batches=24,
                events_per_batch=16, sensor=0, transport="tcp", seed=7)
            (rep,) = asyncio.run(_run_replay(
                door, [cfg], [R.frame_stream_source(stream, 0, 16)],
                [R.host_oracle(chips[0])]))
            assert rep.verified, (backend, pattern, rate, rep.mismatches)
            a = rep.ack
            assert a["events_in"] == (
                a["events_admitted"] + a["events_shed"]
                + a["events_queue_dropped"])
            points.append({
                "backend": backend, "pattern": pattern,
                "target_ev_s": rate,
                "achieved_ev_s": rep.achieved_ev_s,
                "p50_us": rep.latency["p50_us"],
                "p99_us": rep.latency["p99_us"],
                "events": rep.n_events, "kept": rep.n_kept,
                "verified": rep.verified,
            })
    path = os.environ.get("REPRO_NET_SOAK_JSON")
    if path:
        with open(path, "w") as f:
            json.dump({"sweep": points}, f, indent=1)
