"""Front-door backpressure + admission interplay (net/ingress.py core).

The bounded ingest queue must drop-and-count at capacity — never block
the transport callback, never grow unboundedly — and compose with the
server's own deadline admission control (PR 7) under an injected fake
clock. All through the synchronous core: no sockets, no event loop.
"""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.pipeline import FrameStream, FrameStreamConfig
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.readout_server import ReadoutServer, ServerConfig
from repro.net import protocol as P
from repro.net.ingress import FrontDoorConfig, ReadoutFrontDoor


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def farm():
    d = generate(SmartPixelConfig(n_events=8_000, seed=5))
    tr, _ = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=4, max_leaf_nodes=8,
        min_samples_leaf=200,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf)
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
    stream = FrameStream(FrameStreamConfig(n_sensors=1, batch=64, seed=702))
    return chip, stream


def _batch_wire(stream, b, per, sensor=0, seq=None):
    blk = stream.batch_at(b, 0)
    return P.encode_frame_batch(
        sensor, b if seq is None else seq,
        blk["frames"][:per], blk["y0"][:per])


def _mk(chip, clock=None, **srv_kw):
    kw = dict(max_batch=512, max_latency_s=1e9, backend="host",
              batch_tile=128)
    kw.update(srv_kw)
    srv = (ReadoutServer([chip], ServerConfig(**kw), clock=clock)
           if clock else ReadoutServer([chip], ServerConfig(**kw)))
    return srv


# --------------------------------------------------------- bounded queue
def test_queue_at_capacity_drops_whole_batches_and_counts(farm):
    chip, stream = farm
    srv = _mk(chip)
    door = ReadoutFrontDoor(srv, FrontDoorConfig(queue_events=16))
    out = []
    door.client_connect("c", out.append, stream=False)
    for b in range(10):                       # 10 x 8 events, no pump
        door.feed_datagram("c", _batch_wire(stream, b, 8))
        assert door.stats()["queue_events"] <= 16    # never exceeds cap
    s = door.stats()["totals"]
    assert s["events_in"] == 80
    assert s["events_queue_dropped"] == 64    # batches 2..9 dropped whole
    assert door.stats()["queue_events"] == 16

    door.feed_datagram("c", P.encode_flush(0, 10))
    door.drain()
    got = [P.decode_datagram(w) for w in out]
    trig = [m for m in got if m.msg_type == P.MSG_TRIGGER_BATCH]
    ack = [m for m in got if m.msg_type == P.MSG_FLUSH_ACK][0]
    # only the 2 admitted batches are answered; the ack carries the drop
    assert sorted(m.orig_seq for m in trig) == [0, 1]
    assert ack.counters["events_queue_dropped"] == 64
    assert ack.counters["events_in"] == (
        ack.counters["events_admitted"]
        + ack.counters["events_shed"]
        + ack.counters["events_queue_dropped"])
    assert door.stats()["queue_events"] == 0


def test_feed_never_blocks_and_capacity_frees_after_pump(farm):
    """Sustained overfeed: the callback always returns, the queue stays
    bounded, and pumping frees capacity for later batches."""
    chip, stream = farm
    srv = _mk(chip)
    door = ReadoutFrontDoor(srv, FrontDoorConfig(queue_events=8))
    door.client_connect("c", lambda b: None, stream=False)
    for b in range(50):
        door.feed_datagram("c", _batch_wire(stream, b % 4, 8, seq=b))
        if b % 2 == 1:
            door.pump()                       # drains -> capacity frees
        assert door.stats()["queue_events"] <= 8
    s = door.stats()["totals"]
    assert s["events_in"] == 400
    assert s["events_admitted"] + s["events_queue_dropped"] == 400
    assert s["events_admitted"] >= 8 * 25     # every pumped slot refilled


# ------------------------------------- admission interplay (deadline_us)
def test_deadline_shed_backlog_interplay_with_fake_clock(farm):
    """Network backlog + deadline admission: a batch submitted while the
    server queue's oldest event has blown the deadline is shed BY THE
    SERVER (counted, answered with n_admitted=0) — the front door's
    queue accounting and the server's shed accounting compose."""
    chip, stream = farm
    clk = FakeClock()
    srv = _mk(chip, clock=clk, deadline_us=1_000.0, overload_policy="shed")
    door = ReadoutFrontDoor(srv)
    out = []
    door.client_connect("c", out.append, stream=False)

    # batch A admitted (idle probe), sits in the server queue undispatched
    door.feed_datagram("c", _batch_wire(stream, 0, 8))
    door.pump()
    assert srv.queue_depth == 8
    # 100 ms pass: the queue head is now 100x past the 1 ms deadline
    clk.advance(0.1)
    door.feed_datagram("c", _batch_wire(stream, 1, 8))
    door.pump()
    s = door.stats()["totals"]
    assert s["events_shed"] == 8              # all of B, at submit time
    trig_b = [m for m in (P.decode_datagram(w) for w in out)
              if m.msg_type == P.MSG_TRIGGER_BATCH and m.orig_seq == 1]
    assert len(trig_b) == 1                   # B answered immediately...
    assert trig_b[0].n_admitted == 0
    assert len(trig_b[0].idx) == 0

    door.feed_datagram("c", P.encode_flush(0, 2))
    door.drain()                              # ...A completes by flush
    got = [P.decode_datagram(w) for w in out]
    trig = sorted(
        (m.orig_seq, m.n_admitted) for m in got
        if m.msg_type == P.MSG_TRIGGER_BATCH)
    assert trig == [(0, 8), (1, 0)]
    ack = [m for m in got if m.msg_type == P.MSG_FLUSH_ACK][0]
    assert ack.counters["events_shed"] == 8
    assert ack.counters["events_admitted"] == 8
    assert ack.counters["events_in"] == 16
    # the server's own ledger agrees with the wire's
    assert srv.report()["per_chip"][0]["n_shed"] == 8


# ------------------------------------------------------- report surface
def test_net_stats_surface_in_server_report(farm):
    chip, _ = farm
    srv = _mk(chip)
    assert srv.report()["net"] == {"attached": False}
    door = ReadoutFrontDoor(srv)
    net = srv.report()["net"]
    assert net["attached"] is True and net["n_clients"] == 0
    door.client_connect("c", lambda b: None)
    assert srv.report()["net"]["n_clients"] == 1
    assert "c" in srv.report()["net"]["per_client"]


def test_front_door_requires_dense_server(farm):
    chip, _ = farm
    srv = _mk(chip, sparse=True)
    with pytest.raises(ValueError, match="sparse"):
        ReadoutFrontDoor(srv)


def test_bad_sensor_id_is_counted_not_fatal(farm):
    chip, stream = farm
    srv = _mk(chip)                           # 1 chip: sensor 3 invalid
    door = ReadoutFrontDoor(srv)
    out = []
    door.client_connect("c", out.append, stream=False)
    door.feed_datagram("c", _batch_wire(stream, 0, 4, sensor=3))
    door.feed_datagram("c", _batch_wire(stream, 1, 4, seq=1))
    door.feed_datagram("c", P.encode_flush(0, 2))
    door.drain()
    s = door.stats()["totals"]
    assert s["events_bad_sensor"] == 4
    assert s["events_admitted"] == 4
    trig = [P.decode_datagram(w) for w in out
            if P.decode_datagram(w).msg_type == P.MSG_TRIGGER_BATCH]
    assert [m.orig_seq for m in trig] == [1]


def test_garbage_bytes_on_both_transports_count_never_crash(farm):
    chip, stream = farm
    srv = _mk(chip)
    door = ReadoutFrontDoor(srv)
    rng = np.random.default_rng(0)
    out = []
    door.client_connect("udp", out.append, stream=False)
    door.client_connect("tcp", out.append, stream=True)
    door.feed_datagram("udp", rng.bytes(100))
    door.feed("tcp", rng.bytes(1000))
    wire = _batch_wire(stream, 0, 4)
    door.feed("tcp", wire[:30])               # split across chunks
    door.feed("tcp", wire[30:])
    door.pump()
    per = door.stats()["per_client"]
    assert per["udp"]["decode_errors"] == 1
    assert per["tcp"]["decode_errors"] >= 1
    assert per["tcp"]["batches_in"] == 1      # chunked frame decoded
    assert door.stats()["totals"]["events_admitted"] == 4


# ------------------------------------------- multi-tenant fleet routing
def test_sensor_tenants_routes_the_front_door_onto_a_fleet(farm):
    """``FrontDoorConfig.sensor_tenants`` fronts a TenantFleet: mapped
    sensors route to their tenant's bucket, unmapped (and retired)
    sensors count as bad-sensor, and the wire-level accounting identity
    still closes over the fleet."""
    from repro.launch.fleet import TenantFleet

    chip, stream = farm
    fleet = TenantFleet(ServerConfig(
        max_batch=512, max_latency_s=1e9, backend="host",
        batch_tile=128))
    fleet.admit("pix", chip)
    door = ReadoutFrontDoor(
        fleet, FrontDoorConfig(sensor_tenants={0: "pix", 1: "gone"}))
    out = []
    door.client_connect("c", out.append, stream=False)
    door.feed_datagram("c", _batch_wire(stream, 0, 8, sensor=0))
    door.feed_datagram("c", _batch_wire(stream, 1, 4, sensor=2, seq=1))
    # sensor 1 maps to a tenant the fleet does not know -> bad sensor
    door.feed_datagram("c", _batch_wire(stream, 2, 4, sensor=1, seq=2))
    door.feed_datagram("c", P.encode_flush(0, 3))
    door.drain()
    s = door.stats()["totals"]
    assert s["events_admitted"] == 8
    assert s["events_bad_sensor"] == 8        # unmapped + unknown tenant
    assert s["events_in"] == (s["events_admitted"] + s["events_shed"]
                              + s["events_queue_dropped"]
                              + s["events_bad_sensor"])
    trig = [P.decode_datagram(w) for w in out
            if P.decode_datagram(w).msg_type == P.MSG_TRIGGER_BATCH]
    assert [m.orig_seq for m in trig] == [0]
    assert fleet.report()["tenants"]["pix"]["events_in"] == 8


def test_sensor_tenants_must_be_a_mapping():
    with pytest.raises(ValueError, match="sensor_tenants"):
        FrontDoorConfig(sensor_tenants=[("a", 1)])
