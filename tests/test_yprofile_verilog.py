"""yprofile kernel (front-end feature extraction) + Verilog export."""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.synth import synth_ensemble
from repro.core.verilog import to_verilog
from repro.data.smartpixel import (
    N_FEATURES, SmartPixelConfig, generate, train_test_split,
)
from repro.kernels.yprofile import ops as yp_ops
from repro.kernels.yprofile.ref import yprofile_ref

import jax.numpy as jnp


@pytest.mark.parametrize("batch", [16, 256, 300])
def test_yprofile_kernel_matches_ref(batch):
    rng = np.random.default_rng(batch)
    frames = rng.exponential(500.0, (batch, 8, 13, 21)).astype(np.float32)
    y0 = rng.normal(0.0, 10.0, batch).astype(np.float32)
    got = np.asarray(yp_ops.yprofile(frames, y0))
    want = np.asarray(yprofile_ref(jnp.asarray(frames), jnp.asarray(y0)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got.shape == (batch, N_FEATURES)


def test_yprofile_matches_generator_features():
    """Full frame path reproduces the generator's own feature pipeline to
    within the generator's profile-level noise model."""
    d = generate(SmartPixelConfig(n_events=512, seed=3, noise_electrons=0.0),
                 return_frames=True)
    got = np.asarray(yp_ops.yprofile(d["frames"], d["features"][:, 13]))
    # y-profile from frames == generator features (both zero-suppressed ke-)
    np.testing.assert_allclose(got[:, :13], d["features"][:, :13],
                               rtol=1e-4, atol=2e-3)
    np.testing.assert_allclose(got[:, 13], d["features"][:, 13], rtol=1e-5)


def test_verilog_export_structure():
    d = generate(SmartPixelConfig(n_events=15_000, seed=17))
    tr, _ = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=4, max_leaf_nodes=8, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    synth = synth_ensemble(clf.quantized())
    v = to_verilog(synth.netlist, "pileup_bdt")
    assert v.count("LUT4 #(") == synth.netlist.n_luts
    assert v.count("FDRE") == synth.netlist.n_ffs
    assert f"module pileup_bdt" in v
    assert v.count("input wire in_") == len(synth.netlist.inputs)
    assert v.count("output wire out_") == len(synth.netlist.outputs)
    # every INIT is a valid 16-bit hex literal
    import re

    inits = re.findall(r"INIT\(16'h([0-9A-F]{4})\)", v)
    assert len(inits) == synth.netlist.n_luts


def test_verilog_sequential_counter():
    from repro.core.netlist import counter_netlist

    v = to_verilog(counter_netlist(8), "counter8")
    assert "input wire clk" in v
    assert v.count("FDRE") == 8
