"""Bit-sliced LUT evaluation: the cross-backend conformance suite.

The tentpole claim under test: packing 32 events per uint32 lane and
evaluating every 4-LUT as 15 bitwise mux ops over whole words — with the
TMR majority vote folded into the same bitwise pass — is BIT-EXACT
against every other evaluator in the repo. The matrix:

  evaluators   bitsliced kernel (layout="bitsliced", traceable jnp)
               x banded Pallas x dense Pallas
               x FabricSim / MultiFabricSim (levelized host oracle)
               x BitslicedSim (independent numpy word-parallel twin,
                 written against RAW net ids, not the packed layout)
  axes         every registered fabric x TMR on/off x sparse on/off
               x batch sizes off the 32-event word boundary

plus the satellite guarantees:
  * word-transpose properties (seeded sweeps via tests/_propshim):
    pack/unpack round-trips in both directions, arbitrary event counts
    including non-multiple-of-32 tails, and padding lanes that never
    leak into outputs or scores;
  * hot-swap (swap_chip / swap_replica) on a bit-sliced stack is an
    array swap — no retrace — and readback returns the same scrub-loop
    table image as the matmul layouts;
  * layout/band validation errors name the offending field and the
    allowed values, identically at pack_fabric(s) and ServerConfig.
"""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import (
    FABRICS,
    BitslicedSim,
    FabricSim,
    MultiFabricSim,
    pack_event_words,
    place_and_route,
    unpack_event_words,
)
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels.lut_eval import bitsliced, ops as lut_ops
from repro.launch.mesh import make_readout_mesh
from repro.launch.readout_server import ReadoutServer, ServerConfig
from tests._propshim import given, settings, strategies as st
from tests.test_banded import _layered_netlist, _long_edge_netlist
from tests.test_kernels import _random_netlist

import repro.core.tmr  # noqa: F401  (registers efpga_28nm_xl)


# ------------------------------------------------------------ helpers
def _cfg(seed, name="efpga_28nm", n_inputs=10, n_luts=48):
    return place_and_route(_random_netlist(seed, n_inputs, n_luts),
                           FABRICS[name])


@pytest.fixture(scope="module")
def farm():
    """Two heterogeneous chips + a feature batch whose size (37) is NOT a
    multiple of the 32-event word, so every served batch exercises the
    tail-lane masking."""
    d = generate(SmartPixelConfig(n_events=10_000, seed=11))
    tr, te = train_test_split(d)
    chips = []
    for fabric, depth in (("efpga_28nm", 3), ("efpga_130nm", 3)):
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=depth, max_leaf_nodes=5,
            min_samples_leaf=300,
        ).fit(tr["features"], tr["label"])
        chip = ReadoutChip.build(clf, fabric=fabric)
        chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
        chips.append(chip)
    return chips, te["features"][:37]


def _golden(chip, X):
    return chip.golden.decision_function_raw(chip.golden.quantize_features(X))


def _serve(server, X, chip_slot=0):
    server.submit_batch(chip_slot, X)
    res = sorted(server.flush(), key=lambda r: r.seq)
    return [(r.seq, r.chip, r.score_raw, r.keep) for r in res]


# --------------------------------------- word-transpose properties
@given(seed=st.integers(0, 10_000), n_events=st.integers(1, 200),
       n_nets=st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_word_transpose_roundtrip_bits(seed, n_events, n_nets):
    """unpack(pack(bits)) == bits for arbitrary event counts (including
    non-multiple-of-32 tails), on BOTH the jnp packer and its numpy twin
    — and the two packers agree word for word."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n_events, n_nets)).astype(np.uint8)
    w_np = pack_event_words(bits)
    w_jx = np.asarray(bitsliced.pack_words(bits))
    assert w_np.dtype == np.uint32 and w_jx.dtype == np.uint32
    assert w_np.shape == (max(-(-n_events // 32), 1), n_nets)
    np.testing.assert_array_equal(w_np, w_jx)
    np.testing.assert_array_equal(unpack_event_words(w_np, n_events), bits)
    np.testing.assert_array_equal(
        np.asarray(bitsliced.unpack_words(w_jx, n_events)), bits)


@given(seed=st.integers(0, 10_000), n_words=st.integers(1, 5),
       n_nets=st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_word_transpose_roundtrip_words(seed, n_words, n_nets):
    """pack(unpack(w)) == w: the transpose is a bijection on full words,
    so no configuration of 32-event lanes is unreachable or aliased."""
    rng = np.random.default_rng(seed)
    w = rng.integers(0, 2 ** 32, (n_words, n_nets), dtype=np.uint64)
    w = w.astype(np.uint32)
    bits = unpack_event_words(w, n_words * 32)
    np.testing.assert_array_equal(pack_event_words(bits), w)
    np.testing.assert_array_equal(
        np.asarray(bitsliced.pack_words(bits)), w)


@given(seed=st.integers(0, 1000), n_events=st.integers(1, 70))
@settings(max_examples=8, deadline=None)
def test_padding_lanes_never_leak(seed, n_events):
    """Outputs for a B-event batch are identical whether B fills its last
    32-lane word or not, and equal the per-event host oracle — garbage in
    the padding lanes of the final word can never reach a real event."""
    cfg = _cfg(7, n_luts=30)
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n_events, cfg.n_inputs)).astype(np.uint8)
    want, _ = FabricSim(cfg).run(bits)
    got = np.asarray(lut_ops.fabric_eval(cfg, bits, layout="bitsliced"))
    np.testing.assert_array_equal(got, want)
    # same events embedded in a bigger batch (different tail occupancy)
    pad = rng.integers(0, 2, (91 - n_events, cfg.n_inputs)).astype(np.uint8)
    big = np.concatenate([bits, pad])
    got_big = np.asarray(lut_ops.fabric_eval(cfg, big, layout="bitsliced"))
    np.testing.assert_array_equal(got_big[:n_events], want)


def test_padding_lanes_never_leak_into_scores(farm):
    """The scored dispatch (the server's launch path) on a batch that
    straddles a word boundary: bit-sliced scores == matmul scores ==
    golden, event for event."""
    chips, X = farm
    chip = chips[0]
    assert len(X) % 32 != 0
    bits = chip.encode_features(X)[None]
    thr = np.array([chip.score_threshold_raw], np.int32)
    mesh = make_readout_mesh(1)
    golden = _golden(chip, X)
    for layout in ("matmul", "bitsliced"):
        stack = lut_ops.pack_fabrics([chip.config], redundancy="tmr",
                                     layout=layout)
        w = lut_ops.decode_plan([chip.config], stack.n_outputs)
        score, keep, dis = lut_ops.fabric_eval_multi_scored(
            stack, bits, w, thr, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(score)[0], golden,
                                      err_msg=layout)
        np.testing.assert_array_equal(
            np.asarray(keep)[0], golden <= chip.score_threshold_raw)
        assert not np.asarray(dis).any(), layout


# --------------------------------------------- the conformance matrix
def test_bitsliced_conformance_every_fabric():
    """Every registered fabric: bitsliced kernel == banded == dense ==
    FabricSim == BitslicedSim, bit for bit, on a batch off the word
    boundary. THE acceptance bar of the tentpole."""
    fabric_names = sorted({s.name for s in FABRICS.values()})
    assert {"efpga_130nm", "efpga_28nm", "efpga_28nm_xl"} <= set(fabric_names)
    for fi, name in enumerate(fabric_names):
        cfg = place_and_route(_random_netlist(60 + fi, 10, 48), FABRICS[name])
        rng = np.random.default_rng(fi)
        bits = rng.integers(0, 2, (41, cfg.n_inputs)).astype(np.uint8)
        want, _ = FabricSim(cfg).run(bits)
        evals = {
            "bitsliced": np.asarray(
                lut_ops.fabric_eval(cfg, bits, layout="bitsliced")),
            "banded": np.asarray(lut_ops.fabric_eval(cfg, bits, band=True)),
            "dense": np.asarray(lut_ops.fabric_eval(cfg, bits, band=False)),
            "host_word_oracle": BitslicedSim(cfg).run(bits),
        }
        for which, got in evals.items():
            np.testing.assert_array_equal(
                got, want, err_msg=f"{name} via {which}")


def test_bitsliced_stack_tmr_matches_plain_and_multisim(farm):
    """Multi-chip bit-sliced stack, TMR on and off, vs MultiFabricSim and
    vs the matmul stack: the folded word-majority vote changes nothing on
    healthy replicas."""
    chips, X = farm
    configs = [c.config for c in chips]
    per_bits = [c.encode_features(X) for c in chips]
    want = MultiFabricSim(configs).run(
        lut_ops.stack_input_bits(
            lut_ops.pack_fabrics(configs, layout="bitsliced"), per_bits))
    for red in ("none", "tmr"):
        stack = lut_ops.pack_fabrics(configs, redundancy=red,
                                     layout="bitsliced")
        assert stack.layout == "bitsliced" and stack.bitsliced
        assert stack.sel is None and stack.src is not None
        bits = lut_ops.stack_input_bits(stack, per_bits)
        got = np.asarray(lut_ops.fabric_eval_multi(stack, bits))
        np.testing.assert_array_equal(got, want, err_msg=f"red={red}")
        matmul = lut_ops.pack_fabrics(configs, redundancy=red)
        np.testing.assert_array_equal(
            got, np.asarray(lut_ops.fabric_eval_multi(matmul, bits)),
            err_msg=f"red={red} vs matmul")


def test_server_matrix_bitsliced_matches_matmul(farm):
    """The served results (scores, keep decisions, sequence) through the
    kernel server are identical for layout='bitsliced' and 'matmul'
    across the TMR x sparse matrix — and equal the golden model."""
    chips, X = farm
    golden = _golden(chips[0], X)
    kept = golden <= chips[0].score_threshold_raw
    for red in ("none", "tmr"):
        for sparse in (False, True):
            out = {}
            for layout in ("matmul", "bitsliced"):
                srv = ReadoutServer([chips[0]], ServerConfig(
                    max_batch=len(X), max_latency_s=1e9, backend="kernel",
                    layout=layout, redundancy=red, sparse=sparse))
                out[layout] = _serve(srv, X)
                assert srv.report()["seu_disagreement_total"] == 0
            assert out["bitsliced"] == out["matmul"], (red, sparse)
            scores = np.array([s for _, _, s, _ in out["bitsliced"]])
            np.testing.assert_array_equal(
                scores, golden[kept] if sparse else golden,
                err_msg=f"red={red} sparse={sparse}")


def test_server_frames_bitsliced_matches_matmul(farm):
    """The fused frames path (frames -> features -> bits -> score in one
    dispatch) with the fabric stage routed through the bit-sliced
    evaluator: served results identical to the matmul layout, under
    TMR."""
    chips, _ = farm
    d = generate(SmartPixelConfig(n_events=90, seed=9), return_frames=True)
    frames, y0 = d["frames"], d["features"][:, 13]
    out = {}
    for layout in ("matmul", "bitsliced"):
        srv = ReadoutServer([chips[0]], ServerConfig(
            max_batch=64, max_latency_s=1e9, backend="kernel",
            layout=layout, redundancy="tmr"))
        srv.submit_frames(0, frames, y0)
        res = sorted(srv.flush(), key=lambda r: r.seq)
        out[layout] = [(r.seq, r.score_raw, r.keep) for r in res]
    assert out["bitsliced"] == out["matmul"]
    assert len(out["bitsliced"]) == len(frames)


# ------------------------------------------------ hot-swap / no-retrace
def test_bitsliced_swap_chip_no_retrace(farm):
    """swap_chip on a bit-sliced stack rewrites (src, tables,
    output_nets) rows — same pytree structure, so the jit cache must not
    grow — and the swapped slot evaluates as the new config."""
    if not hasattr(lut_ops._eval_stack_arrays, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    cfgs = [_cfg(80 + i, n_luts=30) for i in range(3)]
    stack = lut_ops.pack_fabrics(cfgs, layout="bitsliced")
    rng = np.random.default_rng(4)
    per = [rng.integers(0, 2, (37, c.n_inputs)).astype(np.uint8)
           for c in cfgs]
    bits = lut_ops.stack_input_bits(stack, per)
    np.asarray(lut_ops.fabric_eval_multi(stack, bits))
    n0 = lut_ops._eval_stack_arrays._cache_size()

    new = place_and_route(_layered_netlist(99, 10, 5, levels=3),
                          FABRICS["efpga_28nm"])
    stack2 = stack.swap_chip(1, new)
    per2 = list(per)
    per2[1] = rng.integers(0, 2, (37, new.n_inputs)).astype(np.uint8)
    bits2 = lut_ops.stack_input_bits(stack2, per2)
    got = np.asarray(lut_ops.fabric_eval_multi(stack2, bits2))
    assert lut_ops._eval_stack_arrays._cache_size() == n0, "swap retraced"
    from repro.core.fabric import StackGeometry

    geo = StackGeometry(
        n_levels=stack.n_levels, max_level_size=stack.m_pad,
        n_inputs=stack.n_inputs, n_outputs=stack.n_outputs)
    want = MultiFabricSim([cfgs[0], new, cfgs[2]], geometry=geo).run(bits2)
    np.testing.assert_array_equal(got, want)


def test_bitsliced_swap_replica_and_readback(farm):
    """swap_replica perturbs ONE replica of a bit-sliced TMR stack; the
    vote masks it, the disagreement monitor sees it, and readback returns
    the live (perturbed) scrub-loop table image — the whole
    readback->verify->heal loop works unchanged on this layout."""
    from repro.core.fabric import packed_table_image
    from repro.core.tmr import inject_seu, replicate_config

    chips, X = farm
    chip = chips[0]
    stack = lut_ops.pack_fabrics([chip.config], redundancy="tmr",
                                 layout="bitsliced")
    img0 = stack.readback_replica(0, 1)
    np.testing.assert_array_equal(
        img0, packed_table_image(replicate_config(chip.config, 1),
                                 stack.n_levels, stack.m_pad))
    seu = inject_seu(replicate_config(chip.config, 1), 0, 3)
    stack2 = stack.swap_replica(0, 1, seu)
    assert (stack2.readback_replica(0, 1) != img0).sum() == 1
    bits = lut_ops.stack_input_bits(stack2, [chip.encode_features(X)])
    got = np.asarray(lut_ops.fabric_eval_multi(stack2, bits))
    want, _ = FabricSim(chip.config).run(chip.encode_features(X))
    np.testing.assert_array_equal(got[0], want)


# ------------------------------------------- banded conformance matrix
def test_banded_bitsliced_conformance_matrix():
    """Every registered fabric x band auto/off x TMR on/off x sparse
    on/off: a BANDED bit-sliced stack (the band is a reach envelope —
    same gather kernel, stricter admission) serves scores bit-exact vs
    MultiFabricSim and the banded BitslicedSim host oracle, and the
    word-domain sparse egress ships exactly the kept subset."""
    from repro.parallel.compression import sparse_trigger_unpack

    mesh = make_readout_mesh(1)
    fabric_names = sorted({s.name for s in FABRICS.values()})
    assert {"efpga_130nm", "efpga_28nm", "efpga_28nm_xl"} <= set(fabric_names)
    rng = np.random.default_rng(5)
    for fi, name in enumerate(fabric_names):
        cfg = place_and_route(_layered_netlist(70 + fi, 8, 6, levels=4),
                              FABRICS[name])
        assert cfg.fanin_reach() == 1
        B = 37                          # off the 32-event word boundary
        bits = rng.integers(0, 2, (1, B, cfg.n_inputs)).astype(np.uint8)
        want = MultiFabricSim([cfg]).run(bits)
        np.testing.assert_array_equal(
            BitslicedSim(cfg, band_k=1).run(bits[0]), want[0],
            err_msg=f"{name} banded host oracle")
        for band in (None, False):
            for red in ("none", "tmr"):
                tag = f"{name} band={band} red={red}"
                stack = lut_ops.pack_fabrics(
                    [cfg], band=band, redundancy=red, layout="bitsliced")
                assert stack.bitsliced
                assert stack.banded == (band is None), tag  # reach 1 < L
                w = lut_ops.decode_plan([cfg], stack.n_outputs)
                golden = (want[0].astype(np.int64) * w[0]).sum(-1)
                thr = np.array([int(np.median(golden))], np.int32)
                kept = golden <= thr[0]
                score, keep, dis = lut_ops.fabric_eval_multi_scored(
                    stack, bits, w, thr, mesh=mesh)
                np.testing.assert_array_equal(
                    np.asarray(score)[0], golden, err_msg=tag)
                np.testing.assert_array_equal(
                    np.asarray(keep)[0], kept, err_msg=tag)
                assert not np.asarray(dis).any(), tag
                # sparse cell: word-domain egress == the kept subset
                count, idx, vals, dis2 = (
                    lut_ops.fabric_eval_multi_scored_sparse(
                        stack, bits, w, thr, mesh=mesh))
                assert int(np.asarray(count)) == int(kept.sum()), tag
                s2, k2 = sparse_trigger_unpack(
                    np.asarray(idx), np.asarray(vals), (1, B))
                np.testing.assert_array_equal(k2[0], kept, err_msg=tag)
                np.testing.assert_array_equal(
                    s2[0], golden * kept, err_msg=tag)
                assert not np.asarray(dis2).any(), tag


@given(seed=st.integers(0, 500))
@settings(max_examples=5, deadline=None)
def test_bitsliced_swap_reach_exceeding_band_raises_and_preserves(seed):
    """Property: swap_chip of a config whose fan-in reach exceeds a
    banded bit-sliced stack's envelope raises the named admission error
    and leaves the stack unchanged — arrays untouched, outputs
    identical."""
    cfgs = [place_and_route(_layered_netlist(seed + i, 6, 5, levels=5),
                            FABRICS["efpga_28nm"]) for i in range(2)]
    stack = lut_ops.pack_fabrics(cfgs, band=True, layout="bitsliced")
    assert stack.bitsliced and stack.banded and stack.band_k == 1
    rng = np.random.default_rng(seed)
    per = [rng.integers(0, 2, (37, c.n_inputs)).astype(np.uint8)
           for c in cfgs]
    bits = lut_ops.stack_input_bits(stack, per)
    before = np.asarray(lut_ops.fabric_eval_multi(stack, bits))
    src0 = np.asarray(stack.src).copy()
    tbl0 = np.asarray(stack.tables).copy()
    deep = place_and_route(_long_edge_netlist(2, chain=4),
                           FABRICS["efpga_28nm"])
    assert deep.fanin_reach() > stack.band_k
    assert len(deep.level_sizes) <= stack.n_levels  # only the band blocks
    with pytest.raises(ValueError, match="envelope"):
        stack.swap_chip(0, deep)
    with pytest.raises(ValueError, match="envelope"):
        stack.swap_replica(0, 0, deep)
    np.testing.assert_array_equal(np.asarray(stack.src), src0)
    np.testing.assert_array_equal(np.asarray(stack.tables), tbl0)
    np.testing.assert_array_equal(
        np.asarray(lut_ops.fabric_eval_multi(stack, bits)), before)


# ----------------------------------------------------- validation errors
def test_pack_layout_validation_names_field_and_values():
    cfg = _cfg(3, n_luts=12)
    with pytest.raises(ValueError, match=r"unknown layout 'packed'.*"
                       r"'matmul' or 'bitsliced'"):
        lut_ops.pack_fabric(cfg, layout="packed")
    # the band is a layout-independent reach ENVELOPE: every spelling
    # (auto / forced-on / forced-dense) packs on the bit-sliced layout
    for band in (None, True, False):
        assert lut_ops.pack_fabric(cfg, band=band,
                                   layout="bitsliced").bitsliced
        assert lut_ops.pack_fabrics([cfg], band=band,
                                    layout="bitsliced").bitsliced


def test_pack_reach_vs_band_named_error():
    """A config whose fan-in reach exceeds the band K is rejected with
    the named reach-vs-band error by the bit-sliced packer AND by the
    banded host oracle (BitslicedSim band_k) — the conformance pair
    agrees on admission, not just on outputs."""
    cfg = place_and_route(_long_edge_netlist(2, chain=5),
                          FABRICS["efpga_28nm"])
    assert cfg.fanin_reach() == 4
    L = max(len(cfg.level_sizes), 1)
    m_pad = lut_ops._round_up(max(cfg.level_sizes, default=1), 128)
    in_seg = lut_ops._round_up(2 + cfg.n_inputs, 128)
    with pytest.raises(ValueError,
                       match=r"fan-in reach exceeds band: K=2"):
        lut_ops._pack_arrays_bitsliced(cfg, L, m_pad, in_seg,
                                       len(cfg.output_nets), band_k=2)
    with pytest.raises(ValueError,
                       match=r"fan-in reach exceeds band: K=2"):
        BitslicedSim(cfg, band_k=2)
    # at or above the true reach both admit — and the band changes
    # ADMISSION only, never the evaluation
    bits = np.random.default_rng(0).integers(
        0, 2, (37, cfg.n_inputs)).astype(np.uint8)
    np.testing.assert_array_equal(
        BitslicedSim(cfg, band_k=4).run(bits), BitslicedSim(cfg).run(bits))


def test_serverconfig_layout_validation_names_field_and_values():
    ServerConfig(layout="bitsliced")                    # valid
    ServerConfig(layout="bitsliced", redundancy="tmr")  # valid
    # the band is layout-independent: every pairing is a valid config
    ServerConfig(layout="bitsliced", band=True)
    ServerConfig(layout="bitsliced", band=False)
    ServerConfig(layout="matmul", band=True)
    assert ServerConfig().effective_layout == "bitsliced"
    with pytest.raises(ValueError, match=r"unknown layout 'dense'.*"
                       r"'matmul' or 'bitsliced'"):
        ServerConfig(layout="dense")
    with pytest.raises(ValueError, match=r"band must be True, False or "
                       r"None \(auto\), got 'banded'"):
        ServerConfig(band="banded")


# ------------------------------------------------------------- slow tier
@pytest.mark.slow
def test_bitsliced_seeded_sweep_every_fabric():
    """Long conformance sweep: several random netlists per fabric,
    bit-sliced == banded == dense == FabricSim == BitslicedSim across
    randomized batch sizes (word-aligned and not)."""
    fabric_names = sorted({s.name for s in FABRICS.values()})
    for fi, name in enumerate(fabric_names):
        rng = np.random.default_rng(900 + fi)
        for seed in range(4):
            nl = _random_netlist(
                800 + 10 * fi + seed, int(rng.integers(4, 16)),
                int(rng.integers(20, 140)))
            cfg = place_and_route(nl, FABRICS[name])
            B = int(rng.integers(1, 130))
            bits = rng.integers(0, 2, (B, cfg.n_inputs)).astype(np.uint8)
            want, _ = FabricSim(cfg).run(bits)
            for which, got in (
                ("bitsliced", lut_ops.fabric_eval(cfg, bits,
                                                  layout="bitsliced")),
                ("banded", lut_ops.fabric_eval(cfg, bits, band=True)),
                ("dense", lut_ops.fabric_eval(cfg, bits, band=False)),
                ("host_word_oracle", BitslicedSim(cfg).run(bits)),
            ):
                np.testing.assert_array_equal(
                    np.asarray(got), want,
                    err_msg=f"{name} seed={seed} B={B} via {which}")
