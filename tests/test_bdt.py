"""Gradient boosting from scratch + quantized golden model."""
import numpy as np
import pytest

from repro.core.bdt import (
    GradientBoostedClassifier, operating_point_at_signal_eff,
    signal_eff_background_rej,
)
from repro.core.quantize import AP_FIXED_28_19, FixedSpec
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split


@pytest.fixture(scope="module")
def data():
    d = generate(SmartPixelConfig(n_events=50_000, seed=5))
    return train_test_split(d)


def _auc(score, y):
    order = np.argsort(score)
    ranks = np.empty(len(score))
    ranks[order] = np.arange(len(score))
    pos = y.astype(bool)
    n_pos, n_neg = pos.sum(), (~pos).sum()
    return (ranks[pos].sum() - n_pos * (n_pos - 1) / 2) / (n_pos * n_neg)


def test_single_tree_learns(data):
    tr, te = data
    clf = GradientBoostedClassifier(n_estimators=1, max_depth=5,
                                    min_samples_leaf=500).fit(
        tr["features"], tr["label"])
    p = clf.predict_proba(te["features"])
    y = te["label"]
    # ranks pileup above signal better than chance (the paper's own Table 1
    # shows a WEAK classifier: 4-6% rejection at ~97% signal efficiency)
    assert _auc(p, y) > 0.52  # chance = 0.500 +- 0.005 at this n
    assert clf.trees[0].depth() <= 5


def test_more_trees_reduce_loss(data):
    tr, te = data
    y = te["label"].astype(np.float64)

    def logloss(clf):
        p = np.clip(clf.predict_proba(te["features"]), 1e-9, 1 - 1e-9)
        return -(y * np.log(p) + (1 - y) * np.log(1 - p)).mean()

    l1 = logloss(GradientBoostedClassifier(n_estimators=1).fit(tr["features"], tr["label"]))
    l5 = logloss(GradientBoostedClassifier(n_estimators=5).fit(tr["features"], tr["label"]))
    assert l5 < l1


def test_max_leaf_nodes_limits_thresholds(data):
    tr, _ = data
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10
    ).fit(tr["features"], tr["label"])
    t = clf.trees[0]
    assert t.n_leaves <= 10
    assert t.n_internal <= 9  # the paper's "9 threshold parameters" regime


def test_quantized_close_to_float(data):
    tr, te = data
    clf = GradientBoostedClassifier(n_estimators=2, max_depth=4).fit(
        tr["features"], tr["label"])
    pf = clf.predict_proba(te["features"][:4000])
    pq = clf.quantized(AP_FIXED_28_19).predict_proba(te["features"][:4000])
    # ap_fixed<28,19> has 2^-9 resolution; scores nearly identical
    assert np.abs(pf - pq).max() < 0.05
    assert (np.sign(pf - 0.5) == np.sign(pq - 0.5)).mean() > 0.99


def test_quantized_integer_path_is_exact(data):
    tr, te = data
    clf = GradientBoostedClassifier(n_estimators=1, max_depth=5).fit(
        tr["features"], tr["label"])
    q = clf.quantized()
    X_raw = q.quantize_features(te["features"][:2000])
    r1 = q.decision_function_raw(X_raw)
    r2 = q.decision_function_raw(X_raw)
    np.testing.assert_array_equal(r1, r2)
    assert r1.dtype == np.int64


def test_coarse_spec_degrades_gracefully(data):
    tr, te = data
    clf = GradientBoostedClassifier(n_estimators=1, max_depth=5).fit(
        tr["features"], tr["label"])
    coarse = clf.quantized(FixedSpec(12, 10))
    p = coarse.predict_proba(te["features"][:2000])
    assert np.isfinite(p).all()


def test_operating_point_metrics(data):
    tr, te = data
    clf = GradientBoostedClassifier(n_estimators=1, max_depth=5).fit(
        tr["features"], tr["label"])
    score = clf.predict_proba(te["features"])
    thr, sig_eff, bkg_rej = operating_point_at_signal_eff(score, te["label"], 0.97)
    assert 0.9 <= sig_eff <= 1.0
    assert 0.0 <= bkg_rej <= 1.0
    rows = signal_eff_background_rej(score, te["label"], np.asarray([thr]))
    assert rows[0][1] == pytest.approx(sig_eff)
