"""Optimizers, microbatching, data pipeline, end-to-end loss descent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_SHAPE, smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.train import TINY
from repro.models import registry
from repro.train.optimizer import (
    OptimizerConfig, adafactor_init, adafactor_update, adamw_init,
    adamw_update, global_norm, make_optimizer, schedule,
)
from repro.train.train_step import make_opt_init, make_train_step


def _numpy_adamw_step(p, g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1**t)
    vh = v / (1 - b2**t)
    upd = mh / (np.sqrt(vh) + eps) + (wd * p if p.ndim >= 2 else 0)
    return p - lr * upd, m, v


def test_adamw_matches_numpy_reference():
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=10**9,
                          min_lr_frac=1.0, clip_norm=1e9)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(0, 1, (4, 3)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(0, 1, (4, 3)).astype(np.float32))}
    state = adamw_init(cfg, p)
    newp, state, _ = adamw_update(cfg, g, state, p)
    ref_p, _, _ = _numpy_adamw_step(
        np.asarray(p["w"]), np.asarray(g["w"]),
        np.zeros((4, 3)), np.zeros((4, 3)), 1, 1e-2)
    np.testing.assert_allclose(np.asarray(newp["w"]), ref_p, rtol=1e-5)


def test_clipping_bounds_update():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=0, min_lr_frac=1.0,
                          clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((8, 8))}
    g = {"w": jnp.full((8, 8), 1e6)}
    state = adamw_init(cfg, p)
    _, _, metrics = adamw_update(cfg, g, state, p)
    assert float(metrics["grad_norm"]) > 1e6  # reports pre-clip norm


def test_schedule_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.asarray(110))) == pytest.approx(0.1, abs=1e-6)


def test_adafactor_shrinks_loss_quadratic():
    cfg = OptimizerConfig(name="adafactor", lr=0.1, warmup_steps=0,
                          total_steps=10**9, min_lr_frac=1.0, weight_decay=0.0)
    init, update = make_optimizer(cfg)
    target = jnp.asarray(np.random.default_rng(1).normal(0, 1, (16, 8)).astype(np.float32))
    p = {"w": jnp.zeros((16, 8))}
    state = init(p)
    for _ in range(60):
        g = {"w": p["w"] - target}
        p, state, _ = update(g, state, p)
    assert float(jnp.mean(jnp.square(p["w"] - target))) < 0.05


def test_adafactor_state_is_factored():
    cfg = OptimizerConfig(name="adafactor")
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = adafactor_init(cfg, p)
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (32,)
    assert st["v"]["b"]["v"].shape == (32,)


@pytest.mark.slow
def test_microbatch_equivalence():
    """grads(n_mb=4) == grads(n_mb=1) up to accumulation order.

    Jit-compiles TWO full train steps (~20 s on CPU): slow tier, so the
    fast tier's per-test budget (tests/conftest.py) holds with margin."""
    import dataclasses

    cfg1 = dataclasses.replace(TINY, num_microbatches=1)
    cfg4 = dataclasses.replace(TINY, num_microbatches=4)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=0, clip_norm=1e9)
    params = registry.init_params(cfg1, jax.random.PRNGKey(0))
    opt_state = make_opt_init(cfg1, opt_cfg)(params)
    batch = registry.make_batch(
        cfg1, type(SMOKE_SHAPE)("x", 64, 8, "train"), jax.random.PRNGKey(1))
    p1, _, m1 = make_train_step(cfg1, opt_cfg)(params, opt_state, batch)
    p4, _, m4 = make_train_step(cfg4, opt_cfg)(params, opt_state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3  # same step direction


def test_pipeline_deterministic_and_shard_recomputable():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    p1 = TokenPipeline(cfg, n_shards=2, shard=0)
    p2 = TokenPipeline(cfg, n_shards=2, shard=1)
    b0 = p1.batch_at(7)
    b0_again = TokenPipeline(cfg, n_shards=2, shard=0).batch_at(7)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    # shard 0 can recompute shard 1's batch (failover property)
    b1 = p1.batch_at(7, shard=1)
    np.testing.assert_array_equal(b1["tokens"], p2.batch_at(7)["tokens"])
    # labels are next-tokens
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


@pytest.mark.slow
def test_tiny_training_descends():
    cfg = TINY
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                    global_batch=8, seed=0))
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = make_opt_init(cfg, opt_cfg)(params)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
