"""Multi-chip streaming readout server (launch/readout_server.py).

Covers the three tentpole properties:
  (a) chip-batched kernel scores == per-chip host FabricSim oracle, bit-exact
  (b) micro-batch coalescing preserves per-event ordering and keep/drop
  (c) heterogeneous tree shapes pad/stack into one shared geometry
plus hot-swap reconfiguration and the latency-triggered partial flush.
"""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import (
    FABRIC_28NM, CapacityError, FabricSim, MultiFabricSim, StackGeometry,
    place_and_route,
)
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.readout_server import ReadoutServer, ScoredEvent, ServerConfig


class FakeClock:
    """Deterministic clock so latency-triggered flushes are testable."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def chip_farm():
    """Four chips with deliberately heterogeneous designs: different depths
    and leaf budgets -> different netlist level counts, level widths, input
    widths (used-feature sets) and LUT counts. Single trees only — a
    multi-tree ensemble's ripple-adder makes the levelized form ~3x deeper,
    which the dense interpret-mode kernel pays for quadratically; the adder
    path is covered at netlist level in test_synth_fabric_bitstream."""
    d = generate(SmartPixelConfig(n_events=12_000, seed=5))
    tr, te = train_test_split(d)
    chips = []
    for depth, leaves in [(5, 10), (4, 8), (4, 12), (3, 5)]:
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=depth, max_leaf_nodes=leaves,
            min_samples_leaf=200,
        ).fit(tr["features"], tr["label"])
        chip = ReadoutChip.build(clf)
        chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
        chips.append(chip)
    return chips, te["features"]


def _stream_all(server, chips, X, n_per_chip, interleave=16):
    """Submit n_per_chip events to every chip in interleaved blocks and
    return all results (poll as we go + final flush)."""
    results = []
    submitted = {i: [] for i in range(len(chips))}
    pos = 0
    while any(len(submitted[i]) < n_per_chip for i in range(len(chips))):
        for c in range(len(chips)):
            take = min(interleave, n_per_chip - len(submitted[c]))
            if take <= 0:
                continue
            block = X[pos : pos + take]
            pos += take
            seqs = server.submit_batch(c, block)
            submitted[c].extend(zip(seqs, block))
        results.extend(server.poll())
    results.extend(server.flush())
    return results, submitted


# ------------------------------------------------------------------ (a)
def test_multichip_kernel_bit_identical_to_host_oracle(chip_farm):
    """One chip-batched Pallas dispatch == per-chip FabricSim, bit-exact."""
    chips, X = chip_farm
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=10_000, max_latency_s=1e9, backend="kernel"))
    results, submitted = _stream_all(srv, chips, X, n_per_chip=48)
    assert len(results) == 48 * len(chips)

    by_seq = {r.seq: r for r in results}
    for c, chip in enumerate(chips):
        seqs = [s for s, _ in submitted[c]]
        feats = np.stack([f for _, f in submitted[c]])
        # independent oracle: host FabricSim through the same bitstream
        want_raw = chip.infer_raw(feats, backend="host")
        want_keep = want_raw <= chip.score_threshold_raw
        got_raw = np.array([by_seq[s].score_raw for s in seqs])
        got_keep = np.array([by_seq[s].keep for s in seqs])
        np.testing.assert_array_equal(got_raw, want_raw)
        np.testing.assert_array_equal(got_keep, want_keep)
        # and the golden quantized model agrees (the paper's 100% check)
        golden = chip.golden.decision_function_raw(
            chip.golden.quantize_features(feats))
        np.testing.assert_array_equal(got_raw, golden)


@pytest.mark.slow
def test_kernel_and_host_servers_agree(chip_farm):
    chips, X = chip_farm
    out = {}
    for backend in ("kernel", "host"):
        srv = ReadoutServer(chips, ServerConfig(
            max_batch=64, max_latency_s=1e9, backend=backend))
        results, _ = _stream_all(srv, chips, X, n_per_chip=32)
        out[backend] = sorted(results, key=lambda r: r.seq)
    assert out["kernel"] == out["host"]


# ------------------------------------------------------------------ (b)
def test_microbatch_coalescing_preserves_order_and_decisions(chip_farm):
    chips, X = chip_farm
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=50, max_latency_s=1e9, backend="host"))
    results, submitted = _stream_all(srv, chips, X, n_per_chip=60,
                                     interleave=7)
    # every submitted event comes back exactly once
    all_seqs = sorted(s for c in submitted for s, _ in submitted[c])
    assert sorted(r.seq for r in results) == all_seqs
    # multiple micro-batches actually happened (coalescing was exercised)
    rep = srv.report()
    assert sum(pc["n_dispatches"] for pc in rep["per_chip"]) > len(chips)
    # per-chip FIFO: results for a chip appear in submission order
    for c in range(len(chips)):
        seqs_in = [s for s, _ in submitted[c]]
        seqs_out = [r.seq for r in results if r.chip == c]
        assert seqs_out == seqs_in
    # keep/drop decisions match the chip's own integer-domain cut
    by_seq = {r.seq: r for r in results}
    for c, chip in enumerate(chips):
        feats = np.stack([f for _, f in submitted[c]])
        want_keep = chip.keep_mask(feats, backend="host")
        got_keep = np.array([by_seq[s].keep for s, _ in submitted[c]])
        np.testing.assert_array_equal(got_keep, want_keep)
    # report accounting is consistent with the decisions
    assert rep["n_in"] == len(all_seqs)
    assert rep["n_kept"] == sum(r.keep for r in results)


def test_max_latency_flushes_partial_batch(chip_farm):
    chips, X = chip_farm
    clock = FakeClock()
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=1_000, max_latency_s=0.010, backend="host"), clock=clock)
    srv.submit_batch(0, X[:5])
    assert srv.poll() == []            # fresh partial batch: not due yet
    assert srv.queue_depth == 5
    clock.advance(0.011)
    got = srv.poll()                   # latency budget exceeded -> dispatch
    assert srv.queue_depth == 0
    got += srv.flush()                 # host results retire by poll already
    assert [r.seq for r in got] == [0, 1, 2, 3, 4]


def test_poll_retires_ready_batches_promptly(chip_farm):
    """poll never blocks and never sits on finished work: a dispatched
    batch whose results are ready (host backend: always) retires on the
    NEXT poll, it does not wait for later dispatches to push it out."""
    chips, X = chip_farm
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=8, max_latency_s=1e9, backend="host", pipeline_depth=1))
    srv.submit_batch(1, X[:8])
    first = srv.poll()        # dispatch batch 0; host result is ready ->
    assert [r.seq for r in first] == list(range(8))   # retires same poll
    assert srv.queue_depth == 0 and srv.report()["inflight_batches"] == 0
    srv.submit_batch(1, X[8:16])
    second = srv.poll()
    assert [r.seq for r in second] == list(range(8, 16))
    assert srv.flush() == []           # nothing left for flush to block on


def test_full_pipeline_defers_dispatch_instead_of_blocking(chip_farm):
    """The capacity gate: with in-flight batches NOT ready and the
    pipeline at depth, a due micro-batch stays in the queue (where
    admission control can see its wait) — poll neither blocks on the
    device nor launches past the depth. When results finish, the next
    poll retires them and only then dispatches the deferred batch."""
    chips, X = chip_farm
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=8, max_latency_s=1e9, backend="host", pipeline_depth=1))
    # simulate a slow async device: nothing is ready until we flip the gate
    gate = {"ready": False}
    srv._result_ready = lambda x: gate["ready"]
    srv.submit_batch(1, X[:8])
    assert srv.poll() == []            # batch 0 launched, still cooking
    srv.submit_batch(1, X[8:16])
    assert srv.poll() == []            # batch 1 launches (depth allows +1)
    assert srv.report()["inflight_batches"] == 2
    srv.submit_batch(1, X[16:24])
    assert srv.poll() == []            # pipeline full -> batch 2 DEFERRED
    assert srv.queue_depth == 8        # still queued, not silently stuck
    assert srv.report()["inflight_batches"] == 2
    gate["ready"] = True
    got = srv.poll()                   # 0+1 retire; deferred batch 2 goes
    assert [r.seq for r in got] == list(range(24))
    assert srv.queue_depth == 0
    assert srv.flush() == []


# ------------------------------------------------------------------ (c)
def test_heterogeneous_shapes_pad_and_stack(chip_farm):
    from repro.kernels.lut_eval import ops as lut_ops

    chips, X = chip_farm
    configs = [c.config for c in chips]
    # the farm really is heterogeneous on every axis we pad
    assert len({len(c.level_sizes) for c in configs}) > 1
    assert len({c.n_inputs for c in configs}) > 1
    geo = StackGeometry.union(configs)
    assert geo.n_levels == max(len(c.level_sizes) for c in configs)
    assert geo.n_inputs == max(c.n_inputs for c in configs)
    assert all(geo.admits(c) for c in configs)

    stack = lut_ops.pack_fabrics(configs)
    assert stack.n_chips == len(configs)
    assert stack.n_inputs_each == tuple(c.n_inputs for c in configs)

    rng = np.random.default_rng(3)
    per_chip = [
        rng.integers(0, 2, (19, c.n_inputs)).astype(np.uint8) for c in configs
    ]
    bits = lut_ops.stack_input_bits(stack, per_chip)
    got = np.asarray(lut_ops.fabric_eval_multi(stack, bits))
    want = MultiFabricSim(configs).run(bits)
    np.testing.assert_array_equal(got, want)
    # padded output lanes read 0 on both paths
    for i, c in enumerate(configs):
        assert (got[i, :, len(c.output_nets):] == 0).all()


def test_stack_rejects_sequential_configs():
    from repro.core.netlist import counter_netlist

    cfg = place_and_route(counter_netlist(8), FABRIC_28NM)
    with pytest.raises(CapacityError, match="sequential"):
        MultiFabricSim([cfg])


# ------------------------------------------------------- reconfiguration
def _check_hot_swap(chips, X, backend):
    srv = ReadoutServer(list(chips), ServerConfig(
        max_batch=10_000, max_latency_s=1e9, backend=backend))
    srv.submit_batch(2, X[:16])
    pre = srv.reconfigure(2, chips[3])   # pending events flushed first
    assert len(pre) == 16
    want_pre = chips[2].infer_raw(X[:16], backend="host")
    np.testing.assert_array_equal([r.score_raw for r in pre], want_pre)

    srv.submit_batch(2, X[16:40])
    post = srv.flush()
    want_post = chips[3].infer_raw(X[16:40], backend="host")
    np.testing.assert_array_equal([r.score_raw for r in post], want_post)


def test_hot_swap_reconfigure_matches_new_chip(chip_farm):
    chips, X = chip_farm
    _check_hot_swap(chips, X, "host")


@pytest.mark.slow
def test_hot_swap_reconfigure_kernel_backend(chip_farm):
    chips, X = chip_farm
    _check_hot_swap(chips, X, "kernel")


def test_swap_rejects_config_exceeding_envelope(chip_farm):
    from repro.kernels.lut_eval import ops as lut_ops
    from tests.test_kernels import _random_netlist

    chips, _ = chip_farm
    stack = lut_ops.pack_fabrics([c.config for c in chips])
    # a config wider than the envelope on the input axis cannot hot-swap
    wide = place_and_route(
        _random_netlist(0, stack.n_inputs + 7, 30), FABRIC_28NM)
    with pytest.raises(ValueError, match="envelope"):
        stack.swap_chip(0, wide)


def test_reconfigure_envelope_enforced_on_both_backends(chip_farm):
    """Host and kernel servers must reject the same hot-swaps (a
    deployment validated on the oracle must not crash on the kernel)."""
    import types

    from tests.test_kernels import _random_netlist

    chips, _ = chip_farm
    for backend in ("host", "kernel"):
        srv = ReadoutServer(list(chips), ServerConfig(
            max_batch=10_000, max_latency_s=1e9, backend=backend))
        geo_before = srv.geometry
        wide = place_and_route(
            _random_netlist(0, srv.geometry.n_inputs + 5, 30), FABRIC_28NM)
        with pytest.raises(ValueError, match="envelope"):
            srv.reconfigure(1, types.SimpleNamespace(config=wide))
        # the fixed envelope never changes, even across a valid swap
        srv.reconfigure(1, chips[3])
        assert srv.geometry == geo_before


@pytest.mark.slow
def test_hot_swap_does_not_retrace_kernel(chip_farm):
    """The 'array swap, no recompile' guarantee, enforced at the jit
    layer: swapping a chip with different true widths must not grow the
    jit cache of the stacked evaluator."""
    from repro.kernels.lut_eval import ops as lut_ops

    if not hasattr(lut_ops._eval_stack_arrays, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    chips, X = chip_farm
    stack = lut_ops.pack_fabrics([c.config for c in chips])
    bits = lut_ops.stack_input_bits(
        stack, [c.encode_features(X[:8]) for c in chips])
    np.asarray(lut_ops.fabric_eval_multi(stack, bits))
    n0 = lut_ops._eval_stack_arrays._cache_size()

    stack2 = stack.swap_chip(0, chips[3].config)  # different widths
    per2 = [chips[3].encode_features(X[:8])] + [
        c.encode_features(X[:8]) for c in chips[1:]]
    out = np.asarray(lut_ops.fabric_eval_multi(
        stack2, lut_ops.stack_input_bits(stack2, per2)))
    assert lut_ops._eval_stack_arrays._cache_size() == n0
    # and the swapped stack still scores correctly
    want = MultiFabricSim(
        [chips[3].config] + [c.config for c in chips[1:]],
        geometry=StackGeometry(
            n_levels=stack.n_levels, max_level_size=stack.m_pad,
            n_inputs=stack.n_inputs, n_outputs=stack.n_outputs),
    ).run(lut_ops.stack_input_bits(stack2, per2))
    np.testing.assert_array_equal(out, want)
