"""Physics generator sanity: determinism + the paper's discriminating signal."""
import numpy as np

from repro.data.smartpixel import (
    N_FEATURES, N_T, N_X, N_Y, SmartPixelConfig, generate, generate_batch,
    iter_batches,
)


def test_deterministic_by_seed():
    a = generate(SmartPixelConfig(n_events=5_000, seed=1))
    b = generate(SmartPixelConfig(n_events=5_000, seed=1))
    np.testing.assert_array_equal(a["features"], b["features"])
    c = generate(SmartPixelConfig(n_events=5_000, seed=2))
    assert not np.array_equal(a["features"], c["features"])


def test_shapes_and_labels():
    d = generate(SmartPixelConfig(n_events=3_000, seed=4), return_frames=True)
    assert d["features"].shape == (3_000, N_FEATURES)
    assert d["frames"].shape == (3_000, N_T, N_Y, N_X)
    assert set(np.unique(d["label"])) <= {0, 1}
    np.testing.assert_array_equal(d["label"], (d["pt"] < 2.0).astype(np.int8))


def test_pileup_dominates():
    d = generate(SmartPixelConfig(n_events=20_000, seed=6))
    frac = d["label"].mean()
    assert 0.8 < frac < 0.99  # LHC-like: most tracks are soft pileup


def test_low_pt_tracks_leave_wider_clusters():
    """The paper's §5 physics: low-momentum tracks curve more, crossing at a
    steeper angle, spreading charge over more y-pixels."""
    d = generate(SmartPixelConfig(n_events=40_000, seed=7))
    yprof = d["features"][:, :13]
    total = yprof.sum(1) + 1e-9
    # cluster width = participation number of the profile
    width = total**2 / (np.square(yprof).sum(1) + 1e-9)
    lo = width[d["pt"] < 0.3]
    hi = width[d["pt"] > 5.0]
    # weak-but-real signal (the paper's Table 1 classifier is weak too);
    # at n~40k the std error on the means is ~0.01, so 5% is >>5 sigma.
    assert lo.mean() > hi.mean() * 1.05


def test_streaming_matches_bulk():
    cfg = SmartPixelConfig(n_events=4_000, seed=8)
    bulk = generate(cfg)
    stream = np.concatenate([b["features"] for b in iter_batches(cfg, 1_000)])
    np.testing.assert_array_equal(bulk["features"], stream)


def test_charge_positive_and_finite():
    d = generate(SmartPixelConfig(n_events=2_000, seed=9))
    assert np.isfinite(d["features"]).all()
    assert (d["features"][:, :13] >= 0).all()
