"""SEU fault-injection campaign for the TMR serving stage + sparse link.

The resilience claim under test (ISSUE acceptance bar): with
``ServerConfig(redundancy="tmr")`` a SINGLE configuration-bit flip in any
one replica of any served chip leaves the voted server outputs
bit-identical to the unperturbed golden model — on both backends, banded
and dense — while the per-replica disagreement counters (the SEU health
monitor) record the upset. Structure:

  fast tier
    * voter / replica-encoding / coordinate-translation properties
      (seeded sweeps via tests/_propshim);
    * a seeded random SUBSAMPLE of (replica, lut, bit) flips per
      registered fabric, injected through the live server on both
      backends (kernel: banded, dense AND the bit-sliced layout, whose
      majority vote is fused into the word-parallel bitwise pass) via
      ``server.inject_seu`` — flips are healed by re-flipping the same
      bit, so one server serves the whole subsample with no repacking;
    * the double-fault negative controls, the sparse-readout semantics,
      hot-swap/no-retrace under TMR, config validation, and the
      committed-benchmark keys.
  slow tier (nightly)
    * the FULL sweep — every LUT x every truth-table bit of one replica —
      per registered fabric on the host-oracle server, plus an every-LUT
      kernel-dispatch sweep (banded, dense, and bit-sliced on every
      fabric) through the same scoring dispatch the server launches
      (fabric_eval_multi_scored), and a banded bit-sliced sub-campaign
      through the WORD-domain sparse dispatch
      (fabric_eval_multi_scored_sparse). Writes the disagreement-counter
      campaign summary to $REPRO_SEU_REPORT for the CI artifact.

Replica-vote math note: a config upset perturbs ONE replica, so the two
healthy replicas always outvote it — what the sweep actually proves is
the serving plumbing (placement-rotated replica encodings pack into
aligned output lanes, banded windows survive the rotation, the vote and
decode read the right slots). Those are exactly the failure modes a
plumbing bug would introduce.
"""
import json
import os

import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import FABRICS, FabricSim
from repro.core.readout import ReadoutChip
from repro.core.tmr import (
    N_REPLICAS,
    inject_seu,
    majority_vote,
    replica_lut_index,
    replicate_config,
)
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.readout_server import ReadoutServer, ServerConfig
from tests._propshim import given, settings, strategies as st

import repro.core.tmr  # noqa: F401  (registers efpga_28nm_xl)


# ------------------------------------------------------------ helpers
def _golden(chip, X):
    return chip.golden.decision_function_raw(chip.golden.quantize_features(X))


@pytest.fixture(scope="module")
def farm():
    """One SMALL chip per registered fabric (the sweep cost scales with
    LUT count x 16 bits), plus a feature batch and its golden scores."""
    d = generate(SmartPixelConfig(n_events=10_000, seed=11))
    tr, te = train_test_split(d)
    fabric_names = sorted({s.name for s in FABRICS.values()})
    assert {"efpga_130nm", "efpga_28nm", "efpga_28nm_xl"} <= set(fabric_names)
    chips = {}
    for name in fabric_names:
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=3, max_leaf_nodes=5,
            min_samples_leaf=300,
        ).fit(tr["features"], tr["label"])
        chip = ReadoutChip.build(clf, fabric=name)
        chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
        chips[name] = chip
    X = te["features"][:96]
    return chips, X


def _serve_features(server, X, chip_slot=0):
    server.submit_batch(chip_slot, X)
    res = sorted(server.flush(), key=lambda r: r.seq)
    return (np.array([r.score_raw for r in res]),
            np.array([r.keep for r in res]))


# ---------------------------------------------------- voter properties
@given(seed=st.integers(0, 10_000), n=st.integers(1, 200))
@settings(max_examples=40, deadline=None)
def test_majority_vote_two_agreeing_always_win(seed, n):
    """vote(a,a,b) == a in every argument order, for all bit patterns —
    the property that makes any single-replica fault maskable."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, n).astype(np.uint8)
    b = rng.integers(0, 2, n).astype(np.uint8)
    np.testing.assert_array_equal(majority_vote(a, a, b), a)
    np.testing.assert_array_equal(majority_vote(a, b, a), a)
    np.testing.assert_array_equal(majority_vote(b, a, a), a)
    np.testing.assert_array_equal(majority_vote(a, a, a), a)


def test_majority_vote_exhaustive_truth_table():
    a, b, c = np.meshgrid(*[np.arange(2, dtype=np.uint8)] * 3, indexing="ij")
    got = majority_vote(a, b, c)
    want = ((a + b + c) >= 2).astype(np.uint8)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------- replica encoding properties
def test_replicas_functionally_identical_all_fabrics(farm):
    chips, X = farm
    for name, chip in chips.items():
        bits = chip.encode_features(X)
        want = _golden(chip, X)
        for r in range(N_REPLICAS):
            rc = replicate_config(chip.config, r)
            outs, _ = FabricSim(rc).run(bits)
            got = chip.synth.decode_outputs(np.asarray(outs))
            np.testing.assert_array_equal(got, want, err_msg=f"{name} r={r}")
            # the fan-in reach (the banded-routing budget) is invariant
            assert rc.fanin_reach() == chip.config.fanin_reach(), (name, r)


def test_replica_placements_distinct(farm):
    """Replica encodings must be different configuration-memory images
    wherever a level is wide enough to permute (>= 3 slots) — the
    common-mode-aliasing defence."""
    chips, _ = farm
    for name, chip in chips.items():
        cfgs = [replicate_config(chip.config, r) for r in range(N_REPLICAS)]
        assert any(s >= 3 for s in chip.config.level_sizes), name
        for i in range(N_REPLICAS):
            for j in range(i + 1, N_REPLICAS):
                assert not np.array_equal(
                    cfgs[i].lut_tables, cfgs[j].lut_tables), (name, i, j)


@given(seed=st.integers(0, 1000), replica=st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_replica_lut_index_tracks_tables(seed, replica, _cache={}):
    """replica_lut_index(cfg, r, j) points at the slot holding base LUT
    j's truth table in replica r's encoding — the coordinate translation
    the double-fault campaign relies on."""
    if "cfg" not in _cache:
        d = generate(SmartPixelConfig(n_events=8_000, seed=3))
        tr, _ = train_test_split(d)
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=3, max_leaf_nodes=5,
            min_samples_leaf=300).fit(tr["features"], tr["label"])
        _cache["cfg"] = ReadoutChip.build(clf).config
    cfg = _cache["cfg"]
    rc = replicate_config(cfg, replica)
    rng = np.random.default_rng(seed)
    for j in rng.integers(0, cfg.n_luts, 8):
        k = replica_lut_index(cfg, replica, int(j))
        np.testing.assert_array_equal(rc.lut_tables[k], cfg.lut_tables[j])


# ----------------------------------------------------- inject_seu bounds
def test_inject_seu_bounds_checked(farm):
    chips, _ = farm
    cfg = next(iter(chips.values())).config
    with pytest.raises(ValueError, match="lut_index"):
        inject_seu(cfg, -1, 0)       # numpy would wrap to the last LUT
    with pytest.raises(ValueError, match="lut_index"):
        inject_seu(cfg, cfg.n_luts, 0)
    with pytest.raises(ValueError, match="bit"):
        inject_seu(cfg, 0, -3)
    with pytest.raises(ValueError, match="bit"):
        inject_seu(cfg, 0, 16)
    with pytest.raises(ValueError, match="lut_index"):
        inject_seu(cfg, 1.5, 0)
    # a valid flip still flips exactly one bit
    seu = inject_seu(cfg, 2, 5)
    diff = seu.lut_tables.astype(np.int64) - cfg.lut_tables.astype(np.int64)
    assert np.abs(diff).sum() == 1 and diff[2, 5] != 0


def test_server_inject_seu_validates(farm):
    chips, _ = farm
    srv = ReadoutServer([chips["efpga_28nm"]], ServerConfig(
        max_batch=64, max_latency_s=1e9, backend="host", redundancy="tmr"))
    with pytest.raises(ValueError, match="replica"):
        srv.inject_seu(0, 3, 0, 0)
    with pytest.raises(ValueError, match="lut_index"):
        srv.inject_seu(0, 0, -1, 0)


# ------------------------------------------------- TMR serving, healthy
def test_tmr_server_matches_plain_and_golden_both_backends(farm):
    chips, X = farm
    pool = [chips["efpga_28nm"], chips["efpga_130nm"]]
    want = _golden(pool[0], X)
    for backend in ("host", "kernel"):
        out = {}
        for red in ("none", "tmr"):
            srv = ReadoutServer(list(pool), ServerConfig(
                max_batch=64, max_latency_s=1e9, backend=backend,
                redundancy=red))
            out[red] = _serve_features(srv, X)
            rep = srv.report()
            assert rep["seu_disagreement_total"] == 0, (backend, red)
            assert rep["redundancy"] == red
        np.testing.assert_array_equal(out["tmr"][0], out["none"][0])
        np.testing.assert_array_equal(out["tmr"][0], want)
        np.testing.assert_array_equal(out["tmr"][1], out["none"][1])


def test_tmr_stack_voted_eval_matches_plain(farm):
    """fabric_eval_multi on a redundant stack returns the voted output
    word — equal to the plain stack's, banded and dense."""
    from repro.kernels.lut_eval import ops as lut_ops

    chips, X = farm
    pool = [chips["efpga_28nm"], chips["efpga_130nm"]]
    configs = [c.config for c in pool]
    per_bits = [c.encode_features(X[:40]) for c in pool]
    for band in (None, False):
        plain = lut_ops.pack_fabrics(configs, band=band)
        tmr = lut_ops.pack_fabrics(configs, band=band, redundancy="tmr")
        assert tmr.n_chips == 2 and tmr.n_replicas == 3
        assert tmr.sel.shape[0] == 6
        bits = lut_ops.stack_input_bits(tmr, per_bits)
        got = np.asarray(lut_ops.fabric_eval_multi(tmr, bits))
        want = np.asarray(lut_ops.fabric_eval_multi(plain, bits))
        np.testing.assert_array_equal(got, want, err_msg=f"band={band}")


# --------------------------------------- single-SEU subsample (fast tier)
def _sweep_flips(server, chip, X, flips, golden, *, heal=True):
    """Inject each (replica, lut, bit), serve, compare, optionally heal
    (re-flipping the same bit restores the config). Returns per-replica
    disagreement totals accumulated over the sweep."""
    masked = 0
    for replica, li, bi in flips:
        server.inject_seu(0, replica, li, bi)
        scores, keeps = _serve_features(server, X)
        np.testing.assert_array_equal(
            scores, golden,
            err_msg=f"SEU not masked: replica={replica} lut={li} bit={bi}")
        np.testing.assert_array_equal(
            keeps, golden <= chip.score_threshold_raw)
        masked += 1
        if heal:
            server.inject_seu(0, replica, li, bi)
    return masked


def test_single_seu_subsample_every_fabric_host(farm):
    """Seeded random subsample of single-bit flips per registered fabric,
    through the live host-oracle server: voted outputs stay golden."""
    chips, X = farm
    rng = np.random.default_rng(2026)
    for name, chip in chips.items():
        srv = ReadoutServer([chip], ServerConfig(
            max_batch=len(X), max_latency_s=1e9, backend="host",
            redundancy="tmr"))
        n = chip.config.n_luts
        flips = [(int(rng.integers(0, 3)), int(rng.integers(0, n)),
                  int(rng.integers(0, 16))) for _ in range(10)]
        golden = _golden(chip, X)
        assert _sweep_flips(srv, chip, X, flips, golden) == len(flips)
        # healed server is disagreement-free again on a fresh batch
        base = srv.report()["seu_disagreement_total"]
        _serve_features(srv, X)
        assert srv.report()["seu_disagreement_total"] == base, name


def test_single_seu_subsample_kernel_banded_and_dense(farm):
    """The same campaign through the kernel backend, banded AND dense —
    the acceptance bar's backend x routing matrix, subsampled."""
    chips, X = farm
    rng = np.random.default_rng(7)
    for name, chip in chips.items():
        golden = _golden(chip, X)
        for band in (None, False):
            srv = ReadoutServer([chip], ServerConfig(
                max_batch=len(X), max_latency_s=1e9, backend="kernel",
                redundancy="tmr", band=band))
            n = chip.config.n_luts
            flips = [(int(rng.integers(0, 3)), int(rng.integers(0, n)),
                      int(rng.integers(0, 16))) for _ in range(2)]
            assert _sweep_flips(srv, chip, X, flips, golden) == len(flips)


def test_single_seu_subsample_kernel_bitsliced(farm):
    """The same campaign through the bit-sliced kernel layout, per
    registered fabric: the vote folded into the word-parallel bitwise
    pass masks every subsampled flip exactly like the matmul voter."""
    chips, X = farm
    rng = np.random.default_rng(13)
    for name, chip in chips.items():
        golden = _golden(chip, X)
        srv = ReadoutServer([chip], ServerConfig(
            max_batch=len(X), max_latency_s=1e9, backend="kernel",
            redundancy="tmr", layout="bitsliced"))
        n = chip.config.n_luts
        flips = [(int(rng.integers(0, 3)), int(rng.integers(0, n)),
                  int(rng.integers(0, 16))) for _ in range(3)]
        assert _sweep_flips(srv, chip, X, flips, golden) == len(flips)


def test_seu_disagreement_counter_is_live(farm):
    """An EFFECTIVE flip (one that changes the faulty replica's outputs)
    must fire that replica's disagreement counter while outputs stay
    golden — the health monitor actually monitors."""
    chips, X = farm
    chip = chips["efpga_28nm"]
    golden = _golden(chip, X)
    srv = ReadoutServer([chip], ServerConfig(
        max_batch=len(X), max_latency_s=1e9, backend="host",
        redundancy="tmr"))
    # find a flip that matters: perturb the PLAIN config until outputs move
    rep1 = replicate_config(chip.config, 1)
    bits = chip.encode_features(X)
    eff = None
    for li in range(rep1.n_luts):
        for bi in range(16):
            outs, _ = FabricSim(inject_seu(rep1, li, bi)).run(bits)
            if not np.array_equal(
                    chip.synth.decode_outputs(np.asarray(outs)), golden):
                eff = (li, bi)
                break
        if eff:
            break
    assert eff is not None, "no effective flip found (degenerate chip?)"
    srv.inject_seu(0, 1, *eff)
    scores, _ = _serve_features(srv, X)
    np.testing.assert_array_equal(scores, golden)
    dis = srv.report()["per_chip"][0]["seu_disagreements"]
    assert dis[1] > 0 and dis[0] == 0 and dis[2] == 0, dis


# ------------------------------------------------- double-fault controls
def test_double_fault_same_logical_lut_detectably_wrong(farm):
    """Two SEUs at the SAME logical LUT/bit in two replicas: the majority
    is now wrong wherever the fault manifests — the voted output MUST
    differ from golden (it is not silently maskable) and the healthy
    minority replica's counter fires. Guards against a 'voter' that
    reads a single replica and would hide nothing."""
    chips, X = farm
    chip = chips["efpga_28nm"]
    golden = _golden(chip, X)
    bits = chip.encode_features(X)
    # effective flip in base coordinates
    eff = None
    for li in range(chip.config.n_luts):
        for bi in range(16):
            outs, _ = FabricSim(inject_seu(chip.config, li, bi)).run(bits)
            faulty = chip.synth.decode_outputs(np.asarray(outs))
            if not np.array_equal(faulty, golden):
                eff, want_faulty = (li, bi), faulty
                break
        if eff:
            break
    assert eff is not None
    li, bi = eff
    for backend, layout in (("host", "matmul"), ("kernel", "matmul"),
                            ("kernel", "bitsliced")):
        srv = ReadoutServer([chip], ServerConfig(
            max_batch=len(X), max_latency_s=1e9, backend=backend,
            redundancy="tmr", layout=layout))
        srv.inject_seu(0, 0, replica_lut_index(chip.config, 0, li), bi)
        srv.inject_seu(0, 1, replica_lut_index(chip.config, 1, li), bi)
        scores, _ = _serve_features(srv, X)
        # the double fault outvotes the healthy replica: served == faulty
        np.testing.assert_array_equal(
            scores, want_faulty, err_msg=f"{backend}/{layout}")
        assert not np.array_equal(scores, golden), (backend, layout)
        dis = srv.report()["per_chip"][0]["seu_disagreements"]
        # healthy minority voted against
        assert dis[2] > 0, (backend, layout, dis)


def test_double_fault_different_luts_counters_fire(farm):
    """Two effective SEUs at DIFFERENT logical LUTs in different
    replicas: each faulty replica is voted against on its own fault's
    events, so BOTH counters fire (and, faults being independent, the
    voted output stays golden wherever at most one replica is wrong)."""
    chips, X = farm
    chip = chips["efpga_28nm"]
    golden = _golden(chip, X)
    bits = chip.encode_features(X)
    effective = []
    for li in range(chip.config.n_luts):
        if len(effective) == 2:
            break
        for bi in range(16):
            outs, _ = FabricSim(inject_seu(chip.config, li, bi)).run(bits)
            if not np.array_equal(
                    chip.synth.decode_outputs(np.asarray(outs)), golden):
                effective.append((li, bi))
                break
    assert len(effective) == 2, "need two effective faults"
    srv = ReadoutServer([chip], ServerConfig(
        max_batch=len(X), max_latency_s=1e9, backend="host",
        redundancy="tmr"))
    (l0, b0), (l1, b1) = effective
    srv.inject_seu(0, 0, replica_lut_index(chip.config, 0, l0), b0)
    srv.inject_seu(0, 1, replica_lut_index(chip.config, 1, l1), b1)
    _serve_features(srv, X)
    dis = srv.report()["per_chip"][0]["seu_disagreements"]
    assert dis[0] > 0 and dis[1] > 0, dis


# ------------------------------------------------------- sparse readout
def test_sparse_server_returns_kept_subset_only(farm):
    chips, X = farm
    pool = [chips["efpga_28nm"], chips["efpga_130nm"]]
    for backend in ("host", "kernel"):
        # one micro-batch => exactly one sparse header on the wire
        dense_srv = ReadoutServer(list(pool), ServerConfig(
            max_batch=1000, max_latency_s=1e9, backend=backend))
        sparse_srv = ReadoutServer(list(pool), ServerConfig(
            max_batch=1000, max_latency_s=1e9, backend=backend, sparse=True))
        for srv in (dense_srv, sparse_srv):
            srv.submit_batch(0, X[:50])
            srv.submit_batch(1, X[50:90])
        dense = sorted(dense_srv.flush(), key=lambda r: r.seq)
        sparse = sorted(sparse_srv.flush(), key=lambda r: r.seq)
        want = [(r.seq, r.chip, r.score_raw, r.keep) for r in dense if r.keep]
        got = [(r.seq, r.chip, r.score_raw, r.keep) for r in sparse]
        assert got == want, backend
        # accounting: n_in counts DROPPED events too; wire bytes measured
        rep = sparse_srv.report()
        assert rep["n_in"] == 90 and rep["n_kept"] == len(want)
        lb = rep["link_bytes"]
        assert lb["on_wire"] == 4 + 8 * len(want)
        assert lb["dense_equivalent"] == 5 * 90


def test_serverconfig_validates_redundancy_and_sparse():
    ServerConfig(redundancy="tmr", sparse=True)  # valid
    with pytest.raises(ValueError, match="redundancy"):
        ServerConfig(redundancy="dmr")
    with pytest.raises(ValueError, match="sparse"):
        ServerConfig(sparse=1)


# ---------------------------------------------- hot-swap / no-retrace
def test_tmr_hot_swap_and_inject_do_not_retrace(farm):
    from repro.kernels import frontend as fe
    from repro.kernels.lut_eval import ops as lut_ops

    if not hasattr(lut_ops._eval_stack_scored, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    chips, X = farm
    a, b = chips["efpga_28nm"], chips["efpga_130nm"]
    srv = ReadoutServer([a, b], ServerConfig(
        max_batch=64, max_latency_s=1e9, backend="kernel",
        redundancy="tmr", sparse=True))
    _serve_features(srv, X[:32])
    n0 = lut_ops._eval_stack_scored._cache_size()
    srv.reconfigure(0, b)
    srv.inject_seu(1, 2, 0, 3)
    scores, _ = _serve_features(srv, X[:32])
    assert lut_ops._eval_stack_scored._cache_size() == n0
    # swapped slot now scores as chip b (sparse: only kept events return),
    # and the SEU on slot 1 stays masked
    want = _golden(b, X[:32])
    kept = want <= b.score_threshold_raw
    np.testing.assert_array_equal(scores, want[kept])


def test_tmr_swap_replica_rejects_mismatched_io(farm):
    from repro.kernels.lut_eval import ops as lut_ops

    chips, _ = farm
    a, b = chips["efpga_28nm"], chips["efpga_130nm"]
    stack = lut_ops.pack_fabrics([a.config], redundancy="tmr")
    if b.config.n_inputs != a.config.n_inputs:
        with pytest.raises(ValueError, match="IO widths|envelope"):
            stack.swap_replica(0, 1, b.config)
    with pytest.raises(ValueError, match="replica"):
        stack.swap_replica(0, 5, a.config)


# ------------------------------------------------------ committed bench
def test_bench_json_has_tmr_sparse_scenario():
    """The committed benchmark record must carry the TMR + sparse-link
    scenario, including measured bytes-on-wire (the CI fast tier asserts
    the same keys on the freshly-generated smoke JSON)."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_fabric.json")
    with open(path) as f:
        doc = json.load(f)
    names = {r["name"] for r in doc["records"]}
    assert any(n.startswith("fabric.tmr_sparse_") for n in names), names
    rows = [r for r in doc["records"]
            if r["name"] == "fabric.tmr_sparse_link_bytes"]
    assert rows and "link_bytes_sparse" in rows[0] and \
        "wire_reduction" in rows[0]


# ------------------------------------------------------------- slow tier
def _campaign_record(summary):
    """Append the campaign summary for the CI artifact (nightly uploads
    $REPRO_SEU_REPORT)."""
    path = os.environ.get("REPRO_SEU_REPORT", "")
    if not path:
        return
    doc = {"campaign": "seu_single_fault_full_sweep", "fabrics": summary}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


@pytest.mark.slow
def test_single_seu_full_sweep_host_every_fabric(farm):
    """THE campaign: every LUT x every truth-table bit of one replica
    (the placement-rotated replica 1), per registered fabric, through the
    host-oracle server. 100% of flips must leave voted outputs golden."""
    chips, X = farm
    Xs = X[:48]
    summary = {}
    for name, chip in chips.items():
        golden = _golden(chip, Xs)
        srv = ReadoutServer([chip], ServerConfig(
            max_batch=len(Xs), max_latency_s=1e9, backend="host",
            redundancy="tmr"))
        n_flips = 0
        for li in range(chip.config.n_luts):
            for bi in range(16):
                srv.inject_seu(0, 1, li, bi)
                scores, _ = _serve_features(srv, Xs)
                np.testing.assert_array_equal(
                    scores, golden,
                    err_msg=f"{name}: SEU lut={li} bit={bi} not masked")
                srv.inject_seu(0, 1, li, bi)  # heal
                n_flips += 1
        rep = srv.report()
        summary[name] = {
            "n_flips": n_flips,
            "n_luts": chip.config.n_luts,
            "masked": n_flips,
            "seu_disagreements_by_replica": [
                int(v) for v in rep["per_chip"][0]["seu_disagreements"]],
            "events_per_flip": len(Xs),
        }
    _campaign_record(summary)


@pytest.mark.slow
def test_single_seu_sweep_kernel_every_lut_banded_and_dense(farm):
    """Kernel sweep through the SAME scoring dispatch the server launches
    (fabric_eval_multi_scored), banded and dense: EVERY LUT of replica 1,
    one seeded truth-table bit each, every flip swapped in via
    swap_replica (pure array swap, one compiled dispatch reused
    throughout). The per-bit exhaustive axis lives in the host sweep
    above — the kernel is proven bit-identical to the host oracle on
    perturbed stacks by the fast-tier subsample, and a full 16-bit kernel
    sweep costs ~40 min in CPU interpret mode (it is a ~2 s/flip
    dispatch; compiled TPU would do it in seconds)."""
    from repro.kernels.lut_eval import ops as lut_ops
    from repro.launch.mesh import make_readout_mesh

    chips, X = farm
    chip = chips["efpga_28nm"]
    Xs = X[:32]
    bits = chip.encode_features(Xs)[None]
    golden = _golden(chip, Xs)
    mesh = make_readout_mesh(1)
    rng = np.random.default_rng(404)
    for band in (None, False):
        stack = lut_ops.pack_fabrics(
            [chip.config], band=band, redundancy="tmr")
        w = lut_ops.decode_plan([chip.config], stack.n_outputs)
        thr = np.array([chip.score_threshold_raw], np.int32)
        rep1 = replicate_config(chip.config, 1)
        for li in range(chip.config.n_luts):
            bi = int(rng.integers(0, 16))
            stack2 = stack.swap_replica(0, 1, inject_seu(rep1, li, bi))
            score, _, _ = lut_ops.fabric_eval_multi_scored(
                stack2, bits, w, thr, mesh=mesh)
            np.testing.assert_array_equal(
                np.asarray(score)[0], golden,
                err_msg=f"band={band} lut={li} bit={bi}")


@pytest.mark.slow
def test_single_seu_sweep_bitsliced_every_lut_every_fabric(farm):
    """Bit-sliced every-LUT sweep, EVERY registered fabric, through the
    scoring dispatch (fabric_eval_multi_scored with layout='bitsliced'):
    each flip is swapped into replica 1 as a pure array update (the
    bit-sliced stack keeps the no-retrace swap) and must be outvoted by
    the word-majority pass fused into the evaluator. The bit-sliced
    evaluator is traceable XLA, not interpret-mode Pallas, so this sweep
    covers every fabric where the matmul sweep above can afford one."""
    from repro.kernels.lut_eval import ops as lut_ops
    from repro.launch.mesh import make_readout_mesh

    chips, X = farm
    Xs = X[:32]
    mesh = make_readout_mesh(1)
    rng = np.random.default_rng(808)
    for name, chip in chips.items():
        bits = chip.encode_features(Xs)[None]
        golden = _golden(chip, Xs)
        stack = lut_ops.pack_fabrics(
            [chip.config], redundancy="tmr", layout="bitsliced")
        w = lut_ops.decode_plan([chip.config], stack.n_outputs)
        thr = np.array([chip.score_threshold_raw], np.int32)
        rep1 = replicate_config(chip.config, 1)
        for li in range(chip.config.n_luts):
            bi = int(rng.integers(0, 16))
            stack2 = stack.swap_replica(0, 1, inject_seu(rep1, li, bi))
            score, _, dis = lut_ops.fabric_eval_multi_scored(
                stack2, bits, w, thr, mesh=mesh)
            np.testing.assert_array_equal(
                np.asarray(score)[0], golden,
                err_msg=f"{name} lut={li} bit={bi} (bitsliced)")


@pytest.mark.slow
def test_single_seu_sweep_bitsliced_banded_sparse(farm):
    """BANDED bit-sliced TMR stacks under SEU, served through the
    word-domain sparse dispatch (fabric_eval_multi_scored_sparse): every
    sampled replica-1 flip must be outvoted — the packed (count, idx,
    vals) egress stays bit-identical to the golden kept set — proving
    the band (a pure reach envelope) and the fused word-domain egress
    change neither the vote nor the wire contents. Sub-campaign of the
    nightly SEU tier, every registered fabric."""
    from repro.kernels.lut_eval import ops as lut_ops
    from repro.launch.mesh import make_readout_mesh
    from repro.parallel.compression import sparse_trigger_unpack

    chips, X = farm
    Xs = X[:37]                         # off the 32-event word boundary
    mesh = make_readout_mesh(1)
    rng = np.random.default_rng(811)
    for name, chip in chips.items():
        bits = chip.encode_features(Xs)[None]
        golden = _golden(chip, Xs)
        kept = golden <= chip.score_threshold_raw
        stack = lut_ops.pack_fabrics(
            [chip.config], band=True, redundancy="tmr", layout="bitsliced")
        if not stack.banded:
            continue                    # reach covers the depth: no band
        w = lut_ops.decode_plan([chip.config], stack.n_outputs)
        thr = np.array([chip.score_threshold_raw], np.int32)
        rep1 = replicate_config(chip.config, 1)
        for li in range(0, chip.config.n_luts, 3):
            bi = int(rng.integers(0, 16))
            stack2 = stack.swap_replica(0, 1, inject_seu(rep1, li, bi))
            count, idx, vals, dis = lut_ops.fabric_eval_multi_scored_sparse(
                stack2, bits, w, thr, mesh=mesh)
            tag = f"{name} lut={li} bit={bi} (banded bitsliced sparse)"
            assert int(np.asarray(count)) == int(kept.sum()), tag
            s2, k2 = sparse_trigger_unpack(
                np.asarray(idx), np.asarray(vals), (1, len(Xs)))
            np.testing.assert_array_equal(k2[0], kept, err_msg=tag)
            np.testing.assert_array_equal(
                s2[0], golden * kept, err_msg=tag)
            d = np.asarray(dis)
            assert d[0, 0] == 0 and d[0, 2] == 0, tag  # healthy replicas
