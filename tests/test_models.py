"""Per-arch smoke tests (reduced configs, one train grad + decode on CPU)
plus model-level correctness properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_SHAPE, smoke_config
from repro.models import dense, registry
from repro.models import layers as L

# The whole model-zoo sweep is the dominant cost of the suite (~90s on CPU);
# the readout/fabric fast tier does not need it.
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_train_decode(name):
    """Assignment requirement: reduced same-family config, one forward/train
    step on CPU, asserting output shapes + no NaNs."""
    cfg = smoke_config(name)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = registry.make_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(
        lambda p: registry.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0

    B = 2
    if cfg.family == "vlm":
        dec_in = batch["embeds"][:B, :1]
        cache = registry.init_cache(cfg, B, 16)
    elif cfg.family == "encdec":
        dec_in = batch["tokens"][:B, :1]
        cache = registry.init_cache(cfg, B, 16, params=params,
                                    enc_embeds=batch["enc_embeds"][:B])
    else:
        dec_in = batch["tokens"][:B, :1]
        cache = registry.init_cache(cfg, B, 16)
    logits, cache = registry.decode_step(cfg, params, cache, dec_in)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["pos"]) == 1


@pytest.mark.parametrize("name", ["gemma-7b", "phi3-medium-14b", "deepseek-moe-16b"])
def test_incremental_decode_matches_forward(name):
    """Token-by-token decode must reproduce the teacher-forced forward.

    MoE needs a high capacity factor here: with the default 1.25, capacity
    drops depend on the token GROUPING (24-token forward groups vs 2-token
    decode groups) — correct GShard semantics, but not comparable."""
    cfg = dataclasses.replace(smoke_config(name), capacity_factor=16.0)
    mod = registry.model_for(cfg)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, T), 0, cfg.vocab,
                              jnp.int32)
    full = mod.forward(cfg, params, toks)
    if isinstance(full, tuple):
        full = full[0]
    cache = registry.init_cache(cfg, 2, T)
    got = []
    for t in range(T):
        logits, cache = registry.decode_step(cfg, params, cache, toks[:, t:t+1])
        got.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_chunked_attention_equals_full():
    cfg = dataclasses.replace(smoke_config("phi3-medium-14b"), attn_chunk=8)
    cfg_full = dataclasses.replace(cfg, attn_chunk=0)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab,
                              jnp.int32)
    a = dense.forward(cfg, params, toks)
    b = dense.forward(cfg_full, params, toks)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2, atol=2e-2)


def test_chunked_xent_equals_full():
    cfg = smoke_config("gemma-7b")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab,
                              jnp.int32)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab,
                                jnp.int32)
    x = dense.hidden_states(cfg, params, toks)
    full = L.softmax_xent(L.lm_logits(cfg, params["embed"], x), labels)
    chunked = L.chunked_xent(cfg, params["embed"], x, labels)
    assert float(full) == pytest.approx(float(chunked), rel=1e-5)


def test_ssd_matches_naive_recurrence():
    from repro.models.ssm import _ssd_scan

    B, S, H, P, N = 2, 64, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bv = jax.random.normal(ks[2], (B, S, N))
    Cv = jax.random.normal(ks[3], (B, S, N))
    for chunk in (8, 16, 64):
        y, st = _ssd_scan(x, a, Bv, Cv, chunk=chunk)
        stn = np.zeros((B, H, P, N))
        xn, an, Bn, Cn = map(np.asarray, (x, a, Bv, Cv))
        ys = []
        for t in range(S):
            stn = stn * np.exp(an[:, t])[:, :, None, None] + np.einsum(
                "bn,bhp->bhpn", Bn[:, t], xn[:, t])
            ys.append(np.einsum("bn,bhpn->bhp", Cn[:, t], stn))
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.stack(ys, 1), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st), stn, rtol=1e-4, atol=1e-4)


def test_ssm_decode_matches_forward():
    cfg = smoke_config("mamba2-130m")
    mod = registry.model_for(cfg)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    T = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, T), 0, cfg.vocab,
                              jnp.int32)
    full = mod.forward(cfg, params, toks)
    cache = registry.init_cache(cfg, 2, T)
    got = []
    for t in range(T):
        logits, cache = registry.decode_step(cfg, params, cache, toks[:, t:t+1])
        got.append(np.asarray(logits[:, 0], np.float32))
    np.testing.assert_allclose(np.stack(got, 1), np.asarray(full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_rope_rotation_properties():
    pos = jnp.asarray([[3, 7]], jnp.int32)
    cos, sin = L.rope_angles(pos, 8, 10_000.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 2, 8))
    y = L.apply_rope(x, cos, sin)
    # norm-preserving per pair
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_moe_router_balanced_dispatch_capacity():
    from repro.models.moe import _dispatch_tensors, moe_capacity, _route

    cfg = smoke_config("deepseek-moe-16b")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, cfg.d_model))
    router = jax.random.normal(jax.random.PRNGKey(1),
                               (cfg.d_model, cfg.n_experts)) * 0.1
    gates, idx, probs = _route(cfg, router, x)
    C = moe_capacity(cfg, 32)
    disp, comb, kept = _dispatch_tensors(cfg, gates, idx, C)
    # every capacity slot holds at most one token
    assert float(jnp.max(jnp.sum(disp, axis=1))) <= 1.0 + 1e-6
    # combine weights <= gate weights and zero where dropped
    assert float(jnp.max(jnp.sum(comb, axis=(2, 3)) - jnp.sum(gates, axis=-1))) < 1e-4


def test_param_count_analytic_close_to_actual():
    for name in ("gemma-7b", "deepseek-moe-16b", "mamba2-130m"):
        cfg = smoke_config(name)
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, (name, actual, analytic)


def test_int8_kv_cache_decode_close_to_exact():
    """int8 KV decode (the at-source-quantization serving mode) stays close
    to the bf16-cache decode, and its cache really is int8."""
    cfg_q = dataclasses.replace(smoke_config("gemma-7b"), kv_cache_dtype="int8")
    cfg_f = smoke_config("gemma-7b")
    params = registry.init_params(cfg_f, jax.random.PRNGKey(0))
    T = 10
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, T), 0, cfg_f.vocab,
                              jnp.int32)
    cq = registry.init_cache(cfg_q, 2, T)
    cf = registry.init_cache(cfg_f, 2, T)
    assert cq["k"].dtype == jnp.int8 and "k_scale" in cq
    for t in range(T):
        lq, cq = registry.decode_step(cfg_q, params, cq, toks[:, t:t+1])
        lf, cf = registry.decode_step(cfg_f, params, cf, toks[:, t:t+1])
    pq = np.asarray(jax.nn.softmax(lq[:, 0].astype(jnp.float32)))
    pf = np.asarray(jax.nn.softmax(lf[:, 0].astype(jnp.float32)))
    assert np.abs(pq - pf).max() < 0.05
    # top-1 agreement
    assert (pq.argmax(-1) == pf.argmax(-1)).all()
