"""At-source compression: int8 quantization bounds + compressed all-reduce."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to the seeded sweep shim (tests/_propshim.py)
    from tests._propshim import given, settings, strategies as st

from repro.parallel.compression import (
    dequantize_int8, dequantize_kv, quantize_int8, quantize_kv,
    sparse_trigger_pack, sparse_trigger_pack_jit, sparse_trigger_pack_words,
    sparse_trigger_unpack, WireFormatError,
)


@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 100))
@settings(max_examples=50, deadline=None)
def test_int8_error_bound(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, 256).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    bound = float(jnp.max(jnp.abs(x))) / 254 + 1e-6
    assert err.max() <= bound * 1.01


def test_int8_wire_format():
    q, s = quantize_int8(jnp.ones((4, 4)))
    assert q.dtype == jnp.int8
    assert s.shape == ()


@given(seed=st.integers(0, 10_000), c=st.integers(1, 5), b=st.integers(1, 64),
       p_keep=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_sparse_trigger_roundtrip_identity(seed, c, b, p_keep):
    """compress -> decompress is the identity on arbitrary keep masks:
    unpack(pack(score, keep)) == (score * keep, keep) — the sparse host
    link loses nothing about kept events and nothing leaks about dropped
    ones."""
    rng = np.random.default_rng(seed)
    score = rng.integers(-(2 ** 20), 2 ** 20, (c, b)).astype(np.int32)
    keep = rng.random((c, b)) < p_keep
    count, idx, vals = jax.jit(sparse_trigger_pack)(
        jnp.asarray(score), jnp.asarray(keep))
    n = int(np.asarray(count))
    assert n == int(keep.sum())
    # padded region is -1/0; the count-prefix is what crosses the wire
    idx_np = np.asarray(idx)
    assert (idx_np[n:] == -1).all() and (np.asarray(vals)[n:] == 0).all()
    assert (np.diff(idx_np[:n]) > 0).all()  # ascending flat indices
    got_score, got_keep = sparse_trigger_unpack(idx, vals, score.shape)
    np.testing.assert_array_equal(got_keep, keep)
    np.testing.assert_array_equal(got_score, score * keep)
    # the count-sliced wire form round-trips identically
    got_score2, got_keep2 = sparse_trigger_unpack(
        idx_np[:n], np.asarray(vals)[:n], score.shape)
    np.testing.assert_array_equal(got_keep2, keep)
    np.testing.assert_array_equal(got_score2, score * keep)


def test_sparse_trigger_all_keep_and_all_drop():
    score = np.arange(12, dtype=np.int32).reshape(3, 4) - 5
    for keep in (np.ones((3, 4), bool), np.zeros((3, 4), bool)):
        count, idx, vals = sparse_trigger_pack_jit(
            jnp.asarray(score), jnp.asarray(keep))
        s, k = sparse_trigger_unpack(idx, vals, score.shape)
        np.testing.assert_array_equal(k, keep)
        np.testing.assert_array_equal(s, score * keep)
        assert int(np.asarray(count)) == int(keep.sum())


# --------------------------------------------- word-domain sparse egress
def _word_form(score, keep):
    """Event-domain (C, B) -> the word-domain egress inputs, zero/False
    padded to the 32-event word boundary: (keep_w (C, W) uint32, lane
    scores (C, W, 32) int32, padded event-domain (score, keep))."""
    from repro.kernels.lut_eval import bitsliced

    C, B = score.shape
    W = max(-(-B // 32), 1)
    sp = np.zeros((C, W * 32), np.int32)
    sp[:, :B] = score
    kp = np.zeros((C, W * 32), bool)
    kp[:, :B] = keep
    keep_w = bitsliced.mask_words(jnp.asarray(kp))
    return keep_w, jnp.asarray(sp.reshape(C, W, 32)), sp, kp


@given(seed=st.integers(0, 10_000), c=st.integers(1, 4),
       b=st.integers(1, 130), p_keep=st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_sparse_word_pack_matches_event_oracle(seed, c, b, p_keep):
    """The word-domain popcount prefix-sum compaction reproduces the
    event-domain ``sparse_trigger_pack`` wire format byte for byte —
    count, ascending -1-padded flat indices, 0-padded scores — for
    arbitrary keep masks, full-range int32 scores and batch sizes off
    the 32-event word boundary."""
    rng = np.random.default_rng(seed)
    score = rng.integers(-(2 ** 31), 2 ** 31, (c, b),
                         dtype=np.int64).astype(np.int32)
    keep = rng.random((c, b)) < p_keep
    keep_w, scores_w, sp, kp = _word_form(score, keep)
    count0, idx0, vals0 = sparse_trigger_pack(
        jnp.asarray(sp), jnp.asarray(kp))
    count1, idx1, vals1 = jax.jit(sparse_trigger_pack_words)(
        keep_w, scores_w)
    assert int(np.asarray(count1)) == int(np.asarray(count0)) \
        == int(keep.sum())
    np.testing.assert_array_equal(np.asarray(idx0), np.asarray(idx1))
    np.testing.assert_array_equal(np.asarray(vals0), np.asarray(vals1))
    # round-trip through the host inverse recovers exactly the kept set
    s2, k2 = sparse_trigger_unpack(np.asarray(idx1), np.asarray(vals1),
                                   sp.shape)
    np.testing.assert_array_equal(k2[:, :b], keep)
    np.testing.assert_array_equal(s2[:, :b], score * keep)
    assert not k2[:, b:].any()      # padding lanes never ship


def test_sparse_word_pack_all_keep_all_drop_and_tails():
    """The degenerate masks on word-aligned AND ragged batch sizes: all
    keep ships everything in order, all drop ships the empty prefix."""
    for b in (1, 31, 32, 33, 64, 95):
        score = (np.arange(2 * b, dtype=np.int32).reshape(2, b) - b)
        for keep_all in (True, False):
            keep = np.full((2, b), keep_all)
            keep_w, scores_w, sp, kp = _word_form(score, keep)
            count, idx, vals = sparse_trigger_pack_words(keep_w, scores_w)
            assert int(np.asarray(count)) == int(keep.sum()), (b, keep_all)
            s2, k2 = sparse_trigger_unpack(
                np.asarray(idx), np.asarray(vals), sp.shape)
            np.testing.assert_array_equal(k2, kp, err_msg=f"{b} {keep_all}")
            np.testing.assert_array_equal(s2, sp * kp,
                                          err_msg=f"{b} {keep_all}")


def test_sparse_unpack_rejects_oversized_count_prefix():
    """Regression: a count prefix larger than the record buffer used to
    be silently clamped by numpy slicing — a corrupt/forged wire count
    produced a truncated dense batch with no error. It must now raise
    the named WireFormatError family (what net/protocol.py surfaces as
    FieldBoundsError) before any scatter happens."""
    idx = np.array([0, 2, -1, -1], np.int32)
    vals = np.array([5, 7, 0, 0], np.int32)
    # valid counts, including the exact buffer size, still work
    for count in (0, 1, 2, 4):
        s, k = sparse_trigger_unpack(idx, vals, (4,), count=count)
        assert int(k.sum()) <= count
    s, k = sparse_trigger_unpack(idx, vals, (4,), count=2)
    np.testing.assert_array_equal(k, [True, False, True, False])
    np.testing.assert_array_equal(s, [5, 0, 7, 0])
    for bad in (5, 6, 1 << 20, -1):
        with pytest.raises(WireFormatError, match="count prefix"):
            sparse_trigger_unpack(idx, vals, (4,), count=bad)


def test_sparse_unpack_rejects_out_of_range_indices():
    """An index at/above prod(shape), or below the -1 padding sentinel,
    is corrupt wire data: named error, not a numpy IndexError or a
    silent negative-index aliasing scatter."""
    with pytest.raises(WireFormatError, match="outside dense shape"):
        sparse_trigger_unpack(np.array([0, 4]), np.array([1, 1]), (2, 2))
    with pytest.raises(WireFormatError, match="outside dense shape"):
        sparse_trigger_unpack(np.array([-2, 1]), np.array([1, 1]), (2, 2))
    # boundary: the largest valid flat index and the padding sentinel
    s, k = sparse_trigger_unpack(np.array([3, -1]), np.array([9, 0]), (2, 2))
    np.testing.assert_array_equal(s, [[0, 0], [0, 9]])
    assert int(k.sum()) == 1


def test_sparse_unpack_rejects_mismatched_buffers():
    with pytest.raises(WireFormatError, match="disagree"):
        sparse_trigger_unpack(np.array([0, 1, 2]), np.array([1, 2]), (4,))


def test_kv_quantization_per_vector():
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.normal(0, 1, (2, 16, 4, 32)).astype(np.float32))
    q, s = quantize_kv(kv)
    assert q.dtype == jnp.int8 and s.shape == (2, 16, 4, 1)
    back = np.asarray(dequantize_kv(q, s, jnp.float32))
    rel = np.abs(back - np.asarray(kv)).max() / np.abs(np.asarray(kv)).max()
    assert rel < 0.01


_POD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compression import make_compressed_value_and_grad

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)

rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32))}
batch = {"x": jnp.asarray(rng.normal(0, 1, (16, 8)).astype(np.float32)),
         "y": jnp.asarray(rng.normal(0, 1, (16, 4)).astype(np.float32))}
specs = {"x": P("pod", None), "y": P("pod", None)}

with mesh:
    f = jax.jit(make_compressed_value_and_grad(loss_fn, mesh, specs))
    loss_c, grads_c = f(params, batch)
    loss_e, grads_e = jax.jit(jax.value_and_grad(loss_fn))(params, batch)

assert abs(float(loss_c) - float(loss_e)) < 1e-4, (loss_c, loss_e)
gc, ge = np.asarray(grads_c["w"]), np.asarray(grads_e["w"])
# int8-per-pod-partial error bound: each pod's partial grad quantized
bound = 2 * np.abs(ge).max() / 254 + 1e-5
assert np.abs(gc - ge).max() < bound * 4, (np.abs(gc - ge).max(), bound)
print("COMPRESSED_ALLREDUCE_OK", np.abs(gc - ge).max())
"""


@pytest.mark.slow
def test_compressed_gradient_allreduce_multipod():
    """Runs in a subprocess so the 8-fake-device flag never leaks into this
    test process (tests must keep seeing 1 device)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _POD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESSED_ALLREDUCE_OK" in r.stdout
