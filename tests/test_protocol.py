"""Wire-protocol fuzz/property campaign (net/protocol.py).

The decoder's contract, pinned here: EVERY malformed input raises a
named ``ProtocolError`` subclass — never a raw struct/numpy error, never
a silent partial decode — and the stream decoder resyncs on the next
magic, so one corrupted frame costs exactly one frame. The corpus is
deterministic (seeded via tests/_propshim.py when hypothesis is absent),
so CI replays the same corruptions every run.
"""
import struct
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to the seeded sweep shim (tests/_propshim.py)
    from tests._propshim import given, settings, strategies as st

from repro.net import protocol as P
from repro.parallel.compression import WireFormatError


def _frames(rng, n):
    return (rng.normal(size=(n, 8, 13, 21)).astype(np.float32) * 1e3,
            rng.normal(size=n).astype(np.float32) * 100)


def _corpus(rng):
    """One of each message type, random field values."""
    n = int(rng.integers(1, 9))
    fr, y0 = _frames(rng, n)
    kept = np.sort(rng.choice(n, size=int(rng.integers(0, n + 1)),
                              replace=False)).astype(np.int32)
    scores = rng.integers(-2**20, 2**20, size=len(kept)).astype(np.int32)
    sensor = int(rng.integers(0, 2**16))
    seq = int(rng.integers(0, 2**32))
    return [
        P.encode_frame_batch(sensor, seq, fr, y0),
        P.encode_trigger_batch(sensor, seq, orig_seq=seq, n_events=n,
                               n_admitted=n, idx=kept, scores=scores),
        P.encode_flush(sensor, seq),
        P.encode_flush_ack(sensor, seq, {
            k: int(rng.integers(0, 2**40)) for k in P.ACK_COUNTERS}),
    ]


# ------------------------------------------------------- round-trip props
@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_roundtrip_identity_every_message_type(seed):
    """encode -> decode is the identity on every field of every type."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 9))
    fr, y0 = _frames(rng, n)
    sensor = int(rng.integers(0, 2**16))
    seq = int(rng.integers(0, 2**32))

    m = P.decode_datagram(P.encode_frame_batch(sensor, seq, fr, y0))
    assert (m.msg_type, m.sensor_id, m.seq, m.n_events) == \
        (P.MSG_FRAME_BATCH, sensor, seq, n)
    np.testing.assert_array_equal(m.frames, fr)
    np.testing.assert_array_equal(m.y0, y0)

    kept = np.arange(0, n, 2, dtype=np.int32)
    scores = rng.integers(-2**30, 2**30, len(kept)).astype(np.int32)
    m = P.decode_datagram(P.encode_trigger_batch(
        sensor, seq, orig_seq=seq ^ 1, n_events=n, n_admitted=n,
        idx=kept, scores=scores))
    assert m.orig_seq == seq ^ 1 and m.n_admitted == n
    np.testing.assert_array_equal(m.idx, kept)
    np.testing.assert_array_equal(m.scores, scores)

    m = P.decode_datagram(P.encode_flush(sensor, seq))
    assert (m.msg_type, m.sensor_id, m.seq) == (P.MSG_FLUSH, sensor, seq)

    counters = {k: int(rng.integers(0, 2**40)) for k in P.ACK_COUNTERS}
    m = P.decode_datagram(P.encode_flush_ack(sensor, seq, counters))
    assert m.counters == counters


def test_encoder_enforces_header_field_bounds():
    rng = np.random.default_rng(0)
    fr, y0 = _frames(rng, 2)
    for bad in [dict(sensor_id=1 << 16), dict(sensor_id=-1),
                dict(seq=1 << 32), dict(seq=-1)]:
        kw = dict(sensor_id=0, seq=0)
        kw.update(bad)
        with pytest.raises(P.FieldBoundsError):
            P.encode_frame_batch(kw["sensor_id"], kw["seq"], fr, y0)
    with pytest.raises(P.FieldBoundsError):
        P.encode_frame_batch(0, 0, fr[:0], y0[:0])        # n_events = 0
    with pytest.raises(P.FieldBoundsError):
        P.encode_frame_batch(0, 0, fr[:, :4], y0)         # wrong shape
    with pytest.raises(P.FieldBoundsError):
        P.encode_trigger_batch(0, 0, orig_seq=0, n_events=4, n_admitted=5,
                               idx=[], scores=[])
    with pytest.raises(P.FieldBoundsError):
        P.encode_trigger_batch(0, 0, orig_seq=0, n_events=4, n_admitted=4,
                               idx=[4], scores=[1])       # idx out of batch


def test_error_family_is_shared_with_the_sparse_pack():
    """One except-clause catches both the socket decoder and the
    in-process sparse unpack: ProtocolError IS a WireFormatError."""
    assert issubclass(P.ProtocolError, WireFormatError)
    for exc in (P.TruncatedError, P.BadMagicError, P.BadCrcError,
                P.VersionSkewError, P.FieldBoundsError):
        assert issubclass(exc, P.ProtocolError)


# ------------------------------------------------------------ fuzz corpus
@given(seed=st.integers(0, 2_000))
@settings(max_examples=25, deadline=None)
def test_truncation_always_named_error(seed):
    """Every proper prefix of every message decodes to TruncatedError,
    with a .needed that, when honored, completes the frame."""
    rng = np.random.default_rng(seed)
    for wire in _corpus(rng):
        cuts = set(rng.integers(0, len(wire), 8).tolist()) | {
            0, 3, 4, P.HEADER_BYTES - 1, len(wire) - 1}
        for cut in cuts:
            with pytest.raises(P.TruncatedError) as ei:
                P.decode_message(wire[:cut])
            assert ei.value.needed > 0
        # honoring .needed from any prefix eventually completes
        have = 0
        while have < len(wire):
            try:
                msg, consumed = P.decode_message(wire[:have])
                break
            except P.TruncatedError as e:
                have += e.needed
        else:
            msg, consumed = P.decode_message(wire)
        assert consumed == len(wire)


@given(seed=st.integers(0, 2_000))
@settings(max_examples=25, deadline=None)
def test_bit_flips_never_decode_silently(seed):
    """Single-bit flips anywhere in the frame: either the decode raises
    a named ProtocolError, or (flip in a payload float's bits can never
    collide with the CRC) — there is NO undetected-corruption outcome.
    A flip that still decodes identical to the original is impossible:
    CRC32 detects all single-bit errors."""
    rng = np.random.default_rng(seed)
    for wire in _corpus(rng):
        positions = rng.integers(0, len(wire) * 8, size=24)
        for bitpos in positions:
            bad = bytearray(wire)
            bad[bitpos // 8] ^= 1 << (bitpos % 8)
            try:
                P.decode_message(bytes(bad))
            except P.ProtocolError:
                continue
            pytest.fail(
                f"bit {int(bitpos)} flip decoded silently in a "
                f"{len(wire)}-byte frame")


def test_version_skew_is_its_own_error():
    rng = np.random.default_rng(1)
    fr, y0 = _frames(rng, 2)
    wire = P.encode_frame_batch(0, 0, fr, y0, version=2)
    with pytest.raises(P.VersionSkewError):
        P.decode_message(wire)
    # skew must be detected AFTER the CRC (a flipped version byte with a
    # stale CRC is corruption, not a speaker of version 2)
    bad = bytearray(P.encode_frame_batch(0, 0, fr, y0))
    bad[4] = 2
    with pytest.raises(P.BadCrcError):
        P.decode_message(bytes(bad))


def test_unknown_msg_type_and_oversized_length_are_bounded():
    rng = np.random.default_rng(2)
    fr, y0 = _frames(rng, 1)
    wire = bytearray(P.encode_frame_batch(0, 0, fr, y0))
    wire[5] = 99                                 # unknown msg_type
    head = bytes(wire[:16])
    crc = zlib.crc32(bytes(wire[20:]), zlib.crc32(head))
    wire[16:20] = struct.pack("<I", crc)         # re-seal so CRC passes
    with pytest.raises(P.FieldBoundsError):
        P.decode_message(bytes(wire))

    wire = bytearray(P.encode_frame_batch(0, 0, fr, y0))
    wire[12:16] = struct.pack("<I", P.MAX_PAYLOAD_BYTES + 1)
    with pytest.raises(P.FieldBoundsError):     # caught BEFORE waiting
        P.decode_message(bytes(wire))


def test_trigger_count_prefix_beyond_buffer_is_named():
    """The count-prefix-larger-than-buffer corruption (the same bug class
    fixed in sparse_trigger_unpack) raises FieldBoundsError, resealed CRC
    and all."""
    wire = bytearray(P.encode_trigger_batch(
        0, 0, orig_seq=0, n_events=8, n_admitted=8,
        idx=[1, 2], scores=[10, 20]))
    off = P.HEADER_BYTES + 8                     # the count word
    wire[off:off + 4] = struct.pack("<I", 1000)
    head = bytes(wire[:16])
    crc = zlib.crc32(bytes(wire[20:]), zlib.crc32(head))
    wire[16:20] = struct.pack("<I", crc)
    with pytest.raises(P.FieldBoundsError):
        P.decode_message(bytes(wire))


# -------------------------------------------------------- stream decoder
@given(seed=st.integers(0, 2_000))
@settings(max_examples=20, deadline=None)
def test_stream_resync_skips_exactly_the_corrupt_frame(seed):
    """[A][garbage][B][corrupt C][D] fed in random chunks: A, B, D decode,
    the garbage and C are counted, resync succeeds every time."""
    rng = np.random.default_rng(seed)
    a, b, c, dd = _corpus(rng)
    corrupt = bytearray(c)
    # flip a seq-field byte: present in every message type, CRC-covered
    corrupt[8 + int(rng.integers(0, 4))] ^= 0xFF
    garbage = rng.bytes(int(rng.integers(1, 64)))
    stream = bytes(a) + garbage + bytes(b) + bytes(corrupt) + bytes(dd)

    dec = P.StreamDecoder()
    got = []
    pos = 0
    while pos < len(stream):
        step = int(rng.integers(1, 4096))
        got.extend(dec.feed(stream[pos:pos + step]))
        pos += step
    kinds = [m.msg_type for m in got]
    assert kinds == [a_m.msg_type for a_m in
                     (P.decode_datagram(a), P.decode_datagram(b),
                      P.decode_datagram(dd))]
    assert dec.errors_total >= 2          # the garbage + the corrupt frame
    assert dec.resyncs >= 2
    assert dec.buffered == 0              # nothing stuck


def test_stream_duplicated_and_reordered_frames_decode_in_arrival_order():
    """The decoder is stateless across frames: dup/reorder is the
    ingress layer's problem, every well-formed frame decodes."""
    rng = np.random.default_rng(3)
    msgs = _corpus(rng)
    order = [0, 2, 1, 1, 3, 0]
    dec = P.StreamDecoder()
    got = dec.feed(b"".join(bytes(msgs[i]) for i in order))
    assert [m.msg_type for m in got] == \
        [P.decode_datagram(msgs[i]).msg_type for i in order]
    assert dec.errors_total == 0


def test_embedded_magic_in_payload_does_not_derail_resync():
    """A payload containing the magic bytes: a corrupted frame's resync
    may first land on the false magic, error again, and must STILL find
    the next real frame."""
    rng = np.random.default_rng(4)
    fr, y0 = _frames(rng, 2)
    # plant the magic inside the charge data
    fr_bytes = bytearray(fr.tobytes())
    fr_bytes[40:44] = P.MAGIC
    fr = np.frombuffer(bytes(fr_bytes), np.float32).reshape(fr.shape)
    a = P.encode_frame_batch(0, 0, fr, y0)
    b = P.encode_frame_batch(0, 1, fr, y0)
    corrupt = bytearray(a)
    corrupt[6] ^= 0xFF                     # header corruption -> bad CRC
    dec = P.StreamDecoder()
    got = dec.feed(bytes(corrupt) + bytes(b))
    assert [m.seq for m in got] == [1]
    assert dec.resyncs >= 1


def test_datagram_rejects_trailing_bytes():
    rng = np.random.default_rng(5)
    fr, y0 = _frames(rng, 1)
    wire = P.encode_frame_batch(0, 0, fr, y0)
    with pytest.raises(P.FieldBoundsError):
        P.decode_datagram(wire + b"x")


def test_random_garbage_with_one_valid_frame_is_recovered():
    """Pure noise around one real frame: the frame comes out, everything
    else is counted errors — zero crashes on arbitrary bytes."""
    rng = np.random.default_rng(6)
    fr, y0 = _frames(rng, 3)
    wire = P.encode_frame_batch(7, 42, fr, y0)
    noise1, noise2 = rng.bytes(997), rng.bytes(1013)
    dec = P.StreamDecoder()
    got = dec.feed(noise1 + wire + noise2)
    assert len(got) == 1 and got[0].seq == 42 and got[0].sensor_id == 7
