import os
import sys

# Tests see ONE CPU device (the 512-device flag belongs to dryrun.py only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # Two test tiers (see README "Testing"):
    #   fast:  python -m pytest -m "not slow"   (CPU, well under 2 minutes)
    #   full:  python -m pytest                 (adds Pallas interpret-mode
    #          sweeps, model-zoo smoke tests, subprocess system tests)
    config.addinivalue_line(
        "markers",
        "slow: long-running Pallas/system tests, excluded from the fast "
        'tier (-m "not slow")',
    )
