import os
import sys

# Tests see ONE CPU device (the 512-device flag belongs to dryrun.py only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
