import os
import sys

import pytest

# Tests see ONE CPU device (the 512-device flag belongs to dryrun.py only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Fast-tier per-test time budget (seconds). ROADMAP's "<2 min fast tier"
# contract is machine-checked: any test NOT marked `slow` whose BODY
# (the `call` phase) takes longer than this FAILS, instead of quietly
# eroding the tier until the total blows the budget. Fixture setup is
# deliberately exempt — module-scoped fixtures are shared, and charging
# their one-time cost to whichever test runs first would fail it for
# work it amortizes across the module. Override with REPRO_FAST_BUDGET_S
# (0 disables — e.g. on a heavily-loaded or emulated machine).
FAST_BUDGET_S = float(os.environ.get("REPRO_FAST_BUDGET_S", "20"))


def pytest_configure(config):
    # Two test tiers (see README "Testing"):
    #   fast:  python -m pytest -m "not slow"   (CPU, well under 2 minutes)
    #   full:  python -m pytest                 (adds Pallas interpret-mode
    #          sweeps, model-zoo smoke tests, subprocess system tests)
    config.addinivalue_line(
        "markers",
        "slow: long-running Pallas/system tests, excluded from the fast "
        'tier (-m "not slow")',
    )


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    report = outcome.get_result()
    if (
        FAST_BUDGET_S > 0
        and report.when == "call"
        and report.passed
        and "slow" not in item.keywords
        and report.duration > FAST_BUDGET_S
    ):
        report.outcome = "failed"
        report.longrepr = (
            f"{item.nodeid} took {report.duration:.1f}s — over the "
            f"{FAST_BUDGET_S:g}s fast-tier per-test budget. Mark it "
            "`slow` (nightly tier) or speed it up; the <2 min fast-tier "
            "contract in ROADMAP.md is enforced here. Override with "
            "REPRO_FAST_BUDGET_S=<seconds> (0 disables)."
        )
