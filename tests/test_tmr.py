"""TMR + SEU injection (paper §5 future work, implemented)."""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import CapacityError, FABRIC_28NM, FabricSim, place_and_route
from repro.core.netlist import NetlistBuilder, counter_netlist
from repro.core.synth import synth_ensemble
from repro.core.tmr import FABRIC_28NM_XL, inject_seu, triplicate
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split


@pytest.fixture(scope="module")
def bdt_parts():
    d = generate(SmartPixelConfig(n_events=25_000, seed=13))
    tr, te = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    ens = clf.quantized()
    synth = synth_ensemble(ens)
    return te, ens, synth


def test_tmr_functionally_identical(bdt_parts):
    te, ens, synth = bdt_parts
    tmr = triplicate(synth.netlist)
    X_raw = ens.quantize_features(te["features"][:600])
    bits = synth.encode_inputs(X_raw)
    want, _ = synth.netlist.evaluate(bits)
    got, _ = tmr.evaluate(bits)
    np.testing.assert_array_equal(got, want)


def test_tmr_cost_exceeds_fabricated_chip(bdt_parts):
    """The paper's motivation for a bigger next-gen fabric: TMR ~ 3x+."""
    _, _, synth = bdt_parts
    tmr = triplicate(synth.netlist)
    assert tmr.n_luts > 3 * synth.netlist.n_luts  # 3 replicas + voters
    with pytest.raises(CapacityError):
        place_and_route(tmr, FABRIC_28NM)


def test_tmr_fits_next_gen_fabric(bdt_parts):
    _, _, synth = bdt_parts
    tmr = triplicate(synth.netlist)
    cfg = place_and_route(tmr, FABRIC_28NM_XL)
    assert cfg.utilization()["lut_utilization"] <= 1.0


def test_seu_corrupts_plain_but_not_tmr(bdt_parts):
    te, ens, synth = bdt_parts
    X_raw = ens.quantize_features(te["features"][:2_000])
    bits = synth.encode_inputs(X_raw)
    golden = ens.decision_function_raw(X_raw)

    plain_cfg = place_and_route(synth.netlist, FABRIC_28NM)
    tmr_cfg = place_and_route(triplicate(synth.netlist), FABRIC_28NM_XL)

    rng = np.random.default_rng(0)
    plain_corrupted = 0
    tmr_corrupted = 0
    n_trials = 40
    for _ in range(n_trials):
        li = int(rng.integers(0, plain_cfg.n_luts))
        bi = int(rng.integers(0, 16))
        out, _ = FabricSim(inject_seu(plain_cfg, li, bi)).run(bits)
        plain_corrupted += int(
            (synth.decode_outputs(out) != golden).any())
        # flip a random REPLICA lut: any single-replica upset must be
        # voted out. Voter LUTs themselves are excluded — like Xilinx XTMR,
        # the output voters are the hardened minority (or triplicated with
        # off-chip convergence); a voter flip is outside the fault model.
        from repro.core.tmr import TBL_VOTE
        vote_bits = np.array([(TBL_VOTE >> k) & 1 for k in range(16)], np.uint8)
        while True:
            li_t = int(rng.integers(0, tmr_cfg.n_luts))
            if not np.array_equal(tmr_cfg.lut_tables[li_t], vote_bits):
                break
        out_t, _ = FabricSim(inject_seu(tmr_cfg, li_t, bi)).run(bits)
        tmr_corrupted += int(
            (synth.decode_outputs(out_t) != golden).any())
    # plain chip: SEUs frequently flip decisions; TMR: never (single fault)
    # measured corruption probability ~0.25/flip; P(X<3 | n=40) ~ 1e-4
    assert plain_corrupted >= 3, plain_corrupted
    assert tmr_corrupted == 0, tmr_corrupted


def test_tmr_sequential_counter():
    """State elements are triplicated too: a counter under single-replica
    SEU still counts correctly."""
    nl = counter_netlist(8)
    tmr = triplicate(nl)
    cfgf = place_and_route(tmr, FABRIC_28NM_XL)
    seu = inject_seu(cfgf, 3, 7)  # one replica's adder LUT
    outs, _ = FabricSim(seu).run(np.zeros((1, 0)), n_cycles=40,
                                 trace_outputs=True)
    vals = (outs[0] * (1 << np.arange(8))).sum(-1)
    np.testing.assert_array_equal(vals, np.arange(40))


def test_tmr_random_netlists_property():
    """Property: TMR(netlist) is functionally identical for arbitrary
    combinational netlists, and any single non-voter SEU is masked."""
    from repro.core.tmr import TBL_VOTE, FABRIC_28NM_XL
    from tests.test_kernels import _random_netlist

    rng = np.random.default_rng(9)
    for seed in (0, 1, 2):
        nl = _random_netlist(seed, 8, 30)
        tmr = triplicate(nl)
        bits = rng.integers(0, 2, (64, 8)).astype(np.uint8)
        want, _ = nl.evaluate(bits)
        got, _ = tmr.evaluate(bits)
        np.testing.assert_array_equal(got, want)
        cfg = place_and_route(tmr, FABRIC_28NM_XL)
        vote_bits = np.array([(TBL_VOTE >> k) & 1 for k in range(16)], np.uint8)
        for _ in range(5):
            li = int(rng.integers(0, cfg.n_luts))
            if np.array_equal(cfg.lut_tables[li], vote_bits):
                continue
            out, _ = FabricSim(inject_seu(cfg, li, int(rng.integers(0, 16)))).run(bits)
            np.testing.assert_array_equal(out, want)
