"""End-to-end behaviour tests for the paper's system (§5 pipeline):

    simulate sensor -> train BDT -> quantize -> synthesize -> bitstream ->
    fabric -> classify -> verify 100% vs golden -> data-rate reduction.
"""
import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.power import (
    area_efficiency_ratio, core_power_ratio, energy_per_inference_nj,
    power_mw, sweep, total_power_mw,
)
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split


@pytest.fixture(scope="module")
def chip_and_data():
    d = generate(SmartPixelConfig(n_events=40_000, seed=21))
    tr, te = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf, fabric="efpga_28nm")
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.97)
    return chip, te


def test_paper_headline_100pct_match(chip_and_data):
    chip, te = chip_and_data
    v = chip.verify_vs_golden(te["features"])
    assert v["accuracy"] == 1.0
    assert v["n"] >= 10_000


def test_kernel_backend_matches_host(chip_and_data):
    chip, te = chip_and_data
    X = te["features"][:2_000]
    np.testing.assert_array_equal(
        chip.infer_raw(X, backend="host"),
        np.asarray(chip.infer_raw(X, backend="kernel")),
    )


def test_classifier_operating_regime(chip_and_data):
    """Paper Table 1 regime: high signal efficiency, modest background
    rejection (the 448-LUT fabric bounds model capacity, §5)."""
    chip, te = chip_and_data
    rep = chip.data_reduction_report(te["features"], te["label"])
    assert rep["signal_efficiency"] > 0.90
    assert 0.0 < rep["background_rejection"] < 0.5
    assert rep["data_reduction_factor"] > 1.0


def test_fits_28nm_fabric(chip_and_data):
    chip, _ = chip_and_data
    util = chip.config.utilization()
    assert util["luts"] <= 448
    assert util["lut_utilization"] < 1.0


def test_reconfigurability_swap_model(chip_and_data):
    """The eFPGA's selling point: a NEW model loads onto the SAME fabric
    (new bitstream, no re-fabrication)."""
    _, te = chip_and_data
    d = generate(SmartPixelConfig(n_events=15_000, seed=77,
                                  pileup_fraction=0.7))
    tr, _ = train_test_split(d)
    clf2 = GradientBoostedClassifier(
        n_estimators=1, max_depth=4, max_leaf_nodes=8
    ).fit(tr["features"], tr["label"])
    chip2 = ReadoutChip.build(clf2, fabric="efpga_28nm")
    assert chip2.verify_vs_golden(te["features"][:3000])["accuracy"] == 1.0
    assert chip2.bitstream != b""


def test_power_model_reproduces_paper_relations():
    assert core_power_ratio(100.0) == pytest.approx(2.8, abs=0.15)   # §3
    assert core_power_ratio(125.0) == pytest.approx(3.0, abs=0.25)   # §4.4.2 "~1/3"
    assert area_efficiency_ratio() == pytest.approx(21.0, abs=1.0)   # §3
    # monotone increasing power with clock, both nodes and rails
    for node in ("130nm", "28nm"):
        rows = sweep(node)
        t = [r["total_mw"] for r in rows]
        assert all(a < b for a, b in zip(t, t[1:]))
    # 130nm SUGOI readback ceiling at 74 MHz (§2.4.2)
    rows = {r["f_mhz"]: r for r in sweep("130nm")}
    assert rows[74]["sugoi_readback_ok"] == 1.0
    assert rows[100]["sugoi_readback_ok"] == 0.0


def test_energy_per_inference_sane():
    e = energy_per_inference_nj("28nm", 200.0, cycles=5)
    assert 0.01 < e < 10.0  # nJ scale — far below transmission cost/hit
