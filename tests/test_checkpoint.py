"""Fault tolerance: atomic checkpoints, integrity, retention, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointError, CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.normal(0, 1, (8, 4)).astype(np.float32),
                   "b": rng.normal(0, 1, (4,)).astype(np.float32)},
        "opt": {"m": {"w": np.zeros((8, 4), np.float32),
                      "b": np.zeros((4,), np.float32)},
                "step": np.asarray(7, np.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree()
    mgr.save(10, tree)
    step, got = mgr.restore(jax.tree.map(jnp.asarray, tree))
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(a, b)


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (5, 10, 15, 20):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 20
    assert mgr.all_steps() == [15, 20]  # keep=2 garbage-collects the rest


def test_integrity_check_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree())
    path = os.path.join(str(tmp_path), "step_00000003", "arrays.npz")
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(path, "wb").write(bytes(data))
    with pytest.raises(CheckpointError, match="integrity"):
        mgr.restore(_tree())


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad = _tree()
    bad["params"]["w"] = np.zeros((9, 4), np.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(bad)


def test_crash_mid_write_keeps_previous(tmp_path):
    """Simulate a crash: a stale .tmp dir must not break restore of the
    previous good step."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    # fake a crashed partial write
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    with open(os.path.join(str(tmp_path), "step_00000002.tmp", "arrays.npz"), "wb") as f:
        f.write(b"partial")
    assert mgr.latest_step() == 1
    step, _ = mgr.restore(_tree())
    assert step == 1
    # and a retried save of step 2 succeeds
    mgr.save(2, _tree(2))
    assert mgr.latest_step() == 2


def test_restore_with_shardings_device_puts(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(4, tree)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    step, got = mgr.restore(tree, shardings=sh)
    assert step == 4
    assert all(x.sharding == NamedSharding(mesh, P())
               for x in jax.tree.leaves(got))


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    """launch/train.py restarts from its checkpoint (end-to-end).

    A subprocess system test (two full interpreter+jit startups, ~20 s on
    CPU): slow tier, like the other subprocess tests — the fast tier's
    per-test budget (tests/conftest.py) is enforced now."""
    import subprocess
    import sys

    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    ck = str(tmp_path / "run")
    base = [sys.executable, "-m", "repro.launch.train", "--preset", "tiny",
            "--ckpt-dir", ck, "--ckpt-every", "5", "--log-every", "100",
            "--seq", "32", "--batch", "4"]
    r1 = subprocess.run(base + ["--steps", "6"], env=env, capture_output=True,
                        text=True, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(base + ["--steps", "8", "--resume"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step 6" in r2.stdout
